"""Node-axis scaling bench: sparse segment_sum gossip vs the dense m x m
matvec it replaces — and, with ``--devices``, vs the node-SHARDED sparse
path (shard_map + ppermute halo exchange over a ("node",) mesh).

The dense path materializes the m x m mixing matrix (DenseMatrixMixer's
tensordot), so its memory is quadratic in the node count: at m = 10^5 the
matrix alone is 40 GB and the point is SKIPPED (``dense_s: null``) — which
is precisely the regime the sparse edge-list path exists for (a ring at
m = 10^5 is 3 x 10^5 edges, ~3.6 MB). The curve reports rounds/sec per
node count for every path that can run.

Correctness rides along: at the gate scale the sparse run must stay inside
the float32 reduction-order bound of the dense run (``dense_match_identical``
— the same contract tests/test_sparse_graph.py asserts per field), and the
node-sharded run must be deterministic to the BIT across replays and inside
the same bound of the unsharded sparse run (``sharded_identical``, the
tests/test_shard_node.py contract).

    PYTHONPATH=src python -m benchmarks.bench_nodes [--smoke]
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.bench_nodes --smoke --devices 4

Writes BENCH_nodes.json; benchmarks/check_bench.py gates the identity
verdicts and the ``sparse_vs_dense_speedup`` scaling key against the
committed baselines (sharded fields stay null without --devices).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.api import ExecConfig, RunSpec, run

# float32 reduction-order bound for whole-run trajectories at the gate
# scale (tests/test_sparse_graph.py holds 2e-6 at m=10; the bench's gate
# point is larger, so allow the same slack the shard tests do)
BOUND = 5e-6

# dense is O(m^2) memory: above this the matrix no longer fits comfortably
# (32768^2 floats = 4 GB) and the point is skipped rather than measured
DENSE_MAX_NODES = 8192


def _spec(m: int, *, dim: int, horizon: int, mixer: str) -> RunSpec:
    options = ({"topology": "ring"} if mixer in ("sparse", "dense") else {})
    return RunSpec(nodes=m, dim=dim, horizon=horizon, eps=1.0, alpha0=0.5,
                   lam=0.01, stream="drift", stream_options={"period": 7},
                   mixer=mixer, mixer_options=options)


def _timed(spec: RunSpec, **kw):
    """(result, wall) with compile excluded: warmup=True compiles the first
    chunk outside the runner's timed region (needs >= 2 chunks), and the
    reported wall is ``RunResult.wall_clock`` — steady-state execution, so
    the curve compares the per-round math, not XLA compile times."""
    chunk = max(1, spec.horizon // 2)
    res = run(spec, exec=ExecConfig(chunk_rounds=chunk, compute_regret=False,
                                    warmup=True, **kw))
    return res, float(res.wall_clock)


def _within(a, b, bound: float) -> bool:
    return all(
        float(np.abs(np.asarray(getattr(a, f))
                     - np.asarray(getattr(b, f))).max()) <= bound
        for f in ("final_w", "loss", "correct", "w_bar_loss", "sparsity"))


def _bit_identical(a, b) -> bool:
    return all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f)))
               for f in ("final_w", "loss", "correct", "w_bar_loss",
                         "sparsity"))


def run_bench(*, curve: list[int], dim: int, horizon: int, gate_nodes: int,
              dense_max: int = DENSE_MAX_NODES,
              devices: int | str | None = None,
              bench_path: str = "BENCH_nodes.json") -> dict:
    node_mesh = None
    n_devices = None
    if devices is not None:
        from repro.launch.mesh import node_mesh as make_node_mesh
        node_mesh = make_node_mesh(devices)
        if node_mesh is not None:
            n_devices = int(node_mesh.shape["node"])

    points = []
    gate_speedup = None
    for m in curve:
        row = {"nodes": m, "dense_s": None, "dense_rounds_per_sec": None,
               "sparse_s": None, "sparse_rounds_per_sec": None,
               "sharded_s": None, "sharded_rounds_per_sec": None}
        sparse_res, sparse_wall = _timed(
            _spec(m, dim=dim, horizon=horizon, mixer="sparse"))
        row["sparse_s"] = round(sparse_wall, 3)
        row["sparse_rounds_per_sec"] = round(sparse_res.rounds_per_sec, 1)
        if m <= dense_max:
            dense_res, dense_wall = _timed(
                _spec(m, dim=dim, horizon=horizon, mixer="dense"))
            row["dense_s"] = round(dense_wall, 3)
            row["dense_rounds_per_sec"] = round(dense_res.rounds_per_sec, 1)
        if n_devices is not None:
            shard_res, shard_wall = _timed(
                _spec(m, dim=dim, horizon=horizon, mixer="sparse"),
                node_devices=n_devices)
            row["sharded_s"] = round(shard_wall, 3)
            row["sharded_rounds_per_sec"] = round(shard_res.rounds_per_sec, 1)
        points.append(row)
        print(f"  m={m}: dense {row['dense_s']}s  sparse {row['sparse_s']}s"
              f"  sharded {row['sharded_s']}s", flush=True)

    # the speedup gate reads the LARGEST node count both paths measured:
    # that is where the O(m^2) vs O(E) gap is, and where it must not erode
    both = [p for p in points if p["dense_s"] is not None]
    if both:
        top = both[-1]
        gate_speedup = round(top["dense_s"] / top["sparse_s"], 2) \
            if top["sparse_s"] > 0 else None

    # correctness gate point: dense-vs-sparse within the asserted bound,
    # sharded bit-deterministic and within the bound of unsharded sparse
    gspec = _spec(gate_nodes, dim=dim, horizon=horizon, mixer="sparse")
    gate_cfg = ExecConfig(chunk_rounds=max(1, horizon // 2),
                          compute_regret=False, warmup=False)
    gate_sparse = run(gspec, exec=gate_cfg)
    gate_dense = run(_spec(gate_nodes, dim=dim, horizon=horizon,
                           mixer="dense"),
                     exec=gate_cfg)
    dense_match = _within(gate_sparse, gate_dense, BOUND)
    sharded_identical = None
    if n_devices is not None:
        shard_cfg = gate_cfg.replace(node_devices=n_devices)
        shard_a = run(gspec, exec=shard_cfg)
        shard_b = run(gspec, exec=shard_cfg)
        sharded_identical = (_bit_identical(shard_a, shard_b)
                             and _within(shard_a, gate_sparse, BOUND))

    bench = {
        "bench": "nodes_sparse_scaling",
        "dim": dim,
        "rounds": horizon,
        "dense_max_nodes": dense_max,
        "devices": n_devices,
        "curve": points,
        "gate_nodes": gate_nodes,
        "sparse_vs_dense_speedup": gate_speedup,
        "dense_match_identical": dense_match,
        "sharded_identical": sharded_identical,
    }
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1)
    if not dense_match:
        raise AssertionError("sparse run left the asserted float32 bound "
                             f"({BOUND}) of the dense run at the gate point")
    if sharded_identical is False:
        raise AssertionError("node-sharded run is not deterministic or left "
                             "the asserted bound of the unsharded sparse run")
    return bench


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny curve (seconds) for the CI jobs")
    ap.add_argument("--devices", default=None, metavar="N|auto",
                    help="also time the node-sharded sparse path over N "
                         "local devices ('auto' = all, skipping the sharded "
                         "lane on a 1-device host; an explicit N errors "
                         "when fewer than N devices are visible)")
    ap.add_argument("--bench-path", default="BENCH_nodes.json")
    args = ap.parse_args()
    devices = (None if args.devices is None
               else "auto" if args.devices == "auto" else int(args.devices))
    if args.smoke:
        kw = dict(curve=[256, 2048], dim=8, horizon=20, gate_nodes=256,
                  dense_max=2048)
    else:
        kw = dict(curve=[256, 2048, 8192, 32768, 131072], dim=8, horizon=20,
                  gate_nodes=256)
    bench = run_bench(devices=devices, bench_path=args.bench_path, **kw)
    top = bench["curve"][-1]
    print(f"{len(bench['curve'])} node counts to m={top['nodes']}: "
          f"sparse {top['sparse_s']}s "
          f"(dense skipped above m={bench['dense_max_nodes']}); "
          f"sparse_vs_dense_speedup={bench['sparse_vs_dense_speedup']} "
          f"dense_match={bench['dense_match_identical']} "
          f"sharded_identical={bench['sharded_identical']}")


if __name__ == "__main__":
    main()
