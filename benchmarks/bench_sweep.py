"""Seed-axis vectorization bench: vmapped `run_batch` vs the sequential
per-seed `run()` loop it replaces, same config, >= 8 seeds.

The vmapped path compiles ONE program (vmap over the seed axis inside the
runner's jitted per-chunk lax.scan) and drives all S trajectories in ~one
memory-bound pass; the sequential loop pays S compiles and S dispatch
streams. Both paths must agree to NUMERICAL IDENTITY per seed (the same
guarantee tests/test_sweep.py holds to the bit) — the bench asserts it.

    PYTHONPATH=src python -m benchmarks.bench_sweep [--smoke] [--seeds 8]

Writes BENCH_sweep.json: wall-clock for both paths, the speedup, and the
identity verdict.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import Scale, make_spec
from repro.api import run, run_batch


def _identical(a, b) -> bool:
    return all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f)))
               for f in ("final_w", "loss", "correct", "w_bar_loss",
                         "sparsity"))


def run_bench(scale: Scale | None = None, *, n_seeds: int = 8,
              engine: str = "sim", eps: float = 1.0,
              bench_path: str = "BENCH_sweep.json") -> dict:
    scale = scale or Scale()
    spec = make_spec(scale, eps=eps, lam=0.01)
    seeds = list(range(n_seeds))
    chunk = min(scale.T, 256)

    # the loop every benchmark used to hand-roll: one run() per seed,
    # each paying its own compile + per-chunk dispatch
    t0 = time.time()
    sequential = [run(spec.replace(seed=s), engine=engine, chunk_rounds=chunk,
                      compute_regret=False, warmup=False) for s in seeds]
    seq_wall = time.time() - t0

    t0 = time.time()
    vmapped = run_batch(spec, seeds, engine=engine, chunk_rounds=chunk,
                        compute_regret=False, warmup=False)
    vec_wall = time.time() - t0

    identical = all(_identical(a, b) for a, b in zip(sequential, vmapped))
    bench = {
        "bench": "sweep_seed_vmap",
        "engine": engine,
        "scale": {"n": scale.n, "m": scale.m, "T": scale.T},
        "eps": eps,
        "seeds": n_seeds,
        "sequential_s": round(seq_wall, 3),
        "vmapped_s": round(vec_wall, 3),
        "speedup": round(seq_wall / vec_wall, 2) if vec_wall > 0 else None,
        "identical": identical,
        "sequential_seed_rounds_per_sec": round(
            n_seeds * scale.T / seq_wall, 1),
        "vmapped_seed_rounds_per_sec": round(
            n_seeds * scale.T / vec_wall, 1),
    }
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1)
    if not identical:
        raise AssertionError(
            "vmapped seed batch diverged from the sequential per-seed loop")
    return bench


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale (seconds) for the CI bench-smoke job")
    ap.add_argument("--seeds", type=int, default=8)
    ap.add_argument("--engine", default="sim", choices=["sim", "dist"])
    ap.add_argument("--bench-path", default="BENCH_sweep.json")
    args = ap.parse_args()
    scale = Scale.smoke() if args.smoke else None
    bench = run_bench(scale, n_seeds=args.seeds, engine=args.engine,
                      bench_path=args.bench_path)
    print(f"{bench['seeds']} seeds, {bench['engine']}: "
          f"sequential {bench['sequential_s']}s -> "
          f"vmapped {bench['vmapped_s']}s "
          f"({bench['speedup']}x, identical={bench['identical']})")


if __name__ == "__main__":
    main()
