"""Seed-axis vectorization bench: vmapped `run_batch` vs the sequential
per-seed `run()` loop it replaces — and, with ``--devices``, vs the
device-SHARDED seed axis (shard_map over a ("seed",) mesh), same config,
>= 8 seeds.

The vmapped path compiles ONE program (vmap over the seed axis inside the
runner's jitted per-chunk lax.scan) and drives all S trajectories in ~one
memory-bound pass; the sequential loop pays S compiles and S dispatch
streams; the sharded path splits the same vmapped program into S/D blocks,
one per device. All paths must agree to NUMERICAL IDENTITY per seed (the
same guarantee tests/test_sweep.py and tests/test_shard_seed.py hold to the
bit) — the bench asserts it.

    PYTHONPATH=src python -m benchmarks.bench_sweep [--smoke] [--seeds 8]
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.bench_sweep --smoke --devices 4

Writes BENCH_sweep.json: wall-clock for every path, the speedups, and the
identity verdicts (sharded fields stay null without --devices).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import Scale, make_spec
from repro.api import ExecConfig, run, run_batch


def _identical(a, b) -> bool:
    return all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f)))
               for f in ("final_w", "loss", "correct", "w_bar_loss",
                         "sparsity"))


def run_bench(scale: Scale | None = None, *, n_seeds: int = 8,
              engine: str = "sim", eps: float = 1.0,
              devices: int | str | None = None,
              bench_path: str = "BENCH_sweep.json") -> dict:
    scale = scale or Scale()
    spec = make_spec(scale, eps=eps, lam=0.01)
    seeds = list(range(n_seeds))
    chunk = min(scale.T, 256)

    # the loop every benchmark used to hand-roll: one run() per seed,
    # each paying its own compile + per-chunk dispatch
    t0 = time.time()
    cfg = ExecConfig(chunk_rounds=chunk, compute_regret=False, warmup=False)
    sequential = [run(spec.replace(seed=s), engine=engine, exec=cfg)
                  for s in seeds]
    seq_wall = time.time() - t0

    t0 = time.time()
    vmapped = run_batch(spec, seeds, engine=engine, exec=cfg)
    vec_wall = time.time() - t0

    sharded = None
    shard_wall = None
    n_devices = None
    if devices is not None:
        from repro.launch.mesh import seed_mesh
        mesh = seed_mesh(devices)
        if mesh is not None:
            n_devices = int(mesh.shape["seed"])
            t0 = time.time()
            sharded = run_batch(spec, seeds, engine=engine,
                                exec=cfg.replace(mesh=mesh))
            shard_wall = time.time() - t0

    identical = all(_identical(a, b) for a, b in zip(sequential, vmapped))
    sharded_identical = (None if sharded is None else all(
        _identical(a, b) for a, b in zip(sequential, sharded)))
    bench = {
        "bench": "sweep_seed_vmap",
        "engine": engine,
        "scale": {"n": scale.n, "m": scale.m, "T": scale.T},
        "eps": eps,
        "seeds": n_seeds,
        "sequential_s": round(seq_wall, 3),
        "vmapped_s": round(vec_wall, 3),
        "speedup": round(seq_wall / vec_wall, 2) if vec_wall > 0 else None,
        "identical": identical,
        "sequential_seed_rounds_per_sec": round(
            n_seeds * scale.T / seq_wall, 1),
        "vmapped_seed_rounds_per_sec": round(
            n_seeds * scale.T / vec_wall, 1),
        "devices": n_devices,
        "sharded_s": None if shard_wall is None else round(shard_wall, 3),
        "sharded_speedup_vs_sequential": (
            None if shard_wall is None or shard_wall <= 0
            else round(seq_wall / shard_wall, 2)),
        "sharded_speedup_vs_vmapped": (
            None if shard_wall is None or shard_wall <= 0
            else round(vec_wall / shard_wall, 2)),
        "sharded_identical": sharded_identical,
    }
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1)
    if not identical:
        raise AssertionError(
            "vmapped seed batch diverged from the sequential per-seed loop")
    if sharded_identical is False:
        raise AssertionError(
            "device-sharded seed batch diverged from the sequential "
            "per-seed loop")
    return bench


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale (seconds) for the CI bench-smoke job")
    ap.add_argument("--seeds", type=int, default=8)
    ap.add_argument("--engine", default="sim", choices=["sim", "dist"])
    ap.add_argument("--devices", default=None, metavar="N|auto",
                    help="also time the device-sharded seed axis over N "
                         "local devices ('auto' = all, skipping the sharded "
                         "lane on a 1-device host; an explicit N errors "
                         "when fewer than N devices are visible)")
    ap.add_argument("--bench-path", default="BENCH_sweep.json")
    args = ap.parse_args()
    scale = Scale.smoke() if args.smoke else None
    devices = (None if args.devices is None
               else "auto" if args.devices == "auto" else int(args.devices))
    bench = run_bench(scale, n_seeds=args.seeds, engine=args.engine,
                      devices=devices, bench_path=args.bench_path)
    msg = (f"{bench['seeds']} seeds, {bench['engine']}: "
           f"sequential {bench['sequential_s']}s -> "
           f"vmapped {bench['vmapped_s']}s "
           f"({bench['speedup']}x, identical={bench['identical']})")
    if bench["sharded_s"] is not None:
        msg += (f" -> sharded/{bench['devices']}dev {bench['sharded_s']}s "
                f"({bench['sharded_speedup_vs_sequential']}x vs sequential, "
                f"identical={bench['sharded_identical']})")
    print(msg)


if __name__ == "__main__":
    main()
