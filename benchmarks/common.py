"""Shared harness for the paper-figure benchmarks.

Two scales:
  CI    (default)  n=512, m=16, T=500   — minutes on this 1-core container
  paper (--full)   n=10_000, m=64, T=1562 (100k samples) — the paper's §V scale
"""
from __future__ import annotations

import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import RunSpec
from repro.core.regret import best_fixed_hinge, cumulative_regret
from repro.data.social import SocialStream


@dataclasses.dataclass
class Scale:
    n: int = 512
    m: int = 16
    T: int = 500
    alpha0: float = 1.0
    L: float = 1.0

    @classmethod
    def paper(cls) -> "Scale":
        return cls(n=10_000, m=64, T=100_000 // 64)


def make_spec(scale: Scale, *, eps: float, lam: float = 1e-3,
              topology: str = "ring", seed: int = 0,
              clip_style: str = "coordinate", **kw) -> RunSpec:
    """The shared declarative description all figure sweeps build from."""
    return RunSpec(
        nodes=scale.m, dim=scale.n, mixer=topology, seed=seed,
        eps=eps, clip_norm=scale.L, calibration=clip_style,
        alpha0=scale.alpha0, schedule="sqrt_t", lam=lam, **kw)


def run_algorithm1(scale: Scale, *, eps: float, lam: float = 1e-3,
                   topology: str = "ring", seed: int = 0,
                   clip_style: str = "coordinate", **spec_kw):
    """One full Algorithm-1 run; returns (outs, xs, ys, seconds).

    clip_style='coordinate' is the tighter per-coordinate Laplace calibration
    (DESIGN.md deviation #3); 'global' is the paper's exact Lemma-1 scale
    (sqrt(n) larger — with n=10^4 it drowns learning entirely, which is why
    the paper's own Fig. 2 cannot have used it; we report both).
    Extra keywords (local_rule=, delay=, mechanism=, ...) pass through to
    `repro.api.RunSpec`.
    """
    stream = SocialStream(n=scale.n, nodes=scale.m, rounds=scale.T,
                          sparsity_true=0.05, seed=seed)
    xs, ys = stream.chunk(0, scale.T)
    alg = make_spec(scale, eps=eps, lam=lam, topology=topology, seed=seed,
                    clip_style=clip_style, **spec_kw).build_simulator()
    t0 = time.time()
    outs = alg.run(jax.random.PRNGKey(seed + 1), xs, ys)
    jax.block_until_ready(outs.loss)
    return outs, xs, ys, time.time() - t0


def accuracy_curve(outs, window: int = 50) -> np.ndarray:
    correct = np.asarray(outs.correct.mean(axis=1))
    c = np.cumsum(np.insert(correct, 0, 0.0))
    return (c[window:] - c[:-window]) / window


def final_accuracy(outs, frac: float = 0.2) -> float:
    correct = np.asarray(outs.correct)
    k = max(1, int(len(correct) * frac))
    return float(correct[-k:].mean())


_WSTAR_CACHE: dict = {}


def regret_curve(outs, xs, ys, m: int) -> np.ndarray:
    """Comparator w* is cached per stream identity — fig sweeps reuse the
    same stream across eps/topology, and best_fixed_hinge is the expensive
    part at paper scale (full-batch GD over 100k x 10k)."""
    import hashlib
    probe = np.asarray(xs[0, : min(2, xs.shape[1]), : min(16, xs.shape[2])]).tobytes()
    key = (hashlib.md5(probe).hexdigest(), xs.shape, ys.shape)
    if key not in _WSTAR_CACHE:
        _WSTAR_CACHE[key] = best_fixed_hinge(xs, ys)
    return cumulative_regret(outs.w_bar_loss, xs, ys, m,
                             w_star=_WSTAR_CACHE[key])
