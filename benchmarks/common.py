"""Shared harness for the paper-figure benchmarks.

Every figure is one `repro.sweep` call — the benchmarks own WHAT to sweep
(the axes and the plot), never HOW to drive runs: the sweep engine vmaps
the seed axis per point, and every (point, seed) record persists in the
sweep store (experiments/store/) so `--from-store` regenerates a figure's
JSON without re-running anything.

Two scales:
  CI    (default)  n=512, m=16, T=500   — minutes on this 1-core container
  paper (--full)   n=10_000, m=64, T=1562 (100k samples) — the paper's §V scale
"""
from __future__ import annotations

import dataclasses

from repro.api import RunSpec
from repro.sweep import DEFAULT_STORE, SweepResult, SweepSpec, sweep

# every figure averages over these seeds (mean±std in its JSON rows)
SEEDS = (0, 1, 2)


@dataclasses.dataclass
class Scale:
    n: int = 512
    m: int = 16
    T: int = 500
    alpha0: float = 1.0
    L: float = 1.0

    @classmethod
    def paper(cls) -> "Scale":
        return cls(n=10_000, m=64, T=100_000 // 64)

    @classmethod
    def smoke(cls) -> "Scale":
        """Tiny CI-smoke scale (seconds): exercises every code path."""
        return cls(n=64, m=8, T=120)


def make_spec(scale: Scale, *, eps: float, lam: float = 1e-3,
              topology: str = "ring", seed: int = 0,
              clip_style: str = "coordinate", stream: str = "social_sparse",
              stream_options: dict | None = None, **kw) -> RunSpec:
    """The shared declarative description all figure sweeps build from.

    clip_style='coordinate' is the tighter per-coordinate Laplace calibration
    (DESIGN.md deviation #3); 'global' is the paper's exact Lemma-1 scale
    (sqrt(n) larger — with n=10^4 it drowns learning entirely, which is why
    the paper's own Fig. 2 cannot have used it; we report both).
    """
    return RunSpec(
        nodes=scale.m, dim=scale.n, mixer=topology, seed=seed,
        eps=eps, clip_norm=scale.L, calibration=clip_style,
        alpha0=scale.alpha0, schedule="sqrt_t", lam=lam, horizon=scale.T,
        stream=stream, stream_options=stream_options or {}, **kw)


def figure_sweep(name: str, scale: Scale, axes: dict, *,
                 seeds: tuple = SEEDS, engine: str = "sim",
                 compute_regret: bool = True, from_store: bool = False,
                 store: str | None = DEFAULT_STORE,
                 devices: int | str | None = None,
                 **spec_kw) -> SweepResult:
    """One figure = one sweep: axes over `make_spec`, seeds vmapped per
    point, records persisted under the figure's name in the sweep store.

    ``from_store=True`` reuses matching stored records instead of running —
    the figure JSON regenerates without a single engine call.
    """
    base = make_spec(scale, **spec_kw)
    spec = SweepSpec(base=base, axes=axes, seeds=tuple(seeds), engine=engine,
                     name=name, chunk_rounds=scale.T,
                     compute_regret=compute_regret, devices=devices)
    # --from-store promises regeneration WITHOUT re-running: require_store
    # raises SweepStoreMiss (naming the stale/missing points) BEFORE any
    # engine call, so a broken store-reuse path can never pass CI unseen
    return sweep(spec, store=store, reuse=from_store,
                 require_store=from_store)
