"""Shared harness for the paper-figure benchmarks.

Every sweep is one `repro.api.run` call — the benchmarks own WHAT to sweep,
never HOW to drive a run (no hand-rolled loops; metrics, regret, privacy
ledger and wall-clock all come back in the RunResult).

Two scales:
  CI    (default)  n=512, m=16, T=500   — minutes on this 1-core container
  paper (--full)   n=10_000, m=64, T=1562 (100k samples) — the paper's §V scale
"""
from __future__ import annotations

import dataclasses


from repro.api import RunResult, RunSpec
from repro.api import run as api_run


@dataclasses.dataclass
class Scale:
    n: int = 512
    m: int = 16
    T: int = 500
    alpha0: float = 1.0
    L: float = 1.0

    @classmethod
    def paper(cls) -> "Scale":
        return cls(n=10_000, m=64, T=100_000 // 64)

    @classmethod
    def smoke(cls) -> "Scale":
        """Tiny CI-smoke scale (seconds): exercises every code path."""
        return cls(n=64, m=8, T=120)


def make_spec(scale: Scale, *, eps: float, lam: float = 1e-3,
              topology: str = "ring", seed: int = 0,
              clip_style: str = "coordinate", stream: str = "social_sparse",
              stream_options: dict | None = None, **kw) -> RunSpec:
    """The shared declarative description all figure sweeps build from."""
    return RunSpec(
        nodes=scale.m, dim=scale.n, mixer=topology, seed=seed,
        eps=eps, clip_norm=scale.L, calibration=clip_style,
        alpha0=scale.alpha0, schedule="sqrt_t", lam=lam, horizon=scale.T,
        stream=stream, stream_options=stream_options or {}, **kw)


def run_algorithm1(scale: Scale, *, eps: float, lam: float = 1e-3,
                   topology: str = "ring", seed: int = 0,
                   clip_style: str = "coordinate", engine: str = "sim",
                   compute_regret: bool = True, **spec_kw) -> RunResult:
    """One full run via `repro.api.run`; returns the RunResult.

    clip_style='coordinate' is the tighter per-coordinate Laplace calibration
    (DESIGN.md deviation #3); 'global' is the paper's exact Lemma-1 scale
    (sqrt(n) larger — with n=10^4 it drowns learning entirely, which is why
    the paper's own Fig. 2 cannot have used it; we report both).
    Extra keywords (local_rule=, delay=, mechanism=, stream=, ...) pass
    through to `repro.api.RunSpec`.
    """
    spec = make_spec(scale, eps=eps, lam=lam, topology=topology, seed=seed,
                     clip_style=clip_style, **spec_kw)
    return api_run(spec, engine=engine, chunk_rounds=scale.T,
                   compute_regret=compute_regret)
