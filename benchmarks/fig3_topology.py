"""Fig. 3 reproduction: topology (fixed or time-varying) has no significant
effect on utility."""
from __future__ import annotations

import json
import os


from benchmarks.common import Scale, run_algorithm1

TOPOLOGIES = ("ring", "complete", "hypercube", "random", "time_varying")


def run(scale: Scale | None = None, out_dir: str = "experiments/figures",
        eps: float = 1.0) -> dict:
    scale = scale or Scale()
    rows = {}
    for topo in TOPOLOGIES:
        res = run_algorithm1(scale, eps=eps, topology=topo)
        rows[topo] = {"regret_final": float(res.regret[-1]),
                      "accuracy": res.accuracy, "seconds": res.wall_clock}
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig3_topology.json"), "w") as f:
        json.dump(rows, f, indent=1)
    accs = [r["accuracy"] for r in rows.values()]
    return {"rows": rows, "spread": max(accs) - min(accs)}


if __name__ == "__main__":
    res = run()
    for topo, r in res["rows"].items():
        print(f"{topo:14s}: regret={r['regret_final']:10.1f} acc={r['accuracy']:.3f}")
    print(f"accuracy spread across topologies: {res['spread']:.3f} "
          f"(paper: no significant difference)")
