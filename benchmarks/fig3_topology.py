"""Fig. 3 reproduction: topology (fixed or time-varying) has no significant
effect on utility. The figure owns only the topology axis; `repro.sweep`
drives the multi-seed runs (mean±std per topology) and persists the
records, so ``from_store=True`` regenerates the JSON without re-running.

Note: 'random' and 'time_varying' are SEEDED topologies — the sweep engine
detects that the resolved mixer depends on the seed and falls back to
sequential per-seed runs for those points, keeping per-seed semantics
exactly (each seed draws its own graph)."""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import SEEDS, Scale, figure_sweep

TOPOLOGIES = ("ring", "complete", "hypercube", "random", "time_varying")


def run(scale: Scale | None = None, out_dir: str = "experiments/figures",
        eps: float = 1.0, seeds: tuple = SEEDS,
        from_store: bool = False) -> dict:
    scale = scale or Scale()
    out = figure_sweep("fig3_topology", scale, {"mixer": TOPOLOGIES},
                       seeds=seeds, from_store=from_store, eps=eps)
    rows = {}
    for point, results in zip(out.points, out.results):
        regs = np.asarray([float(r.regret[-1]) for r in results])
        accs = np.asarray([r.accuracy for r in results])
        rows[point.coords["mixer"]] = {
            "regret_final": float(regs.mean()),
            "regret_final_std": float(regs.std()),
            "accuracy": float(accs.mean()),
            "accuracy_std": float(accs.std()),
            "seeds": list(seeds),
            "seconds": float(sum(r.wall_clock for r in results)),
        }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig3_topology.json"), "w") as f:
        json.dump(rows, f, indent=1)
    accs = [r["accuracy"] for r in rows.values()]
    return {"rows": rows, "spread": max(accs) - min(accs)}


if __name__ == "__main__":
    res = run()
    for topo, r in res["rows"].items():
        print(f"{topo:14s}: regret={r['regret_final']:10.1f} "
              f"acc={r['accuracy']:.3f}±{r['accuracy_std']:.3f}")
    print(f"accuracy spread across topologies: {res['spread']:.3f} "
          f"(paper: no significant difference)")
