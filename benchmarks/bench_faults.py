"""Fault-injection bench: regret / throughput under an unreliable fabric,
anchored by the ``zero_fault_identical`` bit-identity gate.

Two properties of `repro.faults` are measured and gated here:

  * **Zero-cost abstraction**: a ``FaultSpec`` with every rate at zero runs
    the SAME uniform draws and self-healing renormalization as a faulty
    spec, yet must be bit-identical to a run with no faults at all — for
    both engines, delay in {0, 2}, the dense mixer form, and (with
    ``--devices``) the node-sharded path. Any drift here means the fault
    machinery perturbs the round math it claims to only mask
    (``zero_fault_identical``, also asserted in tests/test_faults.py).
  * **Graceful degradation**: the accuracy and throughput retained at a
    5% link-drop rate relative to the zero-rate run
    (``accuracy_retention_floor`` / ``throughput_retention_floor``) —
    check_bench gates both as ``*_floor`` keys so a future change cannot
    quietly turn "survives a lossy DCN" into "collapses under it". The
    full rate curve and a transient-partition recovery point ride along
    as informational fields.

    PYTHONPATH=src python -m benchmarks.bench_faults [--smoke]
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.bench_faults --smoke --devices 4

Writes BENCH_faults.json; benchmarks/check_bench.py gates
``zero_fault_identical`` and the ``*_floor`` ratios against the committed
baselines (sharded checks stay absent without --devices).
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.api import ExecConfig, RunSpec, run
from repro.faults import FaultSpec, rounds_to_recover

# float32 reduction-order bound for sharded-vs-unsharded trajectories
# (the tests/test_shard_node.py contract)
BOUND = 5e-6

FIELDS = ("final_w", "loss", "correct", "w_bar_loss", "sparsity")


def _spec(m: int, *, dim: int, horizon: int, mixer: str = "sparse",
          delay: int = 0, faults=None, faults_options=None) -> RunSpec:
    return RunSpec(nodes=m, dim=dim, horizon=horizon, eps=1.0, alpha0=0.5,
                   lam=0.01, stream="drift", stream_options={"period": 7},
                   mixer=mixer, mixer_options={"topology": "ring"},
                   delay=delay, faults=faults,
                   faults_options=faults_options or {})


def _bit_identical(a, b) -> bool:
    return all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f)))
               for f in FIELDS)


def _timed(spec: RunSpec, **kw):
    """(result, wall) with compile excluded: warmup=True compiles the first
    chunk outside the runner's timed region (needs >= 2 chunks)."""
    chunk = max(1, spec.horizon // 2)
    res = run(spec, exec=ExecConfig(chunk_rounds=chunk, warmup=True, **kw))
    return res, float(res.wall_clock)


def _zero_fault_checks(*, nodes: int, dim: int, horizon: int,
                       n_devices: int | None) -> list[dict]:
    """One clean-vs-zero-rate-faults pair per configuration.

    The zero-rate spec exercises the REAL machinery (per-round uniform
    draws, keep masks, healed-mass fold) — keep == 1.0 everywhere makes
    every op bitwise equal to the clean mixer, which is the property gated.
    """
    cfg = ExecConfig(chunk_rounds=max(1, horizon // 2), compute_regret=False,
                     warmup=False)
    zero = {"link_rate": 0.0}
    configs = [("sparse", engine, delay, None)
               for engine in ("sim", "dist") for delay in (0, 2)]
    configs.append(("dense", "sim", 0, None))
    if n_devices is not None:
        configs += [("sparse", engine, delay, n_devices)
                    for engine in ("sim", "dist") for delay in (0, 2)]
    checks = []
    for mixer, engine, delay, nd in configs:
        clean = run(_spec(nodes, dim=dim, horizon=horizon, mixer=mixer,
                          delay=delay),
                    engine=engine, exec=cfg.replace(node_devices=nd))
        faulted = run(_spec(nodes, dim=dim, horizon=horizon, mixer=mixer,
                            delay=delay, faults="links", faults_options=zero),
                      engine=engine, exec=cfg.replace(node_devices=nd))
        checks.append({"mixer": mixer, "engine": engine, "delay": delay,
                       "node_devices": nd,
                       "identical": _bit_identical(clean, faulted)})
    return checks


def run_bench(*, nodes: int, dim: int, horizon: int,
              rates: list[float],
              devices: int | str | None = None,
              bench_path: str = "BENCH_faults.json") -> dict:
    n_devices = None
    if devices is not None:
        from repro.launch.mesh import node_mesh as make_node_mesh
        mesh = make_node_mesh(devices)
        if mesh is not None:
            n_devices = int(mesh.shape["node"])

    checks = _zero_fault_checks(nodes=nodes, dim=dim, horizon=horizon,
                                n_devices=n_devices)
    zero_fault_identical = all(c["identical"] for c in checks)
    print(f"  zero_fault_identical={zero_fault_identical} "
          f"({len(checks)} configs)", flush=True)

    # degradation curve: link-drop rates, the paper's workload otherwise
    curve = []
    for rate in rates:
        res, wall = _timed(
            _spec(nodes, dim=dim, horizon=horizon, faults="links",
                  faults_options={"link_rate": rate}),
            compute_regret=True)
        faults_m = res.metrics.get("faults", {})
        curve.append({
            "link_rate": rate,
            "regret_final": (None if res.regret is None
                             else round(float(res.regret[-1]), 4)),
            "accuracy": round(float(res.accuracy), 4),
            "rounds_per_sec": round(res.rounds_per_sec, 1),
            "mean_connectivity": faults_m.get("mean_connectivity"),
        })
        print(f"  link_rate={rate}: accuracy={curve[-1]['accuracy']} "
              f"regret={curve[-1]['regret_final']} "
              f"conn={curve[-1]['mean_connectivity']}", flush=True)

    # retention floors vs the ZERO-RATE row (same machinery, no drops), so
    # the ratio isolates the fault rate from the wrapper's own overhead
    base, hit = curve[0], curve[1]
    accuracy_floor = (round(hit["accuracy"] / base["accuracy"], 4)
                      if base["accuracy"] > 0 else None)
    throughput_floor = (round(hit["rounds_per_sec"]
                              / base["rounds_per_sec"], 4)
                        if base["rounds_per_sec"] > 0 else None)

    # informational: rounds to reconverge after a transient partition heals
    cfg = ExecConfig(chunk_rounds=max(1, horizon // 2), compute_regret=False,
                     warmup=False)
    heal = horizon // 2
    part = FaultSpec(partitions=((horizon // 4, heal, nodes // 2),))
    clean = run(_spec(nodes, dim=dim, horizon=horizon), exec=cfg)
    parted = run(_spec(nodes, dim=dim, horizon=horizon, faults=part),
                 exec=cfg)
    recovery = rounds_to_recover(clean.correct.mean(axis=1),
                                 parted.correct.mean(axis=1),
                                 heal_round=heal, tol=0.05, window=3)

    bench = {
        "bench": "faults_degradation",
        "nodes": nodes,
        "dim": dim,
        "rounds": horizon,
        "devices": n_devices,
        "zero_fault_identical": zero_fault_identical,
        "zero_fault_checks": checks,
        "curve": curve,
        "accuracy_retention_floor": accuracy_floor,
        "throughput_retention_floor": throughput_floor,
        "partition_recovery_rounds": recovery,
        "partition_min_connectivity": float(np.min(parted.connectivity)),
    }
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1)
    if not zero_fault_identical:
        bad = [c for c in checks if not c["identical"]]
        raise AssertionError(
            f"zero-rate FaultSpec is not bit-identical to the fault-free "
            f"run for {bad} — the fault machinery perturbs the round math")
    return bench


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale (seconds) for the CI jobs")
    ap.add_argument("--devices", default=None, metavar="N|auto",
                    help="also gate the node-sharded zero-fault identity "
                         "over N local devices ('auto' = all, skipping the "
                         "sharded checks on a 1-device host)")
    ap.add_argument("--bench-path", default="BENCH_faults.json")
    args = ap.parse_args()
    devices = (None if args.devices is None
               else "auto" if args.devices == "auto" else int(args.devices))
    if args.smoke:
        kw = dict(nodes=16, dim=8, horizon=24, rates=[0.0, 0.05, 0.2])
    else:
        kw = dict(nodes=32, dim=16, horizon=40, rates=[0.0, 0.05, 0.2])
    bench = run_bench(devices=devices, bench_path=args.bench_path, **kw)
    print(f"zero_fault_identical={bench['zero_fault_identical']} "
          f"accuracy_retention_floor={bench['accuracy_retention_floor']} "
          f"throughput_retention_floor={bench['throughput_retention_floor']} "
          f"partition_recovery_rounds={bench['partition_recovery_rounds']}")


if __name__ == "__main__":
    main()
