"""Fig. 4 reproduction: sparsity/accuracy trade-off.

Paper claim: an appropriate sparsity gives the best accuracy (~18% better
than non-sparse); too much or too little hurts. We sweep the Lasso strength
lambda and report (sparsity, accuracy) pairs, mean±std over seeds —
`repro.sweep` owns the driving loop and the persistent records
(``from_store=True`` regenerates the JSON without re-running).
"""
from __future__ import annotations

import json
import math
import os

import numpy as np

from benchmarks.common import SEEDS, Scale, figure_sweep

LAMBDAS = (0.0, 1e-2, 1e-1, 3e-1, 1.0, 3.0, 10.0, 30.0)


def run(scale: Scale | None = None, out_dir: str = "experiments/figures",
        eps: float = math.inf, seeds: tuple = SEEDS,
        from_store: bool = False) -> dict:
    scale = scale or Scale()
    out = figure_sweep("fig4_sparsity", scale, {"lam": LAMBDAS}, seeds=seeds,
                       from_store=from_store, compute_regret=False, eps=eps)
    rows = []
    for point, results in zip(out.points, out.results):
        spars = np.asarray([float(np.asarray(r.sparsity)[-50:].mean())
                            for r in results])
        accs = np.asarray([r.accuracy for r in results])
        rows.append({
            "lambda": point.coords["lam"],
            "sparsity": float(spars.mean()),
            "sparsity_std": float(spars.std()),
            "accuracy": float(accs.mean()),
            "accuracy_std": float(accs.std()),
            "seeds": list(seeds),
            "seconds": float(sum(r.wall_clock for r in results)),
        })
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig4_sparsity.json"), "w") as f:
        json.dump(rows, f, indent=1)
    best = max(rows, key=lambda r: r["accuracy"])
    return {"rows": rows, "best": best,
            "interior_best": 0.0 < best["sparsity"] < 0.99}


if __name__ == "__main__":
    res = run()
    for r in res["rows"]:
        print(f"lam={r['lambda']:7.3f} sparsity={r['sparsity']:.3f} "
              f"acc={r['accuracy']:.3f}±{r['accuracy_std']:.3f}")
    print("best:", res["best"], "| interior optimum (paper Fig.4):",
          res["interior_best"])
