"""Fig. 4 reproduction: sparsity/accuracy trade-off.

Paper claim: an appropriate sparsity gives the best accuracy (~18% better
than non-sparse); too much or too little hurts. We sweep the Lasso strength
lambda and report (sparsity, accuracy) pairs.
"""
from __future__ import annotations

import json
import math
import os

import numpy as np

from benchmarks.common import Scale, run_algorithm1

LAMBDAS = (0.0, 1e-2, 1e-1, 3e-1, 1.0, 3.0, 10.0, 30.0)


def run(scale: Scale | None = None, out_dir: str = "experiments/figures",
        eps: float = math.inf) -> dict:
    scale = scale or Scale()
    rows = []
    for lam in LAMBDAS:
        res = run_algorithm1(scale, eps=eps, lam=lam, compute_regret=False)
        rows.append({
            "lambda": lam,
            "sparsity": float(np.asarray(res.sparsity)[-50:].mean()),
            "accuracy": res.accuracy,
            "seconds": res.wall_clock,
        })
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig4_sparsity.json"), "w") as f:
        json.dump(rows, f, indent=1)
    best = max(rows, key=lambda r: r["accuracy"])
    return {"rows": rows, "best": best,
            "interior_best": 0.0 < best["sparsity"] < 0.99}


if __name__ == "__main__":
    res = run()
    for r in res["rows"]:
        print(f"lam={r['lambda']:7.3f} sparsity={r['sparsity']:.3f} acc={r['accuracy']:.3f}")
    print("best:", res["best"], "| interior optimum (paper Fig.4):",
          res["interior_best"])
