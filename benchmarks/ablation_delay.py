"""Beyond-paper: communication delay tolerance — the paper's §VI future work
("there must exist delay in social networks, which we did not consider").

Neighbors' theta~ arrive `delay` rounds late via the engines' history ring
(see docs/delayed_gossip.md). The sweep exercises BOTH engines through ONE
`repro.api.run` call each — the dense simulator measures accuracy/regret vs
delay, and the distributed `GossipDP` engine (same stream, same seed)
proves the history ring works end-to-end outside the simulator and
contributes its wall-clock.

    PYTHONPATH=src python -m benchmarks.ablation_delay [--smoke]

Emits two artifacts:
  experiments/figures/ablation_delay.json — the legacy accuracy rows
  BENCH_delay.json                        — per-delay wall-clock + final
                                            regret for the bench trajectory
"""
from __future__ import annotations

import argparse
import json
import math
import os

from benchmarks.common import Scale, run_algorithm1

DELAYS = (0, 1, 4, 16, 64)
SMOKE_DELAYS = (0, 2)


def run(scale: Scale | None = None, eps: float = math.inf,
        out_dir: str = "experiments/figures",
        bench_path: str = "BENCH_delay.json",
        delays: tuple = DELAYS) -> dict:
    scale = scale or Scale()
    rows, bench_rows = [], []
    for d in delays:
        sim = run_algorithm1(scale, eps=eps, lam=0.01, delay=d, engine="sim")
        dist = run_algorithm1(scale, eps=eps, lam=0.01, delay=d,
                              engine="dist", compute_regret=False)
        rows.append({"delay": d, "accuracy": sim.accuracy,
                     "accuracy_distributed": dist.accuracy})
        bench_rows.append({
            "delay": d,
            "accuracy": sim.accuracy,
            "regret_final": float(sim.regret[-1]),
            "regret_per_round": float(sim.regret[-1] / scale.T),
            "simulator_seconds": round(sim.wall_clock, 3),
            "distributed_seconds": round(dist.wall_clock, 3),
        })
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "ablation_delay.json"), "w") as f:
        json.dump(rows, f, indent=1)
    bench = {
        "bench": "ablation_delay",
        "scale": {"n": scale.n, "m": scale.m, "T": scale.T},
        "eps": None if math.isinf(eps) else eps,
        "rows": bench_rows,
    }
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1)
    return {"rows": rows, "bench": bench,
            "graceful": rows[-1]["accuracy"] > 0.5 * rows[0]["accuracy"]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale + delays (0, 2) for the CI bench-smoke "
                         "job (seconds, not minutes)")
    ap.add_argument("--bench-path", default="BENCH_delay.json")
    args = ap.parse_args()
    scale = Scale.smoke() if args.smoke else None
    delays = SMOKE_DELAYS if args.smoke else DELAYS
    res = run(scale, bench_path=args.bench_path, delays=delays)
    for r in res["bench"]["rows"]:
        print(f"delay={r['delay']:3d}: acc={r['accuracy']:.3f} "
              f"regret/T={r['regret_per_round']:.4f} "
              f"sim={r['simulator_seconds']:.1f}s "
              f"dist={r['distributed_seconds']:.1f}s")
    print("graceful degradation:", res["graceful"])


if __name__ == "__main__":
    main()
