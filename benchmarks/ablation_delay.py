"""Beyond-paper: communication delay tolerance — the paper's §VI future work
("there must exist delay in social networks, which we did not consider").

Neighbors' theta~ arrive `delay` rounds late via the engines' history ring
(see docs/delayed_gossip.md). The delay axis drives BOTH engines through
`repro.sweep` — the dense simulator measures accuracy/regret vs delay
(multi-seed, mean±std), and the distributed `GossipDP` engine (same
streams, same seeds) proves the history ring works end-to-end outside the
simulator and contributes its wall-clock. All records persist in the sweep
store; ``from_store=True`` regenerates both artifacts without re-running.

    PYTHONPATH=src python -m benchmarks.ablation_delay [--smoke]

Emits two artifacts:
  experiments/figures/ablation_delay.json — the legacy accuracy rows
  BENCH_delay.json                        — per-delay wall-clock + final
                                            regret for the bench trajectory
"""
from __future__ import annotations

import argparse
import json
import math
import os

import numpy as np

from benchmarks.common import SEEDS, Scale, figure_sweep

DELAYS = (0, 1, 4, 16, 64)
SMOKE_DELAYS = (0, 2)


def run(scale: Scale | None = None, eps: float = math.inf,
        out_dir: str = "experiments/figures",
        bench_path: str = "BENCH_delay.json",
        delays: tuple = DELAYS, seeds: tuple = SEEDS,
        from_store: bool = False) -> dict:
    scale = scale or Scale()
    sim = figure_sweep("ablation_delay_sim", scale, {"delay": delays},
                       seeds=seeds, from_store=from_store,
                       eps=eps, lam=0.01)
    dist = figure_sweep("ablation_delay_dist", scale, {"delay": delays},
                        seeds=seeds, engine="dist", from_store=from_store,
                        compute_regret=False, eps=eps, lam=0.01)
    rows, bench_rows = [], []
    for point, sim_rs, dist_rs in zip(sim.points, sim.results, dist.results):
        d = point.coords["delay"]
        sim_acc = np.asarray([r.accuracy for r in sim_rs])
        dist_acc = np.asarray([r.accuracy for r in dist_rs])
        regs = np.asarray([float(r.regret[-1]) for r in sim_rs])
        rows.append({"delay": d,
                     "accuracy": float(sim_acc.mean()),
                     "accuracy_std": float(sim_acc.std()),
                     "accuracy_distributed": float(dist_acc.mean()),
                     "seeds": list(seeds)})
        bench_rows.append({
            "delay": d,
            "accuracy": float(sim_acc.mean()),
            "regret_final": float(regs.mean()),
            "regret_final_std": float(regs.std()),
            "regret_per_round": float(regs.mean() / scale.T),
            "simulator_seconds": round(
                float(sum(r.wall_clock for r in sim_rs)), 3),
            "distributed_seconds": round(
                float(sum(r.wall_clock for r in dist_rs)), 3),
        })
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "ablation_delay.json"), "w") as f:
        json.dump(rows, f, indent=1)
    bench = {
        "bench": "ablation_delay",
        "scale": {"n": scale.n, "m": scale.m, "T": scale.T},
        "eps": None if math.isinf(eps) else eps,
        "seeds": list(seeds),
        "rows": bench_rows,
    }
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1)
    return {"rows": rows, "bench": bench,
            "graceful": rows[-1]["accuracy"] > 0.5 * rows[0]["accuracy"]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale + delays (0, 2) for the CI bench-smoke "
                         "job (seconds, not minutes)")
    ap.add_argument("--bench-path", default="BENCH_delay.json")
    ap.add_argument("--from-store", action="store_true")
    args = ap.parse_args()
    scale = Scale.smoke() if args.smoke else None
    delays = SMOKE_DELAYS if args.smoke else DELAYS
    res = run(scale, bench_path=args.bench_path, delays=delays,
              from_store=args.from_store)
    for r in res["bench"]["rows"]:
        print(f"delay={r['delay']:3d}: acc={r['accuracy']:.3f} "
              f"regret/T={r['regret_per_round']:.4f} "
              f"sim={r['simulator_seconds']:.1f}s "
              f"dist={r['distributed_seconds']:.1f}s")
    print("graceful degradation:", res["graceful"])


if __name__ == "__main__":
    main()
