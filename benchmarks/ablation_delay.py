"""Beyond-paper: communication delay tolerance — the paper's §VI future work
("there must exist delay in social networks, which we did not consider").

Neighbors' theta~ arrive `delay` rounds late via the engines' history ring
(see docs/delayed_gossip.md). Since PR 2 the sweep exercises BOTH engines:
the dense simulator measures accuracy/regret vs delay, and the distributed
`GossipDP` engine (driven with the same hinge stream) proves the history
ring works end-to-end outside the simulator and contributes its wall-clock.

Emits two artifacts:
  experiments/figures/ablation_delay.json — the legacy accuracy rows
  BENCH_delay.json                        — per-delay wall-clock + final
                                            regret for the bench trajectory
"""
from __future__ import annotations

import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Scale, final_accuracy, make_spec, regret_curve
from repro.core.algorithm1 import hinge_loss_and_grad
from repro.data.social import SocialStream

DELAYS = (0, 1, 4, 16, 64)


def _run_distributed(spec, xs, ys) -> tuple[float, float]:
    """Drive GossipDP over the same stream; returns (accuracy, seconds).

    The whole horizon runs under one jitted lax.scan — same execution shape
    as the simulator's run() — so the two wall-clock columns in
    BENCH_delay.json compare engine cost, not host dispatch overhead.
    """
    gdp = spec.build_distributed()
    m, n = xs.shape[1], xs.shape[2]

    @jax.jit
    def run_scan(state, xs, ys):
        def body(st, batch):
            x, y = batch
            w = gdp.primal(st)["w"]
            _, grad = hinge_loss_and_grad(w, x, y)
            correct = (jnp.sign(jnp.einsum("mn,mn->m", w, x)) == y)
            st, _ = gdp.update(st, {"w": grad})
            return st, correct.astype(jnp.float32)
        return jax.lax.scan(body, state, (xs, ys))

    def fresh():
        return gdp.init({"w": jnp.zeros((m, n))}, jax.random.PRNGKey(1))

    # warm-up compile outside the timed region
    jax.block_until_ready(run_scan(fresh(), xs, ys)[0].theta["w"])
    t0 = time.time()
    state, corrects = run_scan(fresh(), xs, ys)
    jax.block_until_ready(state.theta["w"])
    secs = time.time() - t0
    tail = max(1, int(corrects.shape[0] * 0.2))
    acc = float(corrects[-tail:].mean())
    return acc, secs


def run(scale: Scale | None = None, eps: float = math.inf,
        out_dir: str = "experiments/figures",
        bench_path: str = "BENCH_delay.json") -> dict:
    scale = scale or Scale()
    stream = SocialStream(n=scale.n, nodes=scale.m, rounds=scale.T,
                          sparsity_true=0.05, seed=0)
    xs, ys = stream.chunk(0, scale.T)
    rows, bench_rows = [], []
    for d in DELAYS:
        spec = make_spec(scale, eps=eps, lam=0.01, delay=d)
        alg = spec.build_simulator()
        # jit + warm up so the timed run measures steady-state execution
        # (a bare alg.run re-traces its scan body on every call), matching
        # the warmed jitted loop in _run_distributed
        run_fn = jax.jit(alg.run)
        jax.block_until_ready(run_fn(jax.random.PRNGKey(1), xs, ys).loss)
        t0 = time.time()
        outs = run_fn(jax.random.PRNGKey(1), xs, ys)
        jax.block_until_ready(outs.loss)
        sim_secs = time.time() - t0
        reg = regret_curve(outs, xs, ys, scale.m)
        dist_acc, dist_secs = _run_distributed(spec, xs, ys)
        acc = final_accuracy(outs)
        rows.append({"delay": d, "accuracy": acc,
                     "accuracy_distributed": dist_acc})
        bench_rows.append({
            "delay": d,
            "accuracy": acc,
            "regret_final": float(reg[-1]),
            "regret_per_round": float(reg[-1] / scale.T),
            "simulator_seconds": round(sim_secs, 3),
            "distributed_seconds": round(dist_secs, 3),
        })
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "ablation_delay.json"), "w") as f:
        json.dump(rows, f, indent=1)
    bench = {
        "bench": "ablation_delay",
        "scale": {"n": scale.n, "m": scale.m, "T": scale.T},
        "eps": None if math.isinf(eps) else eps,
        "rows": bench_rows,
    }
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1)
    return {"rows": rows, "bench": bench,
            "graceful": rows[-1]["accuracy"] > 0.5 * rows[0]["accuracy"]}


if __name__ == "__main__":
    res = run()
    for r in res["bench"]["rows"]:
        print(f"delay={r['delay']:3d}: acc={r['accuracy']:.3f} "
              f"regret/T={r['regret_per_round']:.4f} "
              f"sim={r['simulator_seconds']:.1f}s "
              f"dist={r['distributed_seconds']:.1f}s")
    print("graceful degradation:", res["graceful"])
