"""Beyond-paper: communication delay tolerance — the paper's §VI future work
("there must exist delay in social networks, which we did not consider").

Neighbors' theta~ arrive `delay` rounds late (ring history buffer); the own
state stays current. Measures accuracy vs delay on the standard stream.
"""
from __future__ import annotations

import json
import math
import os

import jax
import numpy as np

from benchmarks.common import Scale, final_accuracy, make_spec
from repro.data.social import SocialStream

DELAYS = (0, 1, 4, 16, 64)


def run(scale: Scale | None = None, eps: float = math.inf,
        out_dir: str = "experiments/figures") -> dict:
    scale = scale or Scale()
    stream = SocialStream(n=scale.n, nodes=scale.m, rounds=scale.T,
                          sparsity_true=0.05, seed=0)
    xs, ys = stream.chunk(0, scale.T)
    rows = []
    for d in DELAYS:
        alg = make_spec(scale, eps=eps, lam=0.01, delay=d).build_simulator()
        outs = alg.run(jax.random.PRNGKey(1), xs, ys)
        rows.append({"delay": d, "accuracy": final_accuracy(outs)})
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "ablation_delay.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return {"rows": rows,
            "graceful": rows[-1]["accuracy"] > 0.5 * rows[0]["accuracy"]}


if __name__ == "__main__":
    res = run()
    for r in res["rows"]:
        print(f"delay={r['delay']:3d}: acc={r['accuracy']:.3f}")
    print("graceful degradation:", res["graceful"])
