"""Regression gate for the BENCH_*.json smoke artifacts.

CI used to only *upload* the bench JSONs — a silent 10x slowdown (or a
broken identity guarantee) would sail through green. This check compares a
freshly written BENCH_*.json against its committed baseline under
``benchmarks/baselines/`` and FAILS when:

  * any ``identical``-ish field (bool) flips from its baseline value —
    the bit-identity guarantees are not allowed to erode, ever;
  * any ``speedup``-ish field (number) drops below ``tolerance`` x the
    baseline value — generous by default (0.25) because CI runners are
    noisy and slower than the dev container, but a vanished vectorization
    win still trips it;
  * any ``qps`` throughput field drops below ``tolerance`` x baseline, or
    any ``*_ms`` latency field climbs above baseline / ``tolerance`` —
    the serving bench's sustained-QPS floor and latency ceiling
    (BENCH_serve baselines are committed pre-softened for CI, so the
    default tolerance leaves further headroom on top);
  * any ``*_floor`` retention ratio (e.g. BENCH_faults' accuracy /
    throughput retention under injected faults) drops below ``tolerance``
    x baseline — graceful degradation is a gated property, not a hope;
  * any ``overhead_ratio`` ceiling (BENCH_obs' telemetry-on / telemetry-off
    wall) climbs above baseline / ``tolerance`` — instrumentation on the
    chunk path must stay observation, not a tax.

Baseline fields that are null are skipped (e.g. the sharded timings on a
1-device host, or a speedup too noise-bound to gate); fields present in
the baseline but MISSING from the fresh file fail — a bench that silently
stops measuring something is a regression too.

Two baseline sets: ``benchmarks/baselines/`` (1-device, used by the
bench-smoke job) and ``benchmarks/baselines/sharded/`` (8 fake devices,
used by the multi-device job — gates ``sharded_identical`` and the
sharded-vs-sequential speedup; the sharded-vs-vmapped ratio is nulled
there because 2-core runners faking 8 devices make it pure noise).

    PYTHONPATH=src python -m benchmarks.check_bench            # all baselines
    PYTHONPATH=src python -m benchmarks.check_bench BENCH_sweep.json
    PYTHONPATH=src python -m benchmarks.check_bench \
        --baseline-dir benchmarks/baselines/sharded BENCH_sweep.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BASELINE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "baselines")


def _is_identity_key(key: str) -> bool:
    return key == "identical" or key.endswith("_identical")


def _is_speedup_key(key: str) -> bool:
    return "speedup" in key


def _is_rate_key(key: str) -> bool:
    """Throughput floors: higher is better, gated like speedups."""
    return key == "qps" or key.endswith("_qps")


def _is_floor_key(key: str) -> bool:
    """Degradation floors (retention ratios): higher is better."""
    return key.endswith("_floor")


def _is_latency_key(key: str) -> bool:
    """Latency ceilings (milliseconds): lower is better."""
    return key.endswith("_ms")


def _is_overhead_key(key: str) -> bool:
    """Overhead ceilings (ratios, e.g. telemetry-on / telemetry-off wall
    from BENCH_obs): lower is better, gated like latency."""
    return key == "overhead_ratio" or key.endswith("_overhead_ratio")


def _walk(tree, path=()):
    """(path, key, value) for every dict entry, depth-first."""
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield path, k, v
            yield from _walk(v, path + (k,))
    elif isinstance(tree, list):
        for i, v in enumerate(tree):
            yield from _walk(v, path + (str(i),))


def _get(tree, path, key):
    node = tree
    for p in path:
        if isinstance(node, dict):
            node = node.get(p, {})
        elif isinstance(node, list) and p.isdigit() and int(p) < len(node):
            node = node[int(p)]
        else:
            return None
    return node.get(key) if isinstance(node, dict) else None


def check_file(current_path: str, baseline_path: str,
               tolerance: float) -> list[str]:
    """Human-readable failure messages (empty = pass)."""
    with open(baseline_path) as f:
        baseline = json.load(f)
    if not os.path.exists(current_path):
        return [f"{current_path}: missing (baseline {baseline_path} exists "
                f"— did the bench stop running?)"]
    with open(current_path) as f:
        current = json.load(f)

    failures = []
    checked = 0
    for path, key, base_val in _walk(baseline):
        where = ".".join(path + (key,))
        if _is_identity_key(key) and isinstance(base_val, bool):
            cur = _get(current, path, key)
            checked += 1
            if cur != base_val:
                failures.append(
                    f"{current_path}: {where} = {cur!r}, baseline "
                    f"{base_val!r} — the bit-identity guarantee regressed")
        elif (_is_speedup_key(key) or _is_rate_key(key)
                or _is_floor_key(key)) \
                and isinstance(base_val, (int, float)) \
                and not isinstance(base_val, bool):
            cur = _get(current, path, key)
            checked += 1
            floor = base_val * tolerance
            what = ("vectorization win" if _is_speedup_key(key)
                    else "degradation floor" if _is_floor_key(key)
                    else "serving throughput")
            if not isinstance(cur, (int, float)) or isinstance(cur, bool):
                failures.append(
                    f"{current_path}: {where} missing/non-numeric "
                    f"(baseline {base_val})")
            elif cur < floor:
                failures.append(
                    f"{current_path}: {where} = {cur} < {floor:.2f} "
                    f"({tolerance} x baseline {base_val}) — {what} "
                    f"regressed")
        elif (_is_latency_key(key) or _is_overhead_key(key)) \
                and isinstance(base_val, (int, float)) \
                and not isinstance(base_val, bool):
            cur = _get(current, path, key)
            checked += 1
            ceiling = base_val / tolerance
            what = ("telemetry overhead" if _is_overhead_key(key)
                    else "serving latency")
            if not isinstance(cur, (int, float)) or isinstance(cur, bool):
                failures.append(
                    f"{current_path}: {where} missing/non-numeric "
                    f"(baseline {base_val})")
            elif cur > ceiling:
                failures.append(
                    f"{current_path}: {where} = {cur} > {ceiling:.2f} "
                    f"(baseline {base_val} / tolerance {tolerance}) — "
                    f"{what} regressed")
    if checked == 0:
        failures.append(f"{baseline_path}: no identical/speedup fields to "
                        f"check — baseline is vacuous")
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.check_bench",
        description="Fail when a BENCH_*.json regresses vs its committed "
                    "baseline (benchmarks/baselines/)")
    ap.add_argument("files", nargs="*",
                    help="BENCH_*.json files to check (default: every file "
                         "with a committed baseline)")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="fresh speedup must be >= tolerance x baseline "
                         "(default 0.25 — CI runners are noisy)")
    ap.add_argument("--baseline-dir", default=BASELINE_DIR)
    args = ap.parse_args(argv)

    names = args.files or sorted(
        f for f in os.listdir(args.baseline_dir) if f.endswith(".json"))
    if not names:
        print("check_bench: no baselines found", file=sys.stderr)
        return 2

    failures = []
    for name in names:
        base = os.path.basename(name)
        baseline_path = os.path.join(args.baseline_dir, base)
        if not os.path.exists(baseline_path):
            failures.append(f"{name}: no committed baseline at "
                            f"{baseline_path}")
            continue
        failures.extend(check_file(base if not args.files else name,
                                   baseline_path, args.tolerance))
    if failures:
        for msg in failures:
            print(f"REGRESSION {msg}", file=sys.stderr)
        return 1
    print(f"check_bench: {len(names)} file(s) within tolerance "
          f"{args.tolerance} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
