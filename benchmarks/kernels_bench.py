"""Kernel micro-benchmarks: fused Pallas path vs unfused pure-jnp oracle.

On this CPU container the Pallas kernels run in interpret mode (slow — it is
a CORRECTNESS rig), so the CSV reports the oracle timing and the kernel's
analytic traffic advantage (bytes moved fused vs unfused), which is the
number that transfers to TPU.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, iters=20) -> float:
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def bench_pdomd(rows: int = 4096) -> list[tuple[str, float, str]]:
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    args = [jax.random.normal(k, (rows, 128)) for k in keys]
    alpha, lam = jnp.float32(0.05), jnp.float32(0.01)

    jitted_ref = jax.jit(lambda *a: ref.pdomd_update_ref(
        *a, jnp.float32(0.5), jnp.float32(0.25)))
    us_ref = _time(jitted_ref, *args, alpha, lam)

    n = rows * 128 * 4
    unfused_traffic = 7 * n   # 3 theta reads + mix write+read + sub write+read... see kernel doc
    fused_traffic = 6 * n     # 4 reads + 2 writes
    return [
        ("pdomd_update_oracle_jit", us_ref,
         f"traffic_fused={fused_traffic}B;unfused={unfused_traffic}B;cut={unfused_traffic/fused_traffic:.2f}x"),
    ]


def bench_hinge(B: int = 1024, n: int = 10_240) -> list[tuple[str, float, str]]:
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jax.random.normal(k1, (B, n)) / jnp.sqrt(n * 1.0)
    y = jnp.sign(jax.random.normal(k2, (B,)))
    w = jax.random.normal(k3, (n,))
    jitted_ref = jax.jit(ref.hinge_grad_ref)
    us_ref = _time(jitted_ref, x, y, w, iters=5)
    xbytes = B * n * 4
    return [
        ("hinge_grad_oracle_jit", us_ref,
         f"x_bytes={xbytes};fused_reads_x_once=2x_cut"),
    ]


def bench_algorithm1_round(m: int = 64, n: int = 10_000) -> list[tuple[str, float, str]]:
    """The paper's per-round hot loop at the paper's own scale."""
    from repro.api import RunSpec
    alg = RunSpec(nodes=m, dim=n, mixer="ring", eps=1.0, clip_norm=1.0,
                  alpha0=1.0, lam=1e-3).build_simulator()
    state = alg.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (m, n)) / jnp.sqrt(n * 1.0)
    y = jnp.sign(jax.random.normal(jax.random.PRNGKey(2), (m,)))
    rnd = jax.jit(alg.round)
    us = _time(rnd, state, (x, y), iters=10)
    return [("algorithm1_round_m64_n10k", us, f"m={m};n={n}")]


def bench_flash_traffic(T: int = 4096, H: int = 36, hd: int = 64,
                        B: int = 2) -> list[tuple[str, float, str]]:
    """Analytic HBM-traffic comparison (the TPU-transferable number):
    XLA blockwise (score tensors round-trip) vs flash tiling (q/k/v/o only).
    """
    qc, kc = 1024, 1024
    nq, nk = T // qc, T // kc
    f32, bf16 = 4, 2
    qkvo = 4 * B * T * H * hd * bf16
    # blockwise: per (qi,kj) tile, s write + p read (f32) + small operands
    score_traffic = nq * nk * (2 * B * H * qc * kc * f32)
    kv_reload = nq * (2 * B * T * H * hd * bf16)
    blockwise = qkvo + score_traffic + kv_reload
    flash = qkvo + kv_reload  # scores never leave VMEM
    return [("flash_attention_traffic_model", 0.0,
             f"T={T};blockwise={blockwise/1e9:.1f}GB;flash={flash/1e9:.1f}GB;"
             f"cut={blockwise/flash:.1f}x")]


def bench_wkv6(T: int = 512, H: int = 4, K: int = 64) -> list[tuple[str, float, str]]:
    from repro.kernels.ref import wkv6_ref
    r = jax.random.normal(jax.random.PRNGKey(0), (T, K)) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(1), (T, K)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (T, K))
    w = jax.random.normal(jax.random.PRNGKey(3), (T, K)) * 0.3
    u = jax.random.normal(jax.random.PRNGKey(4), (K,)) * 0.1
    s0 = jnp.zeros((K, K))
    jref = jax.jit(wkv6_ref)
    us = _time(jref, r, k, v, w, u, s0, iters=5)
    # HBM model: scan round-trips S (K,K,f32) twice per step; kernel keeps it in VMEM
    scan_S = T * 2 * K * K * 4
    io = 4 * T * K * 4 + T * K * 4
    return [("wkv6_oracle_scan_1head", us,
             f"S_roundtrip={scan_S/1e6:.1f}MB;io={io/1e6:.1f}MB;"
             f"kernel_cut={(scan_S+io)/io:.1f}x")]


def run_all() -> list[tuple[str, float, str]]:
    out = []
    out += bench_pdomd()
    out += bench_hinge()
    out += bench_algorithm1_round()
    out += bench_flash_traffic()
    out += bench_wkv6()
    return out


if __name__ == "__main__":
    for name, us, derived in run_all():
        print(f"{name},{us:.1f},{derived}")
