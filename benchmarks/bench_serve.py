"""Serving-layer bench: predict-step latency + bursty-replay throughput.

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke] \
        [--bench-path BENCH_serve.json]

Two measurements, both with honest wall clocks (`jax.block_until_ready`
before every stamp):

  predict_step   the jitted batched predict in isolation — p50/p99 ms per
                 max_batch-shaped call against a fixed snapshot (compile
                 excluded via one warmup call).
  replay         the assembled `ServeService` under the `bursty` stream's
                 heavy-tailed arrivals while the background trainer keeps
                 publishing — sustained QPS, end-to-end p50/p99 latency,
                 staleness-in-rounds, shed/refused counts, and the
                 bit-identity verdict of a served response against a fresh
                 reference `repro.api.run` at its snapshot round.

Writes BENCH_serve.json; `benchmarks/check_bench.py` gates
``snapshot_identical``, every ``*_ms`` latency ceiling and the ``qps``
floor against benchmarks/baselines/BENCH_serve.json in CI.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.api import RunSpec
from repro.launch.serve import serve_social
from repro.serve import ServeState


def bench_predict_step(spec: RunSpec, *, max_batch: int,
                       iters: int = 200) -> dict:
    """Isolated jitted-predict latency against a fixed round-0 snapshot."""
    state = ServeState(spec)
    state.publish_initial()
    rng = np.random.default_rng(0)
    feats = rng.standard_normal((max_batch, spec.dim)).astype(np.float32)
    nodes = (np.arange(max_batch) % spec.nodes).astype(np.int32)
    jax.block_until_ready(state.predict(feats, nodes)[:2])       # compile
    lat = np.empty(iters)
    for i in range(iters):
        t0 = time.perf_counter()
        margins, labels, _ = state.predict(feats, nodes)
        jax.block_until_ready((margins, labels))
        lat[i] = time.perf_counter() - t0
    return {
        "max_batch": max_batch,
        "iters": iters,
        "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 4),
        "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 4),
    }


def run(*, smoke: bool = False,
        bench_path: str = "BENCH_serve.json") -> dict:
    if smoke:
        shape = dict(nodes=4, dim=16, horizon=96, chunk_rounds=8,
                     max_batch=8, ticks=64, warmup=False)
    else:
        shape = dict(nodes=8, dim=64, horizon=1024, chunk_rounds=64,
                     max_batch=32, ticks=512)
    spec = RunSpec(nodes=shape["nodes"], dim=shape["dim"],
                   horizon=shape["horizon"], eps=10.0, alpha0=0.5, lam=0.01,
                   stream="bursty")
    step = bench_predict_step(spec, max_batch=shape["max_batch"],
                              iters=50 if smoke else 200)
    end_to_end = serve_social(
        nodes=shape["nodes"], dim=shape["dim"], horizon=shape["horizon"],
        eps=10.0, chunk_rounds=shape["chunk_rounds"],
        max_batch=shape["max_batch"], max_wait_ms=0.5,
        queue_capacity=4 * shape["max_batch"] * shape["nodes"],
        ticks=shape["ticks"], warmup=shape.get("warmup", True))
    adm, rep = end_to_end["admission"], end_to_end["replay"]
    bench = {
        "bench": "serve",
        "scale": {k: shape[k] for k in
                  ("nodes", "dim", "horizon", "chunk_rounds", "max_batch",
                   "ticks")},
        "snapshot_identical": end_to_end["snapshot_identical"],
        "predict_step": step,
        "replay": {
            "qps": round(rep["qps"], 1),
            "submitted": rep["submitted"],
            "served": rep["served"],
            "shed": rep["shed"],
            "shed_reasons": adm.get("shed_reasons", {}),
            "refused": rep["refused"],
            "p50_latency_ms": adm["p50_latency_ms"],
            "p99_latency_ms": adm["p99_latency_ms"],
            "staleness_mean_rounds": adm["staleness_mean_rounds"],
            "staleness_max_rounds": adm["staleness_max_rounds"],
        },
    }
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1)
    return bench


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (seconds) for the CI serve-smoke job")
    ap.add_argument("--bench-path", default="BENCH_serve.json")
    args = ap.parse_args()
    bench = run(smoke=args.smoke, bench_path=args.bench_path)
    step, rep = bench["predict_step"], bench["replay"]
    print(f"predict_step: p50={step['p50_ms']}ms p99={step['p99_ms']}ms "
          f"(batch {step['max_batch']})")
    print(f"replay: {rep['qps']} qps, latency p50={rep['p50_latency_ms']}ms "
          f"p99={rep['p99_latency_ms']}ms, staleness "
          f"mean={rep['staleness_mean_rounds']} "
          f"max={rep['staleness_max_rounds']} rounds, "
          f"{rep['shed']} shed {rep['shed_reasons']} / "
          f"{rep['refused']} refused")
    print(f"snapshot_identical: {bench['snapshot_identical']}")


if __name__ == "__main__":
    main()
