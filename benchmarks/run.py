"""Benchmark entrypoint: one function per paper figure + kernel micro-bench +
roofline aggregation. Prints ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run            # CI scale (minutes)
    PYTHONPATH=src python -m benchmarks.run --full     # paper scale (§V)
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale (n=10k, m=64, 100k samples)")
    ap.add_argument("--skip-figs", action="store_true")
    args = ap.parse_args()

    from benchmarks import fig2_privacy, fig3_topology, fig4_sparsity, fig5_nodes
    from benchmarks import kernels_bench, roofline
    from benchmarks.common import Scale

    scale = Scale.paper() if args.full else None
    rows: list[tuple[str, float, str]] = []

    if not args.skip_figs:
        t0 = time.time()
        r2 = fig2_privacy.run(scale)
        rows.append(("fig2_privacy_regret", (time.time() - t0) * 1e6,
                     f"ordering_holds={r2['ordering_holds']};"
                     + ";".join(f"eps{eps}={v['regret_final']:.0f}"
                                for eps, v in r2["rows"].items())))

        t0 = time.time()
        r3 = fig3_topology.run(scale)
        rows.append(("fig3_topology_invariance", (time.time() - t0) * 1e6,
                     f"acc_spread={r3['spread']:.3f}"))

        t0 = time.time()
        r4 = fig4_sparsity.run(scale)
        rows.append(("fig4_sparsity_sweep", (time.time() - t0) * 1e6,
                     f"best_lambda={r4['best']['lambda']};best_acc={r4['best']['accuracy']:.3f};"
                     f"interior={r4['interior_best']}"))

        t0 = time.time()
        r5 = fig5_nodes.run(scale)
        rows.append(("fig5_node_count", (time.time() - t0) * 1e6,
                     f"declines={r5['declines']};"
                     + ";".join(f"m{r['nodes']}={r['accuracy']:.3f}" for r in r5["rows"])))

    if not args.skip_figs:
        from benchmarks import ablation_delay, ablation_sparse_methods
        t0 = time.time()
        ra = ablation_sparse_methods.run(scale)
        rows.append(("ablation_sparse_methods", (time.time() - t0) * 1e6,
                     ";".join(f"{k.split()[0]}={v['accuracy']:.3f}/{v['sparsity']:.2f}"
                              for k, v in ra.items())))
        t0 = time.time()
        rd = ablation_delay.run(scale)
        rows.append(("ablation_delay", (time.time() - t0) * 1e6,
                     f"graceful={rd['graceful']};"
                     + ";".join(f"d{r['delay']}={r['accuracy']:.3f}" for r in rd["rows"])))

    rows += kernels_bench.run_all()

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    # roofline table from whatever dry-run records exist
    roofline.main()


if __name__ == "__main__":
    main()
