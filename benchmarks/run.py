"""Benchmark entrypoint: one function per paper figure + the round-fusion
kernel bench. Prints ``name,us_per_call,derived`` CSV lines.

Every figure routes through the `repro.sweep` store: multi-seed sweeps with
the seed axis vmapped per point, one JSONL record per (point, seed) under
experiments/store/. ``--from-store`` regenerates every figure JSON from
those records without re-running a single point.

    PYTHONPATH=src python -m benchmarks.run            # CI scale (minutes)
    PYTHONPATH=src python -m benchmarks.run --smoke    # tiny scale (seconds)
    PYTHONPATH=src python -m benchmarks.run --full     # paper scale (§V)
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale (n=10k, m=64, 100k samples)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale (seconds) — the CI bench-smoke entry")
    ap.add_argument("--from-store", action="store_true",
                    help="regenerate figure JSONs from the sweep store "
                         "without re-running")
    ap.add_argument("--skip-figs", action="store_true")
    args = ap.parse_args()

    from benchmarks import (bench_sweep, fig2_privacy, fig3_topology,
                            fig4_sparsity, fig5_nodes)
    from benchmarks.common import Scale

    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")
    scale = (Scale.paper() if args.full
             else Scale.smoke() if args.smoke else None)
    fig_kw = dict(from_store=args.from_store)
    rows: list[tuple[str, float, str]] = []

    if not args.skip_figs:
        t0 = time.time()
        r2 = fig2_privacy.run(scale, **fig_kw)
        rows.append(("fig2_privacy_regret", (time.time() - t0) * 1e6,
                     f"ordering_holds={r2['ordering_holds']};"
                     + ";".join(f"eps{eps}={v['regret_final']:.0f}"
                                for eps, v in r2["rows"].items())))

        t0 = time.time()
        r3 = fig3_topology.run(scale, **fig_kw)
        rows.append(("fig3_topology_invariance", (time.time() - t0) * 1e6,
                     f"acc_spread={r3['spread']:.3f}"))

        t0 = time.time()
        r4 = fig4_sparsity.run(scale, **fig_kw)
        rows.append(("fig4_sparsity_sweep", (time.time() - t0) * 1e6,
                     f"best_lambda={r4['best']['lambda']};best_acc={r4['best']['accuracy']:.3f};"
                     f"interior={r4['interior_best']}"))

        t0 = time.time()
        r5 = fig5_nodes.run(scale, **fig_kw)
        rows.append(("fig5_node_count", (time.time() - t0) * 1e6,
                     f"declines={r5['declines']};"
                     + ";".join(f"m{r['nodes']}={r['accuracy']:.3f}" for r in r5["rows"])))

        from benchmarks import ablation_delay, ablation_sparse_methods
        t0 = time.time()
        ra = ablation_sparse_methods.run(scale, **fig_kw)
        rows.append(("ablation_sparse_methods", (time.time() - t0) * 1e6,
                     ";".join(f"{k.split()[0]}={v['accuracy']:.3f}/{v['sparsity']:.2f}"
                              for k, v in ra.items())))
        t0 = time.time()
        rd = ablation_delay.run(
            scale, delays=(ablation_delay.SMOKE_DELAYS if args.smoke
                           else ablation_delay.DELAYS), **fig_kw)
        rows.append(("ablation_delay", (time.time() - t0) * 1e6,
                     f"graceful={rd['graceful']};"
                     + ";".join(f"d{r['delay']}={r['accuracy']:.3f}" for r in rd["rows"])))

        # the sweep engine's own bench: vmapped seed axis vs sequential loop
        t0 = time.time()
        rs = bench_sweep.run_bench(scale, n_seeds=8)
        rows.append(("bench_sweep_seed_vmap", (time.time() - t0) * 1e6,
                     f"speedup={rs['speedup']};identical={rs['identical']}"))

    # the round-fusion bench (BENCH_kernels.json: pallas backend vs
    # reference + the seed-kernel micro rows)
    from benchmarks import bench_kernels
    t0 = time.time()
    rk = bench_kernels.run_bench(
        nodes=6 if args.smoke else 8,
        dims=[40, 160] if args.smoke else [64, 256, 1024],
        horizon=8 if args.smoke else 16)
    rows.append(("bench_kernels_round_fusion", (time.time() - t0) * 1e6,
                 f"reference_match={rk['reference_match_identical']};"
                 f"traffic_cut={rk['traffic_model']['traffic_cut_speedup']}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
