"""Fig. 2 reproduction: privacy level (eps) vs regret.

Paper claim: non-private has the lowest regret; regret approaches it as
eps grows (weaker privacy). We sweep eps in {0.1, 1, 10, inf}.
"""
from __future__ import annotations

import json
import math
import os


from benchmarks.common import Scale, run_algorithm1

EPS_SWEEP = (0.1, 1.0, 10.0, math.inf)


def run(scale: Scale | None = None, out_dir: str = "experiments/figures",
        clip_style: str = "coordinate") -> dict:
    scale = scale or Scale()
    rows = {}
    for eps in EPS_SWEEP:
        res = run_algorithm1(scale, eps=eps, clip_style=clip_style)
        reg = res.regret
        rows[str(eps)] = {
            "regret_final": float(reg[-1]),
            "regret_curve": reg[:: max(1, len(reg) // 200)].tolist(),
            "accuracy": res.accuracy,
            "eps_total": (None if math.isinf(res.privacy["eps_total"])
                          else res.privacy["eps_total"]),
            "seconds": res.wall_clock,
        }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"fig2_privacy_{clip_style}.json"), "w") as f:
        json.dump(rows, f, indent=1)

    # the paper's ordering: higher eps (weaker privacy) => lower regret.
    # Tolerance: near-zero regrets (strong learner vs comparator) jitter.
    finals = [rows[str(e)]["regret_final"] for e in EPS_SWEEP]
    tol = max(50.0, 0.05 * abs(finals[0]))
    ordered = all(a >= b - tol for a, b in zip(finals, finals[1:]))
    accs = [rows[str(e)]["accuracy"] for e in EPS_SWEEP]
    acc_ordered = all(a <= b + 0.03 for a, b in zip(accs, accs[1:]))
    return {"rows": rows, "ordering_holds": ordered and acc_ordered}


if __name__ == "__main__":
    res = run()
    for eps, r in res["rows"].items():
        print(f"eps={eps:>5s}: regret={r['regret_final']:12.1f} acc={r['accuracy']:.3f}")
    print("paper Fig.2 ordering holds:", res["ordering_holds"])
