"""Fig. 2 reproduction: privacy level (eps) vs regret.

Paper claim: non-private has the lowest regret; regret approaches it as
eps grows (weaker privacy). We sweep eps in {0.1, 1, 10, inf} — the figure
owns ONLY the axis and the JSON shape; the multi-seed driving loop lives in
`repro.sweep` (seed axis vmapped, records persisted in the sweep store, so
``from_store=True`` regenerates this JSON without re-running).
"""
from __future__ import annotations

import json
import math
import os

import numpy as np

from benchmarks.common import SEEDS, Scale, figure_sweep

EPS_SWEEP = (0.1, 1.0, 10.0, math.inf)


def run(scale: Scale | None = None, out_dir: str = "experiments/figures",
        clip_style: str = "coordinate", seeds: tuple = SEEDS,
        from_store: bool = False) -> dict:
    scale = scale or Scale()
    out = figure_sweep(f"fig2_privacy_{clip_style}", scale,
                       {"eps": EPS_SWEEP}, seeds=seeds,
                       from_store=from_store, eps=1.0, clip_style=clip_style)
    rows = {}
    for point, results in zip(out.points, out.results):
        regs = np.stack([np.asarray(r.regret) for r in results])   # (S, T)
        accs = np.asarray([r.accuracy for r in results])
        curve = regs.mean(axis=0)
        eps_total = results[0].privacy["eps_total"]
        rows[str(point.coords["eps"])] = {
            "regret_final": float(curve[-1]),
            "regret_final_std": float(regs[:, -1].std()),
            "regret_curve": curve[:: max(1, len(curve) // 200)].tolist(),
            "accuracy": float(accs.mean()),
            "accuracy_std": float(accs.std()),
            "seeds": list(seeds),
            "eps_total": None if math.isinf(eps_total) else eps_total,
            "seconds": float(sum(r.wall_clock for r in results)),
        }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"fig2_privacy_{clip_style}.json"), "w") as f:
        json.dump(rows, f, indent=1)

    # the paper's ordering: higher eps (weaker privacy) => lower regret.
    # Tolerance: near-zero regrets (strong learner vs comparator) jitter.
    finals = [rows[str(e)]["regret_final"] for e in EPS_SWEEP]
    tol = max(50.0, 0.05 * abs(finals[0]))
    ordered = all(a >= b - tol for a, b in zip(finals, finals[1:]))
    accs = [rows[str(e)]["accuracy"] for e in EPS_SWEEP]
    acc_ordered = all(a <= b + 0.03 for a, b in zip(accs, accs[1:]))
    return {"rows": rows, "ordering_holds": ordered and acc_ordered}


if __name__ == "__main__":
    res = run()
    for eps, r in res["rows"].items():
        print(f"eps={eps:>5s}: regret={r['regret_final']:12.1f}"
              f"±{r['regret_final_std']:.1f} acc={r['accuracy']:.3f}"
              f"±{r['accuracy_std']:.3f}")
    print("paper Fig.2 ordering holds:", res["ordering_holds"])
