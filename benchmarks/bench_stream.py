"""Stream-scenario throughput bench: `repro.api.run` end-to-end on a
STREAMS scenario, sim vs dist engine, rounds/sec + quality — driven through
`repro.sweep` (one single-point sweep per engine) so even the throughput
bench persists its records in the sweep store.

    PYTHONPATH=src python -m benchmarks.bench_stream [--smoke] \
        [--stream drift] [--engines sim dist]

Writes BENCH_stream.json — the bench-trajectory point the CI bench-smoke
job uploads: per engine, steady-state rounds/sec (compile excluded via the
runner's warmup), tail accuracy, final regret, and the eps ledger endpoint.
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import Scale, make_spec
from repro.sweep import DEFAULT_STORE, SweepSpec, sweep


def run(scale: Scale | None = None, *, stream: str = "drift",
        stream_options: dict | None = None, eps: float = 1.0,
        engines: tuple = ("sim", "dist"),
        bench_path: str = "BENCH_stream.json",
        store: str | None = DEFAULT_STORE) -> dict:
    scale = scale or Scale()
    base = make_spec(scale, eps=eps, lam=0.01, stream=stream,
                     stream_options=stream_options or {})
    rows = {}
    for engine in engines:
        out = sweep(SweepSpec(base=base, axes={}, seeds=(0,), engine=engine,
                              name=f"bench_stream_{engine}",
                              chunk_rounds=min(scale.T, 256)),
                    store=store)
        res = out.results[0][0]
        rows[engine] = {
            "rounds_per_sec": round(res.rounds_per_sec, 2),
            "wall_clock_s": round(res.wall_clock, 3),
            "accuracy": res.accuracy,
            "regret_final": (None if res.regret is None
                             else float(res.regret[-1])),
            "eps_total": res.privacy["eps_total"],
        }
    # seeded sim-vs-dist runs are guaranteed bit-identical (PR 3); record the
    # verdict so benchmarks/check_bench.py can gate it against the baseline
    engines_identical = None
    if "sim" in rows and "dist" in rows:
        engines_identical = (
            rows["sim"]["accuracy"] == rows["dist"]["accuracy"]
            and rows["sim"]["regret_final"] == rows["dist"]["regret_final"])
    bench = {
        "bench": "stream_runner",
        "stream": stream,
        "scale": {"n": scale.n, "m": scale.m, "T": scale.T},
        "eps": eps,
        "engines_identical": engines_identical,
        "rows": rows,
    }
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1)
    return bench


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny drift stream (seconds) for the CI "
                         "bench-smoke job")
    ap.add_argument("--stream", default="drift")
    ap.add_argument("--engines", nargs="+", default=["sim", "dist"],
                    choices=["sim", "dist"])
    ap.add_argument("--eps", type=float, default=1.0)
    ap.add_argument("--bench-path", default="BENCH_stream.json")
    args = ap.parse_args()
    scale = Scale.smoke() if args.smoke else None
    bench = run(scale, stream=args.stream, eps=args.eps,
                engines=tuple(args.engines), bench_path=args.bench_path)
    for engine, r in bench["rows"].items():
        print(f"{engine:4s}: {r['rounds_per_sec']:8.1f} rounds/s "
              f"acc={r['accuracy']:.3f} regret={r['regret_final']}")


if __name__ == "__main__":
    main()
