"""Roofline table builder: aggregates experiments/dryrun/*.json into the
EXPERIMENTS.md §Roofline table (single-pod) and §Dry-run summary."""
from __future__ import annotations

import glob
import json
import os

DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str = "16x16", strategy: str = "gossip") -> list[dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(DIR, f"*__{mesh}__{strategy}.json"))):
        with open(fn) as f:
            rows.append(json.load(f))
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 9))
    return rows


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | status | t_compute | t_memory | t_collective | "
           "dominant | 6ND/HLO | per-dev GB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP ({r['reason'][:40]}…) "
                         f"| | | | | | |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | | |")
            continue
        t = r["roofline"]
        mem = r.get("memory_per_device") or {}
        gb = (mem.get("temp", 0) + mem.get("arguments", 0)) / 1e9
        ratio = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {fmt_s(t['t_compute_s'])} "
            f"| {fmt_s(t['t_memory_s'])} | {fmt_s(t['t_collective_s'])} "
            f"| **{t['dominant']}** | {ratio:.2f} | {gb:.1f} |"
            if ratio else
            f"| {r['arch']} | {r['shape']} | ok | {fmt_s(t['t_compute_s'])} "
            f"| {fmt_s(t['t_memory_s'])} | {fmt_s(t['t_collective_s'])} "
            f"| **{t['dominant']}** | n/a | {gb:.1f} |")
    return hdr + "\n".join(lines)


def summary(rows: list[dict]) -> dict:
    ok = [r for r in rows if r.get("status") == "ok"]
    dom = {}
    for r in ok:
        dom[r["roofline"]["dominant"]] = dom.get(r["roofline"]["dominant"], 0) + 1
    return {
        "total": len(rows),
        "ok": len(ok),
        "skipped": sum(1 for r in rows if r.get("status") == "skipped"),
        "failed": sum(1 for r in rows if r.get("status") not in ("ok", "skipped")),
        "dominant_counts": dom,
    }


def main():
    for mesh in ("16x16", "2x16x16"):
        rows = load(mesh)
        if not rows:
            print(f"[{mesh}] no dry-run records yet")
            continue
        print(f"\n===== mesh {mesh} =====")
        print(table(rows))
        print(json.dumps(summary(rows)))


if __name__ == "__main__":
    main()
