"""Fig. 5 reproduction: accuracy vs number of data-center nodes.

Paper claim: more centers slightly reduce accuracy (~4% per +4 nodes at
their scale) — each node sees proportionally less data per round and the
noise compounds across edges.

The node count and horizon co-vary (same total samples), which is exactly
what a ZIPPED sweep axis expresses: one 'nodes,horizon' axis whose values
are (m, T) pairs. Each point is its own compile (the node axis changes
shapes); the seed axis inside each point is still vmapped.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import SEEDS, Scale, figure_sweep

NODE_SWEEP = (4, 8, 16, 32)


def run(scale: Scale | None = None, out_dir: str = "experiments/figures",
        eps: float = 10.0, seeds: tuple = SEEDS,
        from_store: bool = False) -> dict:
    base = scale or Scale()
    # same total samples per point: T scales inversely with m
    axis = tuple((m, base.T * base.m // m) for m in NODE_SWEEP)
    out = figure_sweep("fig5_nodes", base, {"nodes,horizon": axis},
                       seeds=seeds, from_store=from_store,
                       compute_regret=False, eps=eps)
    rows = []
    for point, results in zip(out.points, out.results):
        accs = np.asarray([r.accuracy for r in results])
        rows.append({"nodes": point.coords["nodes"],
                     "horizon": point.coords["horizon"],
                     "accuracy": float(accs.mean()),
                     "accuracy_std": float(accs.std()),
                     "seeds": list(seeds),
                     "seconds": float(sum(r.wall_clock for r in results))})
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig5_nodes.json"), "w") as f:
        json.dump(rows, f, indent=1)
    accs = [r["accuracy"] for r in rows]
    return {"rows": rows, "declines": accs[0] >= accs[-1] - 0.02}


if __name__ == "__main__":
    res = run()
    for r in res["rows"]:
        print(f"m={r['nodes']:3d}: acc={r['accuracy']:.3f}"
              f"±{r['accuracy_std']:.3f}")
    print("accuracy declines with more nodes (paper Fig.5):", res["declines"])
