"""Fig. 5 reproduction: accuracy vs number of data-center nodes.

Paper claim: more centers slightly reduce accuracy (~4% per +4 nodes at
their scale) — each node sees proportionally less data per round and the
noise compounds across edges.
"""
from __future__ import annotations

import json
import os


from benchmarks.common import Scale, run_algorithm1

NODE_SWEEP = (4, 8, 16, 32)


def run(scale: Scale | None = None, out_dir: str = "experiments/figures",
        eps: float = 10.0) -> dict:
    base = scale or Scale()
    rows = []
    for m in NODE_SWEEP:
        s = Scale(n=base.n, m=m, T=base.T * base.m // m)  # same total samples
        res = run_algorithm1(s, eps=eps, compute_regret=False)
        rows.append({"nodes": m, "accuracy": res.accuracy,
                     "seconds": res.wall_clock})
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig5_nodes.json"), "w") as f:
        json.dump(rows, f, indent=1)
    accs = [r["accuracy"] for r in rows]
    return {"rows": rows, "declines": accs[0] >= accs[-1] - 0.02}


if __name__ == "__main__":
    res = run()
    for r in res["rows"]:
        print(f"m={r['nodes']:3d}: acc={r['accuracy']:.3f}")
    print("accuracy declines with more nodes (paper Fig.5):", res["declines"])
