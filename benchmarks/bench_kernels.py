"""Round-fusion bench: the Pallas backend vs the reference XLA engines.

Writes BENCH_kernels.json — the CI gate behind `RunSpec(backend="pallas")`:

  * **reference_match_identical** — for every STREAMS scenario x engine
    (plus delay rings), a pallas run must match the reference run within
    the per-field tolerance contract of `docs/kernels.md` (correct /
    sparsity / eps_ledger bit-exact, float trajectories within the f32
    reduction-order bound). A kernel that drifts from the oracle fails CI.
  * **traffic_cut_speedup** — the analytic HBM-traffic advantage of the
    fused round body (array passes unfused / fused). On this CPU
    container the kernels execute in interpret mode (a correctness rig,
    orders of magnitude slower than compiled XLA), so the *measured*
    rounds/sec curve below is informational and the gated speedup is the
    machine-independent number that transfers to TPU.
  * **cost_error_ratio** — `repro.obs.cost`'s predicted-vs-measured
    roofline ratio for the pallas chunk program (informational; PR 9's
    predict-then-measure loop holding the fusion accountable).

    PYTHONPATH=src python -m benchmarks.bench_kernels            # CI scale
    PYTHONPATH=src python -m benchmarks.bench_kernels --smoke    # seconds
"""
from __future__ import annotations

import argparse
import json

import numpy as np

import repro.obs as obs
from repro.api import ExecConfig, RunSpec, run

# f32 reduction-order bound for float trajectories (same contract as
# tests/test_backends.py and docs/kernels.md); counts stay bit-exact.
FLOAT_BOUND = 5e-6
EXACT_FIELDS = ("correct", "sparsity", "eps_ledger")
FLOAT_FIELDS = ("final_w", "loss", "w_bar_loss")

# Analytic (m, n)-array passes over HBM per round.  Unfused XLA: prox
# (theta->w), margin (w, x), grad+clip write, tilde = theta + delta
# (theta, delta, tilde), mix (tilde gather, mixed), update (mixed, grad,
# theta_next) — ~15 passes.  Fused: stats pass reads (theta, x); update
# pass reads (theta, x, delta, recv) and writes (theta_next, tilde) — 8
# passes.  The ratio is the memory-bound headroom the kernel banks on TPU
# (see src/repro/kernels/pdomd_update.py for the per-op walk-through).
UNFUSED_PASSES = 15
FUSED_PASSES = 8


def _spec(m: int, n: int, horizon: int, *, stream: str = "drift",
          delay: int = 0, backend: str = "reference") -> RunSpec:
    options = {"period": 7} if stream == "drift" else {}
    return RunSpec(nodes=m, dim=n, horizon=horizon, eps=1.0, alpha0=0.5,
                   lam=0.01, stream=stream, stream_options=options,
                   mixer="sparse", mixer_options={"topology": "ring"},
                   delay=delay, backend=backend)


def _field_diffs(ref, pal) -> dict:
    diffs = {}
    for f in FLOAT_FIELDS + EXACT_FIELDS:
        a, b = np.asarray(getattr(ref, f)), np.asarray(getattr(pal, f))
        diffs[f] = float(np.abs(a - b).max()) if a.size else 0.0
    return diffs


def _match_checks(*, nodes: int, dim: int, horizon: int) -> list[dict]:
    """Pallas-vs-reference per-field equivalence over every STREAMS
    scenario x engine, plus the delay-ring and hybrid-mode paths."""
    cfg = ExecConfig(chunk_rounds=max(1, horizon // 2), compute_regret=False,
                     warmup=False)
    configs = [(stream, engine, 0, "auto")
               for stream in ("social_sparse", "drift", "heterogeneous",
                              "bursty")
               for engine in ("sim", "dist")]
    configs += [("drift", "sim", 2, "auto"), ("drift", "dist", 2, "auto"),
                ("drift", "sim", 0, "hybrid")]
    checks = []
    for stream, engine, delay, mode in configs:
        ref = run(_spec(nodes, dim, horizon, stream=stream, delay=delay),
                  engine=engine, exec=cfg)
        pspec = _spec(nodes, dim, horizon, stream=stream, delay=delay,
                      backend="pallas")
        if mode != "auto":
            pspec = pspec.replace(backend_options={"mode": mode})
        pal = run(pspec, engine=engine, exec=cfg)
        diffs = _field_diffs(ref, pal)
        ok = (all(diffs[f] <= FLOAT_BOUND for f in FLOAT_FIELDS)
              and all(diffs[f] == 0.0 for f in EXACT_FIELDS))
        checks.append({"stream": stream, "engine": engine, "delay": delay,
                       "mode": mode, "match": bool(ok),
                       "max_float_diff": max(diffs[f] for f in FLOAT_FIELDS)})
    return checks


def _timed(spec: RunSpec, horizon: int) -> float:
    """Steady-state rounds/sec (warmup compiles the first chunk outside the
    timed region; needs >= 2 chunks)."""
    res = run(spec, exec=ExecConfig(chunk_rounds=max(1, horizon // 2),
                                    compute_regret=False, warmup=True))
    return float(res.rounds_per_sec)


def _micro() -> list[dict]:
    """Seed-kernel micro rows (folded in from the pre-api kernels_bench):
    oracle us/call for the fused sub-kernels, plus each kernel's analytic
    traffic advantage — the TPU-transferable number on this CPU rig."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels import ref

    def clock(fn, *args, iters=10):
        out = fn(*args)
        jax.block_until_ready(out)
        t0 = time.time()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.time() - t0) / iters * 1e6

    rows = []
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    args = [jax.random.normal(k, (1024, 128)) for k in keys]
    jref = jax.jit(lambda *a: ref.pdomd_update_ref(
        *a, jnp.float32(0.05), jnp.float32(0.01), jnp.float32(0.5),
        jnp.float32(0.25)))
    rows.append({"name": "pdomd_update_oracle", "us": round(clock(jref, *args), 1),
                 "traffic_cut": round(7 / 6, 2)})
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    B, n = 512, 4096
    x = jax.random.normal(k1, (B, n)) / jnp.sqrt(n * 1.0)
    y = jnp.sign(jax.random.normal(k2, (B,)))
    w = jax.random.normal(k3, (n,))
    rows.append({"name": "hinge_grad_oracle",
                 "us": round(clock(jax.jit(ref.hinge_grad_ref), x, y, w), 1),
                 "traffic_cut": 2.0})
    return rows


def run_bench(*, nodes: int, dims: list[int], horizon: int,
              bench_path: str = "BENCH_kernels.json") -> dict:
    checks = _match_checks(nodes=nodes, dim=dims[0], horizon=horizon)
    reference_match = all(c["match"] for c in checks)
    print(f"  reference_match_identical={reference_match} "
          f"({len(checks)} configs)", flush=True)

    curve = []
    for n in dims:
        ref_rps = _timed(_spec(nodes, n, horizon), horizon)
        pal_rps = _timed(_spec(nodes, n, horizon, backend="pallas"), horizon)
        curve.append({
            "dim": n,
            "reference_rounds_per_sec": round(ref_rps, 1),
            "pallas_rounds_per_sec": round(pal_rps, 1),
            "measured_ratio": (round(pal_rps / ref_rps, 4)
                               if ref_rps > 0 else None),
        })
        print(f"  n={n}: reference {ref_rps:.1f} r/s  "
              f"pallas {pal_rps:.1f} r/s", flush=True)

    # the cost loop on the pallas chunk program (PR 9's accountability hook)
    tel = obs.Telemetry(cost=True)
    res = run(_spec(nodes, dims[0], horizon, backend="pallas"),
              exec=ExecConfig(chunk_rounds=max(1, horizon // 2),
                              compute_regret=False, warmup=True, obs=tel))
    cost = res.metrics.get("obs", {}).get("cost") or {}
    cost_error_ratio = cost.get("error_ratio")
    print(f"  cost.error_ratio={cost_error_ratio}", flush=True)

    bench = {
        "bench": "kernels_round_fusion",
        "nodes": nodes,
        "rounds": horizon,
        "interpret_mode": True,
        "reference_match_identical": bool(reference_match),
        "match_checks": checks,
        "curve": curve,
        "traffic_model": {
            "unfused_passes": UNFUSED_PASSES,
            "fused_passes": FUSED_PASSES,
            # the gated floor: the fused round body must keep its analytic
            # HBM-traffic advantage (machine-independent, unlike the
            # interpret-mode wall clocks above)
            "traffic_cut_speedup": round(UNFUSED_PASSES / FUSED_PASSES, 4),
        },
        "cost_error_ratio": cost_error_ratio,
        "micro": _micro(),
    }
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=2)
    print(f"  wrote {bench_path}")
    return bench


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale (seconds) — the CI bench-smoke entry")
    ap.add_argument("--bench-path", default="BENCH_kernels.json")
    args = ap.parse_args()
    if args.smoke:
        kw = dict(nodes=6, dims=[40, 160], horizon=8)
    else:
        kw = dict(nodes=8, dims=[64, 256, 1024], horizon=16)
    run_bench(**kw, bench_path=args.bench_path)


if __name__ == "__main__":
    main()
