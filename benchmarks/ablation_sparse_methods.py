"""Ablation: the paper's OMD+Lasso vs the two prior sparse-online-learning
families it cites (§I refs [11], [12]) under identical gossip + DP setting.

    PYTHONPATH=src python -m benchmarks.ablation_sparse_methods
"""
from __future__ import annotations

import json
import math
import os

import jax
import numpy as np

from benchmarks.common import Scale, final_accuracy, make_spec
from repro.data.social import SocialStream

# lambdas tuned per local rule (they threshold different quantities: w for
# tg, the running mean gradient for rda, theta for omd)
METHODS = {
    "omd (paper)": dict(local_rule="omd", lam=1.0),
    "truncated-gradient [11]": dict(local_rule="tg", lam=0.003),
    "rda [12]": dict(local_rule="rda", lam=0.001),
}


def run(scale: Scale | None = None, eps: float = math.inf,
        out_dir: str = "experiments/figures") -> dict:
    scale = scale or Scale()
    stream = SocialStream(n=scale.n, nodes=scale.m, rounds=scale.T,
                          sparsity_true=0.05, seed=0)
    xs, ys = stream.chunk(0, scale.T)
    rows = {}
    for name, kw in METHODS.items():
        alg = make_spec(scale, eps=eps, **kw).build_simulator()
        outs = alg.run(jax.random.PRNGKey(1), xs, ys)
        rows[name] = {
            "accuracy": final_accuracy(outs),
            "sparsity": float(np.asarray(outs.sparsity)[-50:].mean()),
        }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "ablation_sparse_methods.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    for name, r in run().items():
        print(f"{name:26s} acc={r['accuracy']:.3f} sparsity={r['sparsity']:.3f}")
