"""Ablation: the paper's OMD+Lasso vs the two prior sparse-online-learning
families it cites (§I refs [11], [12]) under identical gossip + DP setting.

One zipped sweep axis pairs each local rule with its tuned lambda (they
threshold different quantities: w for tg, the running mean gradient for
rda, theta for omd); `repro.sweep` drives the seeds and persists the
records (``from_store=True`` regenerates without re-running).

    PYTHONPATH=src python -m benchmarks.ablation_sparse_methods
"""
from __future__ import annotations

import json
import math
import os

import numpy as np

from benchmarks.common import SEEDS, Scale, figure_sweep

# (registry name, tuned lambda, display label)
METHODS = (
    ("omd", 1.0, "omd (paper)"),
    ("tg", 0.003, "truncated-gradient [11]"),
    ("rda", 0.001, "rda [12]"),
)


def run(scale: Scale | None = None, eps: float = math.inf,
        out_dir: str = "experiments/figures", seeds: tuple = SEEDS,
        from_store: bool = False) -> dict:
    scale = scale or Scale()
    axis = tuple((rule, lam) for rule, lam, _ in METHODS)
    out = figure_sweep("ablation_sparse_methods", scale,
                       {"local_rule,lam": axis}, seeds=seeds,
                       from_store=from_store, compute_regret=False, eps=eps)
    labels = {rule: label for rule, _, label in METHODS}
    rows = {}
    for point, results in zip(out.points, out.results):
        accs = np.asarray([r.accuracy for r in results])
        spars = np.asarray([float(np.asarray(r.sparsity)[-50:].mean())
                            for r in results])
        rows[labels[point.coords["local_rule"]]] = {
            "accuracy": float(accs.mean()),
            "accuracy_std": float(accs.std()),
            "sparsity": float(spars.mean()),
            "sparsity_std": float(spars.std()),
            "seeds": list(seeds),
        }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "ablation_sparse_methods.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    for name, r in run().items():
        print(f"{name:26s} acc={r['accuracy']:.3f}±{r['accuracy_std']:.3f} "
              f"sparsity={r['sparsity']:.3f}")
