"""Ablation: the paper's OMD+Lasso vs the two prior sparse-online-learning
families it cites (§I refs [11], [12]) under identical gossip + DP setting.

    PYTHONPATH=src python -m benchmarks.ablation_sparse_methods
"""
from __future__ import annotations

import json
import math
import os

import numpy as np

from benchmarks.common import Scale, run_algorithm1

# lambdas tuned per local rule (they threshold different quantities: w for
# tg, the running mean gradient for rda, theta for omd)
METHODS = {
    "omd (paper)": dict(local_rule="omd", lam=1.0),
    "truncated-gradient [11]": dict(local_rule="tg", lam=0.003),
    "rda [12]": dict(local_rule="rda", lam=0.001),
}


def run(scale: Scale | None = None, eps: float = math.inf,
        out_dir: str = "experiments/figures") -> dict:
    scale = scale or Scale()
    rows = {}
    for name, kw in METHODS.items():
        res = run_algorithm1(scale, eps=eps, compute_regret=False, **kw)
        rows[name] = {
            "accuracy": res.accuracy,
            "sparsity": float(np.asarray(res.sparsity)[-50:].mean()),
        }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "ablation_sparse_methods.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    for name, r in run().items():
        print(f"{name:26s} acc={r['accuracy']:.3f} sparsity={r['sparsity']:.3f}")
