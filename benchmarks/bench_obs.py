"""Telemetry bench: the ``obs_off_identical`` bit-identity gate, the
telemetry overhead ceiling, and the predicted-vs-measured chunk cost.

Three properties of `repro.obs` are measured and gated here:

  * **Bit-identity**: a run with full telemetry (spans + metrics + event
    stream + cost loop) must be bit-identical to a run with telemetry off —
    for ``run()`` under both engines and for the vmapped ``run_batch()``.
    Telemetry is host-side observation only; any drift means it leaked into
    the device math (``obs_off_identical``, also pinned in
    tests/test_obs.py).
  * **Overhead**: wall-clock of a fully-instrumented run over the
    uninstrumented one (min over repeats, compile excluded via warmup) —
    ``overhead_ratio``, gated as a ceiling in check_bench so spans on the
    chunk path cannot quietly eat the throughput the runner benches report.
  * **Cost loop**: `repro.obs.cost.analyze_chunk`'s roofline prediction for
    the jitted chunk program vs the measured chunk wall-clock
    (``cost.error_ratio``) — recorded per run as the drift signal the
    ROADMAP's predict-then-measure loop asks for (informational: the ratio
    is machine-dependent, so it is written, not gated).

    PYTHONPATH=src python -m benchmarks.bench_obs [--smoke]

Writes BENCH_obs.json plus a sample Chrome ``trace.json`` and the run-event
stream; benchmarks/check_bench.py gates ``obs_off_identical`` (bool) and
``overhead_ratio`` (ceiling) against the committed baseline.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

import repro.obs as obs
from repro.api import ExecConfig, RunSpec, run, run_batch

FIELDS = ("final_w", "loss", "correct", "w_bar_loss", "sparsity")


def _spec(m: int, *, dim: int, horizon: int) -> RunSpec:
    return RunSpec(nodes=m, dim=dim, horizon=horizon, eps=1.0, alpha0=0.5,
                   lam=0.01, stream="drift", stream_options={"period": 7},
                   mixer="sparse", mixer_options={"topology": "ring"})


def _bit_identical(a, b) -> bool:
    return all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f)))
               for f in FIELDS)


def _identity_checks(spec: RunSpec, *, chunk_rounds: int,
                     events_path: str) -> tuple[list[dict], dict]:
    """Telemetry-on vs telemetry-off runs over every driving path; the ON
    runs carry the full stack (spans + metrics + events + cost loop)."""
    cfg = ExecConfig(chunk_rounds=chunk_rounds, compute_regret=True,
                     warmup=True)
    checks = []
    on_metrics = {}
    for engine in ("sim", "dist"):
        off = run(spec, engine=engine, exec=cfg)
        tel = obs.Telemetry(events=events_path, cost=True)
        on = run(spec, engine=engine, exec=cfg.replace(obs=tel))
        tel.close()
        checks.append({"path": "run", "engine": engine,
                       "identical": _bit_identical(off, on)})
        on_metrics[engine] = on.metrics.get("obs", {})
    seeds = [0, 1]
    off_b = run_batch(spec, seeds, engine="sim", exec=cfg)
    tel = obs.Telemetry(events=events_path, cost=True)
    on_b = run_batch(spec, seeds, engine="sim", exec=cfg.replace(obs=tel))
    tel.close()
    checks.append({"path": "run_batch", "engine": "sim",
                   "identical": all(_bit_identical(o, n)
                                    for o, n in zip(off_b, on_b))})
    on_metrics["run_batch"] = on_b[0].metrics.get("obs", {})
    return checks, on_metrics


def _overhead(spec: RunSpec, *, chunk_rounds: int, repeats: int) -> dict:
    """min-over-repeats wall of a fully-instrumented run vs an
    uninstrumented one (warmup excludes compile from both)."""
    cfg = ExecConfig(chunk_rounds=chunk_rounds, compute_regret=False,
                     warmup=True)
    wall_off = min(float(run(spec, exec=cfg).wall_clock)
                   for _ in range(repeats))
    walls_on = []
    for _ in range(repeats):
        tel = obs.Telemetry(cost=True)    # spans + metrics + cost, no I/O —
        walls_on.append(float(run(spec, exec=cfg.replace(obs=tel))
                              .wall_clock))
    wall_on = min(walls_on)               # the steady-state per-chunk tax
    return {
        "wall_off_s": round(wall_off, 6),
        "wall_on_s": round(wall_on, 6),
        "overhead_ratio": (round(wall_on / wall_off, 4)
                           if wall_off > 0 else None),
    }


def run_bench(*, nodes: int, dim: int, horizon: int, chunk_rounds: int,
              repeats: int,
              bench_path: str = "BENCH_obs.json",
              trace_path: str = "trace.json",
              events_path: str = "obs_events.jsonl") -> dict:
    spec = _spec(nodes, dim=dim, horizon=horizon)
    if os.path.exists(events_path):
        os.remove(events_path)

    checks, on_metrics = _identity_checks(spec, chunk_rounds=chunk_rounds,
                                          events_path=events_path)
    obs_off_identical = all(c["identical"] for c in checks)
    print(f"  obs_off_identical={obs_off_identical} "
          f"({len(checks)} paths)", flush=True)

    overhead = _overhead(spec, chunk_rounds=chunk_rounds, repeats=repeats)
    print(f"  overhead_ratio={overhead['overhead_ratio']} "
          f"(off={overhead['wall_off_s']}s on={overhead['wall_on_s']}s)",
          flush=True)

    # sample trace: one fully-instrumented run, exported for the CI artifact
    tel = obs.Telemetry(events=events_path, cost=True)
    res = run(spec, engine="sim",
              exec=ExecConfig(obs=tel, chunk_rounds=chunk_rounds,
                              compute_regret=True, warmup=True))
    tel.export_chrome(trace_path)
    span_summary = tel.tracer.summary()
    tel.close()
    cost = res.metrics.get("obs", {}).get("cost")
    events = obs.read_events(events_path)
    print(f"  cost.error_ratio="
          f"{None if cost is None else cost.get('error_ratio')} "
          f"trace_spans={len(tel.tracer.spans)} events={len(events)}",
          flush=True)

    bench = {
        "bench": "obs_telemetry",
        "nodes": nodes,
        "dim": dim,
        "rounds": horizon,
        "chunk_rounds": chunk_rounds,
        "obs_off_identical": obs_off_identical,
        "identity_checks": checks,
        **overhead,
        "cost": cost,
        "cost_by_path": {k: v.get("cost") for k, v in on_metrics.items()},
        "span_summary": span_summary,
        "events_emitted": len(events),
        "event_kinds": sorted({e["event"] for e in events}),
        "trace_path": trace_path,
    }
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1)
    if not obs_off_identical:
        bad = [c for c in checks if not c["identical"]]
        raise AssertionError(
            f"telemetry-on runs are not bit-identical to telemetry-off for "
            f"{bad} — repro.obs leaked into the device math")
    return bench


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale (seconds) for the CI jobs")
    ap.add_argument("--bench-path", default="BENCH_obs.json")
    ap.add_argument("--trace-path", default="trace.json")
    ap.add_argument("--events-path", default="obs_events.jsonl")
    args = ap.parse_args()
    if args.smoke:
        kw = dict(nodes=8, dim=8, horizon=48, chunk_rounds=8, repeats=3)
    else:
        kw = dict(nodes=16, dim=16, horizon=512, chunk_rounds=32, repeats=5)
    bench = run_bench(bench_path=args.bench_path, trace_path=args.trace_path,
                      events_path=args.events_path, **kw)
    print(f"obs_off_identical={bench['obs_off_identical']} "
          f"overhead_ratio={bench['overhead_ratio']} "
          f"cost_error_ratio="
          f"{None if bench['cost'] is None else bench['cost']['error_ratio']}")


if __name__ == "__main__":
    main()
