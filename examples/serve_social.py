"""Predict-while-learning example: bursty replay against a live service.

    PYTHONPATH=src python examples/serve_social.py [--ticks 64]

Stands up the `repro.serve` loop (background gossip trainer + admission/
batching front end), replays the `bursty` stream's heavy-tailed arrivals
against it, verifies one served response bit-identically against a fresh
reference run, and prints the latency/QPS/staleness summary. The full CLI
(budget refusal demo, JSON output) is `python -m repro.launch.serve`.
"""
import argparse

from repro.launch.serve import serve_social


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=64)
    ap.add_argument("--chunk-rounds", type=int, default=8)
    args = ap.parse_args()
    out = serve_social(nodes=4, dim=16, horizon=96, eps=10.0,
                       chunk_rounds=args.chunk_rounds, max_batch=8,
                       max_wait_ms=0.5, ticks=args.ticks, warmup=False)
    rep, adm = out["replay"], out["admission"]
    print(f"{rep['served']}/{rep['submitted']} served ({rep['shed']} shed) "
          f"at {rep['qps']:.0f} qps; latency p50={adm['p50_latency_ms']}ms "
          f"p99={adm['p99_latency_ms']}ms; verified bit-identical: "
          f"{out['snapshot_identical']}")


if __name__ == "__main__":
    main()
