"""End-to-end paper reproduction driver: the §V simulation at configurable
scale, producing all four figure datasets.

    PYTHONPATH=src python examples/private_social_training.py           # CI scale
    PYTHONPATH=src python examples/private_social_training.py --paper   # n=10k, m=64, 100k samples
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks/
from benchmarks import fig2_privacy, fig3_topology, fig4_sparsity, fig5_nodes
from benchmarks.common import Scale


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="paper-scale (100,000 samples, n=10,000, m=64)")
    ap.add_argument("--out", default="experiments/figures")
    args = ap.parse_args()
    scale = Scale.paper() if args.paper else Scale()

    print(f"scale: n={scale.n} m={scale.m} T={scale.T}")
    print("\n[Fig 2] privacy level vs regret")
    r2 = fig2_privacy.run(scale, out_dir=args.out)
    for eps, row in r2["rows"].items():
        print(f"  eps={eps:>5s}: regret={row['regret_final']:12.1f} acc={row['accuracy']:.3f}")
    print("  ordering holds:", r2["ordering_holds"])

    print("\n[Fig 3] topology invariance")
    r3 = fig3_topology.run(scale, out_dir=args.out)
    for topo, row in r3["rows"].items():
        print(f"  {topo:14s}: acc={row['accuracy']:.3f}")
    print(f"  spread={r3['spread']:.3f}")

    print("\n[Fig 4] sparsity sweep")
    r4 = fig4_sparsity.run(scale, out_dir=args.out)
    for row in r4["rows"]:
        print(f"  lam={row['lambda']:7.3f} sparsity={row['sparsity']:.3f} acc={row['accuracy']:.3f}")
    print("  interior optimum:", r4["interior_best"])

    print("\n[Fig 5] node-count sweep")
    r5 = fig5_nodes.run(scale, out_dir=args.out)
    for row in r5["rows"]:
        print(f"  m={row['nodes']:3d}: acc={row['accuracy']:.3f}")
    print("  declines with m:", r5["declines"])
    print(f"\nfigure data written to {args.out}/")


if __name__ == "__main__":
    main()
