"""Quickstart: the paper's Algorithm 1 in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Runs private distributed online learning (8 simulated data centers, ring
gossip, Laplace DP, Lasso sparsity) on a synthetic social-data stream and
prints the regret/accuracy trajectory — then shows the SAME declarative
`RunSpec` building the algorithm as a framework distribution strategy
(GossipDP) doing one distributed round.
"""
import math

import jax
import jax.numpy as jnp

from repro.api import RunSpec
from repro.core.regret import cumulative_regret
from repro.data.social import SocialStream

# --- 1. the paper's simulation -------------------------------------------
m, n, T = 8, 256, 800
stream = SocialStream(n=n, nodes=m, rounds=T, sparsity_true=0.05, seed=0)
xs, ys = stream.chunk(0, T)

spec = RunSpec(
    nodes=m, dim=n,
    mixer="ring",                       # data-center network (MIXERS registry)
    mechanism="laplace", eps=1.0,       # eps-DP broadcast (MECHANISMS registry)
    calibration="coordinate",
    local_rule="omd", lam=1e-2,         # OMD + Lasso (LOCAL_RULES registry)
    clipper="l2", clip_norm=1.0,        # Assumption 2.3 (CLIPPERS registry)
    alpha0=1.0, schedule="sqrt_t",
)
alg = spec.build_simulator()
outs = alg.run(jax.random.PRNGKey(0), xs, ys)
reg = cumulative_regret(outs.w_bar_loss, xs, ys, m)

print("Private distributed online learning (paper Algorithm 1)")
print(f"  nodes={m} dim={n} rounds={T} eps={spec.eps} topology={spec.mixer}")
for t in (100, 400, T - 1):
    acc = float(outs.correct[max(0, t - 100): t].mean())
    print(f"  t={t:4d}: cumulative regret={reg[t]:10.1f}  acc(last100)={acc:.3f}  "
          f"sparsity={float(outs.sparsity[t]):.3f}")

outs_np = spec.replace(eps=math.inf).build_simulator().run(jax.random.PRNGKey(0), xs, ys)
print(f"  non-private final acc: {float(outs_np.correct[-100:].mean()):.3f} "
      f"(privacy cost = {float(outs_np.correct[-100:].mean() - outs.correct[-100:].mean()):.3f})")

# --- 2. the SAME RunSpec as a framework distribution strategy -------------
gdp = spec.replace(alpha0=0.5, lam=1e-3).build_distributed()
params = {"w": jnp.zeros((m, n))}          # any pytree works — here a linear model
state = gdp.init(params, jax.random.PRNGKey(1))
grads = {"w": jax.random.normal(jax.random.PRNGKey(2), (m, n))}
state, metrics = gdp.update(state, grads)  # clip -> noise -> gossip -> OMD -> prox
print("\nGossipDP framework round:", {k: round(float(v), 4) for k, v in metrics.items()})
print("On a TPU mesh the same update lowers to collective-permute on the ICI "
      "ring — see repro/launch/dryrun.py")
