"""Quickstart: the paper's Algorithm 1 in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Runs private distributed online learning (8 simulated data centers, ring
gossip, Laplace DP, Lasso sparsity) on a synthetic social-data stream with
ONE `repro.api.run` call — regret trajectory, accuracy, sparsity and the
privacy ledger all come back in the RunResult — then shows the SAME
declarative `RunSpec` driving the distributed engine (`GossipDP`)
bit-identically, and doing one raw framework round on an arbitrary pytree.
"""
import math

import jax
import jax.numpy as jnp

from repro.api import RunSpec, run

# --- 1. the paper's simulation -------------------------------------------
m, n, T = 8, 256, 800
spec = RunSpec(
    nodes=m, dim=n, horizon=T,
    stream="social_sparse",             # data scenario (STREAMS registry)
    mixer="ring",                       # data-center network (MIXERS registry)
    mechanism="laplace", eps=1.0,       # eps-DP broadcast (MECHANISMS registry)
    calibration="coordinate",
    local_rule="omd", lam=1e-2,         # OMD + Lasso (LOCAL_RULES registry)
    clipper="l2", clip_norm=1.0,        # Assumption 2.3 (CLIPPERS registry)
    alpha0=1.0, schedule="sqrt_t",
)
res = run(spec, engine="sim")

print("Private distributed online learning (paper Algorithm 1)")
print(f"  nodes={m} dim={n} rounds={T} eps={spec.eps} topology={spec.mixer} "
      f"stream={spec.stream}")
for t in (100, 400, T - 1):
    acc = float(res.correct[max(0, t - 100): t].mean())
    print(f"  t={t:4d}: cumulative regret={res.regret[t]:10.1f}  "
          f"acc(last100)={acc:.3f}  sparsity={float(res.sparsity[t]):.3f}")
print(f"  privacy ledger: {res.privacy['eps_total']} eps total over "
      f"{res.privacy['rounds']} rounds ({res.privacy['composition']})")

res_np = run(spec.replace(eps=math.inf), engine="sim")
print(f"  non-private final acc: {res_np.accuracy:.3f} "
      f"(privacy cost = {res_np.accuracy - res.accuracy:.3f})")

# --- 2. the SAME RunSpec on the distributed engine ------------------------
dist = run(spec, engine="dist")
print(f"\nDistributed engine, same seed: final acc {dist.accuracy:.3f}, "
      f"iterates bit-identical: {(dist.final_w == res.final_w).all()}")

# --- 3. GossipDP as a raw framework strategy ------------------------------
gdp = spec.replace(alpha0=0.5, lam=1e-3).build_distributed()
params = {"w": jnp.zeros((m, n))}          # any pytree works — here a linear model
state = gdp.init(params, jax.random.PRNGKey(1))
grads = {"w": jax.random.normal(jax.random.PRNGKey(2), (m, n))}
state, metrics = gdp.update(state, grads)  # clip -> noise -> gossip -> OMD -> prox
print("\nGossipDP framework round:", {k: round(float(v), 4) for k, v in metrics.items()})
print("On a TPU mesh the same update lowers to collective-permute on the ICI "
      "ring — see repro/launch/dryrun.py")
