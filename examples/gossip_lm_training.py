"""Beyond-paper: train a ~100M-parameter transformer with the paper's
private gossip strategy — the technique as a first-class distribution
strategy for modern architectures.

    PYTHONPATH=src python examples/gossip_lm_training.py --steps 200

Uses a 4-node gossip ring over a qwen2-style dense LM (~100M params at this
width) on the synthetic Markov token stream; compares private vs non-private
vs all-reduce-baseline loss trajectories for the same token budget.
"""
import argparse
import math

import numpy as np

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--local-rule", default="omd",
                    help="repro.api LOCAL_RULES registry name for the gossip runs")
    args = ap.parse_args()

    runs = {
        "gossip eps=inf": dict(strategy="gossip", eps=math.inf,
                               local_rule=args.local_rule),
        "gossip eps=1.0": dict(strategy="gossip", eps=1.0,
                               local_rule=args.local_rule),
        "allreduce adamw": dict(strategy="allreduce"),
    }
    results = {}
    for name, kw in runs.items():
        print(f"\n=== {name} ===")
        res = train(args.arch, nodes=args.nodes, steps=args.steps,
                    batch_per_node=2, seq_len=128, lam=1e-5, smoke=True, **kw)
        ce = [h["ce"] for h in res["history"]]
        results[name] = ce
        print(f"  ce: start={np.mean(ce[:5]):.3f} end={np.mean(ce[-5:]):.3f}")

    print("\nsummary (lower is better):")
    for name, ce in results.items():
        print(f"  {name:18s} final ce {np.mean(ce[-5:]):.3f}  "
              f"improvement {np.mean(ce[:5]) - np.mean(ce[-5:]):+.3f}")


if __name__ == "__main__":
    main()
