"""Batched serving example: prefill + greedy decode across architectures,
including SSM (O(1) state) and sliding-window archs.

    PYTHONPATH=src python examples/serve_batched.py --archs qwen2-7b rwkv6-3b
"""
import argparse

from repro.launch.serve_lm import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="+",
                    default=["qwen2-7b", "rwkv6-3b", "mixtral-8x7b"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()
    for arch in args.archs:
        serve(arch, batch=args.batch, prompt_len=16, gen=args.gen,
              cache_len=64, smoke=True)


if __name__ == "__main__":
    main()
