"""Degradation metrics — how much did the faults actually cost?

`degradation` compares a faulty `RunResult` against its clean twin (same
RunSpec minus the faults) and reports the regret / loss / accuracy gaps
plus the connectivity profile the faulty run recorded.
`rounds_to_recover` measures healing after a transient partition: how many
rounds past the heal point until the faulty trajectory re-enters (and
stays within) a tolerance band around the clean one.

>>> from repro.faults.metrics import rounds_to_recover
>>> rounds_to_recover([0., 0., 0., 0.], [1., 1., 0., 0.], heal_round=1)
1
>>> rounds_to_recover([0., 0., 0.], [1., 1., 1.], heal_round=0)
-1
"""
from __future__ import annotations

import numpy as np

__all__ = ["degradation", "rounds_to_recover"]


def degradation(clean, faulty) -> dict:
    """Clean-vs-faulty gap metrics from two `RunResult`s (same spec shape).

    Keys: ``regret_gap`` (final faulty - clean regret, None when either run
    skipped regret), ``loss_gap`` (mean per-round loss delta),
    ``accuracy_drop``, and ``mean_connectivity`` / ``min_connectivity``
    from the faulty run's per-round connectivity trace (None when the run
    carried no fault schedule).
    """
    out = {
        "loss_gap": float(np.mean(faulty.loss) - np.mean(clean.loss)),
        "accuracy_drop": float(clean.accuracy - faulty.accuracy),
        "regret_gap": None,
        "mean_connectivity": None,
        "min_connectivity": None,
    }
    if clean.regret is not None and faulty.regret is not None:
        out["regret_gap"] = float(faulty.regret[-1] - clean.regret[-1])
    conn = getattr(faulty, "connectivity", None)
    if conn is not None and np.asarray(conn).size:
        conn = np.asarray(conn, np.float64)
        out["mean_connectivity"] = float(conn.mean())
        out["min_connectivity"] = float(conn.min())
    return out


def rounds_to_recover(clean_curve, faulty_curve, heal_round: int,
                      tol: float = 1e-3, window: int = 4) -> int:
    """Rounds after ``heal_round`` until ``|faulty - clean| <= tol`` holds
    for ``window`` consecutive rounds (-1 if the curves never re-join).

    Feed it per-round trajectories of the same metric — ``w_bar_loss`` is
    the natural choice since it tracks the consensus iterate the partition
    disturbs.
    """
    a = np.asarray(clean_curve, np.float64).ravel()
    b = np.asarray(faulty_curve, np.float64).ravel()
    if a.shape != b.shape:
        raise ValueError(f"curve lengths differ: {a.shape} vs {b.shape}")
    diff = np.abs(a - b)
    for t in range(max(int(heal_round), 0), diff.size):
        if (diff[t:min(t + int(window), diff.size)] <= tol).all():
            return t - int(heal_round)
    return -1
