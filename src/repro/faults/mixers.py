"""Faulty mixers — lift any registered mixer onto an unreliable fabric.

`wrap_mixer(mixer, schedule)` returns a mixer that applies the schedule's
per-round link drops, partitions, crash masks and straggler lags while
keeping the mixing matrix ROW-stochastic every round (the time-varying-
graph condition the gossip regret analysis needs). Renormalization is
*self-healing*: each off-diagonal edge keeps ``w * keep(t)`` and the
dropped mass ``w * (1 - keep(t))`` folds onto the destination row's
self-loop — a node that hears from fewer neighbors leans on its own state,
no division anywhere.

That formulation is also what makes the ``zero_fault_identical`` gate
non-vacuous: at all-zero rates the keep mask is exactly 1.0 (the uniform
draw still happens — see `FaultSchedule.link_keep`), so every effective
weight is ``w * 1.0`` and every healed term is ``+ 0.0`` — bit-identical
to the clean mixer's arithmetic, while still executing the full fault
machinery under jit/scan.

Symmetry: link drops are drawn per undirected LINK (`link_table`), so a
symmetric input graph keeps ``A_eff[i, j] == A_eff[j, i]`` off the
diagonal at every round. Column stochasticity is intentionally given up
under faults (only row sums are required for consensus-style mixing).

>>> import jax.numpy as jnp
>>> from repro.api.mixers import MIXERS
>>> from repro.faults import FaultSpec, wrap_mixer
>>> clean = MIXERS.build("sparse", m=4, topology="ring")
>>> fm = wrap_mixer(MIXERS.build("sparse", m=4, topology="ring"),
...                 FaultSpec().compile(m=4))
>>> x = jnp.arange(8.0).reshape(4, 2)
>>> bool((fm.apply(x, 0) == clean.apply(x, 0)).all())   # zero-rate contract
True
>>> sched = FaultSpec(link_rate=0.9, seed=3).compile(m=4)
>>> A = wrap_mixer(MIXERS.build("sparse", m=4, topology="ring"),
...                sched).apply(jnp.eye(4), 5)
>>> bool(jnp.allclose(A.sum(axis=1), 1.0))              # row-stochastic
True
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.mixers import (AlternatingRingMixer, CompleteMixer,
                              DelayedMixer, DenseMatrixMixer,
                              DisconnectedMixer, HeterogeneousDelayMixer,
                              MixerBase, RingRollMixer, SparseMixer, _bcast,
                              ring_read)
from repro.api.shard_node import NodePartition, ShardedSparseMixer
from repro.faults.schedule import FaultSchedule, edge_link_idx, link_table

__all__ = ["FaultySparseMixer", "FaultyDenseMixer",
           "FaultyShardedSparseMixer", "wrap_mixer"]


class FaultySparseMixer(MixerBase):
    """SparseMixer under a FaultSchedule: per-round edge keeps + healing.

    Requires a stored self-loop on every node — that is where the dropped
    off-diagonal mass heals to (all standard topologies store one).
    """

    def __init__(self, inner: SparseMixer, schedule: FaultSchedule,
                 delay: int = 0):
        g = inner.graph
        if int(g.m) != int(schedule.m):
            raise ValueError(f"mixer has m={g.m} nodes but the fault "
                             f"schedule was compiled for m={schedule.m}")
        self.inner = inner
        self.schedule = schedule
        self.m = int(g.m)
        self.base_delay = int(delay)
        self.delay = int(delay) + schedule.max_extra
        self.name = f"faulty[{inner.name}]"

        dst = np.asarray(g.dst, np.int64)
        src = np.asarray(g.src, np.int64)
        loops = dst == src
        if np.unique(dst[loops]).size != self.m:
            raise ValueError(
                f"fault injection needs a self-loop on every node (dropped "
                f"edge mass heals onto the diagonal) but topology "
                f"{inner.name!r} stores only {np.unique(dst[loops]).size} "
                f"of {self.m}")
        uniq, self.num_links = link_table(dst, src, self.m)
        self._uniq_pairs = uniq
        idx, _ = edge_link_idx(uniq, dst, src, self.m)
        self._link_idx = jnp.asarray(idx)
        self._is_loop = jnp.asarray(loops)
        self._loop_f = jnp.asarray(loops.astype(np.float32))
        self._dst = inner._dst
        self._src = inner._src
        self._w = inner._w
        self._crossings = tuple(
            (jnp.asarray((((dst < cut) != (src < cut)) & ~loops)
                         .astype(np.float32)), int(start), int(end))
            for start, end, cut in schedule.partitions)
        # straggler delay classes: edges grouped by their SOURCE node's lag
        extra = schedule.extra
        classes = sorted({int(v) for v in extra[src[~loops]]}) \
            if schedule.max_extra else []
        self._classes = tuple(
            (lag, jnp.asarray(((extra[src] == lag) & ~loops)
                              .astype(np.float32)))
            for lag in classes)

    def _edge_keep(self, t) -> jax.Array:
        """(E,) keep in [0, 1] per stored edge; self-loops are always 1."""
        sched = self.schedule
        keep = sched.link_keep(t, self.num_links)[self._link_idx]
        if sched.has_crashes:
            # a crashed SOURCE sends nothing; the destination row heals
            keep = keep * sched.alive_f32(t)[self._src]
        for cross, start, end in self._crossings:
            # t may be a traced scalar (scan) or a concrete python int
            in_w = jnp.asarray((t >= start) & (t < end), jnp.float32)
            keep = keep * (1.0 - cross * in_w)
        return jnp.where(self._is_loop, 1.0, keep)

    def _weights(self, t) -> tuple[jax.Array, jax.Array]:
        """(effective edge weights, healed diagonal mass) for round t."""
        keep = self._edge_keep(t)
        dropped = self._w * (1.0 - keep)
        healed = jax.ops.segment_sum(dropped, self._dst,
                                     num_segments=self.m,
                                     indices_are_sorted=True)
        w_eff = self._w * keep + self._loop_f * healed[self._dst]
        return w_eff, healed

    def apply(self, x, t):
        w_eff, _ = self._weights(t)
        w = w_eff.reshape((-1,) + (1,) * (x.ndim - 1))
        vals = w * x[self._src].astype(jnp.float32)
        out = jax.ops.segment_sum(vals, self._dst, num_segments=self.m,
                                  indices_are_sorted=True)
        return out.astype(x.dtype)

    def diag(self, t):
        _, healed = self._weights(t)
        return self.inner._diag + healed

    def mix_history(self, clean, tilde, hist, noise_self, t):
        # without stragglers every neighbor shares one lag — MixerBase's
        # ring-read algebra applies verbatim (and bit-identically)
        if not self._classes:
            return super().mix_history(clean, tilde, hist, noise_self, t)
        if hist is None:
            raise ValueError(
                f"{type(self).__name__} declares delay={self.delay} but no "
                "history ring was provided (engine state missing .history)")
        w_eff, healed = self._weights(t)
        self_term = tilde if noise_self else clean
        out = _bcast(self.inner._diag + healed, tilde) * self_term
        for lag, cls in self._classes:
            recv = ring_read(hist, t, self.base_delay + lag, tilde)
            w = (w_eff * cls).reshape((-1,) + (1,) * (tilde.ndim - 1))
            vals = w * recv[self._src].astype(jnp.float32)
            out = out + jax.ops.segment_sum(
                vals, self._dst, num_segments=self.m,
                indices_are_sorted=True).astype(tilde.dtype)
        return out

    def connectivity(self, rounds: int) -> np.ndarray:
        """(rounds,) fraction of off-diagonal weight delivered per round
        (1.0 = the clean graph; a partition window shows as a dip)."""
        offdiag = self._w * (1.0 - self._loop_f)
        denom = jnp.sum(offdiag)

        def frac(t):
            surv = jnp.sum(self._w * self._edge_keep(t)
                           * (1.0 - self._loop_f))
            return jnp.where(denom > 0, surv / denom, 1.0)

        return np.asarray(jax.jit(jax.vmap(frac))(jnp.arange(rounds)))


class FaultyDenseMixer(MixerBase):
    """DenseMatrixMixer under a FaultSchedule (time-varying stacks too).

    Same healing algebra as the sparse form, in dense coordinates:
    ``A_eff = A * K(t) + diag(rowsum(A * (1 - K(t))))`` with K == 1 on the
    diagonal, so rows stay stochastic and zero rates are bit-identical.
    """

    def __init__(self, inner: DenseMatrixMixer, schedule: FaultSchedule,
                 delay: int = 0):
        if int(inner.m) != int(schedule.m):
            raise ValueError(f"mixer has m={inner.m} nodes but the fault "
                             f"schedule was compiled for m={schedule.m}")
        self.inner = inner
        self.schedule = schedule
        self.m = int(inner.m)
        self.base_delay = int(delay)
        self.delay = int(delay) + schedule.max_extra
        self.name = f"faulty[{inner.name}]"

        support = (np.asarray(inner.stack) > 0).any(axis=0)
        np.fill_diagonal(support, False)
        dst, src = np.nonzero(support)
        uniq, self.num_links = link_table(dst, src, self.m)
        self._uniq_pairs = uniq
        idx, _ = edge_link_idx(uniq, dst, src, self.m)
        L = np.zeros((self.m, self.m), np.int32)
        L[dst, src] = idx
        self._link_idx = jnp.asarray(L)
        self._has_link = jnp.asarray(support)
        self._eye = jnp.eye(self.m, dtype=jnp.float32)
        offdiag = ~np.eye(self.m, dtype=bool)
        self._offdiag = jnp.asarray(offdiag)
        self._crossings = tuple(
            (jnp.asarray((((np.arange(self.m)[:, None] < cut)
                           != (np.arange(self.m)[None, :] < cut)) & offdiag)
                         .astype(np.float32)), int(start), int(end))
            for start, end, cut in schedule.partitions)
        extra = schedule.extra
        classes = sorted({int(v) for v in extra}) if schedule.max_extra \
            else []
        self._classes = tuple(
            (lag, jnp.asarray(((extra[None, :] == lag) & offdiag)
                              .astype(np.float32)))
            for lag in classes)

    def _keep_mat(self, t) -> jax.Array:
        """(m, m) keep matrix; diagonal and non-edges are exactly 1."""
        sched = self.schedule
        keep = jnp.where(self._has_link,
                         sched.link_keep(t, self.num_links)[self._link_idx],
                         1.0)
        if sched.has_crashes:
            alive = sched.alive_f32(t)
            keep = keep * jnp.where(self._offdiag, alive[None, :], 1.0)
        for cross, start, end in self._crossings:
            # t may be a traced scalar (scan) or a concrete python int
            in_w = jnp.asarray((t >= start) & (t < end), jnp.float32)
            keep = keep * (1.0 - cross * in_w)
        return keep

    def _effective(self, t) -> tuple[jax.Array, jax.Array]:
        A = self.inner.stack[t % self.inner.stack.shape[0]]
        keep = self._keep_mat(t)
        healed = jnp.sum(A * (1.0 - keep), axis=1)
        return A * keep + healed[:, None] * self._eye, healed

    def apply(self, x, t):
        A_eff, _ = self._effective(t)
        return jnp.tensordot(A_eff, x.astype(A_eff.dtype),
                             axes=1).astype(x.dtype)

    def diag(self, t):
        _, healed = self._effective(t)
        return self.inner.diag(t) + healed

    def mix_history(self, clean, tilde, hist, noise_self, t):
        if not self._classes:
            return super().mix_history(clean, tilde, hist, noise_self, t)
        if hist is None:
            raise ValueError(
                f"{type(self).__name__} declares delay={self.delay} but no "
                "history ring was provided (engine state missing .history)")
        A_eff, healed = self._effective(t)
        self_term = tilde if noise_self else clean
        out = _bcast(self.inner.diag(t) + healed, tilde) * self_term
        for lag, cls in self._classes:
            recv = ring_read(hist, t, self.base_delay + lag, tilde)
            Ad = A_eff * cls
            out = out + jnp.tensordot(Ad, recv.astype(Ad.dtype),
                                      axes=1).astype(tilde.dtype)
        return out

    def connectivity(self, rounds: int) -> np.ndarray:
        off = self._offdiag.astype(jnp.float32)

        def frac(t):
            A = self.inner.stack[t % self.inner.stack.shape[0]]
            denom = jnp.sum(A * off)
            surv = jnp.sum(A * self._keep_mat(t) * off)
            return jnp.where(denom > 0, surv / denom, 1.0)

        return np.asarray(jax.jit(jax.vmap(frac))(jnp.arange(rounds)))


class FaultyShardedSparseMixer(ShardedSparseMixer):
    """ShardedSparseMixer under a FaultSchedule — the ("node",) mesh path.

    Every shard replays the SAME per-round link draw (the link table is
    built from the global graph, so a partition edge maps to the identical
    link id its unsharded copy uses), computes its local healed diagonal
    mass, and runs the base class's ppermute-halo exchange with the
    effective weights. Zero-weight padding edges are forced to keep = 1 so
    they never contribute healed mass. Stragglers need the per-class ring
    schedule and are not supported on this path.
    """

    def __init__(self, part: NodePartition, graph,
                 schedule: FaultSchedule, delay: int = 0,
                 axis: str = "node"):
        super().__init__(part, delay=delay, axis=axis)
        if schedule.max_extra:
            raise ValueError(
                "stragglers are not supported on the node-sharded path — "
                "drop straggler_* from the FaultSpec or run unsharded")
        self.schedule = schedule
        m = int(graph.m)
        uniq, self.num_links = link_table(graph.dst, graph.src, m)
        D, block = part.devices, part.block
        dev = np.arange(D)[:, None]
        per_off = []
        for o, dl, sl, ww in part.offsets:
            dst_g = dev * block + np.asarray(dl, np.int64)
            src_g = ((dev + o) % D) * block + np.asarray(sl, np.int64)
            idx, valid = edge_link_idx(uniq, dst_g.ravel(), src_g.ravel(), m)
            loops = dst_g == src_g
            # self-loops and padding edges (absent from the table) pass
            # through untouched
            forced = loops | ~valid.reshape(dst_g.shape)
            # healed mass folds onto REAL self-loops only: zero-filled
            # padding slots at offset 0 alias to (dst_g == src_g) but carry
            # no weight and must not receive the row's healed diagonal
            loop_f = loops & (np.asarray(ww, np.float32) > 0.0)
            crossings = tuple(
                (jnp.asarray((((dst_g < cut) != (src_g < cut)) & ~loops)
                             .astype(np.float32)), int(start), int(end))
                for start, end, cut in schedule.partitions)
            per_off.append((jnp.asarray(idx.reshape(dst_g.shape)),
                            jnp.asarray(forced),
                            jnp.asarray(loop_f.astype(np.float32)),
                            jnp.asarray(np.minimum(src_g, m - 1)
                                        .astype(np.int32)),
                            crossings))
        self._fault_offsets = tuple(per_off)

    def _edge_keeps(self, t) -> list:
        """Per-offset (E_o,) keep vectors for THIS shard's edges."""
        sched = self.schedule
        keep_links = sched.link_keep(t, self.num_links)
        alive = sched.alive_f32(t) if sched.has_crashes else None
        d = jax.lax.axis_index(self.axis)
        keeps = []
        for idx, forced, _, src_g, crossings in self._fault_offsets:
            k = keep_links[idx[d]]
            if alive is not None:
                k = k * alive[src_g[d]]
            for cross, start, end in crossings:
                # t may be a traced scalar (scan) or a concrete python int
                in_w = jnp.asarray((t >= start) & (t < end), jnp.float32)
                k = k * (1.0 - cross[d] * in_w)
            keeps.append(jnp.where(forced[d], 1.0, k))
        return keeps

    def _healed(self, keeps) -> jax.Array:
        """(block,) dropped off-diagonal mass per local row."""
        d = jax.lax.axis_index(self.axis)
        healed = jnp.zeros((self.part.block,), jnp.float32)
        for (o, dl, sl, ww), keep in zip(self._offsets, keeps):
            dropped = ww[d] * (1.0 - keep)
            healed = healed + jax.ops.segment_sum(
                dropped, dl[d], num_segments=self.part.block)
        return healed

    def apply(self, x, t):
        D = self.part.devices
        d = jax.lax.axis_index(self.axis)
        keeps = self._edge_keeps(t)
        healed = self._healed(keeps)
        out = jnp.zeros(x.shape, jnp.float32)
        for (o, dl, sl, ww), keep, fo in zip(self._offsets, keeps,
                                             self._fault_offsets):
            halo = x if o == 0 else jax.lax.ppermute(
                x, self.axis, perm=[(j, (j - o) % D) for j in range(D)])
            loop_f = fo[2]
            w_eff = ww[d] * keep + loop_f[d] * healed[dl[d]]
            w = w_eff.reshape((-1,) + (1,) * (x.ndim - 1))
            vals = w * halo[sl[d]].astype(jnp.float32)
            out = out + jax.ops.segment_sum(vals, dl[d],
                                            num_segments=self.part.block)
        return out.astype(x.dtype)

    def diag(self, t):
        base = self._diag_blocks[jax.lax.axis_index(self.axis)]
        return base + self._healed(self._edge_keeps(t))


def wrap_mixer(mixer, schedule: FaultSchedule):
    """Lift a resolved mixer onto the faulty fabric described by
    ``schedule``.

    Sparse-form mixers (SparseMixer, RingRollMixer via its exact
    `ring_edges` form) become `FaultySparseMixer`; dense-form mixers
    (DenseMatrixMixer stacks, CompleteMixer, AlternatingRingMixer) become
    `FaultyDenseMixer`. A `DelayedMixer` wrapper contributes its uniform
    delay as the base staleness. The zero-rate bit-identity contract holds
    within each family (a lowered ring is compared against the same
    lowered ring, which is what `RunSpec.resolve_mixer` produces on both
    sides).
    """
    from repro.core.graph import complete_matrix, ring_edges

    base_delay = int(getattr(mixer, "delay", 0))
    inner = mixer.inner if isinstance(mixer, DelayedMixer) else mixer
    if isinstance(inner, HeterogeneousDelayMixer):
        raise ValueError(
            "faults do not compose with per-edge heterogeneous delays — "
            "model slow links as FaultSpec stragglers instead")
    if isinstance(inner, DisconnectedMixer):
        raise ValueError(
            "the disconnected topology has no links to fault — nothing "
            "to inject")
    if isinstance(inner, RingRollMixer):
        inner = SparseMixer(graph=ring_edges(inner.m,
                                             self_weight=inner.self_weight),
                            name="ring")
    if isinstance(inner, SparseMixer):
        return FaultySparseMixer(inner=inner, schedule=schedule,
                                 delay=base_delay)
    if isinstance(inner, CompleteMixer):
        inner = DenseMatrixMixer(stack=complete_matrix(inner.m)[None],
                                 name="complete")
    if isinstance(inner, AlternatingRingMixer):
        eye = np.eye(inner.m, dtype=np.float32)
        inner = DenseMatrixMixer(
            stack=np.stack([0.5 * eye + 0.5 * np.roll(eye, 1, axis=0),
                            0.5 * eye + 0.5 * np.roll(eye, -1, axis=0)]),
            name="ring_alternating")
    if isinstance(inner, DenseMatrixMixer):
        return FaultyDenseMixer(inner=inner, schedule=schedule,
                                delay=base_delay)
    raise ValueError(
        f"cannot inject faults into {type(inner).__name__}: no sparse or "
        "dense fixed form (use mixer='sparse'/'dense' or ring/complete/"
        "ring_alternating)")
