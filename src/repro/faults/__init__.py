"""repro.faults — fault injection and graceful degradation for gossip over
unreliable data-center networks.

The paper assumes a perfect fabric; this package makes "does the algorithm
survive a real DCN?" a measured property. A declarative
:class:`FaultSpec` (registry-backed, carried on ``RunSpec.faults``)
compiles into a seeded, jit/scan-safe :class:`FaultSchedule` of per-round
link drops, transient partitions, node crash windows and stragglers;
:func:`wrap_mixer` lifts any registered mixer (sparse, dense, delayed,
node-sharded) onto that schedule with per-round self-healing
renormalization, so both engines, the seed-vmap sweep and the
("seed","node") grid all run under faults. Crashed nodes freeze their
local update, spend no eps (`PrivacyAccountant` participation masks), drop
out of mixing and rejoin from their last state. A FaultSpec with every
rate at zero is bit-identical to a fault-free run — gated in CI as
``zero_fault_identical`` (benchmarks/bench_faults.py). See docs/faults.md.

>>> from repro.faults import FAULTS, FaultSpec
>>> sorted(FAULTS.names())
['crash', 'dcn', 'links', 'none', 'partition']
>>> FAULTS.build("links", {"link_rate": 0.0}).is_zero
True
>>> FaultSpec(partitions=((4, 8, 2),)).compile(m=4).partitions
((4, 8, 2),)
"""
from repro.faults.metrics import degradation, rounds_to_recover
from repro.faults.mixers import (FaultyDenseMixer, FaultyShardedSparseMixer,
                                 FaultySparseMixer, wrap_mixer)
from repro.faults.schedule import FaultSchedule, edge_link_idx, link_table
from repro.faults.spec import FAULTS, FaultSpec

__all__ = [
    "FAULTS",
    "FaultSpec",
    "FaultSchedule",
    "FaultySparseMixer",
    "FaultyDenseMixer",
    "FaultyShardedSparseMixer",
    "wrap_mixer",
    "degradation",
    "rounds_to_recover",
    "link_table",
    "edge_link_idx",
]
