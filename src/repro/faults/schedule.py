"""Compiled fault schedules — seeded, jit/scan-safe per-round fault masks.

A `repro.faults.FaultSpec` is declarative ("drop each link with p=0.05,
crash node 3 for rounds 10..20"); compiling it against a node count (and,
for seeded crash draws, a horizon) yields a :class:`FaultSchedule` whose
per-round queries are pure functions of the traced round counter ``t``:

* ``link_keep(t, num_links)`` — one Bernoulli keep per undirected LINK,
  drawn from ``fold_in(key, t)`` so every round has its own i.i.d. mask and
  any consumer (dense mixer, sparse mixer, every shard of a node-sharded
  mesh) replays the identical draw from the same ``t``. A symmetric input
  graph therefore stays symmetric under link drops: both directions of a
  link share one coin.
* ``alive_mask(t)`` — (m,) node liveness from the compiled crash windows
  (explicit windows plus windows drawn at compile time from
  ``crash_rate``); branch-free in ``t`` so it runs inside ``lax.scan``.
* ``partitions`` — static (start, end, cut) windows; the mixers drop edges
  crossing the cut while ``start <= t < end``.

The schedule also replays itself on the HOST (`alive_table` /
`participation`) so the privacy accountant can skip charging eps for
crashed rounds without touching the jitted round.

Zero-rate contract: a schedule whose spec has every rate at zero still
draws its uniforms — ``u >= 0.0`` is always True, so the keep vector is
exactly 1.0 and every downstream multiply/add is bit-exact against the
fault-free path (the ``zero_fault_identical`` gate).

>>> import numpy as np
>>> from repro.faults.schedule import link_table, edge_link_idx
>>> uniq, n = link_table(np.array([0, 0, 1, 1]), np.array([0, 1, 0, 1]), 2)
>>> n                          # one undirected link {0, 1}; loops excluded
1
>>> idx, valid = edge_link_idx(uniq, np.array([0, 1]), np.array([1, 0]), 2)
>>> idx.tolist(), valid.tolist()       # both directions share the link id
([0, 0], [True, True])
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FaultSchedule", "link_table", "edge_link_idx"]


def link_table(dst, src, m: int) -> tuple[np.ndarray, int]:
    """Canonical undirected link numbering for an edge list.

    Returns ``(uniq_pairs, num_links)``: the sorted unordered-pair ids
    ``min(i,j) * m + max(i,j)`` of every off-diagonal edge, and their count
    (at least 1 so the per-round uniform draw never has shape (0,)). Both
    directions of an edge — and every shard's copy of it — map to the same
    link id, which is what makes the per-round Bernoulli masks symmetric
    and shard-invariant.
    """
    dst = np.asarray(dst, np.int64).ravel()
    src = np.asarray(src, np.int64).ravel()
    lo = np.minimum(dst, src)
    hi = np.maximum(dst, src)
    pair = lo * int(m) + hi
    uniq = np.unique(pair[dst != src])
    return uniq, max(int(uniq.size), 1)


def edge_link_idx(uniq_pairs: np.ndarray, dst, src,
                  m: int) -> tuple[np.ndarray, np.ndarray]:
    """(link index, found) per edge under a `link_table` numbering.

    ``found`` is False for self-loops and for pairs absent from the table
    (e.g. the zero-weight padding edges of a node partition); their index
    is clipped in range so a runtime gather stays safe — consumers force
    ``keep = 1`` wherever ``found`` is False.
    """
    dst = np.asarray(dst, np.int64).ravel()
    src = np.asarray(src, np.int64).ravel()
    lo = np.minimum(dst, src)
    hi = np.maximum(dst, src)
    pair = lo * int(m) + hi
    if uniq_pairs.size == 0:
        return (np.zeros(pair.shape, np.int32),
                np.zeros(pair.shape, bool))
    pos = np.clip(np.searchsorted(uniq_pairs, pair), 0,
                  uniq_pairs.size - 1)
    found = (uniq_pairs[pos] == pair) & (dst != src)
    return pos.astype(np.int32), found


def _as_windows(rows, width: int, what: str) -> tuple:
    out = []
    for row in rows:
        row = tuple(int(v) for v in row)
        if len(row) != width:
            raise ValueError(f"each {what} entry needs {width} ints, "
                             f"got {row}")
        out.append(row)
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A FaultSpec compiled against a node count (and optional horizon).

    Construction resolves everything data-dependent — seeded crash windows,
    straggler assignments, window validation — so the per-round queries are
    pure, branch-free functions of the traced round counter.
    """

    spec: Any                     # repro.faults.FaultSpec
    m: int
    horizon: int | None = None

    def __post_init__(self):
        spec = self.spec
        m = int(self.m)
        if m < 1:
            raise ValueError(f"FaultSchedule needs m >= 1, got {m}")
        set_ = lambda k, v: object.__setattr__(self, k, v)
        set_("_key", jax.random.PRNGKey(int(spec.seed)))

        # -- partitions: static (start, end, cut) windows ---------------------
        parts = _as_windows(spec.partitions, 3, "partition")
        for start, end, cut in parts:
            if not 0 <= start < end:
                raise ValueError(f"partition window [{start}, {end}) is "
                                 "empty or negative")
            if not 0 < cut < m:
                raise ValueError(f"partition cut {cut} must split the node "
                                 f"range (0, {m})")
        set_("partitions", parts)

        # -- crash windows: explicit + compile-time seeded draws --------------
        windows = list(_as_windows(spec.crashes, 3, "crash"))
        for node, start, end in windows:
            if not 0 <= node < m:
                raise ValueError(f"crash node {node} out of range for m={m}")
            if not 0 <= start < end:
                raise ValueError(f"crash window [{start}, {end}) is empty "
                                 "or negative")
        if spec.crash_rate > 0.0:
            if self.horizon is None:
                raise ValueError(
                    "seeded crashes (crash_rate > 0) need a horizon to draw "
                    "start rounds from — set RunSpec.horizon or use explicit "
                    "FaultSpec.crashes windows")
            length = int(spec.crash_rounds) or max(int(self.horizon) // 8, 1)
            rng = np.random.default_rng([int(spec.seed), 1])
            hit = rng.random(m) < float(spec.crash_rate)
            starts = rng.integers(0, max(int(self.horizon) - length, 1),
                                  size=m)
            for node in np.flatnonzero(hit):
                windows.append((int(node), int(starts[node]),
                                int(starts[node]) + length))
        nodes = np.asarray([w[0] for w in windows], np.int32)
        set_("crash_windows", tuple(windows))
        set_("_cw_nodes", jnp.asarray(nodes))
        set_("_cw_start", jnp.asarray([w[1] for w in windows], jnp.int32))
        set_("_cw_end", jnp.asarray([w[2] for w in windows], jnp.int32))

        # -- stragglers: per-node extra staleness (explicit + seeded) ---------
        extra = np.zeros(m, np.int32)
        if spec.straggler_rate > 0.0 and spec.straggler_delay > 0:
            rng = np.random.default_rng([int(spec.seed), 2])
            extra[rng.random(m) < float(spec.straggler_rate)] = \
                int(spec.straggler_delay)
        for node, lag in _as_windows(spec.stragglers, 2, "straggler"):
            if not 0 <= node < m:
                raise ValueError(f"straggler node {node} out of range for "
                                 f"m={m}")
            if lag < 0:
                raise ValueError(f"straggler delay must be >= 0, got {lag}")
            extra[node] = lag
        set_("extra", extra)

    # -- static shape of the schedule ----------------------------------------

    @property
    def has_crashes(self) -> bool:
        return len(self.crash_windows) > 0

    @property
    def max_extra(self) -> int:
        """Deepest straggler lag — widens the history ring by this much."""
        return int(self.extra.max()) if self.extra.size else 0

    # -- jit/scan-safe per-round queries -------------------------------------

    def link_keep(self, t, num_links: int) -> jax.Array:
        """(num_links,) float32 keep mask for round ``t`` (1 = delivered).

        Always draws — at ``link_rate == 0`` the comparison ``u >= 0.0`` is
        identically True, so the mask is exactly 1.0 and the faulty mixers'
        arithmetic collapses bit-for-bit onto the clean path.
        """
        u = jax.random.uniform(jax.random.fold_in(self._key, t),
                               (int(num_links),))
        return (u >= jnp.float32(self.spec.link_rate)).astype(jnp.float32)

    def alive_mask(self, t) -> jax.Array:
        """(m,) bool — False while a node sits inside a crash window."""
        if not self.has_crashes:
            return jnp.ones((self.m,), bool)
        in_w = ((t >= self._cw_start) & (t < self._cw_end)).astype(jnp.int32)
        crashed = jnp.zeros((self.m,), jnp.int32).at[self._cw_nodes].max(in_w)
        return crashed == 0

    def alive_f32(self, t) -> jax.Array:
        return self.alive_mask(t).astype(jnp.float32)

    # -- host-side replay (privacy accounting, analysis) ---------------------

    def alive_table(self, start: int, end: int) -> np.ndarray:
        """(end - start, m) bool liveness table, replayed with numpy."""
        T = int(end) - int(start)
        alive = np.ones((max(T, 0), self.m), bool)
        for node, s, e in self.crash_windows:
            lo, hi = max(s - start, 0), min(e - start, T)
            if lo < hi:
                alive[lo:hi, node] = False
        return alive

    def participation(self, start: int, end: int) -> np.ndarray:
        """(m,) rounds each node actually participated in over
        ``[start, end)`` — what `PrivacyAccountant.step` charges."""
        return self.alive_table(start, end).sum(axis=0).astype(np.int64)
