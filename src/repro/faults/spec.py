"""FaultSpec — declarative fault scenarios for gossip over unreliable DCNs.

A `FaultSpec` names WHAT goes wrong on the fabric; compiling it against a
node count yields the jit/scan-safe :class:`repro.faults.FaultSchedule`
that the faulty mixers and engines consume. Like every other stage of the
round pipeline it is registry-backed: `RunSpec.faults` holds a FAULTS name
(with `RunSpec.faults_options`) or a FaultSpec instance.

The spec's ``seed`` is deliberately INDEPENDENT of ``RunSpec.seed``: the
fault pattern is part of the *scenario*, not of a replicate, so a
multi-seed `run_batch` sweep hits every seed with the same weather and the
seed axis stays vectorizable.

>>> from repro.faults.spec import FAULTS, FaultSpec
>>> FaultSpec().is_zero
True
>>> FAULTS.build("links", {"link_rate": 0.1}).link_rate
0.1
>>> sorted(FAULTS.names())
['crash', 'dcn', 'links', 'none', 'partition']
>>> sched = FaultSpec(crashes=((1, 2, 5),)).compile(m=4)
>>> sched.participation(0, 8).tolist()   # node 1 dark for rounds 2, 3, 4
[8, 5, 8, 8]
"""
from __future__ import annotations

import dataclasses

from repro.api.registry import Registry

__all__ = ["FaultSpec", "FAULTS"]


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """What goes wrong, declaratively. All fields default to "nothing".

    Link faults
        ``link_rate`` — per-round Bernoulli drop probability per undirected
        LINK (both directions share one coin, so symmetric graphs stay
        symmetric). ``partitions`` — transient splits: each
        ``(start, end, cut)`` severs every edge crossing ``node < cut``
        for rounds ``start <= t < end``.
    Crashes
        ``crashes`` — explicit ``(node, start, end)`` windows; a crashed
        node freezes its local update, spends no eps, and is masked out of
        mixing (its dropped weight heals onto neighbors' self-loops).
        ``crash_rate`` / ``crash_rounds`` — additionally draw one window
        per node with probability ``crash_rate`` at compile time (needs a
        horizon).
    Stragglers
        ``stragglers`` — explicit ``(node, extra_delay)`` pairs;
        ``straggler_rate`` / ``straggler_delay`` — seeded assignment. A
        straggler's *outgoing* broadcasts arrive ``extra_delay`` rounds
        later than the base delay, read from the existing history ring.
    ``seed``
        Fault PRNG seed — independent of the run seed (see module note).
    """

    link_rate: float = 0.0
    partitions: tuple = ()
    crashes: tuple = ()
    crash_rate: float = 0.0
    crash_rounds: int = 0
    stragglers: tuple = ()
    straggler_rate: float = 0.0
    straggler_delay: int = 0
    seed: int = 0
    name: str = "faults"

    def __post_init__(self):
        for field in ("link_rate", "crash_rate", "straggler_rate"):
            rate = float(getattr(self, field))
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"{field} must be in [0, 1], got {rate}")
            object.__setattr__(self, field, rate)
        for field in ("crash_rounds", "straggler_delay"):
            if int(getattr(self, field)) < 0:
                raise ValueError(f"{field} must be >= 0")
        for field in ("partitions", "crashes", "stragglers"):
            rows = getattr(self, field)
            object.__setattr__(
                self, field, tuple(tuple(int(v) for v in row)
                                   for row in rows))

    @property
    def is_zero(self) -> bool:
        """True when this spec injects nothing at all."""
        return (self.link_rate == 0.0 and not self.partitions
                and not self.crashes and self.crash_rate == 0.0
                and not self.stragglers
                and (self.straggler_rate == 0.0
                     or self.straggler_delay == 0))

    def compile(self, m: int, horizon: int | None = None):
        """Resolve every data-dependent draw into a `FaultSchedule`."""
        from repro.faults.schedule import FaultSchedule
        return FaultSchedule(spec=self, m=int(m), horizon=horizon)


# Build kwargs supplied by RunSpec.resolve_faults(): none — fault factories
# take only user options, so the fault scenario is fully self-describing
# (and in particular never inherits the run seed; see module docstring).
FAULTS: Registry = Registry("fault")


@FAULTS.register("none")
def _none() -> FaultSpec:
    """The explicit no-op — still exercises the whole fault machinery, so
    it doubles as the zero_fault_identical gate scenario."""
    return FaultSpec(name="none")


@FAULTS.register("links")
def _links(link_rate: float = 0.05, seed: int = 0) -> FaultSpec:
    return FaultSpec(link_rate=link_rate, seed=seed, name="links")


@FAULTS.register("partition")
def _partition(start: int = 0, end: int = 1, cut: int = 1,
               partitions: tuple = (), seed: int = 0) -> FaultSpec:
    parts = tuple(partitions) or ((start, end, cut),)
    return FaultSpec(partitions=parts, seed=seed, name="partition")


@FAULTS.register("crash")
def _crash(crash_rate: float = 0.0, crash_rounds: int = 0,
           crashes: tuple = (), seed: int = 0) -> FaultSpec:
    return FaultSpec(crash_rate=crash_rate, crash_rounds=crash_rounds,
                     crashes=tuple(crashes), seed=seed, name="crash")


@FAULTS.register("dcn")
def _dcn(link_rate: float = 0.02, crash_rate: float = 0.05,
         crash_rounds: int = 8, straggler_rate: float = 0.1,
         straggler_delay: int = 1, seed: int = 0) -> FaultSpec:
    """A composite "typical data-center weather" preset: a little packet
    loss, the odd crash, a few slow racks."""
    return FaultSpec(link_rate=link_rate, crash_rate=crash_rate,
                     crash_rounds=crash_rounds,
                     straggler_rate=straggler_rate,
                     straggler_delay=straggler_delay, seed=seed, name="dcn")
