"""Thread-safe metrics registry: counters, gauges, histograms.

One registry per :class:`~repro.obs.Telemetry`; every subsystem publishes
into it under dotted names — ``run.rounds`` / ``run.eps_total`` from the
runner, ``serve.served`` / ``serve.shed.timeout`` from the admission layer,
``faults.mean_connectivity`` from fault-injected runs — so a single
``snapshot()`` answers "what is the fleet doing" without reaching into any
subsystem's internals.

Instruments share the registry's lock (updates are a dict write under one
mutex — cheap enough for per-chunk cadence, and the serving threads hammer
the counters concurrently without losing increments).

>>> reg = MetricsRegistry()
>>> reg.counter("run.rounds").inc(64)
>>> reg.counter("run.rounds").inc(64)      # get-or-create: same instrument
>>> reg.counter("run.rounds").value
128
>>> reg.gauge("run.eps_total").set(1.0)
>>> h = reg.histogram("run.chunk_seconds")
>>> for v in (0.1, 0.2, 0.3):
...     h.observe(v)
>>> h.count, round(h.mean, 3)
(3, 0.2)
>>> snap = reg.snapshot()
>>> snap["run.rounds"], snap["run.eps_total"]
(128, 1.0)
>>> snap["run.chunk_seconds"]["count"]
3
>>> reg.gauge("run.rounds")
Traceback (most recent call last):
    ...
TypeError: metric 'run.rounds' is already a Counter, not a Gauge
"""
from __future__ import annotations

import threading

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    """Monotonic count (served requests, completed rounds, shed reasons)."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self._lock = lock
        self._value = 0

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease (n={n})")
        with self._lock:
            self._value += n

    @property
    def value(self):
        with self._lock:
            return self._value


class Gauge:
    """Last-written value (eps burn, queue depth, connectivity)."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self._lock = lock
        self._value = None

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v

    @property
    def value(self):
        with self._lock:
            return self._value


class Histogram:
    """Sampled distribution (chunk seconds, batch sizes, latencies).

    Keeps running count/sum exactly plus a bounded sample reservoir for the
    percentiles — ``max_samples`` caps memory on long-lived services (the
    first ``max_samples`` observations are retained, like ServeStats).
    """

    __slots__ = ("name", "_lock", "_count", "_sum", "_min", "_max",
                 "_samples", "max_samples")

    def __init__(self, name: str, lock: threading.Lock,
                 max_samples: int = 65536):
        self.name = name
        self._lock = lock
        self.max_samples = max_samples
        self._count = 0
        self._sum = 0.0
        self._min = None
        self._max = None
        self._samples: list[float] = []

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._count += 1
            self._sum += v
            self._min = v if self._min is None else min(self._min, v)
            self._max = v if self._max is None else max(self._max, v)
            if len(self._samples) < self.max_samples:
                self._samples.append(v)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def mean(self) -> float | None:
        with self._lock:
            return self._sum / self._count if self._count else None

    def percentile(self, p: float) -> float | None:
        with self._lock:
            if not self._samples:
                return None
            return float(np.percentile(np.asarray(self._samples), p))

    def summary(self) -> dict:
        with self._lock:
            if not self._count:
                return {"count": 0}
            arr = np.asarray(self._samples) if self._samples else None
            return {
                "count": self._count,
                "mean": self._sum / self._count,
                "min": self._min,
                "max": self._max,
                "p50": float(np.percentile(arr, 50)) if arr is not None
                       else None,
                "p99": float(np.percentile(arr, 99)) if arr is not None
                       else None,
            }


class MetricsRegistry:
    """Get-or-create instruments by dotted name; one lock for all of them.

    Asking for an existing name with a different instrument type raises —
    two subsystems silently aliasing one metric is a bug, not a feature.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[str, object] = {}

    def _get(self, name: str, cls, **kw):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                # instruments reuse the registry lock: they only take it for
                # dict-free scalar updates, so one mutex keeps ordering simple
                inst = self._instruments[name] = cls(name, self._lock, **kw)
        if not isinstance(inst, cls):
            raise TypeError(f"metric {name!r} is already a "
                            f"{type(inst).__name__}, not a {cls.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, max_samples: int = 65536) -> Histogram:
        return self._get(name, Histogram, max_samples=max_samples)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._instruments)

    def snapshot(self) -> dict:
        """{name: value | histogram-summary} for every instrument, JSON-able
        — the payload `obs report` and the run-event stream carry."""
        with self._lock:
            items = list(self._instruments.items())
        out = {}
        for name, inst in sorted(items):
            out[name] = (inst.summary() if isinstance(inst, Histogram)
                         else inst.value)
        return out
