"""Run-event streams: append-first JSONL, one event per line.

The durable half of the telemetry story: spans and metrics live in process
memory, events land on disk NEXT TO the sweep store (``experiments/store/
events.jsonl`` by default) so any finished — or crashed — run can be
reconstructed after the fact. ``python -m repro.launch.obs report`` renders
a run's event stream into a text/JSON summary.

Schema: every event is one JSON object with at least ``ts`` (epoch seconds),
``event`` (kind) and — for runner-emitted events — ``run_id`` (random
8-hex token grouping one run's events). The kinds the stack emits today:

  run_start    engine, stream, nodes, dim, horizon, kind ('run'|'run_batch')
  chunk        round_start, round_end, seconds, rounds_per_sec, eps
  checkpoint   step
  chunk_cost   predicted_s, measured_s, error_ratio, flops, hbm_bytes
  publish      round, version, eps (serving snapshot publications)
  sweep_point  sweep, label, seeds, source ('ran'|'loaded')
  run_end      rounds, wall_clock_s, rounds_per_sec, accuracy, eps_total

Readers tolerate a torn trailing line (a crashed writer), exactly like the
sweep store's JSONL log.

>>> import tempfile, os
>>> path = os.path.join(tempfile.mkdtemp(), "events.jsonl")
>>> log = EventLog(path)
>>> _ = log.emit("run_start", run_id="abc123", engine="sim")
>>> _ = log.emit("chunk", run_id="abc123", round_end=64)
>>> log.close()
>>> events = read_events(path)
>>> [e["event"] for e in events]
['run_start', 'chunk']
>>> events[1]["round_end"], sorted(events[0])[:2]
(64, ['engine', 'event'])
"""
from __future__ import annotations

import json
import os
import threading
import time

__all__ = ["EventLog", "read_events", "group_runs", "DEFAULT_EVENTS_PATH"]

# next to the sweep store (repro.sweep.store.DEFAULT_STORE), not imported
# from it — keeping repro.obs free of repro.* imports avoids cycles
DEFAULT_EVENTS_PATH = os.path.join("experiments", "store", "events.jsonl")


class EventLog:
    """Append-only JSONL event writer; thread-safe; flushes per event so a
    crash loses at most the line being written."""

    def __init__(self, path: str = DEFAULT_EVENTS_PATH):
        self.path = path
        self._lock = threading.Lock()
        self._fh = None

    def emit(self, event: str, **fields) -> dict:
        rec = {"ts": time.time(), "event": event, **fields}
        line = json.dumps(rec)
        with self._lock:
            if self._fh is None:
                os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
                self._fh = open(self.path, "a")
            self._fh.write(line + "\n")
            self._fh.flush()
        return rec

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


def read_events(path: str = DEFAULT_EVENTS_PATH) -> list[dict]:
    """Every event in the stream, in write order. A torn trailing line
    (crashed writer) is dropped; a torn line in the MIDDLE raises — that is
    corruption, not a crash artifact."""
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        lines = f.read().splitlines()
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                break                      # torn tail from a crashed append
            raise
    return out


def group_runs(events: list[dict]) -> dict[str, list[dict]]:
    """Events grouped by ``run_id`` (insertion-ordered — latest run last).
    Events without a run_id are grouped under ``""``."""
    runs: dict[str, list[dict]] = {}
    for e in events:
        runs.setdefault(e.get("run_id", ""), []).append(e)
    return runs
