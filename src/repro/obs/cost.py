"""Predicted-vs-measured chunk cost: the ROADMAP's predict-then-measure loop.

`repro.launch.hlo_cost.analyze` rolls FLOPs / HBM bytes / collective bytes
out of a compiled chunk program's HLO (trip-count-aware, so the per-round
`lax.scan` body is counted ``chunk_rounds`` times). This module closes the
loop: a roofline :class:`CostModel` turns that static cost into a PREDICTED
chunk wall-clock, the runner's chunk spans supply the MEASURED one, and the
ratio between them becomes a first-class, regression-recorded artifact
(``BENCH_obs.json``) instead of a number someone once eyeballed.

The model is ``max(flops / peak_flops, bytes / peak_bandwidth)`` — the
two-term roofline. Peaks are CALIBRATED once per process with two tiny
probes (a matmul for the FLOP ceiling, a saxpy for the bandwidth ceiling)
so predictions track the machine the run is on, not a spec sheet; pass an
explicit :class:`CostModel` to pin them. A prediction-error ratio near 1
means the static model explains the wall-clock; a drifting ratio is the
signal that the compiled program changed character (new fusion, new
collective) — which is exactly what a regression gate wants to see.

>>> import jax, jax.numpy as jnp
>>> fn = jax.jit(lambda x: x @ x + 1.0)
>>> x = jnp.ones((64, 64), jnp.float32)
>>> model = CostModel(peak_flops=1e12, peak_bandwidth=1e11)
>>> cc = analyze_chunk(fn, x, model=model)
>>> cc.cost.flops >= 2 * 64 * 64 * 64
True
>>> cc.predicted_s > 0
True
>>> cc.record(cc.predicted_s * 2)      # "measured" twice the prediction
>>> round(cc.summary()["error_ratio"], 3)
0.5
"""
from __future__ import annotations

import dataclasses
import time

__all__ = ["CostModel", "ChunkCost", "analyze_chunk", "calibrate"]


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Two-term roofline: seconds = max(flops/peak, bytes/bandwidth)."""

    peak_flops: float          # FLOP/s the device sustains on a hot matmul
    peak_bandwidth: float      # bytes/s on a streaming elementwise op

    def predict_seconds(self, cost) -> float:
        """Predicted wall-clock of one execution of an analyzed program
        (``cost`` is a `repro.launch.hlo_cost.HloCost`)."""
        return max(cost.flops / self.peak_flops,
                   cost.hbm_bytes / self.peak_bandwidth)

    def summary(self) -> dict:
        return {"peak_flops": self.peak_flops,
                "peak_bandwidth": self.peak_bandwidth}


_CALIBRATED: CostModel | None = None


def calibrate(size: int = 512, repeats: int = 5) -> CostModel:
    """Measure this process's achievable peaks with two probes (cached).

    The probes are self-contained jitted programs on throwaway data — they
    never touch a run's PRNG keys or state, so calibrating inside a seeded
    run cannot perturb it (the ``obs_off_identical`` gate would catch it).
    """
    global _CALIBRATED
    if _CALIBRATED is not None:
        return _CALIBRATED
    import jax
    import jax.numpy as jnp

    a = jnp.ones((size, size), jnp.float32)
    mm = jax.jit(lambda x: x @ x)
    jax.block_until_ready(mm(a))                       # compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(mm(a))
        best = min(best, time.perf_counter() - t0)
    peak_flops = 2.0 * size ** 3 / max(best, 1e-9)

    n = size * size * 16
    v = jnp.ones((n,), jnp.float32)
    saxpy = jax.jit(lambda x: 2.0 * x + 1.0)
    jax.block_until_ready(saxpy(v))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(saxpy(v))
        best = min(best, time.perf_counter() - t0)
    peak_bw = 2.0 * 4 * n / max(best, 1e-9)            # read + write, f32

    _CALIBRATED = CostModel(peak_flops=peak_flops, peak_bandwidth=peak_bw)
    return _CALIBRATED


@dataclasses.dataclass
class ChunkCost:
    """One compiled chunk program's predicted cost + its measured executions.

    The runner calls :meth:`record` with every chunk span's duration;
    :meth:`summary` is what lands in ``RunResult.metrics['obs']['cost']``,
    the ``chunk_cost`` run event, and BENCH_obs.json.
    """

    cost: object                     # repro.launch.hlo_cost.HloCost
    model: CostModel
    predicted_s: float
    measured: list = dataclasses.field(default_factory=list)

    def record(self, seconds: float) -> None:
        self.measured.append(float(seconds))

    def summary(self) -> dict:
        mean = (sum(self.measured) / len(self.measured)
                if self.measured else None)
        return {
            "flops": self.cost.flops,
            "hbm_bytes": self.cost.hbm_bytes,
            "collective_bytes": self.cost.collective_bytes,
            "predicted_s": self.predicted_s,
            "measured_mean_s": mean,
            "measured_chunks": len(self.measured),
            # >1: the program ran FASTER than the static model says it
            # could; <1: overheads (dispatch, host sync) the model omits
            "error_ratio": (self.predicted_s / mean
                            if mean and mean > 0 else None),
            "model": self.model.summary(),
        }


def analyze_chunk(jitted, *args, model: CostModel | None = None) -> ChunkCost:
    """Lower + compile ``jitted(*args)``, roll up its HLO cost, and predict
    one execution's wall-clock. ``args`` may be real arrays or
    ``jax.ShapeDtypeStruct``s — only shapes matter."""
    from repro.launch import hlo_cost

    hlo = jitted.lower(*args).compile().as_text()
    cost = hlo_cost.analyze(hlo)
    model = model or calibrate()
    return ChunkCost(cost=cost, model=model,
                     predicted_s=model.predict_seconds(cost))
