"""repro.obs — unified telemetry: spans, metrics, run events, cost loop.

One :class:`Telemetry` object bundles the four observability primitives the
stack publishes into:

  * a span :class:`~repro.obs.trace.Tracer` (compile/chunk/checkpoint/
    publish phases, Chrome ``trace.json`` export);
  * a thread-safe :class:`~repro.obs.metrics.MetricsRegistry` (eps burn,
    rounds/sec, serve counters, fault connectivity);
  * an optional JSONL :class:`~repro.obs.events.EventLog` run-event stream
    (rendered by ``python -m repro.launch.obs report``);
  * the optional predicted-vs-measured :mod:`~repro.obs.cost` loop, plus an
    opt-in ``jax.profiler`` device-trace capture.

Telemetry is OFF by default and ambient: `repro.api.run`, `repro.sweep`
and `repro.serve` consult :func:`active` and do nothing unless a caller
has installed an enabled instance with :func:`enable` (or passed ``obs=``
explicitly). Telemetry never touches device math — a run with it on is
bit-identical to one with it off, and CI gates that (``obs_off_identical``
in BENCH_obs.json) along with the overhead ceiling (``overhead_ratio``).

>>> import repro.obs as obs
>>> obs.active().enabled                   # ambient default: off
False
>>> tel = obs.Telemetry()
>>> with tel.span("phase", k=1):
...     tel.metrics.counter("demo.count").inc()
>>> tel.tracer.summary()["phase"]["count"]
1
>>> tel.metrics.snapshot()["demo.count"]
1
>>> prev = obs.enable()                    # install ambient telemetry...
>>> obs.active().enabled
True
>>> obs.disable()                          # ...and restore the default
>>> obs.active().enabled
False
"""
from __future__ import annotations

import contextlib
import uuid

from repro.obs.cost import ChunkCost, CostModel, analyze_chunk, calibrate
from repro.obs.events import (DEFAULT_EVENTS_PATH, EventLog, group_runs,
                              read_events)
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import NULL_SPAN, Span, Tracer

__all__ = [
    "Telemetry", "enable", "disable", "active",
    "Tracer", "Span", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "EventLog", "read_events", "group_runs", "DEFAULT_EVENTS_PATH",
    "CostModel", "ChunkCost", "analyze_chunk", "calibrate",
]


class Telemetry:
    """One run-scoped (or process-scoped) telemetry bundle.

    enabled:      master switch — False makes every hook a no-op (this is
                  the ambient default the bit-identity gate pins).
    events:       an :class:`EventLog`, a path for one, or None (no stream).
    cost:         True turns on the predicted-vs-measured chunk-cost loop
                  (one extra lower/compile per chunk program, outside the
                  timed region).
    cost_model:   pin the roofline peaks instead of calibrating.
    profile_dir:  opt-in ``jax.profiler`` device-trace capture directory —
                  the runner wraps its chunk loop in
                  ``jax.profiler.trace(profile_dir)``.
    """

    def __init__(self, *, enabled: bool = True,
                 events: "EventLog | str | None" = None,
                 cost: bool = False, cost_model: CostModel | None = None,
                 profile_dir: str | None = None,
                 max_spans: int = 1_000_000):
        self.enabled = enabled
        self.tracer = Tracer(enabled=enabled, max_spans=max_spans)
        self.metrics = MetricsRegistry()
        if isinstance(events, str):
            events = EventLog(events)
        self.events = events if enabled else None
        self.cost_enabled = bool(cost) and enabled
        self.cost_model = cost_model
        self.profile_dir = profile_dir if enabled else None

    # -- hooks the instrumented code calls ----------------------------------

    def span(self, name: str, **args):
        """Timed region (no-op when disabled) — see `Tracer.span`."""
        return self.tracer.span(name, **args)

    def emit(self, event: str, **fields) -> None:
        """Append one run event to the JSONL stream (no-op without one)."""
        if self.events is not None:
            self.events.emit(event, **fields)

    def profile(self):
        """Context manager capturing a ``jax.profiler`` device trace into
        ``profile_dir`` (no-op when unset or the profiler is unavailable)."""
        if not self.profile_dir:
            return contextlib.nullcontext()
        import jax
        try:
            return jax.profiler.trace(self.profile_dir)
        except Exception:                    # pragma: no cover - no profiler
            return contextlib.nullcontext()

    @staticmethod
    def new_run_id() -> str:
        """8-hex token grouping one run's events."""
        return uuid.uuid4().hex[:8]

    # -- introspection ------------------------------------------------------

    def export_chrome(self, path: str) -> str:
        return self.tracer.export_chrome(path)

    def summary(self) -> dict:
        return {"enabled": self.enabled,
                "spans": self.tracer.summary(),
                "metrics": self.metrics.snapshot()}

    def close(self) -> None:
        if self.events is not None:
            self.events.close()


_DISABLED = Telemetry(enabled=False)
_active: Telemetry = _DISABLED


def active() -> Telemetry:
    """The ambient Telemetry (a shared disabled instance by default)."""
    return _active


def enable(**kwargs) -> Telemetry:
    """Install (and return) an enabled ambient Telemetry; kwargs as for
    :class:`Telemetry`. The previous instance is replaced, not stacked."""
    global _active
    _active = Telemetry(enabled=True, **kwargs)
    return _active


def disable() -> None:
    """Restore the disabled ambient default (closes an open event stream)."""
    global _active
    if _active is not _DISABLED:
        _active.close()
    _active = _DISABLED
