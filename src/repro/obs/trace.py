"""Host-side span tracer: nested, thread-safe, Chrome-trace exportable.

Every phase of a run — compile, chunk execution, checkpoint write, snapshot
publication — is wrapped in a :class:`Span` so "where did the wall-clock
go?" has an answer that survives the run. Spans nest through a thread-local
stack (a chunk span inside a run span keeps its parent), carry arbitrary
JSON-able attributes, and export to the Chrome/Perfetto ``trace.json``
format (``chrome://tracing``, https://ui.perfetto.dev).

The tracer is a pure host-side observer: it never touches device values,
so a traced run is bit-identical to an untraced one (the ``obs_off_identical``
gate in BENCH_obs.json holds telemetry to that). A disabled tracer hands
out a shared no-op span — the hot loop pays one attribute check.

>>> tracer = Tracer()
>>> with tracer.span("run", engine="sim"):
...     for i in range(3):
...         with tracer.span("chunk", index=i):
...             pass
>>> [s.name for s in tracer.spans]
['chunk', 'chunk', 'chunk', 'run']
>>> tracer.spans[0].parent, tracer.spans[-1].parent
('run', None)
>>> sorted(tracer.summary()["chunk"])
['count', 'max_s', 'mean_s', 'total_s']
>>> tracer.summary()["chunk"]["count"]
3
>>> off = Tracer(enabled=False)
>>> with off.span("never"):
...     pass
>>> off.spans
[]
"""
from __future__ import annotations

import json
import threading
import time

__all__ = ["Span", "Tracer", "NULL_SPAN"]


class Span:
    """One timed region. ``t0``/``t1`` are ``perf_counter`` stamps; ``args``
    are the JSON-able attributes given at creation."""

    __slots__ = ("name", "t0", "t1", "parent", "depth", "thread", "args")

    def __init__(self, name: str, *, parent: str | None = None,
                 depth: int = 0, thread: int = 0, args: dict | None = None):
        self.name = name
        self.parent = parent
        self.depth = depth
        self.thread = thread
        self.args = args or {}
        self.t0 = 0.0
        self.t1 = 0.0

    @property
    def duration_s(self) -> float:
        return max(self.t1 - self.t0, 0.0)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, {self.duration_s * 1e3:.3f}ms, "
                f"depth={self.depth})")


class _NullSpan:
    """Shared no-op context manager for a disabled tracer."""

    __slots__ = ()
    name = None
    duration_s = 0.0
    args: dict = {}

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _SpanCtx:
    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        stack = self._tracer._stack()
        span = self._span
        span.parent = stack[-1].name if stack else None
        span.depth = len(stack)
        stack.append(span)
        span.t0 = time.perf_counter()
        return span

    def __exit__(self, *exc) -> bool:
        span = self._span
        span.t1 = time.perf_counter()
        self._tracer._stack().pop()
        self._tracer._record(span)
        return False


class Tracer:
    """Collects spans; thread-safe; exports Chrome ``trace.json``.

    Spans are recorded on EXIT (so the list is completion-ordered); nesting
    is tracked per thread, which is what the serving layer needs — trainer,
    batcher and client threads each keep their own span stack but land in
    one trace with their thread names attached.
    """

    def __init__(self, enabled: bool = True, max_spans: int = 1_000_000):
        self.enabled = enabled
        self.max_spans = max_spans
        self.dropped = 0
        self.spans: list[Span] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        # perf_counter has an arbitrary origin; pin one per tracer so the
        # chrome timeline starts near 0
        self._origin = time.perf_counter()

    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, span: Span) -> None:
        span.thread = threading.get_ident()
        with self._lock:
            if len(self.spans) >= self.max_spans:
                self.dropped += 1
                return
            self.spans.append(span)

    def span(self, name: str, **args):
        """Context manager timing one region; yields the live :class:`Span`
        (a shared no-op when the tracer is disabled)."""
        if not self.enabled:
            return NULL_SPAN
        return _SpanCtx(self, Span(name, args=args))

    def clear(self) -> None:
        with self._lock:
            self.spans = []
            self.dropped = 0

    def summary(self) -> dict:
        """Per-name aggregate: {name: {count, total_s, mean_s, max_s}}."""
        with self._lock:
            spans = list(self.spans)
        out: dict[str, dict] = {}
        for s in spans:
            agg = out.setdefault(s.name, {"count": 0, "total_s": 0.0,
                                          "max_s": 0.0})
            agg["count"] += 1
            agg["total_s"] += s.duration_s
            agg["max_s"] = max(agg["max_s"], s.duration_s)
        for agg in out.values():
            agg["mean_s"] = agg["total_s"] / agg["count"]
            agg["total_s"] = round(agg["total_s"], 6)
            agg["mean_s"] = round(agg["mean_s"], 6)
            agg["max_s"] = round(agg["max_s"], 6)
        return out

    def chrome_events(self) -> list[dict]:
        """The spans as Chrome trace ``X`` (complete) events plus thread
        metadata; timestamps/durations in microseconds from tracer start."""
        with self._lock:
            spans = list(self.spans)
        tids: dict[int, int] = {}
        events = []
        for s in spans:
            tid = tids.setdefault(s.thread, len(tids))
            events.append({
                "ph": "X", "name": s.name, "pid": 0, "tid": tid,
                "ts": round((s.t0 - self._origin) * 1e6, 3),
                "dur": round(s.duration_s * 1e6, 3),
                "args": dict(s.args, parent=s.parent),
            })
        meta = [{"ph": "M", "name": "thread_name", "pid": 0, "tid": tid,
                 "args": {"name": f"thread-{tid}"}}
                for tid in sorted(tids.values())]
        return meta + events

    def export_chrome(self, path: str) -> str:
        """Write ``trace.json`` (open in chrome://tracing or Perfetto)."""
        payload = {"displayTimeUnit": "ms",
                   "traceEvents": self.chrome_events()}
        with open(path, "w") as f:
            json.dump(payload, f)
        return path
