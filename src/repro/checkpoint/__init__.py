from repro.checkpoint.store import save_checkpoint, restore_checkpoint, latest_step
from repro.checkpoint.async_writer import AsyncCheckpointer

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]
