"""Checkpointing: pytree -> npz shards + msgpack manifest.

Sharding-aware in the sense that arrays are pulled to host with
jax.device_get (works for fully-addressable shardings; multi-host
checkpointing on a real cluster would gather per-process shards — noted in
DESIGN.md as a deployment delta). Keys are flattened tree paths so the
manifest is stable across jax versions.
"""
from __future__ import annotations

import os
import re
from typing import Any

import jax
import msgpack
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(re.sub(r"[^\w]", "", str(p)) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    # bf16 isn't npz-native: store raw bytes + dtype tag
    arrays, meta = {}, {}
    for k, v in flat.items():
        if v.dtype == np.dtype("bfloat16"):
            arrays[k] = v.view(np.uint16)
            meta[k] = "bfloat16"
        else:
            arrays[k] = v
            meta[k] = str(v.dtype)
    np.savez(path, **arrays)
    with open(path + ".meta", "wb") as f:
        f.write(msgpack.packb({"step": step, "dtypes": meta}))
    return path


def restore_checkpoint(directory: str, tree_like: Any, step: int | None = None) -> Any:
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with open(path + ".meta", "rb") as f:
        meta = msgpack.unpackb(f.read())
    data = np.load(path)
    flat_keys = list(_flatten(tree_like).keys())
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    out = []
    import ml_dtypes
    for key, like in zip(flat_keys, leaves):
        arr = data[key]
        if meta["dtypes"][key] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        out.append(arr.reshape(like.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None
