"""Threaded checkpoint writes: snapshot-to-host now, disk I/O later.

The serving layer publishes model snapshots every few rounds; blocking a
publication on an npz write would stall both the trainer and (through the
publication lock) the predictor. `AsyncCheckpointer` splits the two
halves of `save_checkpoint`: the device->host gather happens synchronously
in `save` (so the caller can keep mutating device state immediately), and
the serialization + file write run on a single background thread. A
bounded queue applies backpressure instead of letting pending host copies
pile up; errors from the writer thread surface on the next `save`, `wait`
or `close`.

>>> import tempfile
>>> import jax.numpy as jnp
>>> from repro.checkpoint import AsyncCheckpointer, restore_checkpoint
>>> d = tempfile.mkdtemp()
>>> ck = AsyncCheckpointer(d)
>>> ck.save(4, {"theta": jnp.ones((2, 3))})
>>> ck.close()                              # flushes pending writes
>>> restored = restore_checkpoint(d, {"theta": jnp.zeros((2, 3))}, step=4)
>>> bool((restored["theta"] == 1.0).all())
True
"""
from __future__ import annotations

import queue
import threading
from typing import Any

import jax

from repro.checkpoint.store import save_checkpoint

__all__ = ["AsyncCheckpointer"]

_SENTINEL = object()


class AsyncCheckpointer:
    """Background-thread `save_checkpoint` with bounded backpressure."""

    def __init__(self, directory: str, max_pending: int = 2):
        if max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        self.directory = directory
        self._q: queue.Queue = queue.Queue(maxsize=max_pending)
        self._error: BaseException | None = None
        self._closed = False
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="repro-async-ckpt")
        self._thread.start()

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is _SENTINEL:
                    return
                step, host_tree = item
                save_checkpoint(self.directory, step, host_tree)
            except BaseException as err:     # surfaced on the caller thread
                self._error = err
            finally:
                self._q.task_done()

    def _raise_pending(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                f"async checkpoint write to {self.directory} failed") from err

    def save(self, step: int, tree: Any) -> None:
        """Gather ``tree`` to host NOW; enqueue the write. Blocks only when
        ``max_pending`` writes are already queued (backpressure)."""
        if self._closed:
            raise RuntimeError("AsyncCheckpointer is closed")
        self._raise_pending()
        host_tree = jax.device_get(tree)
        self._q.put((step, host_tree))

    def wait(self) -> None:
        """Block until every enqueued write hit disk; re-raise failures."""
        self._q.join()
        self._raise_pending()

    def close(self) -> None:
        """Flush pending writes and stop the worker (idempotent)."""
        if self._closed:
            return
        self._closed = True
        self._q.put(_SENTINEL)
        self._thread.join()
        self._raise_pending()

    def __enter__(self) -> "AsyncCheckpointer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
