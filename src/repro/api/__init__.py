"""repro.api — composable strategy layer for the paper's round pipeline.

Decomposes Algorithm 1 (clip -> Laplace-noise -> gossip-mix -> local sparse
update -> L1 prox) into four registry-backed protocols shared by BOTH
engines (the dense simulator `core.algorithm1.Algorithm1` and the
distributed `core.gossip.GossipDP`):

  Mixer      — topology (ring, complete, disconnected, ring_alternating,
               dense/torus/hypercube/random/time_varying, delayed,
               het_delayed)
  Mechanism  — privacy (laplace [global|coordinate calibration], gaussian,
               none)
  LocalRule  — sparse update (omd, tg, rda)
  Clipper    — gradient bounding (l2, value, none)

`RunSpec` is the single declarative description that builds either engine;
new scenarios register via the registries and never touch engine code.

>>> from repro.api import RunSpec, MIXERS, MECHANISMS, LOCAL_RULES, CLIPPERS
>>> "ring" in MIXERS.names() and "het_delayed" in MIXERS.names()
True
>>> ("laplace" in MECHANISMS.names(), "omd" in LOCAL_RULES.names(),
...  "l2" in CLIPPERS.names())
(True, True, True)
>>> spec = RunSpec(nodes=4, dim=8, mixer="ring", mechanism="laplace",
...                eps=1.0, local_rule="omd", lam=1e-3, alpha0=1.0)
>>> spec.resolve_mixer().m
4
>>> round(float(spec.resolve_mechanism().scale(1.0, n=8)), 4)  # Lemma-1 mu
5.6569

Data scenarios are a fifth protocol: `Stream` instances resolve through the
STREAMS registry and `run()` drives either engine over them end-to-end
(regret trajectory, eps ledger, wall-clock — see `repro.api.runner`):

>>> from repro.api import STREAMS, run
>>> {"social_sparse", "drift", "heterogeneous", "bursty"} <= set(STREAMS.names())
True
>>> spec.replace(horizon=4).resolve_stream().__class__.__name__
'SocialStream'

HOW the round body executes is a sixth axis: the BACKENDS registry maps
`RunSpec.backend` to an execution backend — "reference" (plain XLA) or
"pallas" (the fused kernels of `repro.kernels.round_fused`); execution
knobs travel as one `ExecConfig` (see `repro.api.exec_config`):

>>> from repro.api import BACKENDS
>>> BACKENDS.names()
('pallas', 'reference')
"""
from repro.api.registry import (BACKENDS, CLIPPERS, LOCAL_RULES, MECHANISMS,
                                MIXERS, STREAMS, Registry)
from repro.api.mixers import (AlternatingRingMixer, CompleteMixer,
                              DelayedMixer, DenseMatrixMixer,
                              DisconnectedMixer, HeterogeneousDelayMixer,
                              Mixer, MixerBase, RingRollMixer, ring_read,
                              ring_write, sample_edge_delays)
from repro.api.mechanisms import (GaussianMechanism, LaplaceMechanism,
                                  Mechanism, NoNoise)
from repro.api.rules import (LocalRule, OMDLassoRule, RDARule, StepContext,
                             TruncatedGradientRule)
from repro.api.clippers import (Clipper, NoClipper, PerNodeL2Clipper,
                                ValueClipper, per_node_norms)
from repro.api.streams import (BurstyStream, DriftStream,
                               HeterogeneousStream, SocialStream, Stream)
from repro.api.spec import RunSpec
from repro.api.exec_config import ExecConfig
from repro.api.runner import RunResult, run, run_batch, seed_vectorizable
# importing repro.api.backends registers the BACKENDS entries
from repro.api.backends import PallasBackend, ReferenceBackend

__all__ = [
    "Registry", "MIXERS", "MECHANISMS", "LOCAL_RULES", "CLIPPERS", "STREAMS",
    "BACKENDS", "ReferenceBackend", "PallasBackend", "ExecConfig",
    "Mixer", "MixerBase", "DenseMatrixMixer", "RingRollMixer",
    "CompleteMixer", "DisconnectedMixer", "AlternatingRingMixer",
    "DelayedMixer", "HeterogeneousDelayMixer",
    "ring_read", "ring_write", "sample_edge_delays",
    "Mechanism", "LaplaceMechanism", "GaussianMechanism", "NoNoise",
    "LocalRule", "StepContext", "OMDLassoRule", "TruncatedGradientRule",
    "RDARule",
    "Clipper", "PerNodeL2Clipper", "ValueClipper", "NoClipper",
    "per_node_norms",
    "Stream", "SocialStream", "DriftStream", "HeterogeneousStream",
    "BurstyStream",
    "RunSpec", "RunResult", "run", "run_batch", "seed_vectorizable",
    "SweepSpec", "SweepResult", "sweep",
]

# repro.sweep builds ON TOP of repro.api (its modules import repro.api.spec /
# repro.api.runner), so re-exporting it here must be lazy — a plain import
# would re-enter repro.sweep while it is still initializing whenever the
# import chain STARTS at repro.sweep. PEP 562 module __getattr__ keeps
# `repro.api.sweep(spec)` a first-class entry point next to `run(spec)`
# without the cycle.
_SWEEP_EXPORTS = ("SweepSpec", "SweepResult", "sweep")


def __getattr__(name):
    if name in _SWEEP_EXPORTS:
        import repro.sweep as _sweep
        return getattr(_sweep, name)
    raise AttributeError(f"module 'repro.api' has no attribute {name!r}")
