"""Named registries for the pluggable round-pipeline protocols.

Every stage of the paper's round — mixing topology, privacy mechanism,
local sparse-update rule, gradient clipper — is resolved by name through
one of these registries, so a new scenario (topology, mechanism, loss)
registers itself and plugs into BOTH engines (`core.algorithm1.Algorithm1`
and `core.gossip.GossipDP`) without editing engine code:

    from repro.api import MIXERS

    @MIXERS.register("my_topology")
    def _build(m, seed=0, **kw):
        return MyMixer(m=m, **kw)

Factories receive the registry-specific build kwargs (documented on each
registry instance below) plus any user options; extra kwargs a factory does
not need are filtered out by signature inspection, so factories only declare
what they use.

>>> from repro.api import MIXERS
>>> MIXERS.build("ring", m=4).m                 # declarative path
4
>>> mixer = MIXERS.build("ring", m=4)
>>> MIXERS.build(mixer) is mixer                # instances pass through
True
>>> MIXERS.build("nope", m=4)
Traceback (most recent call last):
    ...
repro.api.registry.UnknownEntryError: unknown mixer 'nope'...
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Generic, TypeVar

__all__ = ["Registry", "UnknownEntryError", "MIXERS", "MECHANISMS",
           "LOCAL_RULES", "CLIPPERS", "STREAMS", "BACKENDS"]

T = TypeVar("T")


class UnknownEntryError(KeyError, ValueError):
    """Unknown registry name. Subclasses both KeyError (mapping semantics)
    and ValueError (invalid-argument semantics the legacy constructors
    documented), so either handler style keeps working."""

    def __str__(self) -> str:  # KeyError repr-quotes its arg; keep the message
        return self.args[0] if self.args else ""


class Registry(Generic[T]):
    """A name -> factory map with decorator registration.

    ``build`` accepts either a registered name (factory is invoked with the
    kwargs it declares) or an already-constructed instance (passed through),
    which lets `RunSpec` fields hold names for the declarative path and
    objects for the fully-custom path.
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._factories: dict[str, Callable[..., T]] = {}

    def register(self, name: str, *aliases: str) -> Callable[[Callable[..., T]], Callable[..., T]]:
        def deco(factory: Callable[..., T]) -> Callable[..., T]:
            for key in (name, *aliases):
                if key in self._factories:
                    raise ValueError(f"{self.kind} {key!r} already registered")
                self._factories[key] = factory
            return factory
        return deco

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._factories))

    def describe(self) -> dict[str, str]:
        """name -> first docstring line of the factory, for listings.

        >>> from repro.api import BACKENDS
        >>> for name, what in BACKENDS.describe().items():
        ...     print(f"{name}: {what}")
        pallas: Fused Pallas round body (see docs/kernels.md).
        reference: Plain-XLA engines (the correctness oracle).
        """
        out = {}
        for name in self.names():
            doc = inspect.getdoc(self._factories[name]) or ""
            out[name] = doc.splitlines()[0] if doc else ""
        return out

    def get(self, name: str) -> Callable[..., T]:
        try:
            return self._factories[name]
        except KeyError:
            raise UnknownEntryError(
                f"unknown {self.kind} {name!r}; registered: {self.names()}"
            ) from None

    def build(self, spec: str | T, options: dict | None = None,
              **injected: Any) -> T:
        """Build ``spec`` by name, or pass an instance through.

        ``injected`` kwargs are the caller's shared context (node count,
        privacy knobs, seed): a factory that does not declare one simply
        does not receive it. ``options`` are explicit user choices and must
        be declared by the factory — a typo'd option raises instead of
        silently running the default configuration. ``options`` win over
        ``injected`` on collision.
        """
        if not isinstance(spec, str):
            return spec
        factory = self.get(spec)
        params = inspect.signature(factory).parameters
        has_var_kw = any(p.kind == inspect.Parameter.VAR_KEYWORD
                         for p in params.values())
        options = dict(options or {})
        if not has_var_kw:
            injected = {k: v for k, v in injected.items() if k in params}
            unknown = sorted(k for k in options if k not in params)
            if unknown:
                accepted = sorted(k for k in params
                                  if k != "self" and not k.startswith("_"))
                raise TypeError(
                    f"{self.kind} {spec!r} got unexpected options {unknown}; "
                    f"accepted: {accepted}")
        return factory(**{**injected, **options})


# Build kwargs supplied by RunSpec.resolve_*():
#   MIXERS      — m (node count), seed, + user mixer_options
#   MECHANISMS  — eps, L (clip bound), noise_self, + user mechanism_options
#   LOCAL_RULES — prox_kind, + user local_rule_options
#   CLIPPERS    — max_norm, + user clipper_options
#   STREAMS     — n (feature dim), nodes, rounds (horizon), seed,
#                 + user stream_options
MIXERS: Registry = Registry("mixer")
MECHANISMS: Registry = Registry("mechanism")
LOCAL_RULES: Registry = Registry("local rule")
CLIPPERS: Registry = Registry("clipper")
STREAMS: Registry = Registry("stream")
#   BACKENDS    — how the round body executes ("reference" XLA engines or
#                 the fused "pallas" kernels); built by RunSpec.resolve_
#                 backend() with user backend_options. Entries register in
#                 repro.api.backends.
BACKENDS: Registry = Registry("backend")
