"""Mechanism protocol — the privacy stage of the round pipeline.

A Mechanism owns (a) the per-round noise scale, calibrated to the Lemma-1
sensitivity of the broadcast theta~, and (b) the sampler that perturbs the
egress copies. Engines call ``scale`` once per round and ``sample`` once per
state leaf; they never branch on what kind of mechanism is installed.

Calibrations (Laplace):
  'global'     — the paper's exact Lemma-1 L1 sensitivity 2*alpha_t*sqrt(n)*L
  'coordinate' — beyond-paper per-coordinate sensitivity 2*alpha_t*L, the
                 deployable choice at transformer scale where the sqrt(n)
                 factor of the global bound drowns learning (DESIGN.md #3).

>>> from repro.api import MECHANISMS
>>> mech = MECHANISMS.build("laplace", eps=2.0, L=1.0,
...                         calibration="coordinate")
>>> float(mech.scale(0.5, n=100))               # 2 * alpha_t * L / eps
0.5
>>> MECHANISMS.build("none").is_private
False
"""
from __future__ import annotations

import dataclasses
import math
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.api.registry import MECHANISMS

__all__ = ["Mechanism", "LaplaceMechanism", "GaussianMechanism", "NoNoise"]


@runtime_checkable
class Mechanism(Protocol):
    """Privacy stage: per-round scale + sampler for the broadcast noise."""

    noise_self: bool  # faithful Algorithm 1 mixes noisy theta~ for j == i too

    @property
    def is_private(self) -> bool: ...

    def scale(self, alpha_t, n: int) -> jax.Array:
        """Noise scale for a round with step size alpha_t and dimension n."""
        ...

    def sample(self, key: jax.Array, shape, scale, dtype=jnp.float32) -> jax.Array:
        """Draw the egress perturbation (zeros when scale == 0)."""
        ...


@dataclasses.dataclass(frozen=True)
class LaplaceMechanism:
    """The paper's mechanism: Laplace(S(t)/eps) on every broadcast (Eq. 8).

    eps = inf degrades exactly to the non-private path (scale 0, and the
    inverse-CDF sampler returns exact zeros), so sweeps over eps need no
    special casing.
    """

    eps: float = 1.0
    L: float = 1.0
    calibration: str = "global"   # 'global' (Lemma 1) | 'coordinate'
    noise_self: bool = True

    def __post_init__(self):
        if self.calibration not in ("global", "coordinate"):
            raise ValueError(f"unknown calibration {self.calibration!r}")

    @property
    def is_private(self) -> bool:
        return not math.isinf(self.eps)

    def scale(self, alpha_t, n: int) -> jax.Array:
        # deferred import: repro.core.__init__ imports the engines, which
        # import this module — a top-level core import would be circular
        from repro.core.privacy import laplace_scale
        if not self.is_private:
            return jnp.zeros(())
        if self.calibration == "coordinate":
            return 2.0 * jnp.asarray(alpha_t) * self.L / self.eps
        return laplace_scale(alpha_t, n, self.L, self.eps)

    def sample(self, key, shape, scale, dtype=jnp.float32):
        from repro.core.privacy import sample_laplace
        return sample_laplace(key, shape, scale, dtype)


@dataclasses.dataclass(frozen=True)
class GaussianMechanism:
    """Beyond-paper (eps, delta)-DP: Gaussian noise with the classic
    analytic calibration sigma = sqrt(2 ln(1.25/delta)) * S2(t) / eps, where
    the L2 sensitivity of theta~ is S2(t) = 2 * alpha_t * L (no sqrt(n):
    the L2 ball of Assumption 2.3 is dimension-free)."""

    eps: float = 1.0
    delta: float = 1e-5
    L: float = 1.0
    noise_self: bool = True

    @property
    def is_private(self) -> bool:
        return not math.isinf(self.eps)

    def scale(self, alpha_t, n: int) -> jax.Array:
        if not self.is_private:
            return jnp.zeros(())
        c = math.sqrt(2.0 * math.log(1.25 / self.delta))
        return c * 2.0 * jnp.asarray(alpha_t) * self.L / self.eps

    def sample(self, key, shape, scale, dtype=jnp.float32):
        return jnp.asarray(scale, dtype) * jax.random.normal(key, shape, dtype)


@dataclasses.dataclass(frozen=True)
class NoNoise:
    """Explicit non-private mechanism (plain gossip averaging baseline)."""

    noise_self: bool = True

    @property
    def is_private(self) -> bool:
        return False

    def scale(self, alpha_t, n: int) -> jax.Array:
        return jnp.zeros(())

    def sample(self, key, shape, scale, dtype=jnp.float32):
        return jnp.zeros(shape, dtype)


@MECHANISMS.register("laplace")
def _laplace(eps: float = 1.0, L: float = 1.0, calibration: str = "global",
             noise_self: bool = True) -> Mechanism:
    return LaplaceMechanism(eps=eps, L=L, calibration=calibration,
                            noise_self=noise_self)


@MECHANISMS.register("gaussian")
def _gaussian(eps: float = 1.0, L: float = 1.0, delta: float = 1e-5,
              noise_self: bool = True) -> Mechanism:
    return GaussianMechanism(eps=eps, delta=delta, L=L, noise_self=noise_self)


@MECHANISMS.register("none")
def _none(noise_self: bool = True) -> Mechanism:
    return NoNoise(noise_self=noise_self)
