"""Mixer protocol — the gossip topology stage of the round pipeline.

A Mixer applies the doubly-stochastic A(t) of Assumption 1 along axis 0
(the node axis) of an (m, ...) array. Both engines consume the same
protocol: the simulator (`core.algorithm1`) feeds it (m, n) matrices, the
distributed strategy (`core.gossip`) feeds it every node-stacked pytree
leaf. Roll-based mixers lower to collective-permute when the node axis is
sharded (the paper's "adjacent data centers only" constraint on the ICI
ring); the dense-matrix mixer supports ANY doubly-stochastic schedule and
hoists the matrix stack to construction time (no per-round `jnp.stack`).

The mix signature carries both the clean theta and the noised broadcast
copy theta~ so the mixer — not the engine — owns the noise-placement
algebra: with ``noise_self=True`` (faithful Algorithm 1 line 10) the
self-term uses theta~; with False the own-noise contribution
``diag(A) * (theta~ - theta)`` is removed, since a node's own state needs
no network hop.

Delayed (WAN) mixing: both engines keep a fixed-depth ring buffer of past
theta~ broadcasts (see docs/delayed_gossip.md) and hand the whole ring to
:meth:`Mixer.mix_history`; ``ring_write`` / ``ring_read`` below are the
shared jit/scan-safe ring primitives. ``DelayedMixer`` applies one uniform
staleness to every edge; ``HeterogeneousDelayMixer`` draws a per-edge delay
from a seeded distribution (each WAN link has its own latency).

>>> import jax.numpy as jnp
>>> from repro.api.mixers import MIXERS, RingRollMixer
>>> mixer = MIXERS.build("ring", m=4, self_weight=0.5)
>>> x = jnp.arange(4.0).reshape(4, 1)
>>> [round(v, 3) for v in mixer.apply(x, 0)[:, 0].tolist()]
[1.0, 1.0, 2.0, 2.0]
>>> MIXERS.build("delayed", m=4, inner="ring", delay=2).delay
2
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registry import MIXERS

__all__ = [
    "Mixer",
    "MixerBase",
    "DenseMatrixMixer",
    "SparseMixer",
    "RingRollMixer",
    "CompleteMixer",
    "DisconnectedMixer",
    "AlternatingRingMixer",
    "DelayedMixer",
    "HeterogeneousDelayMixer",
    "ring_write",
    "ring_read",
    "sample_edge_delays",
]


def _bcast(diag: jax.Array, like: jax.Array) -> jax.Array:
    """Broadcast an (m,) diagonal against an (m, ...) leaf."""
    return diag.reshape((-1,) + (1,) * (like.ndim - 1)).astype(like.dtype)


# -- history ring primitives (shared by both engines) ------------------------
#
# A history ring stores the last ``depth`` broadcast copies of one state leaf
# as a stacked leading axis: hist (depth, m, ...). Round t (0-based) writes
# slot t % depth, so the copy from d rounds ago (d < depth) lives at slot
# (t - d) % depth. Both primitives are branch-free in traced values, so they
# are safe inside jit / lax.scan (the round counter t is a traced int32).

def ring_write(hist: jax.Array, t: jax.Array, value: jax.Array) -> jax.Array:
    """Write this round's broadcast copy into its ring slot (t % depth)."""
    return hist.at[t % hist.shape[0]].set(value)


def ring_read(hist: jax.Array, t: jax.Array, d: int,
              fallback: jax.Array) -> jax.Array:
    """The broadcast copy from ``d`` rounds ago, AFTER this round's write.

    During warm-up (t < d, nothing that old exists yet) returns ``fallback``
    — the current theta~, i.e. the engine degrades to synchronous mixing
    until the pipe is full. d == 0 reads back the slot ``ring_write`` just
    filled, so a zero delay degenerates to the synchronous value bit-for-bit.
    """
    depth = hist.shape[0]
    stale = jax.lax.dynamic_index_in_dim(hist, (t - d) % depth, 0,
                                         keepdims=False)
    return jnp.where(t >= d, stale, fallback)


@runtime_checkable
class Mixer(Protocol):
    """Topology stage: mixes (m, ...) arrays with A(t) along axis 0."""

    m: int
    delay: int  # rounds of staleness for neighbor terms (0 = synchronous)

    def apply(self, x: jax.Array, t: jax.Array) -> jax.Array:
        """A(t) @ x along the node axis (noise-agnostic linear map)."""
        ...

    def diag(self, t: jax.Array) -> jax.Array:
        """(m,) diagonal of A(t) — the self-weights."""
        ...

    def mix(self, clean: jax.Array, tilde: jax.Array, noise_self: bool,
            t: jax.Array) -> jax.Array:
        """One synchronous gossip exchange of the noised broadcast copies."""
        ...

    def mix_delayed(self, clean: jax.Array, tilde: jax.Array, recv: jax.Array,
                    noise_self: bool, t: jax.Array) -> jax.Array:
        """Exchange where neighbor terms use the stale ``recv`` copies."""
        ...

    def mix_history(self, clean: jax.Array, tilde: jax.Array,
                    hist: jax.Array | None, noise_self: bool,
                    t: jax.Array) -> jax.Array:
        """Exchange against a (depth, m, ...) ring of past broadcasts.

        ``hist`` is the post-``ring_write`` ring for this round (slot
        t % depth holds the current theta~); None when the engine carries no
        history (mixer.delay == 0), in which case this must equal mix().
        """
        ...


class MixerBase:
    """Default noise-placement algebra shared by all concrete mixers.

    Subclasses implement :meth:`apply` and :meth:`diag`; the generic
    identities below then cover every topology:

      mix         = A x~                      (noise_self)
                  = A x~ - diag * (x~ - x)    (own-noise removed)
      mix_delayed = A r - diag * r + diag * s where s = x~ or x
      mix_history = mix_delayed with r read from the ring at self.delay
    """

    m: int = 0
    delay: int = 0

    def apply(self, x: jax.Array, t: jax.Array) -> jax.Array:
        raise NotImplementedError

    def diag(self, t: jax.Array) -> jax.Array:
        raise NotImplementedError

    def mix(self, clean, tilde, noise_self, t):
        mixed = self.apply(tilde, t)
        if not noise_self:
            mixed = mixed - _bcast(self.diag(t), tilde) * (tilde - clean)
        return mixed

    def mix_delayed(self, clean, tilde, recv, noise_self, t):
        d = _bcast(self.diag(t), recv)
        self_term = tilde if noise_self else clean
        return self.apply(recv, t) - d * recv + d * self_term

    def mix_history(self, clean, tilde, hist, noise_self, t):
        if not self.delay:
            return self.mix(clean, tilde, noise_self, t)
        if hist is None:
            # a lenient fallback here would silently run the synchronous
            # exchange while the caller believes it measured staleness
            raise ValueError(
                f"{type(self).__name__} declares delay={self.delay} but no "
                "history ring was provided (engine state missing .history)")
        recv = ring_read(hist, t, self.delay, tilde)
        return self.mix_delayed(clean, tilde, recv, noise_self, t)


@dataclasses.dataclass(frozen=True)
class DenseMatrixMixer(MixerBase):
    """Any (possibly time-varying) doubly-stochastic schedule as dense A(t).

    The matrix stack and its diagonals are materialised ONCE at construction
    (the seed code re-stacked ``graph.matrices`` inside every traced round).
    ``apply`` contracts the node axis with tensordot, so it also mixes
    node-stacked pytree leaves of any trailing shape.
    """

    stack: Any               # (k, m, m) jnp.float32
    name: str = "dense"
    delay: int = 0

    def __post_init__(self):
        stack = jnp.asarray(self.stack, jnp.float32)
        if stack.ndim == 2:
            stack = stack[None]
        object.__setattr__(self, "stack", stack)
        object.__setattr__(self, "_diags",
                           jnp.stack([jnp.diag(A) for A in stack]))

    @property
    def m(self) -> int:
        return int(self.stack.shape[-1])

    @classmethod
    def from_graph(cls, graph: "GossipGraph", delay: int = 0) -> "DenseMatrixMixer":
        return cls(stack=np.stack([np.asarray(A) for A in graph.matrices]),
                   name=graph.name, delay=delay)

    @classmethod
    def from_topology(cls, topology: str, m: int, seed: int = 0,
                      **kw) -> "DenseMatrixMixer":
        # deferred: repro.core.__init__ imports the engines, which import
        # this module — a top-level core import would be circular
        from repro.core.graph import GossipGraph
        return cls.from_graph(GossipGraph.make(topology, m, seed=seed, **kw))

    def apply(self, x, t):
        A = self.stack[t % self.stack.shape[0]]
        return jnp.tensordot(A, x.astype(A.dtype), axes=1).astype(x.dtype)

    def diag(self, t):
        return self._diags[t % self.stack.shape[0]]


@dataclasses.dataclass(frozen=True)
class SparseMixer(MixerBase):
    """Any FIXED doubly-stochastic topology as an edge list + segment_sum.

    ``apply`` is the sparse matvec ``out[i] = sum_j A[i,j] x[j]`` computed
    as one gather + weighted ``segment_sum`` over the canonical
    (dst, src)-sorted edges of a `repro.core.graph.SparseGraph` — O(edges)
    instead of the dense mixer's O(m^2), which is what lets the node axis
    reach the paper's 10^5..10^6 "social big data" scale. Edge arrays are
    hoisted to construction time (no per-round stacking), mirroring the
    DenseMatrixMixer refactor.

    Equivalence contract: for the same topology the result matches the
    dense matvec to float32 reduction-order tolerance (segment_sum and
    tensordot may reduce a row in different orders); the dense-vs-sparse
    suite (tests/test_sparse_graph.py) asserts the bound. Mixing the SAME
    SparseMixer under sim and dist engines stays bit-identical.
    """

    graph: Any               # repro.core.graph.SparseGraph (fixed topology)
    delay: int = 0
    name: str = "sparse"

    def __post_init__(self):
        g = self.graph
        for field in ("dst", "src", "weight", "m"):
            if not hasattr(g, field):
                raise TypeError(
                    "SparseMixer needs a repro.core.graph.SparseGraph "
                    f"(got {type(g).__name__} without .{field})")
        object.__setattr__(self, "_dst", jnp.asarray(g.dst, jnp.int32))
        object.__setattr__(self, "_src", jnp.asarray(g.src, jnp.int32))
        object.__setattr__(self, "_w", jnp.asarray(g.weight, jnp.float32))
        object.__setattr__(self, "_diag", jnp.asarray(g.diag(), jnp.float32))

    @property
    def m(self) -> int:
        return int(self.graph.m)

    @classmethod
    def from_topology(cls, topology: str, m: int, seed: int = 0,
                      delay: int = 0, **kw) -> "SparseMixer":
        # deferred: repro.core.__init__ imports the engines, which import
        # this module — a top-level core import would be circular
        from repro.core.graph import SparseGraph
        return cls(graph=SparseGraph.make(topology, m, seed=seed, **kw),
                   delay=delay, name=topology)

    def apply(self, x, t):
        w = self._w.reshape((-1,) + (1,) * (x.ndim - 1))
        vals = w * x[self._src].astype(jnp.float32)
        out = jax.ops.segment_sum(vals, self._dst, num_segments=self.m,
                                  indices_are_sorted=True)
        return out.astype(x.dtype)

    def diag(self, t):
        return self._diag


@dataclasses.dataclass(frozen=True)
class RingRollMixer(MixerBase):
    """Bidirectional ring via jnp.roll — lowers to collective-permute on a
    sharded node axis. Numerically identical to ``graph.ring_matrix``."""

    m: int
    self_weight: float = 0.5
    delay: int = 0

    def apply(self, x, t):
        nw = (1.0 - self.self_weight) / 2.0
        return (self.self_weight * x
                + nw * jnp.roll(x, 1, axis=0)
                + nw * jnp.roll(x, -1, axis=0))

    def diag(self, t):
        return jnp.full((self.m,), self.self_weight, jnp.float32)


@dataclasses.dataclass(frozen=True)
class CompleteMixer(MixerBase):
    """Fully connected graph: exact consensus (all-reduce mean) every round."""

    m: int
    delay: int = 0

    def apply(self, x, t):
        return jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape)

    def diag(self, t):
        return jnp.full((self.m,), 1.0 / self.m, jnp.float32)


@dataclasses.dataclass(frozen=True)
class DisconnectedMixer(MixerBase):
    """No communication: every node keeps its own CLEAN state.

    Nothing leaves the node, so nothing needs the Laplace broadcast noise —
    ``mix`` ignores theta~ entirely (local-only ablation baseline).
    """

    m: int
    delay: int = 0

    def apply(self, x, t):
        return x

    def diag(self, t):
        return jnp.ones((self.m,), jnp.float32)

    def mix(self, clean, tilde, noise_self, t):
        return clean

    def mix_delayed(self, clean, tilde, recv, noise_self, t):
        return clean

    def mix_history(self, clean, tilde, hist, noise_self, t):
        return clean


@dataclasses.dataclass(frozen=True)
class AlternatingRingMixer(MixerBase):
    """Time-varying graph: even rounds pair with the +1 ring neighbor, odd
    rounds with the -1 neighbor; each A(t) is a (1/2, 1/2) circulant."""

    m: int
    delay: int = 0

    def apply(self, x, t):
        fwd = 0.5 * x + 0.5 * jnp.roll(x, 1, axis=0)
        bwd = 0.5 * x + 0.5 * jnp.roll(x, -1, axis=0)
        return jnp.where((t % 2) == 0, fwd, bwd)

    def diag(self, t):
        return jnp.full((self.m,), 0.5, jnp.float32)


@dataclasses.dataclass(frozen=True)
class DelayedMixer(MixerBase):
    """Wrap any mixer with a uniform WAN delay: neighbor terms arrive
    ``delay`` rounds late (paper §VI future work). The engines own the
    history ring buffer (see docs/delayed_gossip.md); this wrapper only
    declares the staleness and delegates the algebra to the inner mixer
    (mix_history comes from MixerBase and reads the ring at ``delay``)."""

    inner: Mixer
    delay: int = 1

    def __post_init__(self):
        if self.delay < 1:
            raise ValueError("DelayedMixer needs delay >= 1")

    @property
    def m(self) -> int:
        return self.inner.m

    def apply(self, x, t):
        return self.inner.apply(x, t)

    def diag(self, t):
        return self.inner.diag(t)

    def mix(self, clean, tilde, noise_self, t):
        return self.inner.mix(clean, tilde, noise_self, t)

    def mix_delayed(self, clean, tilde, recv, noise_self, t):
        return self.inner.mix_delayed(clean, tilde, recv, noise_self, t)


def sample_edge_delays(m: int, max_delay: int, dist: str = "uniform",
                       seed: int = 0,
                       support: np.ndarray | None = None) -> np.ndarray:
    """Draw an (m, m) int matrix of per-edge staleness values.

    dist: 'constant'  — every edge lags exactly max_delay rounds;
          'uniform'   — integer delays uniform on [0, max_delay];
          'geometric' — mostly-fresh links with a heavy tail (p=0.5),
                        clipped to max_delay.
    The diagonal is always 0 (a node's own state needs no network hop) and
    delays outside ``support`` (the union of edges with nonzero A weight)
    are zeroed so they cannot inflate the ring depth.
    """
    rng = np.random.default_rng(seed)
    if dist == "constant":
        D = np.full((m, m), max_delay, np.int32)
    elif dist == "uniform":
        D = rng.integers(0, max_delay + 1, size=(m, m)).astype(np.int32)
    elif dist == "geometric":
        D = np.clip(rng.geometric(0.5, size=(m, m)) - 1, 0,
                    max_delay).astype(np.int32)
    else:
        raise ValueError(
            f"unknown delay_dist {dist!r}; expected "
            "'constant' | 'uniform' | 'geometric'")
    np.fill_diagonal(D, 0)
    if support is not None:
        D = np.where(support, D, 0).astype(np.int32)
        np.fill_diagonal(D, 0)
    return D


@dataclasses.dataclass(frozen=True)
class HeterogeneousDelayMixer(MixerBase):
    """Per-edge WAN delays: edge (i, j) delivers node j's broadcast to node
    i ``delays[i, j]`` rounds late, with the per-edge lag drawn once at
    construction from a seeded distribution (``sample_edge_delays``).

    Needs the dense form of A(t) — the mix decomposes into one masked
    matrix-apply per distinct delay class d:

        out_i = A_ii * s_i + sum_d sum_{j != i, delays[i,j]=d} A_ij(t) * r_j(d)

    where r(d) is the ring entry from d rounds ago and s is the current
    theta~ (or clean theta when noise_self=False). The loop over delay
    classes is a static Python loop of depth <= max_delay + 1 — fine under
    jit/scan since the masks are construction-time constants.
    """

    inner: DenseMatrixMixer
    delays: Any = None           # (m, m) np.int32; diagonal forced to 0
    name: str = "het_delayed"

    def __post_init__(self):
        D = np.asarray(self.delays, np.int32)
        if D.shape != (self.inner.m, self.inner.m):
            raise ValueError(
                f"delays must be ({self.inner.m}, {self.inner.m}), got {D.shape}")
        if (D < 0).any():
            raise ValueError("per-edge delays must be >= 0")
        D = D.copy()
        np.fill_diagonal(D, 0)
        object.__setattr__(self, "delays", D)

    @classmethod
    def from_topology(cls, topology: str, m: int, delay: int = 1,
                      delay_dist: str = "uniform", seed: int = 0,
                      **kw) -> "HeterogeneousDelayMixer":
        inner = DenseMatrixMixer.from_topology(topology, m, seed=seed, **kw)
        support = (np.asarray(inner.stack) > 0).any(axis=0)
        np.fill_diagonal(support, False)
        return cls(inner=inner,
                   delays=sample_edge_delays(m, delay, delay_dist, seed,
                                             support=support))

    @property
    def m(self) -> int:
        return self.inner.m

    @property
    def delay(self) -> int:
        return int(self.delays.max())

    def apply(self, x, t):
        return self.inner.apply(x, t)

    def diag(self, t):
        return self.inner.diag(t)

    def mix_delayed(self, clean, tilde, recv, noise_self, t):
        raise NotImplementedError(
            "HeterogeneousDelayMixer has no single stale view — MixerBase's "
            "uniform-recv algebra would silently ignore the per-edge delays; "
            "use mix_history with the engine's ring")

    def mix_history(self, clean, tilde, hist, noise_self, t):
        if hist is None:
            if self.delay:
                raise ValueError(
                    "HeterogeneousDelayMixer needs the engine's history ring "
                    "(GossipState/SimState.history); got None")
            hist = tilde[None]
        A = self.inner.stack[t % self.inner.stack.shape[0]]
        self_term = tilde if noise_self else clean
        out = _bcast(self.diag(t), tilde) * self_term
        offdiag = ~np.eye(self.m, dtype=bool)
        for d in range(self.delay + 1):
            mask = (self.delays == d) & offdiag
            if not mask.any():   # empty delay class: skip the dead tensordot
                continue
            Ad = A * jnp.asarray(mask, A.dtype)
            recv = ring_read(hist, t, d, tilde)
            out = out + jnp.tensordot(Ad, recv.astype(Ad.dtype),
                                      axes=1).astype(tilde.dtype)
        return out


# -- registry entries --------------------------------------------------------

@MIXERS.register("ring")
def _ring(m: int, self_weight: float = 0.5, delay: int = 0) -> Mixer:
    return RingRollMixer(m=m, self_weight=self_weight, delay=delay)


@MIXERS.register("complete")
def _complete(m: int, delay: int = 0) -> Mixer:
    return CompleteMixer(m=m, delay=delay)


@MIXERS.register("disconnected")
def _disconnected(m: int, delay: int = 0) -> Mixer:
    return DisconnectedMixer(m=m, delay=delay)


@MIXERS.register("ring_alternating")
def _ring_alternating(m: int, delay: int = 0) -> Mixer:
    return AlternatingRingMixer(m=m, delay=delay)


@MIXERS.register("dense")
def _dense(m: int, matrices=None, topology: str = "ring", seed: int = 0,
           delay: int = 0, **kw) -> Mixer:
    if matrices is not None:
        mixer = DenseMatrixMixer(stack=np.stack([np.asarray(A) for A in matrices]))
    else:
        mixer = DenseMatrixMixer.from_topology(topology, m, seed=seed, **kw)
    return dataclasses.replace(mixer, delay=delay)


@MIXERS.register("sparse")
def _sparse(m: int, graph=None, topology: str = "ring", seed: int = 0,
            delay: int = 0, **kw) -> Mixer:
    """Edge-list topology via SparseMixer: `graph=` takes a prebuilt
    SparseGraph; otherwise `topology=` builds one (ring/torus natively
    sparse, other fixed topologies via their dense form)."""
    if graph is not None:
        return SparseMixer(graph=graph, delay=delay,
                           name=getattr(graph, "name", "sparse"))
    return SparseMixer.from_topology(topology, m, seed=seed, delay=delay, **kw)


# Graph-backed topologies the simulator's Fig. 3 sweep uses, exposed directly.
for _name in ("torus", "hypercube", "random", "time_varying"):
    @MIXERS.register(_name)
    def _graph_mixer(m: int, seed: int = 0, delay: int = 0,
                     _topology: str = _name, **kw) -> Mixer:
        mixer = DenseMatrixMixer.from_topology(_topology, m, seed=seed, **kw)
        return dataclasses.replace(mixer, delay=delay)


@MIXERS.register("delayed")
def _delayed(m: int, inner: str | Mixer = "ring", delay: int = 1,
             seed: int = 0, **kw) -> Mixer:
    return DelayedMixer(inner=MIXERS.build(inner, m=m, seed=seed, **kw),
                        delay=delay)


@MIXERS.register("het_delayed")
def _het_delayed(m: int, inner: str = "ring", delay: int = 1,
                 delay_dist: str = "uniform", seed: int = 0, **kw) -> Mixer:
    return HeterogeneousDelayMixer.from_topology(inner, m, delay=delay,
                                                 delay_dist=delay_dist,
                                                 seed=seed, **kw)
