"""Mixer protocol — the gossip topology stage of the round pipeline.

A Mixer applies the doubly-stochastic A(t) of Assumption 1 along axis 0
(the node axis) of an (m, ...) array. Both engines consume the same
protocol: the simulator (`core.algorithm1`) feeds it (m, n) matrices, the
distributed strategy (`core.gossip`) feeds it every node-stacked pytree
leaf. Roll-based mixers lower to collective-permute when the node axis is
sharded (the paper's "adjacent data centers only" constraint on the ICI
ring); the dense-matrix mixer supports ANY doubly-stochastic schedule and
hoists the matrix stack to construction time (no per-round `jnp.stack`).

The mix signature carries both the clean theta and the noised broadcast
copy theta~ so the mixer — not the engine — owns the noise-placement
algebra: with ``noise_self=True`` (faithful Algorithm 1 line 10) the
self-term uses theta~; with False the own-noise contribution
``diag(A) * (theta~ - theta)`` is removed, since a node's own state needs
no network hop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registry import MIXERS

__all__ = [
    "Mixer",
    "MixerBase",
    "DenseMatrixMixer",
    "RingRollMixer",
    "CompleteMixer",
    "DisconnectedMixer",
    "AlternatingRingMixer",
    "DelayedMixer",
]


def _bcast(diag: jax.Array, like: jax.Array) -> jax.Array:
    """Broadcast an (m,) diagonal against an (m, ...) leaf."""
    return diag.reshape((-1,) + (1,) * (like.ndim - 1)).astype(like.dtype)


@runtime_checkable
class Mixer(Protocol):
    """Topology stage: mixes (m, ...) arrays with A(t) along axis 0."""

    m: int
    delay: int  # rounds of staleness for neighbor terms (0 = synchronous)

    def apply(self, x: jax.Array, t: jax.Array) -> jax.Array:
        """A(t) @ x along the node axis (noise-agnostic linear map)."""
        ...

    def diag(self, t: jax.Array) -> jax.Array:
        """(m,) diagonal of A(t) — the self-weights."""
        ...

    def mix(self, clean: jax.Array, tilde: jax.Array, noise_self: bool,
            t: jax.Array) -> jax.Array:
        """One synchronous gossip exchange of the noised broadcast copies."""
        ...

    def mix_delayed(self, clean: jax.Array, tilde: jax.Array, recv: jax.Array,
                    noise_self: bool, t: jax.Array) -> jax.Array:
        """Exchange where neighbor terms use the stale ``recv`` copies."""
        ...


class MixerBase:
    """Default noise-placement algebra shared by all concrete mixers.

    Subclasses implement :meth:`apply` and :meth:`diag`; the generic
    identities below then cover every topology:

      mix        = A x~                      (noise_self)
                 = A x~ - diag * (x~ - x)    (own-noise removed)
      mix_delayed= A r - diag * r + diag * s where s = x~ or x
    """

    m: int = 0
    delay: int = 0

    def apply(self, x: jax.Array, t: jax.Array) -> jax.Array:
        raise NotImplementedError

    def diag(self, t: jax.Array) -> jax.Array:
        raise NotImplementedError

    def mix(self, clean, tilde, noise_self, t):
        mixed = self.apply(tilde, t)
        if not noise_self:
            mixed = mixed - _bcast(self.diag(t), tilde) * (tilde - clean)
        return mixed

    def mix_delayed(self, clean, tilde, recv, noise_self, t):
        d = _bcast(self.diag(t), recv)
        self_term = tilde if noise_self else clean
        return self.apply(recv, t) - d * recv + d * self_term


@dataclasses.dataclass(frozen=True)
class DenseMatrixMixer(MixerBase):
    """Any (possibly time-varying) doubly-stochastic schedule as dense A(t).

    The matrix stack and its diagonals are materialised ONCE at construction
    (the seed code re-stacked ``graph.matrices`` inside every traced round).
    ``apply`` contracts the node axis with tensordot, so it also mixes
    node-stacked pytree leaves of any trailing shape.
    """

    stack: Any               # (k, m, m) jnp.float32
    name: str = "dense"
    delay: int = 0

    def __post_init__(self):
        stack = jnp.asarray(self.stack, jnp.float32)
        if stack.ndim == 2:
            stack = stack[None]
        object.__setattr__(self, "stack", stack)
        object.__setattr__(self, "_diags",
                           jnp.stack([jnp.diag(A) for A in stack]))

    @property
    def m(self) -> int:
        return int(self.stack.shape[-1])

    @classmethod
    def from_graph(cls, graph: "GossipGraph", delay: int = 0) -> "DenseMatrixMixer":
        return cls(stack=np.stack([np.asarray(A) for A in graph.matrices]),
                   name=graph.name, delay=delay)

    @classmethod
    def from_topology(cls, topology: str, m: int, seed: int = 0,
                      **kw) -> "DenseMatrixMixer":
        # deferred: repro.core.__init__ imports the engines, which import
        # this module — a top-level core import would be circular
        from repro.core.graph import GossipGraph
        return cls.from_graph(GossipGraph.make(topology, m, seed=seed, **kw))

    def apply(self, x, t):
        A = self.stack[t % self.stack.shape[0]]
        return jnp.tensordot(A, x.astype(A.dtype), axes=1).astype(x.dtype)

    def diag(self, t):
        return self._diags[t % self.stack.shape[0]]


@dataclasses.dataclass(frozen=True)
class RingRollMixer(MixerBase):
    """Bidirectional ring via jnp.roll — lowers to collective-permute on a
    sharded node axis. Numerically identical to ``graph.ring_matrix``."""

    m: int
    self_weight: float = 0.5
    delay: int = 0

    def apply(self, x, t):
        nw = (1.0 - self.self_weight) / 2.0
        return (self.self_weight * x
                + nw * jnp.roll(x, 1, axis=0)
                + nw * jnp.roll(x, -1, axis=0))

    def diag(self, t):
        return jnp.full((self.m,), self.self_weight, jnp.float32)


@dataclasses.dataclass(frozen=True)
class CompleteMixer(MixerBase):
    """Fully connected graph: exact consensus (all-reduce mean) every round."""

    m: int
    delay: int = 0

    def apply(self, x, t):
        return jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape)

    def diag(self, t):
        return jnp.full((self.m,), 1.0 / self.m, jnp.float32)


@dataclasses.dataclass(frozen=True)
class DisconnectedMixer(MixerBase):
    """No communication: every node keeps its own CLEAN state.

    Nothing leaves the node, so nothing needs the Laplace broadcast noise —
    ``mix`` ignores theta~ entirely (local-only ablation baseline).
    """

    m: int
    delay: int = 0

    def apply(self, x, t):
        return x

    def diag(self, t):
        return jnp.ones((self.m,), jnp.float32)

    def mix(self, clean, tilde, noise_self, t):
        return clean

    def mix_delayed(self, clean, tilde, recv, noise_self, t):
        return clean


@dataclasses.dataclass(frozen=True)
class AlternatingRingMixer(MixerBase):
    """Time-varying graph: even rounds pair with the +1 ring neighbor, odd
    rounds with the -1 neighbor; each A(t) is a (1/2, 1/2) circulant."""

    m: int
    delay: int = 0

    def apply(self, x, t):
        fwd = 0.5 * x + 0.5 * jnp.roll(x, 1, axis=0)
        bwd = 0.5 * x + 0.5 * jnp.roll(x, -1, axis=0)
        return jnp.where((t % 2) == 0, fwd, bwd)

    def diag(self, t):
        return jnp.full((self.m,), 0.5, jnp.float32)


@dataclasses.dataclass(frozen=True)
class DelayedMixer(MixerBase):
    """Wrap any mixer with a WAN delay: neighbor terms arrive ``delay``
    rounds late (paper §VI future work). The engines own the history ring
    buffer; this wrapper only declares the staleness and delegates the
    algebra to the inner mixer."""

    inner: Mixer
    delay: int = 1

    def __post_init__(self):
        if self.delay < 1:
            raise ValueError("DelayedMixer needs delay >= 1")

    @property
    def m(self) -> int:
        return self.inner.m

    def apply(self, x, t):
        return self.inner.apply(x, t)

    def diag(self, t):
        return self.inner.diag(t)

    def mix(self, clean, tilde, noise_self, t):
        return self.inner.mix(clean, tilde, noise_self, t)

    def mix_delayed(self, clean, tilde, recv, noise_self, t):
        return self.inner.mix_delayed(clean, tilde, recv, noise_self, t)


# -- registry entries --------------------------------------------------------

@MIXERS.register("ring")
def _ring(m: int, self_weight: float = 0.5, delay: int = 0) -> Mixer:
    return RingRollMixer(m=m, self_weight=self_weight, delay=delay)


@MIXERS.register("complete")
def _complete(m: int, delay: int = 0) -> Mixer:
    return CompleteMixer(m=m, delay=delay)


@MIXERS.register("disconnected")
def _disconnected(m: int, delay: int = 0) -> Mixer:
    return DisconnectedMixer(m=m, delay=delay)


@MIXERS.register("ring_alternating")
def _ring_alternating(m: int, delay: int = 0) -> Mixer:
    return AlternatingRingMixer(m=m, delay=delay)


@MIXERS.register("dense")
def _dense(m: int, matrices=None, topology: str = "ring", seed: int = 0,
           delay: int = 0, **kw) -> Mixer:
    if matrices is not None:
        mixer = DenseMatrixMixer(stack=np.stack([np.asarray(A) for A in matrices]))
    else:
        mixer = DenseMatrixMixer.from_topology(topology, m, seed=seed, **kw)
    return dataclasses.replace(mixer, delay=delay)


# Graph-backed topologies the simulator's Fig. 3 sweep uses, exposed directly.
for _name in ("torus", "hypercube", "random", "time_varying"):
    @MIXERS.register(_name)
    def _graph_mixer(m: int, seed: int = 0, delay: int = 0,
                     _topology: str = _name, **kw) -> Mixer:
        mixer = DenseMatrixMixer.from_topology(_topology, m, seed=seed, **kw)
        return dataclasses.replace(mixer, delay=delay)


@MIXERS.register("delayed")
def _delayed(m: int, inner: str | Mixer = "ring", delay: int = 1,
             seed: int = 0, **kw) -> Mixer:
    return DelayedMixer(inner=MIXERS.build(inner, m=m, seed=seed, **kw),
                        delay=delay)
