"""LocalRule protocol — the node-local sparse-online-learning stage.

A LocalRule defines the two halves of steps 6-10 that are NOT mixing:
primal recovery (state -> prediction weights) and the dual step (mixed
state + clipped gradient -> next state). Rules operate on single (m, ...)
arrays; the distributed engine tree_maps them over node-stacked leaves, so
one implementation serves both engines.

Families (paper §I):
  'omd' — the paper's rule: mirror descent + Lasso prox (Algorithm 1).
  'tg'  — truncated gradient (Langford, Li & Zhang '09, ref [11]):
          gossip mixes w itself; w <- shrink(w_mixed - a g, a lam).
  'rda' — L1 regularized dual averaging (Xiao '10, ref [12]): gossip mixes
          the cumulative gradient G; w = -(sqrt(t)/gamma) shrink(G/t, lam).

>>> import jax.numpy as jnp, numpy as np
>>> from repro.api import LOCAL_RULES, StepContext
>>> rule = LOCAL_RULES.build("omd", prox_kind="l1")
>>> ctx = StepContext(t=jnp.asarray(1), alpha_t=jnp.asarray(1.0),
...                   lam_t=jnp.asarray(1.0), lam=1.0)
>>> theta = jnp.array([[0.5, -2.0, 0.1]])
>>> np.asarray(rule.primal(theta, ctx)).tolist()     # Lasso soft-threshold
[[0.0, -1.0, 0.0]]
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.api.registry import LOCAL_RULES

__all__ = ["StepContext", "LocalRule", "OMDLassoRule", "TruncatedGradientRule",
           "RDARule"]


class StepContext(NamedTuple):
    """Per-round schedule values every rule may consume.

    t is the 1-based round index; lam_t = alpha_t * lam is the Theorem-2
    coupled Lasso strength, lam the raw (schedule-free) strength RDA uses.
    """

    t: jax.Array
    alpha_t: jax.Array
    lam_t: jax.Array
    lam: float


@runtime_checkable
class LocalRule(Protocol):
    """Local update stage: primal recovery + dual step, mixing-agnostic."""

    def init_state(self, params: jax.Array) -> jax.Array:
        """Initial dual state for one leaf of model parameters."""
        ...

    def primal(self, theta: jax.Array, ctx: StepContext) -> jax.Array:
        """State -> prediction weights w_t (steps 6-7)."""
        ...

    def dual_step(self, mixed: jax.Array, grad: jax.Array,
                  ctx: StepContext) -> jax.Array:
        """Post-mixing state + clipped grad -> next state (step 10)."""
        ...


def _prox():
    # deferred import: repro.core.__init__ imports the engines, which import
    # this module — a top-level core import would be circular
    from repro.core import prox
    return prox


_PROX = {
    "l1": lambda p, lam_t: _prox().soft_threshold(p, lam_t),
    "none": lambda p, lam_t: p,
    "group": lambda p, lam_t: _prox().group_soft_threshold(p, lam_t),
}


@dataclasses.dataclass(frozen=True)
class OMDLassoRule:
    """The paper's rule: identity mirror map + composite prox (Thm 2)."""

    prox_kind: str = "l1"

    def __post_init__(self):
        if self.prox_kind not in _PROX:
            raise ValueError(f"unknown prox_kind {self.prox_kind!r}")

    def init_state(self, params):
        return params  # theta_1 = model init (identity mirror map)

    def primal(self, theta, ctx):
        return _PROX[self.prox_kind](_prox().l2_mirror_map(theta), ctx.lam_t)

    def dual_step(self, mixed, grad, ctx):
        return mixed - ctx.alpha_t * grad.astype(mixed.dtype)


@dataclasses.dataclass(frozen=True)
class TruncatedGradientRule:
    """Ref [11]: the state IS w; shrink after every gradient step."""

    def init_state(self, params):
        return params  # state is w itself

    def primal(self, theta, ctx):
        return theta

    def dual_step(self, mixed, grad, ctx):
        return _prox().soft_threshold(
            mixed - ctx.alpha_t * grad.astype(mixed.dtype), ctx.lam_t)


@dataclasses.dataclass(frozen=True)
class RDARule:
    """Ref [12]: the state is the running gradient sum G; w from the
    l1-RDA closed form with the sqrt(t)/gamma schedule."""

    gamma: float = 1.0

    def init_state(self, params):
        # the state is the cumulative gradient sum G, not the weights —
        # seeding it with a model init would silently corrupt the RDA iterate
        return jnp.zeros_like(params)

    def primal(self, theta, ctx):
        tf = jnp.maximum(ctx.t.astype(jnp.float32), 1.0)
        gbar = theta / tf
        return -(jnp.sqrt(tf) / self.gamma) * _prox().soft_threshold(gbar, ctx.lam)

    def dual_step(self, mixed, grad, ctx):
        return mixed + grad.astype(mixed.dtype)


@LOCAL_RULES.register("omd")
def _omd(prox_kind: str = "l1") -> LocalRule:
    return OMDLassoRule(prox_kind=prox_kind)


@LOCAL_RULES.register("tg", "truncated_gradient")
def _tg() -> LocalRule:
    return TruncatedGradientRule()


@LOCAL_RULES.register("rda")
def _rda(gamma: float = 1.0) -> LocalRule:
    return RDARule(gamma=gamma)
