"""Clipper protocol — enforces Assumption 2.3 (||g|| <= L) before noising.

Without clipping the DP guarantee is vacuous for unbounded losses, so the
clipper is a first-class pipeline stage rather than inline engine code.
Clippers act per node (axis 0 of every leaf) on either a bare (m, n) array
or a node-stacked pytree — tree_util treats the bare array as a one-leaf
tree, so one implementation serves both engines.

>>> import jax.numpy as jnp
>>> from repro.api import CLIPPERS
>>> clipped, norms = CLIPPERS.build("l2", max_norm=1.0).clip(
...     jnp.full((2, 4), 2.0))                  # per-node norm = 4
>>> [round(v, 4) for v in norms.tolist()]
[4.0, 4.0]
>>> round(float(jnp.linalg.norm(clipped[0])), 4)
1.0
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.api.registry import CLIPPERS

__all__ = ["Clipper", "PerNodeL2Clipper", "ValueClipper", "NoClipper",
           "per_node_norms"]


def per_node_norms(grads: Any) -> jax.Array:
    """(m,) global L2 norm of each node's slice across all leaves."""
    leaves = jax.tree_util.tree_leaves(grads)
    sq = sum(
        jnp.sum(jnp.square(l.astype(jnp.float32)), axis=tuple(range(1, l.ndim)))
        for l in leaves
    )
    return jnp.sqrt(sq)


@runtime_checkable
class Clipper(Protocol):
    """Gradient-bounding stage. Returns (clipped, (m,) pre-clip norms)."""

    def clip(self, grads: Any) -> tuple[Any, jax.Array]: ...


@dataclasses.dataclass(frozen=True)
class PerNodeL2Clipper:
    """Scale each node's gradient slice to L2 norm <= max_norm (the bound L
    the Lemma-1 sensitivity is calibrated against)."""

    max_norm: float = 1.0

    def clip(self, grads):
        norms = per_node_norms(grads)
        factor = jnp.minimum(1.0, self.max_norm / jnp.maximum(norms, 1e-12))

        def scale(l):
            f = factor.reshape((-1,) + (1,) * (l.ndim - 1))
            return (l * f).astype(l.dtype)

        return jax.tree_util.tree_map(scale, grads), norms


@dataclasses.dataclass(frozen=True)
class ValueClipper:
    """Per-coordinate clamp to [-max_value, max_value] — pairs with the
    'coordinate' Laplace calibration (bounds the L-inf sensitivity)."""

    max_value: float = 1.0

    def clip(self, grads):
        norms = per_node_norms(grads)
        clipped = jax.tree_util.tree_map(
            lambda l: jnp.clip(l, -self.max_value, self.max_value), grads)
        return clipped, norms


@dataclasses.dataclass(frozen=True)
class NoClipper:
    """Pass-through (non-private baselines only: voids Assumption 2.3)."""

    def clip(self, grads):
        return grads, per_node_norms(grads)


@CLIPPERS.register("l2")
def _l2(max_norm: float = 1.0) -> Clipper:
    return PerNodeL2Clipper(max_norm=max_norm)


@CLIPPERS.register("value")
def _value(max_norm: float = 1.0) -> Clipper:
    return ValueClipper(max_value=max_norm)


@CLIPPERS.register("none")
def _noclip() -> Clipper:
    return NoClipper()
