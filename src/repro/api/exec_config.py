"""ExecConfig — the execution knobs of `run`/`run_batch`, as one object.

`run()` historically grew a sprawl of execution kwargs (chunking,
checkpointing, logging, meshes, telemetry). They are now one frozen
dataclass passed as ``run(spec, exec=ExecConfig(...))`` — a config you can
build once, stash on a trainer, log, or `replace()` per call. WHAT to run
stays on `RunSpec` (and `run`'s own horizon/on_chunk/step_fn params); HOW
to execute it lives here.

The old keyword arguments keep working through a deprecation shim: legacy
kwargs are forwarded into an ExecConfig and a DeprecationWarning fires
once per process. Passing both ``exec=`` and legacy kwargs is an error.

>>> from repro.api import ExecConfig
>>> cfg = ExecConfig(chunk_rounds=64, warmup=False)
>>> cfg.chunk_rounds, cfg.resume
(64, False)
>>> cfg.replace(resume=True).resume
True
>>> ExecConfig(chunk=3)
Traceback (most recent call last):
    ...
TypeError: ...chunk...

Migration table (old kwarg -> ExecConfig field):

    run(spec, chunk_rounds=64)      -> run(spec, exec=ExecConfig(chunk_rounds=64))
    run(spec, checkpoint_every=256,
             checkpoint_dir=d)      -> ExecConfig(checkpoint_every=256, checkpoint_dir=d)
    run(spec, resume=True)          -> ExecConfig(resume=True)
    run(spec, log_path=p)           -> ExecConfig(log_path=p)
    run(spec, compute_regret=False) -> ExecConfig(compute_regret=False)
    run(spec, warmup=False)         -> ExecConfig(warmup=False)
    run(spec, print_every=10)       -> ExecConfig(print_every=10)
    run(spec, node_devices=4)       -> ExecConfig(node_devices=4)
    run(spec, node_mesh=mesh)       -> ExecConfig(node_mesh=mesh)
    run(spec, obs=tel)              -> ExecConfig(obs=tel)
    run_batch(spec, seeds,
              devices="auto")       -> ExecConfig(devices="auto")
    run_batch(spec, seeds, mesh=mesh) -> ExecConfig(mesh=mesh)
    run_batch(..., check_vectorizable=False)
                                    -> ExecConfig(check_vectorizable=False)
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

__all__ = ["ExecConfig", "resolve_exec"]


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """How a run executes (see module docstring for the migration table).

    chunk_rounds:       rounds per jitted `lax.scan` chunk.
    checkpoint_every / checkpoint_dir / resume:
                        periodic engine-state checkpoints and bit-identical
                        resume (repro.checkpoint).
    log_path:           CSVLogger per-round metrics mirror (run() only).
    compute_regret:     post-hoc Definition-3 regret vs the best fixed w.
    warmup:             compile the first chunk outside the timed region.
    print_every:        custom-mode (step_fn=) progress prints (run() only).
    node_devices / node_mesh:
                        shard the NODE axis over a ("node",) mesh
                        (repro.api.shard_node).
    devices / mesh:     run_batch() only — shard the SEED axis (or a
                        ("seed","node") grid when mesh carries both axes).
    check_vectorizable: run_batch() only — verify the spec's resolved
                        stages are seed-independent before vmapping.
    obs:                a repro.obs.Telemetry (default: the ambient
                        `repro.obs.active()`).
    """

    chunk_rounds: int = 512
    checkpoint_every: int | None = None
    checkpoint_dir: str | None = None
    resume: bool = False
    log_path: str | None = None
    compute_regret: bool = True
    warmup: bool = True
    print_every: int | None = None
    node_devices: int | str | None = None
    node_mesh: Any = None
    devices: int | str | None = None
    mesh: Any = None
    check_vectorizable: bool = True
    obs: Any = None

    def replace(self, **kw: Any) -> "ExecConfig":
        return dataclasses.replace(self, **kw)


_FIELDS = tuple(f.name for f in dataclasses.fields(ExecConfig))
_BATCH_ONLY = ("devices", "mesh")
_RUN_ONLY = ("log_path", "print_every", "node_mesh")

# one warning per process, not one per call site — a sweep making thousands
# of legacy calls should nag exactly once
_warned_legacy = False


def resolve_exec(exec_cfg: ExecConfig | None, legacy: dict,
                 *, caller: str) -> ExecConfig:
    """The ExecConfig a run/run_batch call resolved to.

    ``legacy`` holds the caller's ``**legacy`` catch-all: deprecated
    execution kwargs forwarded into an ExecConfig (warning once), with
    typos rejected by name exactly like a real keyword argument would be.
    """
    global _warned_legacy
    if legacy:
        unknown = sorted(k for k in legacy if k not in _FIELDS)
        if unknown:
            raise TypeError(
                f"{caller}() got unexpected keyword arguments {unknown}; "
                f"execution options: {sorted(_FIELDS)}")
        if exec_cfg is not None:
            raise TypeError(
                f"{caller}() got both exec= and legacy execution kwargs "
                f"{sorted(legacy)}; pass everything via exec=ExecConfig(...)")
        if not _warned_legacy:
            warnings.warn(
                f"passing execution options to {caller}() as keyword "
                f"arguments ({sorted(legacy)}) is deprecated; use "
                f"{caller}(spec, ..., exec=ExecConfig(...)) — see "
                f"repro.api.exec_config for the migration table",
                DeprecationWarning, stacklevel=3)
            _warned_legacy = True
        exec_cfg = ExecConfig(**legacy)
    cfg = exec_cfg if exec_cfg is not None else ExecConfig()
    if not isinstance(cfg, ExecConfig):
        raise TypeError(f"{caller}() exec= expects an ExecConfig, got "
                        f"{type(cfg).__name__}")
    only = _BATCH_ONLY if caller == "run" else _RUN_ONLY
    bad = [f for f in only
           if getattr(cfg, f) != getattr(ExecConfig, f, None)
           and getattr(cfg, f) is not None]
    if bad:
        other = "run_batch" if caller == "run" else "run"
        raise ValueError(f"ExecConfig fields {bad} apply to {other}(), "
                         f"not {caller}()")
    return cfg
