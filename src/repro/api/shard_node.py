"""Node-axis sharding — run the gossip round with theta split across devices.

Everything before this module scales the *seed* axis; the node axis — the
paper's actual "m data centers" dimension — lived on one device, bounded by
the dense n x n mixing matrix. This module shards it:

* the topology comes in as a `repro.core.graph.SparseGraph` (edge list,
  O(edges) memory) via `SparseMixer` — `sparse_graph_and_delay` also
  converts the fixed dense mixers (ring / single-matrix dense stacks) so
  existing specs work unchanged;
* `partition_graph` splits the m rows into D contiguous blocks of
  ``block = ceil(m / D)`` rows (rows m..m_pad-1 are padding: no edges, zero
  mask) and groups the edges of each destination block by **shard offset**
  ``(src_shard - dst_shard) % D``;
* `ShardedSparseMixer` runs one gossip exchange per used offset: a
  `lax.ppermute` rotates the neighbor block of theta~ across the ("node",)
  mesh axis (the halo exchange — offset 0 is device-local and free), then a
  weighted `segment_sum` scatters it into the local rows;
* `make_node_chunk_fn` wraps the whole per-chunk `lax.scan` in `shard_map`
  so `repro.api.run(..., node_devices=D)` and
  `run_batch(..., node_devices=D)` (the ("seed","node") grid) drive it like
  any other chunk program. State crossing the wrapper stays GLOBAL and
  unpadded, so checkpoints restore under any device count.

Equivalence contract (tests/test_shard_node.py): the per-round Laplace
noise is bit-identical to the dense engines — every shard draws the full
(m, n) sample from the same per-round key and slices its own block — so a
sharded run differs from dense `run()` only by float32 reduction order
(segment_sum vs tensordot, psum'd metrics); the suite asserts the bound.

>>> import jax
>>> from repro.api import RunSpec
>>> from repro.api.shard_node import make_node_chunk_fn
>>> from repro.launch.mesh import make_mesh
>>> spec = RunSpec(nodes=6, dim=4, horizon=4, eps=1.0, alpha0=0.5,
...                lam=0.01, stream="drift", mixer="sparse",
...                mixer_options={"topology": "ring"})
>>> mesh = make_mesh((1,), ("node",))        # 1 device: same program, D=1
>>> chunk_fn, init_fn = make_node_chunk_fn(spec, "sim", mesh)
>>> state = init_fn(jax.random.PRNGKey(spec.seed))
>>> xs, ys = spec.resolve_stream().chunk(0, 4)
>>> state, outs = jax.jit(chunk_fn)(state, xs, ys)
>>> outs.loss.shape, state.theta.shape
((4, 6), (6, 4))
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.mixers import (DelayedMixer, DenseMatrixMixer, MixerBase,
                              RingRollMixer, SparseMixer, ring_write)
from repro.api.spec import RunSpec

__all__ = ["sparse_graph_and_delay", "NodePartition", "partition_graph",
           "ShardedSparseMixer", "make_node_chunk_fn", "resolve_node_mesh",
           "reference_local_round_fn"]


def sparse_graph_and_delay(mixer) -> tuple[Any, int]:
    """(SparseGraph, delay) behind a resolved mixer, for sharding.

    Accepts `SparseMixer` (native), `RingRollMixer` (exact `ring_edges`
    form) and fixed single-matrix `DenseMatrixMixer` stacks (converted via
    `SparseGraph.from_dense`), optionally wrapped in `DelayedMixer`.
    Time-varying schedules, per-edge heterogeneous delays and the
    no-communication mixer have no fixed sparse form and raise.
    """
    from repro.core.graph import SparseGraph, ring_edges

    delay = int(getattr(mixer, "delay", 0))
    inner = mixer.inner if isinstance(mixer, DelayedMixer) else mixer
    if isinstance(inner, SparseMixer):
        return inner.graph, delay
    if isinstance(inner, RingRollMixer):
        return ring_edges(inner.m, self_weight=inner.self_weight), delay
    if isinstance(inner, DenseMatrixMixer):
        stack = np.asarray(inner.stack)
        if stack.shape[0] != 1:
            raise ValueError(
                f"mixer {inner.name!r} is a time-varying dense schedule "
                f"({stack.shape[0]} matrices); node sharding needs one fixed "
                "topology — use mixer='sparse' or a single-matrix stack")
        return SparseGraph.from_dense(stack[0], name=inner.name), delay
    raise ValueError(
        f"{type(inner).__name__} cannot be node-sharded: no fixed sparse "
        "form (use mixer='sparse' with a ring/torus/... topology)")


@dataclasses.dataclass(frozen=True)
class NodePartition:
    """Edges of a SparseGraph regrouped for a D-way contiguous row split.

    ``offsets`` holds one entry per used shard offset o = (src_shard -
    dst_shard) % D: (o, dst_local (D, E_o), src_local (D, E_o), weight
    (D, E_o)) — row d of each array is destination shard d's edges whose
    sources live on shard (d + o) % D, zero-padded to the widest shard
    (weight 0 edges scatter nothing). ``diag_blocks`` is the (D, block)
    self-weight table; padding rows m..m_pad-1 carry no edges and weight 0.
    """

    m: int
    devices: int
    block: int           # rows per device = ceil(m / devices)
    m_pad: int           # block * devices
    offsets: tuple       # ((o, dst_local, src_local, weight), ...)
    diag_blocks: Any     # (D, block) float32


def partition_graph(graph, devices: int) -> NodePartition:
    """Split a SparseGraph's edges by destination shard and source offset."""
    D = int(devices)
    if D < 1:
        raise ValueError(f"partition_graph needs devices >= 1, got {D}")
    m = int(graph.m)
    block = -(-m // D)
    m_pad = block * D
    dst = np.asarray(graph.dst, np.int64)
    src = np.asarray(graph.src, np.int64)
    weight = np.asarray(graph.weight, np.float32)
    dst_shard = dst // block
    offs = (src // block - dst_shard) % D
    offsets = []
    for o in sorted(set(int(v) for v in offs)):
        per_dev = [np.flatnonzero((offs == o) & (dst_shard == d))
                   for d in range(D)]
        width = max(len(ix) for ix in per_dev)
        dl = np.zeros((D, width), np.int32)
        sl = np.zeros((D, width), np.int32)
        ww = np.zeros((D, width), np.float32)
        for d, ix in enumerate(per_dev):
            k = len(ix)
            dl[d, :k] = dst[ix] - d * block
            sl[d, :k] = src[ix] % block
            ww[d, :k] = weight[ix]
        offsets.append((o, dl, sl, ww))
    diag = np.zeros((m_pad,), np.float32)
    diag[:m] = np.asarray(graph.diag(), np.float32)
    return NodePartition(m=m, devices=D, block=block, m_pad=m_pad,
                         offsets=tuple(offsets),
                         diag_blocks=diag.reshape(D, block))


class ShardedSparseMixer(MixerBase):
    """SparseMixer split over a mesh axis: ppermute halo + local segment_sum.

    Must run inside `shard_map` with ``axis`` in the mesh. Each used source
    offset costs one `lax.ppermute` of the whole local theta~ block (offset
    0 — the bulk of a well-laid-out graph — stays device-local); the mixing
    algebra (mix / mix_delayed / mix_history) is inherited from MixerBase so
    noise placement and delay handling match the unsharded mixers exactly.
    """

    def __init__(self, part: NodePartition, delay: int = 0,
                 axis: str = "node"):
        self.part = part
        self.m = part.m
        self.delay = int(delay)
        self.axis = axis
        self._offsets = tuple(
            (o, jnp.asarray(dl), jnp.asarray(sl), jnp.asarray(ww))
            for o, dl, sl, ww in part.offsets)
        self._diag_blocks = jnp.asarray(part.diag_blocks)

    def apply(self, x, t):
        D = self.part.devices
        d = jax.lax.axis_index(self.axis)
        out = jnp.zeros(x.shape, jnp.float32)
        for o, dl, sl, ww in self._offsets:
            halo = x if o == 0 else jax.lax.ppermute(
                x, self.axis, perm=[(j, (j - o) % D) for j in range(D)])
            w = ww[d].reshape((-1,) + (1,) * (x.ndim - 1))
            vals = w * halo[sl[d]].astype(jnp.float32)
            out = out + jax.ops.segment_sum(vals, dl[d],
                                            num_segments=self.part.block)
        return out.astype(x.dtype)

    def diag(self, t):
        return self._diag_blocks[jax.lax.axis_index(self.axis)]


# -- the node-sharded chunk program ------------------------------------------

def _pad_axis(x, pad: int, axis: int):
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _map_node_leaves(state, fn):
    """Apply fn to every theta/history leaf (node axis is always ndim-2)."""
    theta = jax.tree_util.tree_map(fn, state.theta)
    hist = state.history
    if hist is not None:
        hist = jax.tree_util.tree_map(fn, hist)
    return state._replace(theta=theta, history=hist)


def _pad_state(state, pad: int):
    return _map_node_leaves(state, lambda l: _pad_axis(l, pad, l.ndim - 2))


def _unpad_state(state, m: int):
    return _map_node_leaves(state, lambda l: l[..., :m, :])


def _state_pspecs(template, lead: tuple):
    from jax.sharding import PartitionSpec as P
    theta = jax.tree_util.tree_map(lambda _: P(*lead, "node"), template.theta)
    hist = template.history
    if hist is not None:
        hist = jax.tree_util.tree_map(lambda _: P(*lead, None, "node"), hist)
    return template._replace(theta=theta, t=P(*lead), key=P(*lead),
                             history=hist)


def reference_local_round_fn(spec: RunSpec, engine: str, part: NodePartition,
                             delay: int, schedule=None,
                             graph=None) -> Callable:
    """One gossip round over THIS shard's block of nodes (reference backend;
    `make_node_chunk_fn` dispatches here — or to the backend's fused
    variant — via ``spec.resolve_backend()``).

    Mirrors `Algorithm1.round` / `GossipDP.update` term for term; the only
    cross-shard traffic is the mixer's halo exchange and three metric psums.
    The Laplace draw replays the dense engines' stream bit-for-bit: the full
    (m, n) sample comes from the same per-round key on every shard, gets
    zero-padded to m_pad rows (dynamic_slice clamps, so padding must happen
    BEFORE the slice or the last shard would read overlapping rows) and each
    shard keeps only its block.

    ``schedule`` (a `repro.faults.FaultSchedule`, with the global ``graph``
    it was wrapped around) swaps the mixer for `FaultyShardedSparseMixer`
    and freezes crashed rows of the local block, mirroring the unsharded
    engines' fault hooks.
    """
    from repro.core import prox
    from repro.core.algorithm1 import (RoundOutput, SimState,
                                       hinge_loss_and_grad)
    from repro.core.gossip import GossipState

    m, n = part.m, spec.dim
    block, m_pad = part.block, part.m_pad
    mech = spec.resolve_mechanism()
    rule = spec.resolve_local_rule()
    clipper = spec.resolve_clipper()
    omd = spec.omd_config()
    loss_and_grad = spec.loss_and_grad or hinge_loss_and_grad
    if schedule is not None:
        from repro.faults.mixers import FaultyShardedSparseMixer
        smixer = FaultyShardedSparseMixer(part, graph, schedule, delay=delay)
    else:
        smixer = ShardedSparseMixer(part, delay=delay)

    def round_fn(state, batch):
        x, y = batch                              # (block, n), (block,)
        d = jax.lax.axis_index("node")
        gidx = d * block + jnp.arange(block)
        mask = (gidx < m).astype(jnp.float32)     # 0 on the padding rows
        theta = state.theta if engine == "sim" else state.theta["w"]
        hist = state.history
        if engine == "dist" and hist is not None:
            hist = hist["w"]
        ctx = omd.step_context(state.t + 1)

        w = rule.primal(theta, ctx)
        loss, grad = loss_and_grad(w, x, y)
        correct = (jnp.sign(jnp.einsum("mn,mn->m", w, x)) == y
                   ).astype(jnp.float32)
        grad, _ = clipper.clip(grad)

        key, sub = jax.random.split(state.key)
        scale = mech.scale(ctx.alpha_t, n)
        delta = mech.sample(sub, (m, n), scale)
        delta = _pad_axis(delta, m_pad - m, 0)
        delta = jax.lax.dynamic_slice_in_dim(delta, d * block, block, axis=0)
        tilde = theta + delta

        if delay:
            hist = ring_write(hist, state.t, tilde)
            mixed = smixer.mix_history(theta, tilde, hist, mech.noise_self,
                                       state.t)
        else:
            mixed = smixer.mix(theta, tilde, mech.noise_self, state.t)
        theta_next = rule.dual_step(mixed, grad, ctx)
        if schedule is not None and schedule.has_crashes:
            # crashed rows of this block freeze (repro.faults), matching the
            # unsharded engines' hook; pad rows stay zero either way
            alive = _pad_axis(schedule.alive_mask(state.t), m_pad - m, 0)
            alive_blk = jax.lax.dynamic_slice_in_dim(alive, d * block, block,
                                                     axis=0)
            theta_next = jnp.where(alive_blk[:, None], theta_next, theta)

        # global metrics: masked partial sums psum'd over the mesh axis —
        # same algebra as the dense engines up to reduction order
        w_bar = jax.lax.psum(jnp.sum(w * mask[:, None], axis=0), "node") / m
        wb_terms = jnp.maximum(1.0 - y * jnp.sum(w_bar[None, :] * x, axis=-1),
                               0.0)
        wb_loss = jax.lax.psum(jnp.sum(wb_terms * mask), "node") / m
        zeros = jnp.sum((jnp.abs(w) <= 0.0).astype(jnp.float32)
                        * mask[:, None])
        sparsity = jax.lax.psum(zeros, "node") / (m * n)

        out = RoundOutput(loss=loss, w_bar_loss=wb_loss, sparsity=sparsity,
                          correct=correct)
        if engine == "sim":
            new_state = SimState(theta=theta_next, t=state.t + 1, key=key,
                                 history=hist)
        else:
            new_state = GossipState(theta={"w": theta_next}, t=state.t + 1,
                                    key=key,
                                    history=None if hist is None
                                    else {"w": hist})
        return new_state, out

    return round_fn


def resolve_node_mesh(node_devices, mesh):
    """The mesh carrying the "node" axis, or None for the unsharded path.

    Mirrors `runner._resolve_seed_mesh`: a prebuilt ``mesh`` must carry a
    "node" axis; ``node_devices`` goes through `launch.mesh.node_mesh`
    (None / 0 / 1 -> None, "auto" -> every local device).
    """
    if mesh is not None:
        if "node" not in mesh.axis_names:
            raise ValueError(
                f"node sharding needs a mesh with a 'node' axis, got axes "
                f"{tuple(mesh.axis_names)}")
        return mesh
    if node_devices is None:
        return None
    from repro.launch.mesh import node_mesh
    return node_mesh(node_devices)


def make_node_chunk_fn(spec: RunSpec, engine: str, mesh,
                       batched: bool = False) -> tuple[Callable, Callable]:
    """Node-sharded (chunk_fn, init_fn) — drop-in for `make_chunk_program`.

    chunk_fn consumes and returns GLOBAL, unpadded state / data: the node
    padding (m -> m_pad = ceil(m/D)*D) and the `shard_map` over ``mesh``
    live inside, so `run`'s checkpoint / resume / metrics logic — and
    device-count portability of checkpoints — need no changes. With
    ``batched=True`` the per-chunk scan is vmapped over a leading seed axis
    and every spec gains a leading "seed" dim (the ("seed","node") grid
    `run_batch` uses).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core.algorithm1 import RoundOutput

    if engine not in ("sim", "dist"):
        raise ValueError(f"unknown engine {engine!r}; expected 'sim' or 'dist'")
    if "node" in getattr(mesh, "axis_names", ()):
        D = int(mesh.shape["node"])
    else:
        raise ValueError(
            f"make_node_chunk_fn needs a mesh with a 'node' axis, got "
            f"{tuple(getattr(mesh, 'axis_names', ()))}")
    lead = ("seed",) if batched else ()
    if batched and "seed" not in mesh.axis_names:
        raise ValueError("batched node sharding needs a ('seed','node') mesh")

    mixer = spec.resolve_mixer()
    schedule = getattr(mixer, "schedule", None)
    if schedule is not None:
        # repro.faults: the spec resolved to a faulty mixer — shard its
        # INNER edge list and rebuild the fault masks per device block
        from repro.faults.mixers import FaultySparseMixer
        if not isinstance(mixer, FaultySparseMixer):
            raise ValueError(
                f"node sharding under faults needs the sparse edge-list path "
                f"(mixer='sparse' or a ring), got {type(mixer).__name__}")
        if schedule.max_extra:
            raise ValueError(
                "stragglers are not supported under node sharding — "
                "per-class delay rings do not shard; drop "
                "straggler_rate/stragglers or run unsharded")
        graph, delay = mixer.inner.graph, mixer.base_delay
    else:
        graph, delay = sparse_graph_and_delay(mixer)
    if int(graph.m) != int(spec.nodes):
        raise ValueError(f"graph has m={graph.m} nodes but RunSpec.nodes="
                         f"{spec.nodes}")
    part = partition_graph(graph, D)
    m, pad = part.m, part.m_pad - part.m
    # the spec's backend builds the per-shard round body ("reference" is
    # reference_local_round_fn above; "pallas" swaps in the fused stats +
    # dual-step kernels — the ppermute halo exchange stays out here either
    # way, in the sharded mixer the round body calls)
    round_fn = spec.resolve_backend().make_local_round_fn(
        spec, engine, part, delay, schedule=schedule, graph=graph)

    def local_chunk(state, xs, ys):
        return jax.lax.scan(round_fn, state, (xs, ys))

    body = jax.vmap(local_chunk) if batched else local_chunk

    # init states are built by the UNSHARDED reference program: global,
    # unpadded — the same pytree a dense run initializes, so checkpoints
    # interchange across backends and device counts
    from repro.api.runner import reference_chunk_program
    init_fn = reference_chunk_program(spec, engine)[1]

    template = init_fn(jax.random.PRNGKey(0))
    state_spec = _state_pspecs(template, lead)
    data_spec = P(*lead, None, "node")
    outs_spec = RoundOutput(loss=data_spec, w_bar_loss=P(*lead),
                            sparsity=P(*lead), correct=data_spec)
    smapped = shard_map(body, mesh=mesh,
                        in_specs=(state_spec, data_spec, data_spec),
                        out_specs=(state_spec, outs_spec),
                        check_rep=False)

    def chunk_fn(state, xs, ys):
        state = _pad_state(state, pad)
        xs = _pad_axis(xs, pad, xs.ndim - 2)
        ys = _pad_axis(ys, pad, ys.ndim - 1)
        state, outs = smapped(state, xs, ys)
        outs = outs._replace(loss=outs.loss[..., :m],
                             correct=outs.correct[..., :m])
        return _unpad_state(state, m), outs

    return chunk_fn, init_fn
