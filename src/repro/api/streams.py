"""Stream protocol — the data-scenario stage of the experiment pipeline.

A Stream owns WHERE the per-round samples come from; everything downstream
(clip -> noise -> mix -> local rule) is scenario-agnostic. Like the other
`repro.api` protocols, streams resolve by name through a registry
(`STREAMS`) so a new workload registers once and is immediately reachable
from `RunSpec(stream=...)`, the train/dryrun CLIs (``--stream`` /
``--stream-opt``), and `repro.api.run` — without touching engine or runner
code.

Every stream emits fixed-shape, jit-friendly chunks::

    xs, ys = stream.chunk(t0, t1)     # xs (t1-t0, m, n), ys (t1-t0, m)

keyed per ABSOLUTE round, so the data for round t never depends on how the
horizon is partitioned into chunks (the property checkpoint resume and the
sim-vs-dist equivalence tests rely on).

Built-in scenarios:

  social_sparse  — the paper's §V workload: fixed sparse w*, normalized
                   gaussian features, optional label flips.
  drift          — w* is NON-stationary: its sparse support reshuffles
                   (or rotates) every ``period`` rounds, the adversarial
                   regime online regret bounds are actually about.
  heterogeneous  — per-node feature scales and label-noise rates drawn
                   from a seeded distribution: every data center sees its
                   own population (Tekin & van der Schaar's context-
                   dependent nodes).
  bursty         — per-(t, i) sample counts from a seeded heavy-tailed
                   (discrete Pareto) distribution; a round's emitted sample
                   is the mean of its burst, so busy rounds carry lower-
                   variance evidence.

>>> from repro.api.streams import STREAMS
>>> {"social_sparse", "drift", "heterogeneous", "bursty"} <= set(STREAMS.names())
True
>>> s = STREAMS.build("drift", n=32, nodes=4, rounds=64, seed=0)
>>> xs, ys = s.chunk(0, 8)
>>> xs.shape, ys.shape
((8, 4, 32), (8, 4))
>>> b = STREAMS.build("bursty", n=16, nodes=2, rounds=32, seed=1)
>>> int(b.counts(0, 32).min()) >= 1 and int(b.counts(0, 32).max()) <= b.burst_max
True
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.api.registry import STREAMS
from repro.data.social import SocialStream, labels_from_logits, round_keys

__all__ = [
    "Stream",
    "STREAMS",
    "SocialStream",
    "DriftStream",
    "HeterogeneousStream",
    "BurstyStream",
]


@runtime_checkable
class Stream(Protocol):
    """Data-scenario stage: per-round samples for every node.

    ``disjoint`` declares whether round t touches only samples that arrive
    at round t (true for every built-in stream) — the Theorem-1 parallel-
    composition condition `repro.api.run` hands to the PrivacyAccountant.
    """

    n: int        # feature dimension
    nodes: int    # m data centers
    rounds: int   # stream length (the run horizon)
    disjoint: bool

    def chunk(self, t0: int, t1: int) -> tuple[jax.Array, jax.Array]:
        """Rounds [t0, t1): xs (t1-t0, m, n), ys (t1-t0, m) with y in ±1."""
        ...


def _chunks(stream: Stream, chunk_rounds: int) -> Iterator[tuple[jax.Array, jax.Array]]:
    t = 0
    while t < stream.rounds:
        t1 = min(t + chunk_rounds, stream.rounds)
        yield stream.chunk(t, t1)
        t = t1


@dataclasses.dataclass(frozen=True)
class DriftStream:
    """Non-stationary ground truth: w* changes every ``period`` rounds.

    mode='reshuffle' draws a fresh sparse w* per phase (abrupt concept
    drift); mode='rotate' rolls the phase-0 w* by ``period``-proportional
    offsets, so the support wanders through the feature space but keeps its
    geometry (gradual drift). Labels always come from the CURRENT phase's
    w*, so a learner that stops adapting goes stale.
    """

    n: int
    nodes: int
    rounds: int
    period: int = 64
    mode: str = "reshuffle"      # 'reshuffle' | 'rotate'
    sparsity_true: float = 0.05
    label_noise: float = 0.0
    seed: int = 0
    disjoint: bool = True

    def __post_init__(self):
        if self.period < 1:
            raise ValueError("drift period must be >= 1")
        if self.mode not in ("reshuffle", "rotate"):
            raise ValueError(f"unknown drift mode {self.mode!r}")

    def _base(self) -> SocialStream:
        return SocialStream(n=self.n, nodes=self.nodes, rounds=self.rounds,
                            sparsity_true=self.sparsity_true, seed=self.seed)

    def w_true_at(self, t) -> jax.Array:
        """Ground truth in effect at round t (vmap/jit friendly)."""
        phase = jnp.asarray(t) // self.period
        if self.mode == "rotate":
            w0 = self._base().w_true()
            # roll by a phase-proportional offset, coprime-ish with n so the
            # support visits the whole feature space before repeating
            shift = (phase * (self.n // 4 + 1)) % self.n
            return jnp.roll(w0, shift)
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), phase)
        kw, km = jax.random.split(key)
        mask = jax.random.uniform(km, (self.n,)) < self.sparsity_true
        w = jax.random.normal(kw, (self.n,)) * mask
        return (w / jnp.maximum(jnp.linalg.norm(w), 1e-9)).astype(jnp.float32)

    def chunk(self, t0: int, t1: int) -> tuple[jax.Array, jax.Array]:
        keys = round_keys(jax.random.PRNGKey(self.seed + 1), t0, t1)
        kx, kn = jax.vmap(lambda k: tuple(jax.random.split(k)))(keys)
        x = jax.vmap(
            lambda k: jax.random.normal(k, (self.nodes, self.n))
        )(kx) / jnp.sqrt(self.n)
        W = jax.vmap(self.w_true_at)(jnp.arange(t0, t1))       # (T, n)
        y = labels_from_logits(jnp.einsum("tn,tmn->tm", W, x))
        if self.label_noise > 0:
            flip = jax.vmap(
                lambda k: jax.random.uniform(k, (self.nodes,))
            )(kn) < self.label_noise
            y = jnp.where(flip, -y, y)
        return x.astype(jnp.float32), y.astype(jnp.float32)

    def chunks(self, chunk_rounds: int = 512):
        return _chunks(self, chunk_rounds)


@dataclasses.dataclass(frozen=True)
class HeterogeneousStream:
    """Per-node populations: each data center has its own feature scale and
    label-noise rate, drawn once from a seeded distribution.

    Feature scales are lognormal (sigma = ``scale_spread``) around the
    social_sparse normalization, so some nodes see loud features and some
    quiet ones; per-node flip rates are Uniform(0, ``noise_max``). The
    ground truth w* is SHARED — the consensus the gossip step is supposed
    to recover despite the heterogeneity.
    """

    n: int
    nodes: int
    rounds: int
    scale_spread: float = 0.5
    noise_max: float = 0.2
    sparsity_true: float = 0.05
    seed: int = 0
    disjoint: bool = True

    def _base(self) -> SocialStream:
        return SocialStream(n=self.n, nodes=self.nodes, rounds=self.rounds,
                            sparsity_true=self.sparsity_true, seed=self.seed)

    def node_scales(self) -> jax.Array:
        """(m,) per-node lognormal feature scales."""
        k = jax.random.fold_in(jax.random.PRNGKey(self.seed), 7)
        return jnp.exp(
            self.scale_spread * jax.random.normal(k, (self.nodes,))
        ).astype(jnp.float32)

    def node_noise_rates(self) -> jax.Array:
        """(m,) per-node label-flip probabilities in [0, noise_max)."""
        k = jax.random.fold_in(jax.random.PRNGKey(self.seed), 8)
        return (self.noise_max
                * jax.random.uniform(k, (self.nodes,))).astype(jnp.float32)

    def chunk(self, t0: int, t1: int) -> tuple[jax.Array, jax.Array]:
        w = self._base().w_true()
        scales = self.node_scales()
        rates = self.node_noise_rates()
        keys = round_keys(jax.random.PRNGKey(self.seed + 1), t0, t1)
        kx, kn = jax.vmap(lambda k: tuple(jax.random.split(k)))(keys)
        x = jax.vmap(
            lambda k: jax.random.normal(k, (self.nodes, self.n))
        )(kx) * scales[None, :, None] / jnp.sqrt(self.n)
        y = labels_from_logits(jnp.einsum("n,tmn->tm", w, x))
        flip = jax.vmap(
            lambda k: jax.random.uniform(k, (self.nodes,))
        )(kn) < rates[None, :]
        y = jnp.where(flip, -y, y)
        return x.astype(jnp.float32), y.astype(jnp.float32)

    def chunks(self, chunk_rounds: int = 512):
        return _chunks(self, chunk_rounds)


@dataclasses.dataclass(frozen=True)
class BurstyStream:
    """Heavy-tailed per-round sample counts (big-data arrival bursts).

    For every (round, node) a count c is drawn from a capped discrete
    Pareto: c = min(floor(u^(-1/tail)), burst_max) with u ~ Uniform(0, 1),
    so c >= 1 always and P(c >= k) ~ k^-tail. The emitted sample is the
    MEAN of the c fresh samples in the burst (labels come from the mean
    feature), so busy rounds deliver lower-variance, smaller-norm evidence
    — the shape stays (T, m, n) and everything downstream is unchanged.
    ``counts`` exposes the burst sizes for inspection.
    """

    n: int
    nodes: int
    rounds: int
    burst_max: int = 8
    tail: float = 1.5            # Pareto tail index; smaller = heavier
    sparsity_true: float = 0.05
    seed: int = 0
    disjoint: bool = True

    def __post_init__(self):
        if self.burst_max < 1:
            raise ValueError("burst_max must be >= 1")
        if self.tail <= 0:
            raise ValueError("tail must be > 0")

    def _base(self) -> SocialStream:
        return SocialStream(n=self.n, nodes=self.nodes, rounds=self.rounds,
                            sparsity_true=self.sparsity_true, seed=self.seed)

    def counts(self, t0: int, t1: int) -> jax.Array:
        """(t1-t0, m) burst sizes in [1, burst_max], heavy-tailed."""
        keys = round_keys(jax.random.PRNGKey(self.seed + 2), t0, t1)
        u = jax.vmap(
            lambda k: jax.random.uniform(k, (self.nodes,),
                                         minval=1e-7, maxval=1.0)
        )(keys)
        c = jnp.floor(u ** (-1.0 / self.tail))
        return jnp.clip(c, 1, self.burst_max).astype(jnp.int32)

    def chunk(self, t0: int, t1: int) -> tuple[jax.Array, jax.Array]:
        w = self._base().w_true()
        c = self.counts(t0, t1)                                # (T, m)
        keys = round_keys(jax.random.PRNGKey(self.seed + 1), t0, t1)
        total = jnp.zeros((t1 - t0, self.nodes, self.n), jnp.float32)
        # burst_max is small and static: unrolled accumulation keeps memory
        # at one (T, m, n) buffer instead of a (T, m, burst_max, n) stack
        for k in range(self.burst_max):
            sample = jax.vmap(
                lambda kk: jax.random.normal(
                    jax.random.fold_in(kk, k), (self.nodes, self.n))
            )(keys)
            total = total + jnp.where((k < c)[:, :, None], sample, 0.0)
        x = total / c[:, :, None] / jnp.sqrt(self.n)
        y = labels_from_logits(jnp.einsum("n,tmn->tm", w, x))
        return x.astype(jnp.float32), y.astype(jnp.float32)

    def chunks(self, chunk_rounds: int = 512):
        return _chunks(self, chunk_rounds)


@STREAMS.register("social_sparse")
def _social(n: int, nodes: int, rounds: int, seed: int = 0,
            sparsity_true: float = 0.05, label_noise: float = 0.0) -> Stream:
    return SocialStream(n=n, nodes=nodes, rounds=rounds, seed=seed,
                        sparsity_true=sparsity_true, label_noise=label_noise)


@STREAMS.register("drift")
def _drift(n: int, nodes: int, rounds: int, seed: int = 0,
           period: int = 64, mode: str = "reshuffle",
           sparsity_true: float = 0.05, label_noise: float = 0.0) -> Stream:
    return DriftStream(n=n, nodes=nodes, rounds=rounds, seed=seed,
                       period=period, mode=mode,
                       sparsity_true=sparsity_true, label_noise=label_noise)


@STREAMS.register("heterogeneous")
def _het(n: int, nodes: int, rounds: int, seed: int = 0,
         scale_spread: float = 0.5, noise_max: float = 0.2,
         sparsity_true: float = 0.05) -> Stream:
    return HeterogeneousStream(n=n, nodes=nodes, rounds=rounds, seed=seed,
                               scale_spread=scale_spread, noise_max=noise_max,
                               sparsity_true=sparsity_true)


@STREAMS.register("bursty")
def _bursty(n: int, nodes: int, rounds: int, seed: int = 0,
            burst_max: int = 8, tail: float = 1.5,
            sparsity_true: float = 0.05) -> Stream:
    return BurstyStream(n=n, nodes=nodes, rounds=rounds, seed=seed,
                        burst_max=burst_max, tail=tail,
                        sparsity_true=sparsity_true)
