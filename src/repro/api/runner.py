"""`repro.api.run` — one call from RunSpec to RunResult, for either engine.

Before this module existed every benchmark and example hand-rolled its own
driving loop (and none of them did privacy accounting). `run` closes the
loop: it resolves the spec's Stream (STREAMS registry), drives the whole
horizon under a jitted `lax.scan` per chunk on EITHER engine — the dense
simulator (`engine="sim"`) or the node-stacked distributed strategy
(`engine="dist"`) — threads a `PrivacyAccountant` into a per-round eps
ledger, records the regret/accuracy trajectories, and supports periodic
checkpointing with bit-identical resume through `repro.checkpoint`.

Both engines consume the same per-absolute-round stream chunks and the same
PRNG key, so a seeded run produces bit-identical iterates under either
engine (including the Laplace noise — see the single-leaf key note in
`core.gossip.gossip_mix_tree`).

>>> from repro.api import RunSpec, run
>>> spec = RunSpec(nodes=2, dim=8, horizon=6, eps=1.0, alpha0=0.5,
...                lam=0.01, stream="drift", stream_options={"period": 2})
>>> res = run(spec, engine="sim", chunk_rounds=3, compute_regret=False,
...           warmup=False)
>>> res.rounds, res.correct.shape, float(res.eps_ledger[-1])
(6, (6, 2), 1.0)
>>> dist = run(spec, engine="dist", chunk_rounds=3, compute_regret=False,
...            warmup=False)
>>> bool((res.final_w == dist.final_w).all())     # seeded, bit-identical
True

`run` also drives arbitrary step functions (`step_fn=`) so the train CLI's
LM loops share this exact loop — metrics, logging, accounting, checkpoints
— instead of reimplementing it.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.spec import RunSpec
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core.privacy import PrivacyAccountant
from repro.metrics import CSVLogger, MetricTracker

__all__ = ["run", "RunResult", "make_chunk_fn"]


@dataclasses.dataclass
class RunResult:
    """Everything a finished run knows about itself.

    Stream runs fill the trajectory arrays (per-round, horizon-length,
    covering [start_round, rounds)); custom step_fn runs fill ``history``
    (one metrics dict per step) instead. ``eps_ledger[t]`` is the cumulative
    privacy guarantee after round start_round + t + 1.
    """

    engine: str
    rounds: int
    wall_clock: float            # seconds, post-compile (see warmup=)
    rounds_per_sec: float
    stream: str | None = None
    start_round: int = 0         # > 0 when resumed from a checkpoint
    eps_ledger: np.ndarray | None = None
    privacy: dict = dataclasses.field(default_factory=dict)
    loss: np.ndarray | None = None        # (T, m) per-node hinge losses
    w_bar_loss: np.ndarray | None = None  # (T,) loss of the averaged w
    correct: np.ndarray | None = None     # (T, m) prediction correctness
    sparsity: np.ndarray | None = None    # (T,) zero-fraction of w
    regret: np.ndarray | None = None      # (T,) cumulative (Definition 3)
    accuracy: float | None = None         # mean correctness, last 20%
    final_w: np.ndarray | None = None     # (m, n) final primal parameters
    final_state: Any = None               # engine state (checkpointable)
    history: list | None = None           # custom-mode per-step metrics
    metrics: dict = dataclasses.field(default_factory=dict)

    def accuracy_curve(self, window: int = 50) -> np.ndarray:
        """Moving-window mean accuracy over the horizon."""
        correct = self.correct.mean(axis=1)
        c = np.cumsum(np.insert(correct, 0, 0.0))
        return (c[window:] - c[:-window]) / window

    def summary(self) -> dict:
        return {
            "engine": self.engine,
            "stream": self.stream,
            "rounds": self.rounds,
            "wall_clock_s": round(self.wall_clock, 3),
            "rounds_per_sec": round(self.rounds_per_sec, 2),
            "accuracy": self.accuracy,
            "regret_final": (None if self.regret is None
                             else float(self.regret[-1])),
            "eps_total": self.privacy.get("eps_total"),
        }


def make_chunk_fn(spec: RunSpec, engine: str) -> tuple[Callable, Any]:
    """(chunk_fn, initial_state) for one engine.

    chunk_fn(state, xs, ys) scans the engine over a chunk of rounds and
    returns (state, RoundOutput-stacked trajectories). Exposed so
    `launch.dryrun` can lower/compile the exact program `run` executes.
    """
    from repro.core.algorithm1 import RoundOutput, hinge_loss_and_grad
    from repro.core import prox

    m = spec.nodes
    n = spec.dim
    if n is None:
        raise ValueError("RunSpec.dim is required by repro.api.run")
    key = jax.random.PRNGKey(spec.seed)
    loss_and_grad = spec.loss_and_grad or hinge_loss_and_grad

    if engine == "sim":
        alg = spec.build_simulator()

        def chunk_fn(state, xs, ys):
            return jax.lax.scan(alg.round, state, (xs, ys))

        return chunk_fn, alg.init(key)

    if engine == "dist":
        gdp = spec.build_distributed()

        def chunk_fn(state, xs, ys):
            def body(st, batch):
                x, y = batch
                w = gdp.primal(st)["w"]
                loss, grad = loss_and_grad(w, x, y)
                correct = (jnp.sign(jnp.einsum("mn,mn->m", w, x)) == y
                           ).astype(jnp.float32)
                st, _ = gdp.update(st, {"w": grad})
                # identical metric algebra to Algorithm1.round, so the two
                # engines' trajectories compare element-for-element
                w_bar = jnp.mean(w, axis=0, keepdims=True)
                wb_loss = jnp.mean(jnp.maximum(
                    1.0 - y * jnp.einsum("n,mn->m", w_bar[0], x), 0.0))
                out = RoundOutput(loss=loss, w_bar_loss=wb_loss,
                                  sparsity=prox.sparsity(w), correct=correct)
                return st, out
            return jax.lax.scan(body, state, (xs, ys))

        state = gdp.init({"w": jnp.zeros((m, n), jnp.float32)}, key)
        return chunk_fn, state

    raise ValueError(f"unknown engine {engine!r}; expected 'sim' or 'dist'")


def _final_primal(spec: RunSpec, engine: str, state) -> np.ndarray:
    """(m, n) primal parameters from the final engine state — the same
    schedule context for both engines (Algorithm1.final_params convention)."""
    rule = spec.resolve_local_rule()
    ctx = spec.omd_config().step_context(state.t)
    theta = state.theta if engine == "sim" else state.theta["w"]
    return np.asarray(rule.primal(theta, ctx))


def _boundaries(start: int, T: int, chunk_rounds: int,
                checkpoint_every: int | None) -> list[int]:
    """Chunk split points: every chunk_rounds, also landing on every
    checkpoint_every multiple so checkpoints capture exact round states."""
    ts = [start]
    t = start
    while t < T:
        nxt = t + chunk_rounds
        if checkpoint_every:
            nxt = min(nxt, ((t // checkpoint_every) + 1) * checkpoint_every)
        ts.append(min(nxt, T))
        t = ts[-1]
    return ts


_WSTAR_CACHE: dict = {}


def _regret(stream, w_bar_loss: np.ndarray, xs: np.ndarray, ys: np.ndarray,
            m: int) -> np.ndarray:
    from repro.core.regret import best_fixed_hinge, cumulative_regret
    cache_key = (stream, xs.shape)
    try:
        w_star = _WSTAR_CACHE.get(cache_key)
    except TypeError:                      # unhashable custom stream
        cache_key, w_star = None, None
    if w_star is None:
        w_star = best_fixed_hinge(jnp.asarray(xs), jnp.asarray(ys))
        if cache_key is not None:
            _WSTAR_CACHE[cache_key] = w_star
    return cumulative_regret(jnp.asarray(w_bar_loss), jnp.asarray(xs),
                             jnp.asarray(ys), m, w_star=w_star)


def run(spec: RunSpec | None, engine: str = "sim", *,
        chunk_rounds: int = 512,
        checkpoint_every: int | None = None,
        checkpoint_dir: str | None = None,
        resume: bool = False,
        log_path: str | None = None,
        compute_regret: bool = True,
        warmup: bool = True,
        horizon: int | None = None,
        step_fn: Callable | None = None,
        state: Any = None,
        batches: Iterator | None = None,
        print_every: int | None = None) -> RunResult:
    """Drive one run end-to-end and return a RunResult.

    Stream mode (default): resolves ``spec.stream`` and scans the chosen
    engine over the horizon in jitted chunks. ``checkpoint_every`` saves the
    engine state every N rounds into ``checkpoint_dir``; ``resume=True``
    restores the latest checkpoint and continues bit-identically (streams
    are keyed per absolute round, so the data after resume is unchanged).
    ``warmup=True`` compiles the first chunk outside the timed region so
    rounds_per_sec measures steady-state execution.

    Custom mode (``step_fn=``): drives ``state, metrics = step_fn(state,
    next(batches))`` for ``horizon`` steps with the same tracking /
    logging / accounting / checkpointing — the loop `launch.train` uses, so
    the train CLI and the benchmarks cannot diverge.
    """
    if step_fn is not None:
        return _run_custom(spec, engine, step_fn=step_fn, state=state,
                           batches=batches, horizon=horizon,
                           log_path=log_path, print_every=print_every,
                           checkpoint_every=checkpoint_every,
                           checkpoint_dir=checkpoint_dir)
    if spec is None:
        raise ValueError("run() needs a RunSpec (or step_fn= for custom mode)")

    stream = spec.resolve_stream()
    T = horizon or spec.horizon or stream.rounds
    m = spec.nodes

    mech = spec.resolve_mechanism()
    # a custom stream that does not DECLARE disjoint rounds gets the
    # pessimistic sequential composition — never overstate a DP guarantee
    accountant = PrivacyAccountant(
        eps_per_round=spec.eps if mech.is_private else math.inf,
        disjoint_streams=getattr(stream, "disjoint", False))

    chunk_fn, init_state = make_chunk_fn(spec, engine)
    chunk_jit = jax.jit(chunk_fn)

    start = 0
    eng_state = init_state
    if resume:
        if not checkpoint_dir:
            raise ValueError("resume=True needs checkpoint_dir=")
        found = latest_step(checkpoint_dir)
        if found is not None:
            eng_state = restore_checkpoint(checkpoint_dir, init_state,
                                           step=found)
            start = found
    accountant.rounds = start

    bounds = _boundaries(start, T, chunk_rounds, checkpoint_every)
    logger = CSVLogger(log_path) if log_path else None

    first_chunk = None
    if warmup and len(bounds) > 1:
        first_chunk = stream.chunk(bounds[0], bounds[1])
        jax.block_until_ready(chunk_jit(eng_state, *first_chunk)[0].theta)

    losses, wb_losses, sparsities, corrects = [], [], [], []
    xs_all, ys_all = [], []
    t0 = time.time()
    for a, b in zip(bounds[:-1], bounds[1:]):
        if a == bounds[0] and first_chunk is not None:
            xs, ys = first_chunk       # don't regenerate the warmup chunk
        else:
            xs, ys = stream.chunk(a, b)
        eng_state, outs = chunk_jit(eng_state, xs, ys)
        jax.block_until_ready(outs.loss)
        accountant.step(b - a)
        losses.append(np.asarray(outs.loss))
        wb_losses.append(np.asarray(outs.w_bar_loss))
        sparsities.append(np.asarray(outs.sparsity))
        corrects.append(np.asarray(outs.correct))
        if compute_regret:
            xs_all.append(np.asarray(xs))
            ys_all.append(np.asarray(ys))
        if logger:
            for i, t in enumerate(range(a, b)):
                logger.log(t, {
                    "loss": float(losses[-1][i].mean()),
                    "w_bar_loss": float(wb_losses[-1][i]),
                    "sparsity": float(sparsities[-1][i]),
                    "accuracy": float(corrects[-1][i].mean()),
                    "eps": accountant.guarantee_at(t + 1),
                })
        if (checkpoint_every and checkpoint_dir
                and b % checkpoint_every == 0):
            save_checkpoint(checkpoint_dir, b, eng_state)
    wall = time.time() - t0
    if logger:
        logger.close()

    correct = np.concatenate(corrects) if corrects else np.zeros((0, m))
    w_bar_loss = np.concatenate(wb_losses) if wb_losses else np.zeros((0,))
    tail = max(1, int(correct.shape[0] * 0.2)) if correct.size else 1
    regret = None
    if compute_regret and start == 0 and xs_all:
        regret = _regret(stream, w_bar_loss, np.concatenate(xs_all),
                         np.concatenate(ys_all), m)

    done = T - start
    result = RunResult(
        engine=engine,
        rounds=T,
        start_round=start,
        wall_clock=wall,
        rounds_per_sec=(done / wall) if wall > 0 else float("inf"),
        stream=(spec.stream if isinstance(spec.stream, str)
                else type(stream).__name__),
        eps_ledger=np.asarray(accountant.ledger(T)[start:]),
        privacy=accountant.summary(),
        loss=np.concatenate(losses) if losses else None,
        w_bar_loss=w_bar_loss if len(w_bar_loss) else None,
        correct=correct if correct.size else None,
        sparsity=np.concatenate(sparsities) if sparsities else None,
        regret=None if regret is None else np.asarray(regret),
        accuracy=float(correct[-tail:].mean()) if correct.size else None,
        final_w=_final_primal(spec, engine, eng_state),
        final_state=eng_state,
    )
    result.metrics = result.summary()
    return result


def _run_custom(spec, engine, *, step_fn, state, batches, horizon,
                log_path, print_every, checkpoint_every,
                checkpoint_dir) -> RunResult:
    if horizon is None:
        raise ValueError("custom mode needs horizon= (number of steps)")
    accountant = None
    if spec is not None:
        mech = spec.resolve_mechanism()
        accountant = PrivacyAccountant(
            eps_per_round=spec.eps if mech.is_private else math.inf)
    tracker = MetricTracker()
    logger = CSVLogger(log_path) if log_path else None
    history = []
    t0 = time.time()
    for i in range(horizon):
        batch = next(batches)
        state, metrics = step_fn(state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        tracker.update(metrics)
        history.append(metrics)
        if accountant is not None:
            accountant.step()
        if logger:
            logger.log(i, metrics)
        if checkpoint_every and checkpoint_dir and (i + 1) % checkpoint_every == 0:
            save_checkpoint(checkpoint_dir, i + 1, state)
        if print_every and (i % print_every == 0 or i == horizon - 1):
            means = tracker.means()
            print(f"step {i:4d} loss={means.get('loss', 0):.4f} "
                  f"ce={means.get('ce', 0):.4f} "
                  f"sparsity={means.get('theta_sparsity', 0):.3f} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)")
    wall = time.time() - t0
    if logger:
        logger.close()
    return RunResult(
        engine=engine,
        rounds=horizon,
        wall_clock=wall,
        rounds_per_sec=(horizon / wall) if wall > 0 else float("inf"),
        eps_ledger=(None if accountant is None
                    else np.asarray(accountant.ledger())),
        privacy={} if accountant is None else accountant.summary(),
        final_state=state,
        history=history,
        metrics=tracker.means(),
    )
