"""`repro.api.run` — one call from RunSpec to RunResult, for either engine.

Before this module existed every benchmark and example hand-rolled its own
driving loop (and none of them did privacy accounting). `run` closes the
loop: it resolves the spec's Stream (STREAMS registry), drives the whole
horizon under a jitted `lax.scan` per chunk on EITHER engine — the dense
simulator (`engine="sim"`) or the node-stacked distributed strategy
(`engine="dist"`) — threads a `PrivacyAccountant` into a per-round eps
ledger, records the regret/accuracy trajectories, and supports periodic
checkpointing with bit-identical resume through `repro.checkpoint`.

Both engines consume the same per-absolute-round stream chunks and the same
PRNG key, so a seeded run produces bit-identical iterates under either
engine (including the Laplace noise — see the single-leaf key note in
`core.gossip.gossip_mix_tree`).

Execution knobs travel as one frozen `ExecConfig` (`repro.api.exec_config`)
passed via ``exec=``; the legacy keyword arguments still work through a
deprecation shim that forwards into ExecConfig and warns once.

>>> from repro.api import ExecConfig, RunSpec, run
>>> spec = RunSpec(nodes=2, dim=8, horizon=6, eps=1.0, alpha0=0.5,
...                lam=0.01, stream="drift", stream_options={"period": 2})
>>> cfg = ExecConfig(chunk_rounds=3, compute_regret=False, warmup=False)
>>> res = run(spec, engine="sim", exec=cfg)
>>> res.rounds, res.correct.shape, float(res.eps_ledger[-1])
(6, (6, 2), 1.0)
>>> dist = run(spec, engine="dist", exec=cfg)
>>> bool((res.final_w == dist.final_w).all())     # seeded, bit-identical
True

How the round body executes is the spec's business, not the runner's: the
chunk builders dispatch through ``spec.resolve_backend()`` (BACKENDS
registry — "reference" XLA engines or the fused "pallas" kernels, see
`repro.api.backends`), so every path here — run, run_batch, the
node-sharded mesh — honours ``RunSpec.backend`` without special cases.

`run` also drives arbitrary step functions (`step_fn=`) so the train CLI's
LM loops share this exact loop — metrics, logging, accounting, checkpoints
— instead of reimplementing it.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obslib
from repro.api.exec_config import ExecConfig, resolve_exec
from repro.api.spec import RunSpec
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core.privacy import PrivacyAccountant
from repro.metrics import CSVLogger, MetricTracker

__all__ = ["run", "run_batch", "RunResult", "make_chunk_fn",
           "make_chunk_program", "reference_chunk_program"]


# -- JSON round-trip ---------------------------------------------------------
#
# The sweep store (repro.sweep.store) persists one RunResult per record and
# must reconstruct it EXACTLY: trajectories, eps ledger, final parameters and
# (optionally) the raw engine state. float32 values survive the trip through
# Python floats untouched (float32 ⊂ float64 and repr round-trips), so the
# regression tests can assert bit equality, not closeness.

def _encode_tree(obj: Any) -> Any:
    """JSON-able encoding of a (possibly nested) engine state / array."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, (np.ndarray, jnp.ndarray, np.generic)):
        arr = np.asarray(jax.device_get(obj))
        return {"__ndarray__": arr.tolist(), "dtype": str(arr.dtype),
                "shape": list(arr.shape)}
    if isinstance(obj, tuple) and hasattr(obj, "_fields"):   # NamedTuple
        return {"__namedtuple__": type(obj).__name__,
                "fields": {f: _encode_tree(getattr(obj, f))
                           for f in obj._fields}}
    if isinstance(obj, dict):
        return {"__dict__": {str(k): _encode_tree(v) for k, v in obj.items()}}
    if isinstance(obj, (list, tuple)):
        return {"__list__": [_encode_tree(v) for v in obj],
                "tuple": isinstance(obj, tuple)}
    raise TypeError(f"cannot encode {type(obj).__name__} for the JSON record")


def _state_types() -> dict:
    from repro.core.algorithm1 import SimState
    from repro.core.gossip import GossipState
    return {"SimState": SimState, "GossipState": GossipState}


def _decode_tree(obj: Any) -> Any:
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if "__ndarray__" in obj:
        return np.asarray(obj["__ndarray__"],
                          dtype=obj["dtype"]).reshape(obj["shape"])
    if "__namedtuple__" in obj:
        cls = _state_types()[obj["__namedtuple__"]]
        return cls(**{k: _decode_tree(v) for k, v in obj["fields"].items()})
    if "__dict__" in obj:
        return {k: _decode_tree(v) for k, v in obj["__dict__"].items()}
    if "__list__" in obj:
        seq = [_decode_tree(v) for v in obj["__list__"]]
        return tuple(seq) if obj.get("tuple") else seq
    raise TypeError(f"cannot decode record node {obj!r}")


@dataclasses.dataclass
class RunResult:
    """Everything a finished run knows about itself.

    Stream runs fill the trajectory arrays (per-round, horizon-length,
    covering [start_round, rounds)); custom step_fn runs fill ``history``
    (one metrics dict per step) instead. ``eps_ledger[t]`` is the cumulative
    privacy guarantee after round start_round + t + 1.
    """

    engine: str
    rounds: int
    wall_clock: float            # seconds, post-compile (see warmup=)
    rounds_per_sec: float
    stream: str | None = None
    start_round: int = 0         # > 0 when resumed from a checkpoint
    eps_ledger: np.ndarray | None = None
    privacy: dict = dataclasses.field(default_factory=dict)
    loss: np.ndarray | None = None        # (T, m) per-node hinge losses
    w_bar_loss: np.ndarray | None = None  # (T,) loss of the averaged w
    correct: np.ndarray | None = None     # (T, m) prediction correctness
    sparsity: np.ndarray | None = None    # (T,) zero-fraction of w
    regret: np.ndarray | None = None      # (T,) cumulative (Definition 3)
    connectivity: np.ndarray | None = None  # (T,) surviving off-diag mixing
    #                                         weight fraction (faulty runs)
    accuracy: float | None = None         # mean correctness, last 20%
    final_w: np.ndarray | None = None     # (m, n) final primal parameters
    final_state: Any = None               # engine state (checkpointable)
    history: list | None = None           # custom-mode per-step metrics
    metrics: dict = dataclasses.field(default_factory=dict)

    def accuracy_curve(self, window: int = 50) -> np.ndarray:
        """Moving-window mean accuracy over the horizon."""
        correct = self.correct.mean(axis=1)
        c = np.cumsum(np.insert(correct, 0, 0.0))
        return (c[window:] - c[:-window]) / window

    def summary(self) -> dict:
        return {
            "engine": self.engine,
            "stream": self.stream,
            "rounds": self.rounds,
            "wall_clock_s": round(self.wall_clock, 3),
            "rounds_per_sec": round(self.rounds_per_sec, 2),
            "accuracy": self.accuracy,
            "regret_final": (None if self.regret is None
                             else float(self.regret[-1])),
            "eps_total": self.privacy.get("eps_total"),
        }

    _ARRAY_FIELDS = ("eps_ledger", "loss", "w_bar_loss", "correct",
                     "sparsity", "regret", "connectivity", "final_w")

    def to_record(self, include_state: bool = False) -> dict:
        """JSON-able dict that `from_record` reconstructs exactly.

        Every trajectory array, the eps ledger and final_w round-trip
        bit-for-bit (float32 values survive the trip through JSON floats
        untouched). ``include_state=True`` additionally serializes the raw
        engine state (`SimState` / `GossipState` pytree) so a stored record
        can seed a resumed run; the sweep store leaves it off by default to
        keep the JSONL lean.
        """
        rec: dict[str, Any] = {
            "engine": self.engine,
            "rounds": self.rounds,
            "start_round": self.start_round,
            "wall_clock": self.wall_clock,
            "rounds_per_sec": self.rounds_per_sec,
            "stream": self.stream,
            "accuracy": self.accuracy,
            "privacy": dict(self.privacy),
            "metrics": dict(self.metrics),
            "history": self.history,
        }
        for f in self._ARRAY_FIELDS:
            v = getattr(self, f)
            rec[f] = None if v is None else _encode_tree(np.asarray(v))
        rec["final_state"] = (_encode_tree(jax.device_get(self.final_state))
                             if include_state and self.final_state is not None
                             else None)
        return rec

    @classmethod
    def from_record(cls, rec: dict) -> "RunResult":
        kw = {k: rec[k] for k in ("engine", "rounds", "start_round",
                                  "wall_clock", "rounds_per_sec", "stream",
                                  "accuracy")}
        kw["privacy"] = dict(rec.get("privacy") or {})
        kw["metrics"] = dict(rec.get("metrics") or {})
        kw["history"] = rec.get("history")
        for f in cls._ARRAY_FIELDS:
            v = rec.get(f)
            kw[f] = None if v is None else _decode_tree(v)
        fs = rec.get("final_state")
        kw["final_state"] = None if fs is None else _decode_tree(fs)
        return cls(**kw)


def make_chunk_program(spec: RunSpec, engine: str) -> tuple[Callable, Callable]:
    """(chunk_fn, init_fn) for one engine, via the spec's backend.

    chunk_fn(state, xs, ys) scans the round body over a chunk of rounds and
    returns (state, RoundOutput-stacked trajectories); init_fn(key) builds
    the engine state for one PRNG key. The program is seed-independent —
    only the key (and the stream data fed to chunk_fn) vary per seed, which
    is what lets `run_batch` build ONE program and S init states.

    Dispatches through ``spec.resolve_backend()`` (BACKENDS registry):
    backend="reference" is `reference_chunk_program` below; "pallas" swaps
    the round body for the fused kernels of `repro.kernels.round_fused`
    while keeping the same state pytrees, PRNG stream and scan structure.
    """
    return spec.resolve_backend().make_chunk_program(spec, engine)


def reference_chunk_program(spec: RunSpec,
                            engine: str) -> tuple[Callable, Callable]:
    """(chunk_fn, init_fn) of the plain-XLA engines — the reference backend
    (and the init_fn every other backend shares)."""
    from repro.core.algorithm1 import RoundOutput, hinge_loss_and_grad
    from repro.core import prox

    m = spec.nodes
    n = spec.dim
    if n is None:
        raise ValueError("RunSpec.dim is required by repro.api.run")
    loss_and_grad = spec.loss_and_grad or hinge_loss_and_grad

    if engine == "sim":
        alg = spec.build_simulator()

        def chunk_fn(state, xs, ys):
            return jax.lax.scan(alg.round, state, (xs, ys))

        return chunk_fn, alg.init

    if engine == "dist":
        gdp = spec.build_distributed()

        def chunk_fn(state, xs, ys):
            def body(st, batch):
                x, y = batch
                w = gdp.primal(st)["w"]
                loss, grad = loss_and_grad(w, x, y)
                correct = (jnp.sign(jnp.einsum("mn,mn->m", w, x)) == y
                           ).astype(jnp.float32)
                st, _ = gdp.update(st, {"w": grad})
                # identical metric algebra to Algorithm1.round, so the two
                # engines' trajectories compare element-for-element (and the
                # multiply+reduce margin lowers the same under a seed vmap)
                w_bar = jnp.mean(w, axis=0, keepdims=True)
                wb_loss = jnp.mean(jnp.maximum(
                    1.0 - y * jnp.sum(w_bar * x, axis=-1), 0.0))
                out = RoundOutput(loss=loss, w_bar_loss=wb_loss,
                                  sparsity=prox.sparsity(w), correct=correct)
                return st, out
            return jax.lax.scan(body, state, (xs, ys))

        def init_fn(key):
            return gdp.init({"w": jnp.zeros((m, n), jnp.float32)}, key)

        return chunk_fn, init_fn

    raise ValueError(f"unknown engine {engine!r}; expected 'sim' or 'dist'")


def make_chunk_fn(spec: RunSpec, engine: str) -> tuple[Callable, Any]:
    """(chunk_fn, initial_state) for one engine — `make_chunk_program` with
    the state built from ``spec.seed``. Exposed so `launch.dryrun` can
    lower/compile the exact program `run` executes."""
    chunk_fn, init_fn = make_chunk_program(spec, engine)
    return chunk_fn, init_fn(jax.random.PRNGKey(spec.seed))


def _final_primal(spec: RunSpec, engine: str, state) -> np.ndarray:
    """(m, n) primal parameters from the final engine state — the same
    schedule context for both engines (Algorithm1.final_params convention)."""
    rule = spec.resolve_local_rule()
    ctx = spec.omd_config().step_context(state.t)
    theta = state.theta if engine == "sim" else state.theta["w"]
    return np.asarray(rule.primal(theta, ctx))


def _boundaries(start: int, T: int, chunk_rounds: int,
                checkpoint_every: int | None) -> list[int]:
    """Chunk split points: every chunk_rounds, also landing on every
    checkpoint_every multiple so checkpoints capture exact round states."""
    ts = [start]
    t = start
    while t < T:
        nxt = t + chunk_rounds
        if checkpoint_every:
            nxt = min(nxt, ((t // checkpoint_every) + 1) * checkpoint_every)
        ts.append(min(nxt, T))
        t = ts[-1]
    return ts


_WSTAR_CACHE: dict = {}


def _regret(stream, w_bar_loss: np.ndarray, xs: np.ndarray, ys: np.ndarray,
            m: int) -> np.ndarray:
    from repro.core.regret import best_fixed_hinge, cumulative_regret
    cache_key = (stream, xs.shape)
    try:
        w_star = _WSTAR_CACHE.get(cache_key)
    except TypeError:                      # unhashable custom stream
        cache_key, w_star = None, None
    if w_star is None:
        w_star = best_fixed_hinge(jnp.asarray(xs), jnp.asarray(ys))
        if cache_key is not None:
            _WSTAR_CACHE[cache_key] = w_star
    return cumulative_regret(jnp.asarray(w_bar_loss), jnp.asarray(xs),
                             jnp.asarray(ys), m, w_star=w_star)


def run(spec: RunSpec | None, engine: str = "sim", *,
        exec: ExecConfig | None = None,
        horizon: int | None = None,
        on_chunk: Callable | None = None,
        step_fn: Callable | None = None,
        state: Any = None,
        batches: Iterator | None = None,
        **legacy: Any) -> RunResult:
    """Drive one run end-to-end and return a RunResult.

    Execution knobs (chunking, checkpointing, logging, meshes, telemetry)
    travel as ``exec=ExecConfig(...)`` — see `repro.api.exec_config` for
    every field and the legacy-kwarg migration table. The old keyword
    arguments (``chunk_rounds=``, ``checkpoint_every=``, ...) still work
    via ``**legacy`` with a once-per-process DeprecationWarning.

    Stream mode (default): resolves ``spec.stream`` and scans the chosen
    engine over the horizon in jitted chunks. ``checkpoint_every`` saves the
    engine state every N rounds into ``checkpoint_dir``; ``resume=True``
    restores the latest checkpoint and continues bit-identically (streams
    are keyed per absolute round, so the data after resume is unchanged).
    ``warmup=True`` compiles the first chunk outside the timed region so
    rounds_per_sec measures steady-state execution.

    ``on_chunk(round_end, eng_state, accountant)`` fires after every
    completed chunk with the ABSOLUTE round it ended on, the engine state at
    that round (host-synchronized — safe to publish or serialize) and the
    live accountant; returning a truthy value stops the run early at that
    chunk boundary (trajectories and the eps ledger cover only the completed
    rounds). This is the snapshot-publication hook the serving layer
    (`repro.serve`) hangs its background trainer on — a published snapshot
    at round r is bit-identical to a fresh ``run(spec, horizon=r)`` because
    streams are keyed per absolute round and chunking never changes the
    per-round math.

    ``node_devices=`` (or a prebuilt ``node_mesh=`` with a "node" axis)
    SHARDS the node axis itself across devices: the spec's topology is
    lowered to its sparse edge-list form and the whole per-chunk scan runs
    under `shard_map` with a ppermute halo exchange for cross-shard edges
    (see `repro.api.shard_node`). State entering/leaving each chunk stays
    global and unpadded, so checkpoints interchange with any device count
    (and with the unsharded path). The per-round noise is bit-identical to
    the dense engines; only float32 reduction order differs.

    Custom mode (``step_fn=``): drives ``state, metrics = step_fn(state,
    next(batches))`` for ``horizon`` steps with the same tracking /
    logging / accounting / checkpointing — the loop `launch.train` uses, so
    the train CLI and the benchmarks cannot diverge.

    ``obs=`` takes a `repro.obs.Telemetry` (default: the ambient
    ``repro.obs.active()``, disabled unless ``repro.obs.enable()`` ran).
    When enabled, the runner wraps compile / chunk / checkpoint / regret
    phases in spans, publishes ``run.rounds`` / ``run.chunk_seconds`` /
    ``run.eps_total`` (and fault connectivity) into the metrics registry,
    streams ``run_start`` / ``chunk`` / ``checkpoint`` / ``run_end`` events,
    and — with ``Telemetry(cost=True)`` — records the predicted-vs-measured
    chunk cost under ``result.metrics['obs']['cost']``. Telemetry is strictly
    host-side: a telemetry-on run is bit-identical to a telemetry-off run
    (gated as ``obs_off_identical`` in BENCH_obs.json).
    """
    cfg = resolve_exec(exec, legacy, caller="run")
    if step_fn is not None:
        return _run_custom(spec, engine, step_fn=step_fn, state=state,
                           batches=batches, horizon=horizon,
                           log_path=cfg.log_path, print_every=cfg.print_every,
                           checkpoint_every=cfg.checkpoint_every,
                           checkpoint_dir=cfg.checkpoint_dir)
    if spec is None:
        raise ValueError("run() needs a RunSpec (or step_fn= for custom mode)")

    stream = spec.resolve_stream()
    T = horizon or spec.horizon or stream.rounds
    m = spec.nodes

    mech = spec.resolve_mechanism()
    # a custom stream that does not DECLARE disjoint rounds gets the
    # pessimistic sequential composition — never overstate a DP guarantee
    accountant = PrivacyAccountant(
        eps_per_round=spec.eps if mech.is_private else math.inf,
        disjoint_streams=getattr(stream, "disjoint", False))

    # repro.faults: one resolved faulty mixer for metrics + accounting — the
    # fault pattern is seeded by FaultSpec.seed, so this instance agrees
    # bit-for-bit with the one baked into the chunk program
    fault_mixer = (spec.resolve_mixer()
                   if getattr(spec, "faults", None) is not None else None)
    fault_sched = getattr(fault_mixer, "schedule", None)

    tel = cfg.obs if cfg.obs is not None else obslib.active()
    run_id = tel.new_run_id() if tel.enabled else None

    nmesh = None
    if cfg.node_devices is not None or cfg.node_mesh is not None:
        from repro.api.shard_node import resolve_node_mesh
        nmesh = resolve_node_mesh(cfg.node_devices, cfg.node_mesh)
    if nmesh is None:
        chunk_fn, init_state = make_chunk_fn(spec, engine)
    else:
        from repro.api.shard_node import make_node_chunk_fn
        chunk_fn, init_fn = make_node_chunk_fn(spec, engine, nmesh)
        init_state = init_fn(jax.random.PRNGKey(spec.seed))
    chunk_jit = jax.jit(chunk_fn)

    start = 0
    eng_state = init_state
    if cfg.resume:
        if not cfg.checkpoint_dir:
            raise ValueError("resume=True needs checkpoint_dir=")
        found = latest_step(cfg.checkpoint_dir)
        if found is not None:
            eng_state = restore_checkpoint(cfg.checkpoint_dir, init_state,
                                           step=found)
            start = found
    accountant.rounds = start

    bounds = _boundaries(start, T, cfg.chunk_rounds, cfg.checkpoint_every)
    logger = CSVLogger(cfg.log_path) if cfg.log_path else None

    first_chunk = None
    if cfg.warmup and len(bounds) > 1:
        first_chunk = stream.chunk(bounds[0], bounds[1])
        with tel.span("run.compile", engine=engine, run_id=run_id):
            jax.block_until_ready(chunk_jit(eng_state, *first_chunk)[0].theta)

    chunk_cost = None
    if tel.cost_enabled and len(bounds) > 1:
        # one extra lower/compile of the exact chunk program, BEFORE the
        # timed loop (a cache hit when warmup already compiled it), so the
        # cost loop never leaks into steady-state timing
        cxs, cys = (first_chunk if first_chunk is not None
                    else stream.chunk(bounds[0], bounds[1]))
        chunk_cost = obslib.analyze_chunk(chunk_jit, eng_state, cxs, cys,
                                          model=tel.cost_model)

    if tel.enabled:
        tel.emit("run_start", run_id=run_id, kind="run", engine=engine,
                 stream=(spec.stream if isinstance(spec.stream, str)
                         else type(stream).__name__),
                 nodes=m, dim=spec.dim, horizon=T, start_round=start)

    losses, wb_losses, sparsities, corrects = [], [], [], []
    xs_all, ys_all = [], []
    done_to = start
    t0 = time.time()
    with tel.profile():
        for a, b in zip(bounds[:-1], bounds[1:]):
            if a == bounds[0] and first_chunk is not None:
                xs, ys = first_chunk   # don't regenerate the warmup chunk
            else:
                xs, ys = stream.chunk(a, b)
            with tel.span("run.chunk", round_start=a, round_end=b) as sp:
                eng_state, outs = chunk_jit(eng_state, xs, ys)
                # block on the STATE too, not just the metric outputs — the
                # timed region must cover the whole round computation, and
                # on_chunk consumers (snapshot publication) need a finished
                # state
                jax.block_until_ready((eng_state, outs))
            if fault_sched is not None and fault_sched.has_crashes:
                # crashed rounds release no noised broadcast — don't charge
                # them
                accountant.step(b - a,
                                participation=fault_sched.participation(a, b))
            else:
                accountant.step(b - a)
            done_to = b
            if tel.enabled:
                secs = sp.duration_s
                eps_now = accountant.guarantee_at(b)
                tel.metrics.counter("run.rounds").inc(b - a)
                tel.metrics.histogram("run.chunk_seconds").observe(secs)
                tel.metrics.gauge("run.eps_total").set(eps_now)
                if chunk_cost is not None:
                    chunk_cost.record(secs)
                tel.emit("chunk", run_id=run_id, round_start=a, round_end=b,
                         seconds=secs,
                         rounds_per_sec=((b - a) / secs if secs > 0 else None),
                         eps=eps_now)
            losses.append(np.asarray(outs.loss))
            wb_losses.append(np.asarray(outs.w_bar_loss))
            sparsities.append(np.asarray(outs.sparsity))
            corrects.append(np.asarray(outs.correct))
            if cfg.compute_regret:
                xs_all.append(np.asarray(xs))
                ys_all.append(np.asarray(ys))
            if logger:
                for i, t in enumerate(range(a, b)):
                    logger.log(t, {
                        "loss": float(losses[-1][i].mean()),
                        "w_bar_loss": float(wb_losses[-1][i]),
                        "sparsity": float(sparsities[-1][i]),
                        "accuracy": float(corrects[-1][i].mean()),
                        "eps": accountant.guarantee_at(t + 1),
                    })
            if (cfg.checkpoint_every and cfg.checkpoint_dir
                    and b % cfg.checkpoint_every == 0):
                with tel.span("run.checkpoint", step=b):
                    save_checkpoint(cfg.checkpoint_dir, b, eng_state)
                tel.emit("checkpoint", run_id=run_id, step=b)
            if on_chunk is not None and on_chunk(b, eng_state, accountant):
                break
    wall = time.time() - t0
    T = done_to                 # < requested horizon iff on_chunk stopped early
    if logger:
        logger.close()

    correct = np.concatenate(corrects) if corrects else np.zeros((0, m))
    w_bar_loss = np.concatenate(wb_losses) if wb_losses else np.zeros((0,))
    tail = max(1, int(correct.shape[0] * 0.2)) if correct.size else 1
    regret = None
    if cfg.compute_regret and start == 0 and xs_all:
        with tel.span("run.regret", rounds=int(w_bar_loss.shape[0])):
            regret = _regret(stream, w_bar_loss, np.concatenate(xs_all),
                             np.concatenate(ys_all), m)

    done = T - start
    result = RunResult(
        engine=engine,
        rounds=T,
        start_round=start,
        wall_clock=wall,
        rounds_per_sec=(done / wall) if wall > 0 else float("inf"),
        stream=(spec.stream if isinstance(spec.stream, str)
                else type(stream).__name__),
        eps_ledger=np.asarray(accountant.ledger(T)[start:]),
        privacy=accountant.summary(),
        loss=np.concatenate(losses) if losses else None,
        w_bar_loss=w_bar_loss if len(w_bar_loss) else None,
        correct=correct if correct.size else None,
        sparsity=np.concatenate(sparsities) if sparsities else None,
        regret=None if regret is None else np.asarray(regret),
        accuracy=float(correct[-tail:].mean()) if correct.size else None,
        final_w=_final_primal(spec, engine, eng_state),
        final_state=eng_state,
    )
    result.metrics = result.summary()
    if fault_mixer is not None and done > 0:
        conn = np.asarray(fault_mixer.connectivity(T))[start:]
        result.connectivity = conn
        result.metrics["faults"] = _fault_metrics(spec, fault_sched, conn)
        if tel.enabled:
            tel.metrics.gauge("faults.mean_connectivity").set(
                result.metrics["faults"]["mean_connectivity"])
    if tel.enabled:
        obs_info: dict[str, Any] = {"run_id": run_id}
        if chunk_cost is not None:
            cs = chunk_cost.summary()
            obs_info["cost"] = cs
            tel.emit("chunk_cost", run_id=run_id,
                     **{k: cs[k] for k in ("predicted_s", "measured_mean_s",
                                           "error_ratio", "flops",
                                           "hbm_bytes")})
        result.metrics["obs"] = obs_info
        tel.emit("run_end", run_id=run_id, rounds=T, wall_clock_s=wall,
                 rounds_per_sec=result.rounds_per_sec,
                 accuracy=result.accuracy,
                 eps_total=result.privacy.get("eps_total"))
    return result


def _fault_metrics(spec: RunSpec, fault_sched, conn: np.ndarray) -> dict:
    """Per-run degradation summary attached as ``metrics['faults']``."""
    name = (spec.faults if isinstance(spec.faults, str)
            else getattr(spec.faults, "name", "faults"))
    return {
        "spec": name,
        "mean_connectivity": float(conn.mean()),
        "min_connectivity": float(conn.min()),
        "crash_windows": len(getattr(fault_sched, "crash_windows", ()) or ()),
        "partitions": len(getattr(fault_sched, "partitions", ()) or ()),
    }


# -- vectorized multi-seed execution ----------------------------------------

def _config_eq(a: Any, b: Any) -> bool:
    """Structural equality for resolved protocol stages (mixers etc.)."""
    if a is b:
        return True
    if type(a) is not type(b):
        return False
    if isinstance(a, (np.ndarray, jnp.ndarray, np.generic)):
        return (np.shape(a) == np.shape(b)
                and bool(np.array_equal(np.asarray(a), np.asarray(b))))
    if dataclasses.is_dataclass(a) and not isinstance(a, type):
        return all(_config_eq(getattr(a, f.name), getattr(b, f.name))
                   for f in dataclasses.fields(a))
    if isinstance(a, dict):
        return (a.keys() == b.keys()
                and all(_config_eq(v, b[k]) for k, v in a.items()))
    if isinstance(a, (list, tuple)):
        return (len(a) == len(b)
                and all(_config_eq(x, y) for x, y in zip(a, b)))
    if hasattr(a, "__dict__") and not callable(a):
        return _config_eq(vars(a), vars(b))
    try:
        return bool(a == b)
    except Exception:
        return False


def seed_vectorizable(spec: RunSpec, seeds) -> bool:
    """True when a seed batch can share ONE compiled chunk program.

    The vmapped path bakes the resolved mixer (and the rest of the stage
    pipeline) into the program once, from the first seed; only the PRNG key
    and the stream data vary per seed. Seeded topologies ('random',
    'time_varying', per-edge `delay_dist` draws) resolve to DIFFERENT mixing
    matrices per seed, so they must fall back to sequential `run()` calls —
    `repro.sweep` consults this predicate to pick the path automatically.
    """
    seeds = list(seeds)
    if len(seeds) <= 1:
        return True
    base = spec.replace(seed=seeds[0]).resolve_mixer()
    return all(_config_eq(spec.replace(seed=s).resolve_mixer(), base)
               for s in seeds[1:])


def _index_tree(tree: Any, i: int) -> Any:
    return jax.tree_util.tree_map(lambda x: x[i], tree)


def _pad_tree(tree: Any, pad: int) -> Any:
    """Grow every leaf's leading (seed) axis by ``pad`` copies of its last
    entry. Pad seeds are throwaway duplicates — `_unpad_tree` masks them out
    of every aggregate before results are read."""
    if pad == 0:
        return tree
    return jax.tree_util.tree_map(
        lambda x: jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)]), tree)


def _unpad_tree(tree: Any, n: int) -> Any:
    return jax.tree_util.tree_map(lambda x: x[:n], tree)


def _resolve_seed_mesh(devices: int | str | None, mesh: Any):
    """The ("seed",) mesh to shard the batch over, or None for plain vmap.

    ``devices=None`` keeps the single-device vmap path; ``"auto"`` takes
    every local device (falling back to vmap on a 1-device host); an int
    asks for exactly that many. A prebuilt mesh must carry a "seed" axis.
    """
    if mesh is not None:
        if "seed" not in mesh.axis_names:
            raise ValueError(
                f"run_batch needs a mesh with a 'seed' axis, got axes "
                f"{tuple(mesh.axis_names)}")
        return mesh if int(mesh.shape["seed"]) > 1 else None
    if devices is None:
        return None
    from repro.launch.mesh import seed_mesh
    return seed_mesh(devices)


def run_batch(spec: RunSpec, seeds, engine: str = "sim", *,
              exec: ExecConfig | None = None,
              horizon: int | None = None,
              **legacy: Any) -> list[RunResult]:
    """Run one config under S seeds as ONE vmapped program; S RunResults.

    Execution knobs travel as ``exec=ExecConfig(...)`` exactly like `run`
    (legacy kwargs keep working with a once-per-process deprecation
    warning); ``devices=``/``mesh=``/``check_vectorizable=`` are the
    batch-only ExecConfig fields.

    The innermost (seed) axis is vectorized: per-seed engine states are
    stacked into a leading axis of size S, the per-seed stream chunks are
    stacked the same way, and `jax.vmap` of the runner's per-chunk `lax.scan`
    drives all S trajectories in a single compiled pass — one compilation
    and roughly one memory-bound sweep instead of S sequential `run()` calls.
    Each returned RunResult is bit-identical to the corresponding
    ``run(spec.replace(seed=s), engine)`` (same stream chunks, same PRNG
    keys, same scan — the seed-vmap equivalence tests hold this to the bit),
    with ``wall_clock`` amortized as batch wall / S and the batch totals
    under ``metrics["batch"]``.

    ``devices=`` (or a prebuilt ``mesh=`` with a "seed" axis) additionally
    SHARDS the vmapped seed axis across local devices with `shard_map` over
    a 1-D ``("seed",)`` mesh: S is padded up to a multiple of the device
    count D with throwaway duplicate seeds, each device runs the same vmapped
    chunk program over its S/D block, and the pad seeds are sliced out of
    every trajectory, checkpoint and aggregate. Seeds are independent private
    runs, so the sharded results stay bit-identical to the single-device
    vmap (and to sequential `run()`) — noise, delay rings and resume
    included. ``devices="auto"`` uses `jax.local_device_count()` and falls
    back to plain vmap on a 1-device host.

    ``node_devices=`` composes node sharding with the seed batch into a 2-D
    ``("seed", "node")`` grid (``devices`` then counts SEED rows, default 1;
    a prebuilt ``mesh=`` may carry both axes): each seed row runs the
    node-sharded sparse chunk program of `repro.api.shard_node`, vmapped
    over its seed block inside one shard_map. Node padding lives inside the
    chunk program, so the seed pad-and-mask logic and checkpoints here are
    unchanged.

    Checkpoints (``checkpoint_every``/``checkpoint_dir``/``resume``) store
    the STACKED state gathered to host and stripped of pad seeds, so a run
    saved under one device count resumes bit-identically under any other
    (4 devices -> 1, 1 -> 8, ...).

    ``obs=`` instruments the batch exactly like `run` (default: the ambient
    `repro.obs.active`): ``run_batch.compile`` / ``run_batch.chunk``
    spans, ``run_batch.*`` metrics, one shared ``run_id`` across the batch's
    events and RunResults, and — with ``Telemetry(cost=True)`` — the
    predicted-vs-measured cost of the whole S-seed chunk program. Host-side
    only; telemetry-on results stay bit-identical to telemetry-off.
    Raises ValueError when the spec's resolved stages depend on the seed
    (see `seed_vectorizable`) — callers like `repro.sweep` fall back to
    sequential per-seed runs in that case.
    """
    cfg = resolve_exec(exec, legacy, caller="run_batch")
    devices, mesh = cfg.devices, cfg.mesh
    node_devices = cfg.node_devices
    seeds = [int(s) for s in seeds]
    if not seeds:
        raise ValueError("run_batch needs at least one seed")
    # check_vectorizable=False skips the per-seed mixer resolutions when the
    # caller (repro.sweep) already ran seed_vectorizable on this spec
    if cfg.check_vectorizable and not seed_vectorizable(spec, seeds):
        raise ValueError(
            "the resolved mixer depends on RunSpec.seed (seeded topology or "
            "delay_dist); a vmapped batch would share one mixing matrix "
            "across seeds — run sequentially per seed instead (repro.sweep "
            "does this fallback automatically)")

    specs = [spec.replace(seed=s) for s in seeds]
    base = specs[0]
    streams = [s.resolve_stream() for s in specs]
    T = horizon or base.horizon or streams[0].rounds
    m = spec.nodes
    S = len(seeds)

    mech = base.resolve_mechanism()
    accountant = PrivacyAccountant(
        eps_per_round=spec.eps if mech.is_private else math.inf,
        disjoint_streams=getattr(streams[0], "disjoint", False))

    # FaultSpec.seed is independent of RunSpec.seed, so every seed in the
    # batch runs under the SAME fault pattern (it's part of the scenario)
    fault_mixer = (base.resolve_mixer()
                   if getattr(base, "faults", None) is not None else None)
    fault_sched = getattr(fault_mixer, "schedule", None)

    tel = cfg.obs if cfg.obs is not None else obslib.active()
    run_id = tel.new_run_id() if tel.enabled else None

    chunk_fn, init_fn = make_chunk_program(base, engine)
    init_states = [init_fn(jax.random.PRNGKey(s)) for s in seeds]
    batched_init = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *init_states)

    node_grid = None
    if node_devices is not None or (
            mesh is not None and "node" in getattr(mesh, "axis_names", ())):
        if mesh is not None:
            if "seed" not in mesh.axis_names:
                raise ValueError(
                    "run_batch node sharding needs a ('seed','node') mesh")
            node_grid = mesh
        else:
            from repro.launch.mesh import seed_node_mesh
            seed_dev = 1 if devices in (None, "auto") else int(devices)
            node_grid = seed_node_mesh(seed_dev, node_devices)
        mesh = node_grid        # _place shards the seed axis of this grid

    if node_grid is not None:
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.api.shard_node import make_node_chunk_fn
        D = int(node_grid.shape["seed"])
        pad = (-S) % D
        sharding = NamedSharding(node_grid, PartitionSpec("seed"))
        # the node-sharded chunk program vmaps the seed axis inside its own
        # ("seed","node") shard_map; the seed pad-and-mask stays out here
        chunk_jit = jax.jit(make_node_chunk_fn(base, engine, node_grid,
                                               batched=True)[0])
    else:
        mesh = _resolve_seed_mesh(devices, mesh)
        D = int(mesh.shape["seed"]) if mesh is not None else 1
        pad = (-S) % D
        if mesh is None:
            sharding = None
            chunk_jit = jax.jit(jax.vmap(chunk_fn))
        else:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import NamedSharding, PartitionSpec
            pspec = PartitionSpec("seed")
            sharding = NamedSharding(mesh, pspec)
            # each device runs the SAME vmapped chunk program over its S/D
            # block of seeds; no collectives cross the blocks, so per-seed
            # trajectories cannot differ from the single-device vmap
            chunk_jit = jax.jit(shard_map(
                jax.vmap(chunk_fn), mesh=mesh,
                in_specs=(pspec, pspec, pspec), out_specs=(pspec, pspec),
                check_rep=False))

    def _place(tree):
        """Pad the seed axis to S + pad and lay it out over the mesh."""
        if mesh is None:
            return tree
        return jax.device_put(_pad_tree(tree, pad), sharding)

    start = 0
    eng_state = _place(batched_init)
    if cfg.resume:
        if not cfg.checkpoint_dir:
            raise ValueError("resume=True needs checkpoint_dir=")
        found = latest_step(cfg.checkpoint_dir)
        if found is not None:
            # checkpoints hold the UNPADDED (S, ...) host state, so a run
            # saved under any device count restores under this one
            eng_state = _place(restore_checkpoint(cfg.checkpoint_dir,
                                                  batched_init, step=found))
            start = found
    accountant.rounds = start

    def stacked_chunk(a: int, b: int):
        pairs = [st.chunk(a, b) for st in streams]
        return _place((jnp.stack([p[0] for p in pairs]),
                       jnp.stack([p[1] for p in pairs])))

    bounds = _boundaries(start, T, cfg.chunk_rounds, cfg.checkpoint_every)

    first_chunk = None
    if cfg.warmup and len(bounds) > 1:
        first_chunk = stacked_chunk(bounds[0], bounds[1])
        with tel.span("run_batch.compile", engine=engine, seeds=S,
                      run_id=run_id):
            jax.block_until_ready(jax.tree_util.tree_leaves(
                chunk_jit(eng_state, *first_chunk)[0])[0])

    chunk_cost = None
    if tel.cost_enabled and len(bounds) > 1:
        # the WHOLE S-seed chunk program's cost (all seeds in one pass),
        # analyzed outside the timed loop — cache hit after warmup
        cxs, cys = (first_chunk if first_chunk is not None
                    else stacked_chunk(bounds[0], bounds[1]))
        chunk_cost = obslib.analyze_chunk(chunk_jit, eng_state, cxs, cys,
                                          model=tel.cost_model)

    if tel.enabled:
        tel.emit("run_start", run_id=run_id, kind="run_batch", engine=engine,
                 stream=(spec.stream if isinstance(spec.stream, str)
                         else type(streams[0]).__name__),
                 nodes=m, dim=spec.dim, horizon=T, start_round=start,
                 seeds=seeds, devices=D)

    losses, wb_losses, sparsities, corrects = [], [], [], []
    xs_all, ys_all = [], []
    t0 = time.time()
    with tel.profile():
        for a, b in zip(bounds[:-1], bounds[1:]):
            if a == bounds[0] and first_chunk is not None:
                xs, ys = first_chunk
            else:
                xs, ys = stacked_chunk(a, b)
            with tel.span("run_batch.chunk", round_start=a, round_end=b,
                          seeds=S) as sp:
                eng_state, outs = chunk_jit(eng_state, xs, ys)
                # block on state + outputs so the timed region measures the
                # whole round computation, not just the dispatch of the
                # metric arrays
                jax.block_until_ready((eng_state, outs))
            if fault_sched is not None and fault_sched.has_crashes:
                accountant.step(b - a,
                                participation=fault_sched.participation(a, b))
            else:
                accountant.step(b - a)
            if tel.enabled:
                secs = sp.duration_s
                eps_now = accountant.guarantee_at(b)
                tel.metrics.counter("run_batch.rounds").inc(b - a)
                tel.metrics.histogram("run_batch.chunk_seconds").observe(secs)
                tel.metrics.gauge("run_batch.eps_total").set(eps_now)
                if chunk_cost is not None:
                    chunk_cost.record(secs)
                tel.emit("chunk", run_id=run_id, round_start=a, round_end=b,
                         seconds=secs,
                         rounds_per_sec=((b - a) / secs if secs > 0 else None),
                         eps=eps_now)
            # [:S] masks the pad seeds (duplicates of the last real seed) out
            # of every recorded trajectory; a no-op on the unsharded path
            losses.append(np.asarray(outs.loss)[:S])           # (S, C, m)
            wb_losses.append(np.asarray(outs.w_bar_loss)[:S])  # (S, C)
            sparsities.append(np.asarray(outs.sparsity)[:S])
            corrects.append(np.asarray(outs.correct)[:S])
            if cfg.compute_regret:
                xs_all.append(np.asarray(xs)[:S])
                ys_all.append(np.asarray(ys)[:S])
            if (cfg.checkpoint_every and cfg.checkpoint_dir
                    and b % cfg.checkpoint_every == 0):
                with tel.span("run_batch.checkpoint", step=b):
                    save_checkpoint(cfg.checkpoint_dir, b,
                                    _unpad_tree(eng_state, S))
                tel.emit("checkpoint", run_id=run_id, step=b)
    wall = time.time() - t0
    eng_state = _unpad_tree(eng_state, S)

    # a fully-resumed batch (start >= T) executes no chunks; degrade to
    # empty trajectories exactly like run() does instead of crashing
    loss = (np.concatenate(losses, axis=1) if losses
            else np.zeros((S, 0, m)))             # (S, T', m)
    w_bar_loss = (np.concatenate(wb_losses, axis=1) if wb_losses
                  else np.zeros((S, 0)))
    sparsity = (np.concatenate(sparsities, axis=1) if sparsities
                else np.zeros((S, 0)))
    correct = (np.concatenate(corrects, axis=1) if corrects
               else np.zeros((S, 0, m)))
    done = T - start
    tail = max(1, int(correct.shape[1] * 0.2)) if correct.size else 1
    eps_ledger = np.asarray(accountant.ledger(T)[start:])
    batch_info = {"seeds": seeds, "wall_clock_s": wall,
                  "devices": D, "pad_seeds": pad,
                  "seed_rounds_per_sec": (S * done / wall if wall > 0
                                          else float("inf"))}
    conn = faults_info = None
    if fault_mixer is not None and done > 0:
        conn = np.asarray(fault_mixer.connectivity(T))[start:]
        faults_info = _fault_metrics(base, fault_sched, conn)

    obs_info = None
    if tel.enabled:
        if fault_mixer is not None and conn is not None:
            tel.metrics.gauge("faults.mean_connectivity").set(
                faults_info["mean_connectivity"])
        obs_info = {"run_id": run_id}
        if chunk_cost is not None:
            cs = chunk_cost.summary()
            obs_info["cost"] = cs
            tel.emit("chunk_cost", run_id=run_id,
                     **{k: cs[k] for k in ("predicted_s", "measured_mean_s",
                                           "error_ratio", "flops",
                                           "hbm_bytes")})
        tel.emit("run_end", run_id=run_id, rounds=T, wall_clock_s=wall,
                 rounds_per_sec=(S * done / wall if wall > 0 else None),
                 eps_total=accountant.summary().get("eps_total"),
                 seeds=seeds)

    results = []
    for i, (s, st) in enumerate(zip(seeds, streams)):
        regret = None
        if cfg.compute_regret and start == 0 and xs_all:
            with tel.span("run_batch.regret", seed=s):
                regret = _regret(st, w_bar_loss[i],
                                 np.concatenate([x[i] for x in xs_all]),
                                 np.concatenate([y[i] for y in ys_all]), m)
        res = RunResult(
            engine=engine,
            rounds=T,
            start_round=start,
            wall_clock=wall / S,
            rounds_per_sec=(S * done / wall) if wall > 0 else float("inf"),
            stream=(spec.stream if isinstance(spec.stream, str)
                    else type(st).__name__),
            eps_ledger=eps_ledger.copy(),
            privacy=accountant.summary(),
            loss=loss[i] if loss.size else None,
            w_bar_loss=w_bar_loss[i] if w_bar_loss.size else None,
            correct=correct[i] if correct.size else None,
            sparsity=sparsity[i] if sparsity.size else None,
            regret=None if regret is None else np.asarray(regret),
            connectivity=None if conn is None else conn.copy(),
            accuracy=float(correct[i, -tail:].mean()) if correct.size else None,
            final_w=_final_primal(specs[i], engine, _index_tree(eng_state, i)),
            final_state=_index_tree(eng_state, i),
        )
        res.metrics = res.summary()
        res.metrics["batch"] = dict(batch_info)
        if faults_info is not None:
            res.metrics["faults"] = dict(faults_info)
        if obs_info is not None:
            res.metrics["obs"] = dict(obs_info)
        results.append(res)
    return results


def _run_custom(spec, engine, *, step_fn, state, batches, horizon,
                log_path, print_every, checkpoint_every,
                checkpoint_dir) -> RunResult:
    if horizon is None:
        raise ValueError("custom mode needs horizon= (number of steps)")
    accountant = None
    if spec is not None:
        mech = spec.resolve_mechanism()
        accountant = PrivacyAccountant(
            eps_per_round=spec.eps if mech.is_private else math.inf)
    tracker = MetricTracker()
    logger = CSVLogger(log_path) if log_path else None
    history = []
    t0 = time.time()
    for i in range(horizon):
        batch = next(batches)
        state, metrics = step_fn(state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        tracker.update(metrics)
        history.append(metrics)
        if accountant is not None:
            accountant.step()
        if logger:
            logger.log(i, metrics)
        if checkpoint_every and checkpoint_dir and (i + 1) % checkpoint_every == 0:
            save_checkpoint(checkpoint_dir, i + 1, state)
        if print_every and (i % print_every == 0 or i == horizon - 1):
            means = tracker.means()
            print(f"step {i:4d} loss={means.get('loss', 0):.4f} "
                  f"ce={means.get('ce', 0):.4f} "
                  f"sparsity={means.get('theta_sparsity', 0):.3f} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)")
    wall = time.time() - t0
    if logger:
        logger.close()
    return RunResult(
        engine=engine,
        rounds=horizon,
        wall_clock=wall,
        rounds_per_sec=(horizon / wall) if wall > 0 else float("inf"),
        eps_ledger=(None if accountant is None
                    else np.asarray(accountant.ledger())),
        privacy={} if accountant is None else accountant.summary(),
        final_state=state,
        history=history,
        metrics=tracker.means(),
    )
