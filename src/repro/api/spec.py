"""RunSpec — one declarative description that builds either engine.

A RunSpec names (or holds) the four round-pipeline protocols — Mixer,
Mechanism, LocalRule, Clipper — plus the shared schedule knobs, and builds
either the faithful dense simulator (`build_simulator`) or the distributed
node-stacked strategy (`build_distributed`) from the same description:

    spec = RunSpec(nodes=16, dim=512, mixer="ring", mechanism="laplace",
                   eps=1.0, local_rule="omd", lam=1e-3, alpha0=1.0)
    alg = spec.build_simulator()        # core.algorithm1.Algorithm1
    gdp = spec.build_distributed()      # core.gossip.GossipDP

Fields accept registry names (declarative path: CLI flags, sweep configs,
JSON) or constructed protocol instances (fully custom path); scenario
plugins register under `repro.api` registries and become available to both
engines without touching engine code.

>>> from repro.api import RunSpec
>>> spec = RunSpec(nodes=4, dim=8, mixer="ring", eps=float("inf"))
>>> type(spec.build_simulator()).__name__
'Algorithm1'
>>> type(spec.build_distributed()).__name__
'GossipDP'
>>> spec.replace(delay=3).resolve_mixer().delay     # uniform WAN staleness
3
>>> het = spec.replace(delay=2, delay_dist="uniform").resolve_mixer()
>>> type(het).__name__, 0 <= het.delay <= 2
('HeterogeneousDelayMixer', True)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.api.clippers import CLIPPERS, Clipper
from repro.api.mechanisms import MECHANISMS, Mechanism
from repro.api.mixers import (MIXERS, DelayedMixer, HeterogeneousDelayMixer,
                              Mixer)
from repro.api.rules import LOCAL_RULES, LocalRule
from repro.api.streams import STREAMS, Stream
from repro.core.omd import OMDConfig

__all__ = ["RunSpec"]


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """Declarative description of one private-gossip-learning run.

    nodes:   m data centers (the node axis of both engines).
    dim:     feature dimension n — required by `build_simulator` and by the
             'global' Lemma-1 calibration; the distributed engine infers the
             per-node parameter count from the pytree instead.
    mixer / mechanism / local_rule / clipper:
             registry name or protocol instance; *_options are forwarded to
             the registry factory (ignored when an instance is given).
    eps, clip_norm, noise_self, calibration:
             shared privacy knobs injected into the default mechanism and
             clipper factories (explicit *_options win).
    alpha0, schedule, lam, horizon, prox_kind:
             the OMD schedule (Theorem 2) shared by every local rule.
    stream / stream_options:
             data scenario for `repro.api.run` (STREAMS registry name or a
             Stream instance); the stream is built with n=dim, nodes,
             rounds=horizon, seed.
    delay:   WAN staleness in rounds — wraps the mixer in DelayedMixer
             (both engines allocate a delay-deep history ring).
    delay_dist:
             per-edge heterogeneous staleness: 'constant' | 'uniform' |
             'geometric' builds a HeterogeneousDelayMixer over the dense
             form of ``mixer`` with per-edge delays drawn from the seeded
             distribution, capped at ``delay``. None (default) keeps the
             uniform-delay behaviour.
    faults / faults_options:
             fault scenario for the gossip fabric (repro.faults): a FAULTS
             registry name or a FaultSpec instance. Compiles against
             (nodes, horizon) and wraps the resolved mixer in its faulty
             form; see docs/faults.md. The fault pattern is seeded by
             FaultSpec.seed, NOT RunSpec.seed — it is part of the
             scenario, so multi-seed sweeps share the same weather.
    backend / backend_options:
             how the round body executes (BACKENDS registry name or a
             backend instance): 'reference' (default) is the plain-XLA
             engines; 'pallas' fuses the whole round into Pallas kernels
             (same PRNG stream, float32 tolerance contract — see
             docs/kernels.md). backend_options forward to the factory,
             e.g. {"mode": "hybrid", "block_cols": 256}.
    """

    nodes: int
    dim: int | None = None
    mixer: str | Mixer = "ring"
    mixer_options: dict = dataclasses.field(default_factory=dict)
    mechanism: str | Mechanism = "laplace"
    mechanism_options: dict = dataclasses.field(default_factory=dict)
    local_rule: str | LocalRule = "omd"
    local_rule_options: dict = dataclasses.field(default_factory=dict)
    clipper: str | Clipper = "l2"
    clipper_options: dict = dataclasses.field(default_factory=dict)
    # shared knobs
    eps: float = 1.0
    clip_norm: float = 1.0
    noise_self: bool = True
    calibration: str = "global"
    alpha0: float = 0.1
    schedule: str = "sqrt_t"
    lam: float = 0.01
    horizon: int | None = None
    prox_kind: str = "l1"
    delay: int = 0
    delay_dist: str | None = None
    seed: int = 0
    loss_and_grad: Callable | None = None
    # data scenario driven by `repro.api.run`: registry name (STREAMS) or a
    # constructed Stream instance; stream_options forward to the factory
    stream: str | Stream = "social_sparse"
    stream_options: dict = dataclasses.field(default_factory=dict)
    # fault scenario (repro.faults): FAULTS registry name or FaultSpec
    faults: Any = None
    faults_options: dict = dataclasses.field(default_factory=dict)
    # execution backend (BACKENDS registry name or instance)
    backend: Any = "reference"
    backend_options: dict = dataclasses.field(default_factory=dict)

    # -- protocol resolution -------------------------------------------------

    def resolve_faults(self):
        """Compiled `repro.faults.FaultSchedule`, or None without faults."""
        if self.faults is None:
            return None
        from repro.faults import FAULTS
        fault_spec = FAULTS.build(self.faults, self.faults_options)
        return fault_spec.compile(m=self.nodes, horizon=self.horizon)

    def resolve_mixer(self) -> Mixer:
        if self.delay_dist is not None:
            if self.faults is not None:
                raise ValueError(
                    "faults do not compose with delay_dist (per-edge "
                    "heterogeneous delays) — model slow links as FaultSpec "
                    "stragglers instead")
            if not isinstance(self.mixer, str):
                raise ValueError(
                    "delay_dist needs a topology NAME for the dense per-edge "
                    "decomposition (got a constructed mixer instance); build "
                    "a HeterogeneousDelayMixer directly instead")
            if self.delay < 1:
                raise ValueError("delay_dist needs delay >= 1 (the cap on "
                                 "per-edge staleness)")
            try:
                return HeterogeneousDelayMixer.from_topology(
                    self.mixer, self.nodes, delay=self.delay,
                    delay_dist=self.delay_dist, seed=self.seed,
                    **self.mixer_options)
            except ValueError as err:
                # e.g. mixer='ring_alternating' is a valid MIXERS name but
                # not a dense GossipGraph topology — say which knob is at
                # fault instead of surfacing a bare 'unknown topology'
                raise ValueError(
                    f"delay_dist={self.delay_dist!r} (per-edge delays need "
                    f"the dense GossipGraph form of mixer={self.mixer!r}): "
                    f"{err}") from None
        mixer = MIXERS.build(self.mixer, self.mixer_options,
                             m=self.nodes, seed=self.seed)
        if getattr(mixer, "m", self.nodes) != self.nodes:
            raise ValueError(
                f"mixer is built for m={mixer.m} nodes but RunSpec.nodes="
                f"{self.nodes}")
        mixer_delay = getattr(mixer, "delay", 0)
        if self.delay and mixer_delay and mixer_delay != self.delay:
            raise ValueError(
                f"conflicting delays: RunSpec.delay={self.delay} but the "
                f"mixer already carries delay={mixer_delay}")
        if self.delay and not mixer_delay:
            mixer = DelayedMixer(inner=mixer, delay=self.delay)
        faults = self.resolve_faults()
        if faults is not None:
            from repro.faults import wrap_mixer
            mixer = wrap_mixer(mixer, faults)
        return mixer

    def resolve_mechanism(self) -> Mechanism:
        return MECHANISMS.build(
            self.mechanism, self.mechanism_options,
            eps=self.eps, L=self.clip_norm, noise_self=self.noise_self,
            calibration=self.calibration)

    def resolve_local_rule(self) -> LocalRule:
        return LOCAL_RULES.build(self.local_rule, self.local_rule_options,
                                 prox_kind=self.prox_kind)

    def resolve_clipper(self) -> Clipper:
        return CLIPPERS.build(self.clipper, self.clipper_options,
                              max_norm=self.clip_norm)

    def resolve_stream(self) -> Stream:
        """The data scenario `repro.api.run` drives (STREAMS registry)."""
        if isinstance(self.stream, str):
            if self.dim is None:
                raise ValueError("RunSpec.dim is required to build a stream "
                                 "by name")
            if self.horizon is None:
                raise ValueError("RunSpec.horizon is required to build a "
                                 "stream by name (the stream length)")
            return STREAMS.build(self.stream, self.stream_options,
                                 n=self.dim, nodes=self.nodes,
                                 rounds=self.horizon, seed=self.seed)
        stream = self.stream
        if getattr(stream, "nodes", self.nodes) != self.nodes:
            raise ValueError(
                f"stream is built for {stream.nodes} nodes but RunSpec.nodes="
                f"{self.nodes}")
        if self.dim is not None and getattr(stream, "n", self.dim) != self.dim:
            raise ValueError(
                f"stream has n={stream.n} features but RunSpec.dim={self.dim}")
        return stream

    def resolve_backend(self):
        """The execution backend (BACKENDS registry; see repro.api.backends).

        Imported lazily so `repro.api.spec` keeps no kernel dependency —
        the import also triggers backend registration when a RunSpec is
        used without going through `repro.api`.
        """
        from repro.api import backends  # noqa: F401  (registers entries)
        from repro.api.registry import BACKENDS
        return BACKENDS.build(self.backend, self.backend_options)

    def omd_config(self) -> OMDConfig:
        return OMDConfig(alpha0=self.alpha0, schedule=self.schedule,
                         lam=self.lam, T=self.horizon,
                         prox_kind=self.prox_kind)

    # -- engine builders -----------------------------------------------------

    def build_simulator(self) -> "Algorithm1":
        """The dense (m, n) reference engine (core.algorithm1)."""
        from repro.core.algorithm1 import Algorithm1, hinge_loss_and_grad
        if self.dim is None:
            raise ValueError("RunSpec.dim is required for the simulator")
        return Algorithm1(
            omd=self.omd_config(),
            n=self.dim,
            mixer=self.resolve_mixer(),
            mechanism=self.resolve_mechanism(),
            local_rule=self.resolve_local_rule(),
            clipper=self.resolve_clipper(),
            loss_and_grad=self.loss_and_grad or hinge_loss_and_grad,
        )

    def build_distributed(self) -> "GossipDP":
        """The node-stacked pytree engine (core.gossip)."""
        from repro.core.gossip import GossipDP
        return GossipDP(
            omd=self.omd_config(),
            mixer=self.resolve_mixer(),
            mechanism=self.resolve_mechanism(),
            local_rule=self.resolve_local_rule(),
            clipper=self.resolve_clipper(),
        )

    def replace(self, **kw: Any) -> "RunSpec":
        return dataclasses.replace(self, **kw)
