"""BACKENDS — how a RunSpec's round body executes (reference XLA or Pallas).

`RunSpec.backend` selects the execution backend by name (BACKENDS registry)
and `backend_options` configure it; `repro.api.runner.make_chunk_program`
resolves the backend inside the chunk builders, so streams, delay rings,
faults, checkpoints, serving snapshots and telemetry compose with either
backend unchanged:

  "reference" — the engines as built by `RunSpec.build_simulator` /
                `build_distributed`: plain XLA, the correctness oracle every
                other backend is measured against.
  "pallas"    — the fused fast path (`repro.kernels.round_fused`): the
                whole round body — prox + per-node stats, clip (folded into
                a rank-1 coefficient), noise-add, k-neighbor gossip mix over
                the dense form of any fixed `SparseGraph` topology, OMD dual
                step and crash freeze — in two Pallas kernels with per-node
                parameter blocks resident in VMEM across the round. Runs
                under ``interpret=True`` on CPU (CI validates the real
                kernel bodies) and compiles to Mosaic on TPU.

The pallas backend keeps the engines' state pytrees (`SimState` /
`GossipState`), their PRNG stream (noise is sampled OUTSIDE the kernels
with the exact `jax.random` calls of the reference round, so the Laplace
draws are bit-identical) and their chunk scan, so checkpoints, snapshots
and `run_batch`'s seed vmap interchange with the reference backend. The
iterates themselves agree to the float32 tolerance contract documented in
docs/kernels.md (kernel reduction order differs from XLA's).

Two execution modes, picked per spec (``backend_options={"mode": ...}``):

  fused  — mixing happens INSIDE the update kernel via the dense (m, m)
           matrix of the spec's fixed topology (any `SparseGraph` degree);
           requires m <= ``max_fused_nodes`` (the dense block must sit in
           VMEM next to the streamed operands).
  hybrid — mixing stays in XLA (`mixer.mix` / `mix_history` — any mixer:
           faults, per-edge heterogeneous delays, time-varying schedules)
           between the stats kernel and a smaller fused dual-step kernel.

``mode="auto"`` (default) fuses when the resolved mixer lowers to a fixed
sparse graph and m fits, else falls back to hybrid. The node-sharded path
(`repro.api.shard_node`) always runs hybrid per shard: its ppermute halo
exchange stays outside the kernels by design.

>>> from repro.api import BACKENDS, RunSpec, run, ExecConfig
>>> sorted(BACKENDS.names())
['pallas', 'reference']
>>> spec = RunSpec(nodes=4, dim=128, horizon=4, eps=1.0, alpha0=0.5,
...                lam=0.01, stream="drift", backend="pallas")
>>> res = run(spec, engine="sim",
...           exec=ExecConfig(compute_regret=False, warmup=False))
>>> res.rounds
4
>>> BACKENDS.build("nope")
Traceback (most recent call last):
    ...
repro.api.registry.UnknownEntryError: unknown backend 'nope'...
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registry import BACKENDS
from repro.api.mixers import ring_read, ring_write

__all__ = ["BACKENDS", "ReferenceBackend", "PallasBackend",
           "pallas_supported"]


@dataclasses.dataclass(frozen=True)
class ReferenceBackend:
    """The plain-XLA engines — the oracle the pallas backend is held to."""

    name: str = "reference"

    def make_chunk_program(self, spec, engine: str):
        from repro.api import runner
        return runner.reference_chunk_program(spec, engine)

    def make_local_round_fn(self, spec, engine: str, part, delay: int,
                            schedule=None, graph=None) -> Callable:
        from repro.api import shard_node
        return shard_node.reference_local_round_fn(
            spec, engine, part, delay, schedule=schedule, graph=graph)


# ---------------------------------------------------------------------------
# pallas
# ---------------------------------------------------------------------------

def _round_kernels():
    from repro.kernels import round_fused
    return round_fused


def _interpret(flag: bool | None) -> bool:
    if flag is not None:
        return bool(flag)
    from repro.kernels.ops import _default_interpret
    return _default_interpret()


def _check_supported(spec) -> None:
    """The stages the fused round body hard-codes; everything else raises
    with the escape hatch named (backend='reference')."""
    from repro.api.rules import OMDLassoRule
    from repro.api.clippers import NoClipper, PerNodeL2Clipper

    rule = spec.resolve_local_rule()
    if not isinstance(rule, OMDLassoRule) or rule.prox_kind not in ("l1",
                                                                    "none"):
        raise ValueError(
            f"backend='pallas' fuses the paper's OMD + L1/identity prox "
            f"round body; got local_rule={type(rule).__name__}"
            f"{getattr(rule, 'prox_kind', '')!r} — use backend='reference'")
    clipper = spec.resolve_clipper()
    if not isinstance(clipper, (PerNodeL2Clipper, NoClipper)):
        raise ValueError(
            f"backend='pallas' folds clipping into a rank-1 coefficient, "
            f"which needs the per-node L2 clipper (or none); got "
            f"{type(clipper).__name__} — use backend='reference'")
    if spec.loss_and_grad is not None:
        raise ValueError(
            "backend='pallas' fuses the hinge loss/subgradient; a custom "
            "loss_and_grad needs backend='reference'")


def pallas_supported(spec) -> bool:
    """True when `backend="pallas"` accepts this spec's stage pipeline."""
    try:
        _check_supported(spec)
        return True
    except ValueError:
        return False


def _dense_mix_form(spec, mixer):
    """(A, diag, delay) dense mixing form for the fused mode, or None when
    the mixer has no fixed sparse lowering (time-varying, faulty, ...)."""
    if getattr(mixer, "schedule", None) is not None:
        return None                       # repro.faults: per-round weights
    from repro.api.shard_node import sparse_graph_and_delay
    try:
        graph, delay = sparse_graph_and_delay(mixer)
    except ValueError:
        return None
    A = jnp.asarray(graph.to_dense(), jnp.float32)
    diag = jnp.asarray(graph.diag(), jnp.float32)
    return A, diag, delay


def _pad2(a, m_pad: int, n_pad: int):
    m, n = a.shape
    return jnp.pad(a, ((0, m_pad - m), (0, n_pad - n)))


def _pad1(a, m_pad: int):
    return jnp.pad(a, (0, m_pad - a.shape[0]))


@dataclasses.dataclass(frozen=True)
class PallasBackend:
    """Fused-kernel execution of the round body (see module docstring).

    mode:            "auto" | "fused" | "hybrid" (auto fuses when possible).
    block_cols:      lanes per kernel grid step (the n-block width).
    interpret:       None -> interpret off TPU (the CPU CI path); a bool
                     pins it.
    max_fused_nodes: dense-A cap for the fused mode; above it auto falls
                     back to hybrid and "fused" raises.
    """

    mode: str = "auto"
    block_cols: int = 512
    interpret: bool | None = None
    max_fused_nodes: int = 1024
    name: str = "pallas"

    def __post_init__(self):
        if self.mode not in ("auto", "fused", "hybrid"):
            raise ValueError(f"unknown pallas mode {self.mode!r}; expected "
                             "'auto', 'fused' or 'hybrid'")

    # -- unsharded chunk program --------------------------------------------

    def make_chunk_program(self, spec, engine: str):
        if engine not in ("sim", "dist"):
            raise ValueError(
                f"unknown engine {engine!r}; expected 'sim' or 'dist'")
        round_fn = self._make_round_fn(spec, engine)

        def chunk_fn(state, xs, ys):
            return jax.lax.scan(round_fn, state, (xs, ys))

        from repro.api import runner
        init_fn = runner.reference_chunk_program(spec, engine)[1]
        return chunk_fn, init_fn

    def _make_round_fn(self, spec, engine: str) -> Callable:
        from repro.core.algorithm1 import SimState
        from repro.core.gossip import GossipState

        _check_supported(spec)
        rf = _round_kernels()
        m, n = spec.nodes, spec.dim
        if n is None:
            raise ValueError("RunSpec.dim is required by backend='pallas'")
        m_pad, n_pad = rf._pad_rows(m), rf._pad_cols(n)
        interpret = _interpret(self.interpret)
        mech = spec.resolve_mechanism()
        rule = spec.resolve_local_rule()
        clip = spec.resolve_clipper()
        omd = spec.omd_config()
        mixer = spec.resolve_mixer()
        schedule = getattr(mixer, "schedule", None)
        prox_l1 = rule.prox_kind == "l1"
        from repro.api.clippers import PerNodeL2Clipper
        clip_norm = clip.max_norm if isinstance(clip, PerNodeL2Clipper) \
            else None

        dense = None
        if self.mode != "hybrid":
            dense = _dense_mix_form(spec, mixer)
            if dense is not None and dense[0].shape[0] > self.max_fused_nodes:
                dense = None
            if dense is None and self.mode == "fused":
                raise ValueError(
                    f"backend='pallas' mode='fused' needs a fixed topology "
                    f"with nodes <= {self.max_fused_nodes} (got mixer="
                    f"{type(mixer).__name__}, m={m}); use mode='hybrid' or "
                    f"'auto'")
        if dense is not None:
            A, diag_v, delay = dense
            A_pad = _pad2(A, m_pad, m_pad)
            diag_pad = _pad1(diag_v, m_pad)
        else:
            delay = int(getattr(mixer, "delay", 0))

        def stats_and_coeff(theta_p, x_p, y, ctx):
            dot, xsq, nnz, wbdot, _ = rf.round_stats(
                theta_p, x_p, ctx.lam_t, m, prox_l1=prox_l1,
                block_cols=self.block_cols, interpret=interpret)
            dot, xsq, nnz, wbdot = dot[:m], xsq[:m], nnz[:m], wbdot[:m]
            margin = y * dot
            loss = jnp.maximum(1.0 - margin, 0.0)
            correct = (jnp.sign(dot) == y).astype(jnp.float32)
            active = (margin < 1.0).astype(jnp.float32)
            if clip_norm is None:
                factor = 1.0
            else:
                gnorm = active * jnp.sqrt(xsq)
                factor = jnp.minimum(1.0, clip_norm
                                     / jnp.maximum(gnorm, 1e-12))
            coeff = -(active * y) * factor
            wb_loss = jnp.mean(jnp.maximum(1.0 - y * wbdot, 0.0))
            # zero COUNT first (small ints are exact in f32), then divide —
            # bit-equal to the reference's mean-of-indicators
            sparsity = (m * n - jnp.sum(nnz)) / (m * n)
            return coeff, loss, correct, wb_loss, sparsity

        def round_fn(state, batch):
            from repro.core.algorithm1 import RoundOutput

            x, y = batch
            sim = engine == "sim"
            theta = state.theta if sim else state.theta["w"]
            hist = state.history
            if not sim and hist is not None:
                hist = hist["w"]
            ctx = omd.step_context(state.t + 1)
            theta_p = _pad2(theta, m_pad, n_pad)
            x_p = _pad2(x, m_pad, n_pad)
            coeff, loss, correct, wb_loss, sparsity = stats_and_coeff(
                theta_p, x_p, y, ctx)

            # the engines' exact noise draw — bit-identical PRNG stream
            key, sub = jax.random.split(state.key)
            scale = mech.scale(ctx.alpha_t, n)
            delta = mech.sample(sub, (m, n), scale)

            alive = (schedule.alive_f32(state.t)
                     if schedule is not None and schedule.has_crashes
                     else jnp.ones((m,), jnp.float32))

            if dense is not None:
                if delay:
                    tilde = theta + delta
                    hist = ring_write(hist, state.t, tilde)
                    recv = ring_read(hist, state.t, delay, tilde)
                    recv_p, use_recv = _pad2(recv, m_pad, n_pad), 1.0
                else:
                    recv_p, use_recv = theta_p, 0.0
                theta_next_p, _ = rf.round_update(
                    A_pad, theta_p, _pad2(delta, m_pad, n_pad), x_p, recv_p,
                    _pad1(coeff, m_pad), diag_pad, _pad1(alive, m_pad),
                    ctx.alpha_t, use_recv, mech.noise_self,
                    block_cols=self.block_cols, interpret=interpret)
            else:
                tilde = theta + delta
                if delay:
                    hist = ring_write(hist, state.t, tilde)
                    mixed = mixer.mix_history(theta, tilde, hist,
                                              mech.noise_self, state.t)
                else:
                    mixed = mixer.mix(theta, tilde, mech.noise_self, state.t)
                theta_next_p = rf.dual_step(
                    _pad2(mixed, m_pad, n_pad), x_p, theta_p,
                    _pad1(coeff, m_pad), _pad1(alive, m_pad), ctx.alpha_t,
                    block_cols=self.block_cols, interpret=interpret)
            theta_next = theta_next_p[:m, :n]

            out = RoundOutput(loss=loss, w_bar_loss=wb_loss,
                              sparsity=sparsity, correct=correct)
            if sim:
                new_state = SimState(theta=theta_next, t=state.t + 1,
                                     key=key, history=hist)
            else:
                new_state = GossipState(
                    theta={"w": theta_next}, t=state.t + 1, key=key,
                    history=None if hist is None else {"w": hist})
            return new_state, out

        return round_fn

    # -- node-sharded local round (hybrid: halo exchange stays outside) ----

    def make_local_round_fn(self, spec, engine: str, part, delay: int,
                            schedule=None, graph=None) -> Callable:
        from repro.core.algorithm1 import RoundOutput, SimState
        from repro.core.gossip import GossipState
        from repro.api.shard_node import (ShardedSparseMixer, _pad_axis)
        from repro.api.clippers import PerNodeL2Clipper

        _check_supported(spec)
        rf = _round_kernels()
        m, n = part.m, spec.dim
        block, m_pad_g = part.block, part.m_pad
        blk_pad, n_pad = rf._pad_rows(block), rf._pad_cols(n)
        interpret = _interpret(self.interpret)
        mech = spec.resolve_mechanism()
        rule = spec.resolve_local_rule()
        clip = spec.resolve_clipper()
        omd = spec.omd_config()
        prox_l1 = rule.prox_kind == "l1"
        clip_norm = clip.max_norm if isinstance(clip, PerNodeL2Clipper) \
            else None
        if schedule is not None:
            from repro.faults.mixers import FaultyShardedSparseMixer
            smixer = FaultyShardedSparseMixer(part, graph, schedule,
                                              delay=delay)
        else:
            smixer = ShardedSparseMixer(part, delay=delay)

        def round_fn(state, batch):
            x, y = batch                          # (block, n), (block,)
            d = jax.lax.axis_index("node")
            gidx = d * block + jnp.arange(block)
            mask = (gidx < m).astype(jnp.float32)
            theta = state.theta if engine == "sim" else state.theta["w"]
            hist = state.history
            if engine == "dist" and hist is not None:
                hist = hist["w"]
            ctx = omd.step_context(state.t + 1)

            theta_p = _pad2(theta, blk_pad, n_pad)
            x_p = _pad2(x, blk_pad, n_pad)
            dot, xsq, nnz, _, wsum = rf.round_stats(
                theta_p, x_p, ctx.lam_t, m, prox_l1=prox_l1,
                block_cols=self.block_cols, interpret=interpret)
            dot, xsq, nnz = dot[:block], xsq[:block], nnz[:block]
            margin = y * dot
            loss = jnp.maximum(1.0 - margin, 0.0)
            correct = (jnp.sign(dot) == y).astype(jnp.float32)
            active = (margin < 1.0).astype(jnp.float32)
            if clip_norm is None:
                factor = 1.0
            else:
                gnorm = active * jnp.sqrt(xsq)
                factor = jnp.minimum(1.0, clip_norm
                                     / jnp.maximum(gnorm, 1e-12))
            coeff = -(active * y) * factor

            # global w_bar: the kernel's per-shard column sums, psum'd —
            # then one XLA matvec for the w_bar hinge terms
            w_bar = jax.lax.psum(wsum[:n], "node") / m
            wb_terms = jnp.maximum(
                1.0 - y * jnp.sum(w_bar[None, :] * x, axis=-1), 0.0)
            wb_loss = jax.lax.psum(jnp.sum(wb_terms * mask), "node") / m
            zeros = jnp.sum((n - nnz) * mask)
            sparsity = jax.lax.psum(zeros, "node") / (m * n)

            key, sub = jax.random.split(state.key)
            scale = mech.scale(ctx.alpha_t, n)
            delta = mech.sample(sub, (m, n), scale)
            delta = _pad_axis(delta, m_pad_g - m, 0)
            delta = jax.lax.dynamic_slice_in_dim(delta, d * block, block,
                                                 axis=0)
            tilde = theta + delta

            # mixing stays in XLA: the ppermute halo exchange + segment_sum
            # of ShardedSparseMixer, exactly as the reference sharded round
            if delay:
                hist = ring_write(hist, state.t, tilde)
                mixed = smixer.mix_history(theta, tilde, hist,
                                           mech.noise_self, state.t)
            else:
                mixed = smixer.mix(theta, tilde, mech.noise_self, state.t)

            alive_blk = jnp.ones((block,), jnp.float32)
            if schedule is not None and schedule.has_crashes:
                alive = _pad_axis(schedule.alive_f32(state.t),
                                  m_pad_g - m, 0)
                alive_blk = jax.lax.dynamic_slice_in_dim(
                    alive, d * block, block, axis=0)
            theta_next = rf.dual_step(
                _pad2(mixed, blk_pad, n_pad), x_p, theta_p,
                _pad1(coeff, blk_pad), _pad1(alive_blk, blk_pad),
                ctx.alpha_t, block_cols=self.block_cols,
                interpret=interpret)[:block, :n]

            out = RoundOutput(loss=loss, w_bar_loss=wb_loss,
                              sparsity=sparsity, correct=correct)
            if engine == "sim":
                new_state = SimState(theta=theta_next, t=state.t + 1,
                                     key=key, history=hist)
            else:
                new_state = GossipState(
                    theta={"w": theta_next}, t=state.t + 1, key=key,
                    history=None if hist is None else {"w": hist})
            return new_state, out

        return round_fn


@BACKENDS.register("reference")
def _reference() -> ReferenceBackend:
    """Plain-XLA engines (the correctness oracle)."""
    return ReferenceBackend()


@BACKENDS.register("pallas")
def _pallas(mode: str = "auto", block_cols: int = 512,
            interpret: bool | None = None,
            max_fused_nodes: int = 1024) -> PallasBackend:
    """Fused Pallas round body (see docs/kernels.md)."""
    return PallasBackend(mode=mode, block_cols=block_cols,
                         interpret=interpret,
                         max_fused_nodes=max_fused_nodes)
