"""Assigned-architecture registry. One module per arch; ``get_config(id)``.

Every config cites its source in the module docstring and instantiates the
EXACT published numbers from the assignment table. ``get_config(id).reduced()``
gives the CPU smoke-test variant of the same family.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS = [
    "rwkv6-3b",
    "recurrentgemma-2b",
    "mixtral-8x7b",
    "qwen2-vl-2b",
    "llama4-scout-17b-a16e",
    "qwen2-7b",
    "minicpm-2b",
    "seamless-m4t-medium",
    "internlm2-20b",
    "qwen3-32b",
]

# the paper's own workload (not a transformer): exposed via configs.social_linear
PAPER_WORKLOAD = "social-linear"


def get_config(arch_id: str) -> ModelConfig:
    mod_name = arch_id.replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
