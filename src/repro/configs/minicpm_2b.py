"""minicpm-2b — WSD schedule, llama-like arch [arXiv:2404.06395].

[dense] 40L d_model=2304 36H (GQA kv=36 => MHA) d_ff=5760 vocab=122753.
The WSD (warmup-stable-decay) schedule is provided by repro.optim.schedules
and selected by the training recipe for this arch. head_dim 64.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    tie_embeddings=True,
    window_500k=8192,
)
