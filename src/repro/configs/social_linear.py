"""social-linear — the PAPER'S OWN workload (§V Simulations).

100,000 social data points, dimensionality n = 10,000, hinge loss,
m = 64 data-center nodes, Laplace-private gossip. Not a transformer — this
config parameterizes core.Algorithm1 / the GossipDP linear model used by
benchmarks/fig2..fig5 and examples/private_social_training.py.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SocialLinearConfig:
    n: int = 10_000            # feature dimensionality (paper: 10,000)
    total_samples: int = 100_000  # paper: 100,000 social data points
    nodes: int = 64            # paper Figs 2-4 use 64 nodes
    topology: str = "ring"
    eps: float = 1.0           # per-round privacy budget
    L: float = 1.0             # subgradient bound (enforced by clipping)
    alpha0: float = 1.0
    schedule: str = "sqrt_t"
    lam: float = 1e-3          # Lasso strength (sparsity knob, Fig. 4 sweep)
    sparsity_true: float = 0.05  # ground-truth sparse support fraction
    seed: int = 0

    @property
    def rounds(self) -> int:
        return self.total_samples // self.nodes


CONFIG = SocialLinearConfig()


def smoke() -> SocialLinearConfig:
    return dataclasses.replace(CONFIG, n=256, total_samples=2_000, nodes=8)
