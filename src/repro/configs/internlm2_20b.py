"""internlm2-20b — GQA [arXiv:2403.17297].

[dense] 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
long_500k via window_500k sliding-window variant (8192).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    rope_theta=1e6,
    window_500k=8192,
)
