"""qwen2-vl-2b — M-RoPE, dynamic resolution [arXiv:2409.12191].

[vlm] 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936. Vision
frontend (ViT + projector) is STUBBED per carve-out: input_specs provide
precomputed patch embeddings (early fusion over the first frontend_tokens
positions). M-RoPE sections (16, 24, 24) over head_dim 128 // 2.
long_500k runs via the window_500k sliding-window variant (window 8192).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    use_qkv_bias=True,
    rope_style="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    frontend="vision",
    frontend_tokens=1024,   # stub patch embeddings per sequence
    window_500k=8192,
    tie_embeddings=True,
)
