"""seamless-m4t-medium — enc-dec, multimodal [arXiv:2308.11596].

[audio] 12L d_model=1024 16H (kv=16 MHA) d_ff=4096 vocab=256206.
Encoder 12L + decoder 12L transformer backbone; the speech frontend
(mel + conv feature extractor) is STUBBED per carve-out — input_specs
provide frame embeddings (B, seq/4, d_model), the /4 standing in for the
conformer downsampling. long_500k: SKIPPED (full-attention enc-dec; no
500k speech-decode use case — see DESIGN.md §Arch-applicability).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,          # decoder layers
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    frontend="audio",
    tie_embeddings=True,
)
