"""recurrentgemma-2b — RG-LRU + local attention, 2 recurrent : 1 attn
[arXiv:2402.19427].

[hybrid] 26L d_model=2560 10H (GQA kv=1 => MQA) d_ff=7680 vocab=256000.
Local attention window 2048. head_dim 256 (Griffin-2B). Sub-quadratic:
runs long_500k (LRU state + 2048-window cache).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="rglru_hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    rglru_width=2560,
    rglru_conv_width=4,
    local_attn_window=2048,
    hybrid_pattern=("rec", "rec", "attn"),
    tie_embeddings=True,
    scan_layers=False,      # heterogeneous pattern -> python loop
)
