"""qwen2-7b — GQA, QKV bias [arXiv:2407.10671].

[dense] 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
long_500k via window_500k sliding-window variant (8192).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    use_qkv_bias=True,
    rope_theta=1e6,
    window_500k=8192,
)
