"""qwen3-32b — qk_norm, GQA [hf:Qwen/Qwen3-8B family].

[dense] 64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936.
head_dim 128 (so q/k/v project to 64*128 = 8192). qk_norm per head.
long_500k via window_500k sliding-window variant (8192).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    use_qk_norm=True,
    rope_theta=1e6,
    window_500k=8192,
)
