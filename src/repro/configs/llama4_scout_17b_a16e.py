"""llama4-scout-17b-a16e — MoE 16e top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

[moe] 48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16
experts top-1 (sigmoid gate) + shared expert, early-fusion multimodal
(vision frontend STUBBED per carve-out). long_500k via window_500k=8192
(Scout ships interleaved RoPE/NoPE chunked attention; the sliding-window
variant is our sub-quadratic stand-in, see DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    num_experts_per_tok=1,
    shared_expert=True,
    rope_theta=5e5,
    frontend="vision",
    frontend_tokens=1024,
    window_500k=8192,
)
