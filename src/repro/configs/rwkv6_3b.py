"""rwkv6-3b — Finch, data-dependent decay [arXiv:2404.05892].

[ssm] 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536. Attention-free:
runs long_500k natively (O(1) state). num_heads below is d_model /
rwkv_head_dim = 40 WKV heads (head dim 64, the RWKV6 default).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="rwkv6",
    num_layers=32,
    d_model=2560,
    num_heads=40,          # WKV heads = d_model / rwkv_head_dim
    d_ff=8960,
    vocab_size=65536,
    rwkv_head_dim=64,
    rope_style="none",
    norm="layernorm",
    tie_embeddings=False,
)
