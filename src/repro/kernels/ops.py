"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True`` so every
test validates the actual kernel body; on TPU they compile to Mosaic. The
wrappers also handle padding/reshaping from arbitrary parameter pytrees to
the kernels' (rows, 128) tiled layout.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import pdomd_update as _pdomd
from repro.kernels import hinge_grad as _hinge

LANE = _pdomd.LANE
SUBLANE = _pdomd.SUBLANE


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# flat (rows, 128) <-> pytree plumbing
# ---------------------------------------------------------------------------

def flat_size(tree: Any) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))


def padded_rows(size: int) -> int:
    rows = -(-size // LANE)
    return -(-rows // SUBLANE) * SUBLANE


def tree_to_tiles(tree: Any) -> jax.Array:
    """Flatten a pytree into one (rows, 128) f32 array (zero padded)."""
    leaves = [l.reshape(-1).astype(jnp.float32) for l in jax.tree_util.tree_leaves(tree)]
    flat = jnp.concatenate(leaves) if len(leaves) > 1 else leaves[0]
    rows = padded_rows(flat.size)
    pad = rows * LANE - flat.size
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows, LANE)


def tiles_to_tree(tiles: jax.Array, tree_like: Any) -> Any:
    """Inverse of :func:`tree_to_tiles` (casts back to each leaf's dtype)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    flat = tiles.reshape(-1)
    out, off = [], 0
    for l in leaves:
        sz = int(np.prod(l.shape))
        out.append(flat[off:off + sz].reshape(l.shape).astype(l.dtype))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# ops
# ---------------------------------------------------------------------------

def pdomd_update(theta_self, theta_prev, theta_next, grad, alpha, lam,
                 self_weight=0.5, nbr_weight=0.25, *, interpret: bool | None = None,
                 block_rows: int = _pdomd.DEFAULT_BLOCK_ROWS):
    """Fused mix + OMD step + L1 prox on (rows, 128) tiles."""
    if interpret is None:
        interpret = _default_interpret()
    return _pdomd.pdomd_update(
        theta_self, theta_prev, theta_next, grad,
        jnp.asarray(alpha, jnp.float32), jnp.asarray(lam, jnp.float32),
        jnp.asarray(self_weight, jnp.float32), jnp.asarray(nbr_weight, jnp.float32),
        block_rows=block_rows, interpret=interpret,
    )


def hinge_grad(x, y, w, *, interpret: bool | None = None,
               block_b: int = _hinge.DEFAULT_BLOCK_B):
    """Fused hinge loss + subgradient. Returns (loss, grad, margin)."""
    if interpret is None:
        interpret = _default_interpret()
    return _hinge.hinge_grad(x, y, w, block_b=block_b, interpret=interpret)
