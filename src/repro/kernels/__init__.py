"""Pallas TPU kernels for the paper's per-round hot loop.

The round pipeline (clip -> Laplace-noise -> gossip-mix -> sparse-OMD
update -> L1 prox) is memory-bound at the paper's dimensions; these
kernels fuse it into streamed passes over the (m, n) parameter block (see
`round_fused` and docs/kernels.md). `ops` wraps the seed kernels
(`pdomd_update`, `hinge_grad`) with padding + interpret-mode defaults;
`ref` holds the pure-jnp oracles every kernel is allclose-tested against.

The kernels are reached through `RunSpec(backend="pallas")` — see
`repro.api.backends`; on CPU they run with ``interpret=True`` so CI
validates the real kernel bodies.
"""
from repro.kernels.round_fused import (DEFAULT_BLOCK_COLS, LANE,
                                       MAX_FUSED_NODES, SUBLANE, dual_step,
                                       round_stats, round_update)

__all__ = ["round_stats", "round_update", "dual_step", "LANE", "SUBLANE",
           "DEFAULT_BLOCK_COLS", "MAX_FUSED_NODES"]
