"""Pallas TPU kernel: fused hinge loss + subgradient for the paper's workload.

The paper's per-round compute at each data center is a sparse linear model
over n = 10,000-dim social features:

    margin_b = y_b * <w, x_b>
    loss_b   = max(1 - margin_b, 0)
    g        = -(1/B) * sum_b 1[margin_b < 1] * y_b * x_b

Fusing predict + mask + gradient means x is streamed through VMEM exactly
once (one read feeds both the MXU matvec and the masked rank-1 accumulation)
instead of twice for separate forward/backward passes — a 2x cut on the
dominant HBM term (x is (B, n), far larger than w or g).

Tiling: grid over batch blocks; each step holds an (Bb, n) slice of x plus
w, g (both (n_rows=n/128, 128) views) in VMEM. The margin matvec uses the
MXU via jnp.dot on the (Bb, n) x (n,) contraction; the gradient update is a
VPU masked outer-product accumulated across grid steps into the g output
block (same block every step — sequential TPU grid makes this legal).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
DEFAULT_BLOCK_B = 128


def _kernel(x_ref, y_ref, w_ref, loss_ref, g_ref, margin_ref):
    b_idx = pl.program_id(0)

    x = x_ref[...]                      # (Bb, n)
    y = y_ref[...]                      # (Bb, 1)
    w = w_ref[...]                      # (1, n)
    margin = y[:, 0] * jnp.dot(x, w[0, :], preferred_element_type=jnp.float32)  # (Bb,)
    loss = jnp.maximum(1.0 - margin, 0.0)
    loss_ref[...] = loss[:, None]
    margin_ref[...] = margin[:, None]

    coeff = jnp.where(margin < 1.0, -y[:, 0], 0.0)   # (Bb,)
    # rank-1-ish accumulation: g += coeff^T X   -> (1, n)
    contrib = jnp.dot(coeff[None, :], x, preferred_element_type=jnp.float32)

    @pl.when(b_idx == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    g_ref[...] += contrib


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def hinge_grad(
    x: jax.Array,  # (B, n) f32 features
    y: jax.Array,  # (B,) f32 labels in {-1, +1}
    w: jax.Array,  # (n,) f32 current primal parameter
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
):
    """Returns (loss (B,), grad (n,), margin (B,)); grad is mean over batch."""
    B, n = x.shape
    if n % LANE:
        raise ValueError(f"n must be a multiple of {LANE}, got {n}")
    block_b = min(block_b, B)
    while B % block_b:
        block_b //= 2
    block_b = max(block_b, 1)
    grid = (B // block_b,)

    loss, g, margin = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x.astype(jnp.float32), y.astype(jnp.float32)[:, None], w.astype(jnp.float32)[None, :])
    return loss[:, 0], g[0] / B, margin[:, 0]
