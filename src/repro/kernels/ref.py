"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pdomd_update_ref(theta_self, theta_prev, theta_next, grad, alpha, lam,
                     self_weight, nbr_weight):
    """Oracle for kernels/pdomd_update.py."""
    f32 = jnp.float32
    mixed = (
        self_weight.astype(f32) * theta_self.astype(f32)
        + nbr_weight.astype(f32) * theta_prev.astype(f32)
        + nbr_weight.astype(f32) * theta_next.astype(f32)
    )
    theta_new = mixed - alpha.astype(f32) * grad.astype(f32)
    w = jnp.sign(theta_new) * jnp.maximum(jnp.abs(theta_new) - lam.astype(f32), 0.0)
    return w, theta_new


def hinge_grad_ref(x, y, w):
    """Oracle for kernels/hinge_grad.py. Returns (loss, grad, margin)."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    w = w.astype(jnp.float32)
    margin = y * (x @ w)
    loss = jnp.maximum(1.0 - margin, 0.0)
    coeff = jnp.where(margin < 1.0, -y, 0.0)
    grad = (coeff[:, None] * x).mean(axis=0)
    return loss, grad, margin


def wkv6_ref(r, k, v, w, u, state0):
    """Oracle for kernels/wkv6.py (RWKV6 recurrence, data-dependent decay).

    Shapes (single head): r,k,w (T, K); v (T, V); u (K,); state0 (K, V).
    Recurrence (Finch, arXiv:2404.05892):
        y_t   = r_t^T (state + u ⊙ k_t v_t^T)        -> (V,)
        state = diag(exp(-exp(w_t))) state + k_t v_t^T
    """
    def step(state, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[:, None] * v_t[None, :]                    # (K, V)
        y = ((state + u[:, None] * kv) * r_t[:, None]).sum(0)
        state = jnp.exp(-jnp.exp(w_t))[:, None] * state + kv
        return state, y

    state, ys = jax.lax.scan(step, state0.astype(jnp.float32),
                             (r.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), w.astype(jnp.float32)))
    return ys, state
