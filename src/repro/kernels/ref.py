"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pdomd_update_ref(theta_self, theta_prev, theta_next, grad, alpha, lam,
                     self_weight, nbr_weight):
    """Oracle for kernels/pdomd_update.py."""
    f32 = jnp.float32
    mixed = (
        self_weight.astype(f32) * theta_self.astype(f32)
        + nbr_weight.astype(f32) * theta_prev.astype(f32)
        + nbr_weight.astype(f32) * theta_next.astype(f32)
    )
    theta_new = mixed - alpha.astype(f32) * grad.astype(f32)
    w = jnp.sign(theta_new) * jnp.maximum(jnp.abs(theta_new) - lam.astype(f32), 0.0)
    return w, theta_new


def hinge_grad_ref(x, y, w):
    """Oracle for kernels/hinge_grad.py. Returns (loss, grad, margin)."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    w = w.astype(jnp.float32)
    margin = y * (x @ w)
    loss = jnp.maximum(1.0 - margin, 0.0)
    coeff = jnp.where(margin < 1.0, -y, 0.0)
    grad = (coeff[:, None] * x).mean(axis=0)
    return loss, grad, margin
