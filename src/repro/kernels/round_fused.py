"""Pallas TPU kernels: the WHOLE per-round body in two fused passes.

The paper's round (Algorithm 1 steps 6-11) is clip -> Laplace-noise ->
gossip-mix -> sparse-OMD update -> L1 prox over an (m, n) parameter block
with n = 1e4..1e8. The seed kernel (`pdomd_update`) fused the last three
steps for a ring only; these kernels cover the full chain for ANY fixed
topology (general `SparseGraph` degree via its dense (m, m) form) in two
passes, chosen because the clip factor needs each node's FULL-row gradient
norm — a reduction a single streaming pass over n-blocks cannot both
produce and consume:

``round_stats`` (pass 1) streams theta and x once and accumulates every
per-node reduction the round needs, with the prox fused in so w is never
materialized:

    w        = soft_threshold(theta, lam_t)          (or identity)
    dot_i    = sum_j w_ij x_ij          -> margin, loss, correct, active
    xsq_i    = sum_j x_ij^2             -> clip factor (see below)
    nnz_i    = sum_j [w_ij != 0]        -> sparsity
    wsum_j   = sum_i w_ij               -> w_bar (sharded path: psum'd)
    wbdot_i  = sum_j (wsum_j / m) x_ij  -> w_bar hinge loss (unsharded)

The hinge gradient is rank-1 per node (g_i = -[margin_i < 1] y_i x_i), so
its L2 norm is active_i * ||x_i|| and the whole clip collapses to an (m,)
coefficient computed from ``xsq`` on the host side — no gradient matrix is
ever built.

``round_update`` (pass 2) streams theta, delta, x (and the stale recv block
when delayed) once, with the dense mixing matrix A resident in VMEM across
the whole pass, and applies the unified mixing algebra of
`repro.api.mixers.MixerBase`:

    tilde = theta + delta                     (noise-add; delta sampled
                                               OUTSIDE with the engines'
                                               exact jax.random calls)
    recv  = tilde            (synchronous)  |  ring slot (delayed)
    s     = tilde (noise_self) | theta
    mixed = A @ recv + diag(A) * (s - recv)   (k-neighbor mix, MXU)
    next  = mixed - alpha_t * coeff * x       (OMD dual step, clip folded
                                               into coeff)
    next  = alive ? next : theta              (fault crash freeze)

Unfused, the round body is ~7 HBM round-trips over the (m, n) state; fused
it is 3 reads + 1 write for the update pass plus the stats pass — the
memory-bound win `repro.obs.cost` rooflines in BENCH_kernels.json.

Tiling: n is zero-padded to a LANE (128) multiple and the grid walks
column blocks of ``block_cols`` lanes; m is zero-padded to a SUBLANE (8)
multiple and stays fully resident (the dense A cap — `MAX_FUSED_NODES` —
bounds VMEM). Zero-padded rows/columns are provably inert: w and x are
zero there, so every reduction and the update leave them zero. The TPU
grid is sequential, so pass 1 accumulates its reductions into re-visited
output blocks (`@pl.when(j == 0)` zero-init, as in `kernels/hinge_grad`).
On CPU the kernels run with ``interpret=True`` — CI validates the real
kernel bodies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
DEFAULT_BLOCK_COLS = 512
# dense A is (m_pad, m_pad) f32 resident across the column grid; 1024^2 * 4B
# = 4 MiB, leaving ~12 MiB of VMEM for the streamed (m_pad, block_cols)
# operands. Larger m falls back to the hybrid path (mix stays in XLA).
MAX_FUSED_NODES = 1024


def _pad_cols(n: int) -> int:
    return -(-n // LANE) * LANE


def _pad_rows(m: int) -> int:
    return -(-m // SUBLANE) * SUBLANE


def _col_block(n_pad: int, block_cols: int) -> int:
    """Largest LANE multiple <= block_cols that divides n_pad."""
    b = min(block_cols, n_pad)
    b -= b % LANE
    while n_pad % b:
        b -= LANE
    return b


# ---------------------------------------------------------------------------
# pass 1: per-node reductions (prox fused, w never materialized)
# ---------------------------------------------------------------------------

def _stats_kernel(theta_ref, x_ref, scal_ref,
                  dot_ref, xsq_ref, nnz_ref, wbdot_ref, wsum_ref):
    """scal_ref (1, 4): [lam_t, m_real, prox_is_l1, 0]."""
    j = pl.program_id(0)
    lam_t = scal_ref[0, 0]
    m_real = scal_ref[0, 1]
    prox_l1 = scal_ref[0, 2]

    theta = theta_ref[...]
    x = x_ref[...]
    soft = jnp.sign(theta) * jnp.maximum(jnp.abs(theta) - lam_t, 0.0)
    w = jnp.where(prox_l1 > 0, soft, theta)

    @pl.when(j == 0)
    def _init():
        dot_ref[...] = jnp.zeros_like(dot_ref)
        xsq_ref[...] = jnp.zeros_like(xsq_ref)
        nnz_ref[...] = jnp.zeros_like(nnz_ref)
        wbdot_ref[...] = jnp.zeros_like(wbdot_ref)

    # per-node partial reductions over this column block; (m, 1) keepdims
    # broadcast across the LANE-wide output block so the layout stays tiled
    dot_ref[...] += jnp.sum(w * x, axis=1, keepdims=True)
    xsq_ref[...] += jnp.sum(x * x, axis=1, keepdims=True)
    nnz_ref[...] += jnp.sum((w != 0.0).astype(jnp.float32), axis=1,
                            keepdims=True)
    # w_bar restricted to this block: padding rows hold w == 0, so the raw
    # column sum over m_pad rows equals the sum over the m real rows
    wsum = jnp.sum(w, axis=0, keepdims=True)                # (1, B)
    wsum_ref[...] = jnp.broadcast_to(wsum, wsum_ref.shape)
    wbdot_ref[...] += jnp.sum((wsum / m_real) * x, axis=1, keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("prox_l1", "block_cols", "interpret"))
def round_stats(theta: jax.Array, x: jax.Array, lam_t: jax.Array,
                m_real: int, *, prox_l1: bool = True,
                block_cols: int = DEFAULT_BLOCK_COLS,
                interpret: bool = False):
    """Per-node round statistics in one streamed pass over (m_pad, n_pad).

    Returns ``(dot, xsq, nnz, wbdot, wsum)`` — the first four (m_pad,)
    per-node reductions, ``wsum`` the (n_pad,) column sums of w. ``wbdot``
    is only meaningful when all m rows are resident (the unsharded path);
    the node-sharded path psums ``wsum`` across shards instead.
    """
    m_pad, n_pad = theta.shape
    if n_pad % LANE or m_pad % SUBLANE:
        raise ValueError(f"round_stats needs (8k, 128k) padded input, got "
                         f"{theta.shape}")
    B = _col_block(n_pad, block_cols)
    grid = (n_pad // B,)
    blk = pl.BlockSpec((m_pad, B), lambda j: (0, j))
    red = pl.BlockSpec((m_pad, LANE), lambda j: (0, 0))
    scal = jnp.stack([jnp.asarray(lam_t, jnp.float32),
                      jnp.asarray(m_real, jnp.float32),
                      jnp.asarray(1.0 if prox_l1 else 0.0, jnp.float32),
                      jnp.zeros((), jnp.float32)]).reshape(1, 4)
    dot, xsq, nnz, wbdot, wsum = pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[blk, blk, pl.BlockSpec((1, 4), lambda j: (0, 0))],
        out_specs=[red, red, red, red,
                   pl.BlockSpec((SUBLANE, B), lambda j: (0, j))],
        out_shape=[jax.ShapeDtypeStruct((m_pad, LANE), jnp.float32)] * 4
        + [jax.ShapeDtypeStruct((SUBLANE, n_pad), jnp.float32)],
        interpret=interpret,
    )(theta.astype(jnp.float32), x.astype(jnp.float32), scal)
    return dot[:, 0], xsq[:, 0], nnz[:, 0], wbdot[:, 0], wsum[0]


# ---------------------------------------------------------------------------
# pass 2: noise-add + dense gossip mix + OMD dual step (+ crash freeze)
# ---------------------------------------------------------------------------

def _update_kernel(a_ref, theta_ref, delta_ref, x_ref, recv_ref,
                   pernode_ref, scal_ref, out_ref, tilde_ref):
    """pernode_ref (m_pad, 4): [coeff, diag, alive, 0] columns.
    scal_ref (1, 4): [alpha_t, use_recv, noise_self, 0]."""
    alpha = scal_ref[0, 0]
    use_recv = scal_ref[0, 1]
    noise_self = scal_ref[0, 2]
    coeff = pernode_ref[:, 0:1]
    diag = pernode_ref[:, 1:2]
    alive = pernode_ref[:, 2:3]

    theta = theta_ref[...]
    tilde = theta + delta_ref[...]
    recv = jnp.where(use_recv > 0, recv_ref[...], tilde)
    s = jnp.where(noise_self > 0, tilde, theta)
    mixed = jnp.dot(a_ref[...], recv,
                    preferred_element_type=jnp.float32) + diag * (s - recv)
    nxt = mixed - alpha * (coeff * x_ref[...])
    out_ref[...] = jnp.where(alive > 0, nxt, theta)
    tilde_ref[...] = tilde


@functools.partial(jax.jit, static_argnames=("noise_self", "block_cols",
                                             "interpret"))
def round_update(A: jax.Array, theta: jax.Array, delta: jax.Array,
                 x: jax.Array, recv: jax.Array, coeff: jax.Array,
                 diag: jax.Array, alive: jax.Array, alpha_t: jax.Array,
                 use_recv: jax.Array, noise_self: bool, *,
                 block_cols: int = DEFAULT_BLOCK_COLS,
                 interpret: bool = False):
    """Fused noise-add + mix + dual step. Returns (theta_next, tilde).

    ``A`` (m_pad, m_pad) dense doubly-stochastic weights (zero-padded);
    ``recv`` the stale broadcast block when ``use_recv`` (traced bool as
    f32) is set, ignored otherwise; ``coeff`` the clipped hinge coefficient
    (grad = coeff * x); ``alive`` 1.0 except on fault-frozen rows.
    """
    m_pad, n_pad = theta.shape
    if n_pad % LANE or m_pad % SUBLANE:
        raise ValueError(f"round_update needs (8k, 128k) padded input, got "
                         f"{theta.shape}")
    if A.shape != (m_pad, m_pad):
        raise ValueError(f"A must be ({m_pad}, {m_pad}), got {A.shape}")
    B = _col_block(n_pad, block_cols)
    grid = (n_pad // B,)
    blk = pl.BlockSpec((m_pad, B), lambda j: (0, j))
    pernode = jnp.stack([
        coeff.astype(jnp.float32), diag.astype(jnp.float32),
        alive.astype(jnp.float32), jnp.zeros_like(coeff, jnp.float32)],
        axis=1)
    scal = jnp.stack([jnp.asarray(alpha_t, jnp.float32),
                      jnp.asarray(use_recv, jnp.float32),
                      jnp.asarray(1.0 if noise_self else 0.0, jnp.float32),
                      jnp.zeros((), jnp.float32)]).reshape(1, 4)
    theta_next, tilde = pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((m_pad, m_pad), lambda j: (0, 0)),
                  blk, blk, blk, blk,
                  pl.BlockSpec((m_pad, 4), lambda j: (0, 0)),
                  pl.BlockSpec((1, 4), lambda j: (0, 0))],
        out_specs=[blk, blk],
        out_shape=[jax.ShapeDtypeStruct((m_pad, n_pad), jnp.float32)] * 2,
        interpret=interpret,
    )(A.astype(jnp.float32), theta.astype(jnp.float32),
      delta.astype(jnp.float32), x.astype(jnp.float32),
      recv.astype(jnp.float32), pernode, scal)
    return theta_next, tilde


def _dual_kernel(mixed_ref, x_ref, theta_ref, pernode_ref, scal_ref, out_ref):
    alpha = scal_ref[0, 0]
    coeff = pernode_ref[:, 0:1]
    alive = pernode_ref[:, 2:3]
    nxt = mixed_ref[...] - alpha * (coeff * x_ref[...])
    out_ref[...] = jnp.where(alive > 0, nxt, theta_ref[...])


@functools.partial(jax.jit, static_argnames=("block_cols", "interpret"))
def dual_step(mixed: jax.Array, x: jax.Array, theta: jax.Array,
              coeff: jax.Array, alive: jax.Array, alpha_t: jax.Array, *,
              block_cols: int = DEFAULT_BLOCK_COLS,
              interpret: bool = False) -> jax.Array:
    """Hybrid-path pass 2: OMD dual step + crash freeze, mixing already done
    in XLA (any mixer — faults, heterogeneous delays, time-varying A(t))."""
    m_pad, n_pad = mixed.shape
    if n_pad % LANE or m_pad % SUBLANE:
        raise ValueError(f"dual_step needs (8k, 128k) padded input, got "
                         f"{mixed.shape}")
    B = _col_block(n_pad, block_cols)
    grid = (n_pad // B,)
    blk = pl.BlockSpec((m_pad, B), lambda j: (0, j))
    pernode = jnp.stack([
        coeff.astype(jnp.float32), jnp.zeros_like(coeff, jnp.float32),
        alive.astype(jnp.float32), jnp.zeros_like(coeff, jnp.float32)],
        axis=1)
    scal = jnp.stack([jnp.asarray(alpha_t, jnp.float32)] +
                     [jnp.zeros((), jnp.float32)] * 3).reshape(1, 4)
    return pl.pallas_call(
        _dual_kernel,
        grid=grid,
        in_specs=[blk, blk, blk,
                  pl.BlockSpec((m_pad, 4), lambda j: (0, 0)),
                  pl.BlockSpec((1, 4), lambda j: (0, 0))],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(mixed.astype(jnp.float32), x.astype(jnp.float32),
      theta.astype(jnp.float32), pernode, scal)
