"""Pallas TPU kernel: RWKV6 (Finch) WKV recurrence with data-dependent decay.

The rwkv6-3b train/prefill hot spot: the per-head linear recurrence
    y_t = r_t^T (S + u ⊙ k_t v_t^T);   S ← diag(w_t) S + k_t v_t^T
is inherently sequential in t, but CHUNKED: within a chunk of C timesteps
the contribution of the running state S separates from intra-chunk terms:

    y_t = r_t^T diag(prod w)… S_chunk_start  +  intra-chunk attention-like term

This kernel processes (batch*head) blocks over a grid, keeping S (K x V)
and a C-step chunk of r/k/v/w in VMEM; HBM traffic = r,k,v,w read once +
y write once + S carried in VMEM across the sequential chunk axis — vs the
pure-JAX lax.scan which round-trips S every step at small-op granularity.

Grid: (B*H, T/C) with the chunk axis sequential ("arbitrary"); state scratch
persists across chunk steps. Validated against kernels/ref.wkv6_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CHUNK = 128


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, state_scr, *,
            chunk: int, head_k: int, head_v: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0].astype(jnp.float32)   # (C, K)
    k = k_ref[0].astype(jnp.float32)   # (C, K)
    v = v_ref[0].astype(jnp.float32)   # (C, V)
    w = w_ref[0].astype(jnp.float32)   # (C, K) decay logits
    u = u_ref[0].astype(jnp.float32)   # (1, K) bonus (row vector)
    decay = jnp.exp(-jnp.exp(w))       # (C, K)

    def step(t, carry):
        S, y = carry
        r_t = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)      # (1, K)
        k_t = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)
        v_t = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)      # (1, V)
        d_t = jax.lax.dynamic_slice_in_dim(decay, t, 1, 0)  # (1, K)
        kv = k_t.T @ v_t                                     # (K, V)
        y_t = r_t @ (S + u.T * kv)                           # (1, V)
        S = d_t.T * S + kv
        y = jax.lax.dynamic_update_slice_in_dim(y, y_t, t, 0)
        return S, y

    S0 = state_scr[...]
    y0 = jnp.zeros((chunk, head_v), jnp.float32)
    S, y = jax.lax.fori_loop(0, chunk, step, (S0, y0))
    state_scr[...] = S
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, *, chunk: int = DEFAULT_CHUNK,
         interpret: bool = False) -> jax.Array:
    """r,k,w (B, T, H, K); v (B, T, H, V); u (H, K) -> y (B, T, H, V).

    State starts at zero (training/prefill from scratch); the decode path
    carries state outside the kernel (single-step recurrence).
    """
    B, T, H, K = r.shape
    V = v.shape[-1]
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        r, k, w = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0))) for a in (r, k, w))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # padded steps: decay exp(-exp(0)) < 1 fine, k=0 => kv=0, y ignored
    Tp = T + pad

    # (B,T,H,X) -> (B*H, T, X)
    def bh(a):
        return jnp.moveaxis(a, 2, 1).reshape(B * H, Tp, a.shape[-1])

    rb, kb, vb, wb = bh(r), bh(k), bh(v), bh(w)
    ub = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, 1, K)

    grid = (B * H, Tp // chunk)
    y = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, head_k=K, head_v=V),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda bh_, c: (bh_, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda bh_, c: (bh_, c, 0)),
            pl.BlockSpec((1, chunk, V), lambda bh_, c: (bh_, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda bh_, c: (bh_, c, 0)),
            pl.BlockSpec((1, 1, K), lambda bh_, c: (bh_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, V), lambda bh_, c: (bh_, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tp, V), r.dtype),
        scratch_shapes=[_vmem((K, V), jnp.float32)],
        interpret=interpret,
    )(rb, kb, vb, wb, ub)
    y = y.reshape(B, H, Tp, V)[:, :, :T]
    return jnp.moveaxis(y, 1, 2)


def _vmem(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover
        return pl.MemorySpace.ANY(shape, dtype)
