"""Pallas TPU kernel: causal flash attention (forward), GQA-aware.

The §Roofline analysis shows every attention-bearing (arch x shape) pair is
memory-bound, dominated by the f32 score/probability tensors round-tripping
HBM between the two dots of XLA's blockwise attention (fusion cannot keep a
(qc, kc) block resident across the online-softmax chain). This kernel keeps
the entire (q_block x k_block) tile in VMEM: HBM traffic collapses to the
q/k/v reads + o write — the flash-attention bound.

Tiling:
  grid = (B * H, nq, nk)  — ("parallel", "parallel", "arbitrary")
  q block   (1, block_q, hd)      VMEM
  k/v block (1, block_k, hd)      VMEM (kv head = h // group via index_map)
  scratch: acc (block_q, hd) f32, m/l (block_q,) f32 — persist across the
  k-loop (the innermost grid dim revisits the same output block).

Causality is enforced per-tile (position mask) and whole tiles in the
strict upper triangle are skipped with pl.when (no MXU issue).
Numerics match the pure-JAX oracle: f32 online softmax, bf16 I/O.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, seq_len: int, block_q: int, block_k: int,
            window: int | None, causal: bool):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = kj * block_k

    # tile-level skip: strictly-future k tiles contribute nothing
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1

    @pl.when(run if causal else True)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0]                               # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)

        pos_q = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        pos_k = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = pos_k < seq_len
        if causal:
            mask &= pos_k <= pos_q
        if window is not None:
            mask &= pos_k > pos_q - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(kj == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,   # (B, T, H, hd)
    k: jax.Array,   # (B, S, Kv, hd)
    v: jax.Array,   # (B, S, Kv, hd)
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """Returns o (B, T, H, hd). GQA: kv head = h // (H // Kv)."""
    B, T, H, hd = q.shape
    S, Kv = k.shape[1], k.shape[2]
    g = H // Kv
    scale = 1.0 / math.sqrt(hd)

    block_q = min(block_q, max(T, 8))
    block_k = min(block_k, max(S, 8))
    pad_t = (-T) % block_q
    pad_s = (-S) % block_k
    if pad_t:
        q = jnp.pad(q, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    Tp, Sp = T + pad_t, S + pad_s

    # (B, T, H, hd) -> (B*H, T, hd) head-major blocks
    qh = jnp.moveaxis(q, 2, 1).reshape(B * H, Tp, hd)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * Kv, Sp, hd)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * Kv, Sp, hd)

    nq = Tp // block_q
    nk = Sp // block_k
    grid = (B * H, nq, nk)

    def q_idx(bh, qi, kj):
        return (bh, qi, 0)

    def kv_idx(bh, qi, kj):
        b = bh // H
        h = bh % H
        return (b * Kv + h // g, kj, 0)

    kernel = functools.partial(
        _kernel, scale=scale, seq_len=S, block_q=block_q, block_k=block_k,
        window=window, causal=causal)

    o = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_idx),
            pl.BlockSpec((1, block_k, hd), kv_idx),
            pl.BlockSpec((1, block_k, hd), kv_idx),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_idx),
        out_shape=jax.ShapeDtypeStruct((B * H, Tp, hd), q.dtype),
        scratch_shapes=[
            pltpu_smem((block_q,), jnp.float32),
            pltpu_smem((block_q,), jnp.float32),
            pltpu_smem((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=None,
    )(qh, kh, vh)
    o = o.reshape(B, H, Tp, hd)[:, :, :T]
    return jnp.moveaxis(o, 1, 2)


def pltpu_smem(shape, dtype):
    """VMEM scratch allocation (pltpu.VMEM when available, else pl.ANY)."""
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)
    except Exception:
        return pl.MemorySpace.ANY(shape, dtype)  # pragma: no cover
