"""Pallas TPU kernel: fused PDOMD round update (paper Algorithm 1 steps 6-10).

Fuses, in ONE pass over VMEM-resident parameter blocks:

    theta_mixed = sw * theta_self~ + nw * theta_prev~ + nw * theta_next~
    theta_new   = theta_mixed - alpha * g
    w           = sign(theta_new) * max(|theta_new| - lam, 0)     (Lasso prox)

The neighbor copies (theta_prev~/theta_next~, already Laplace-noised at the
sender per step 11) arrive via collective-permute OUTSIDE the kernel — the
kernel is the node-local hot loop that the paper executes every round over
an n = 1e4..1e8 dimensional parameter.

Unfused, this chain is 5 elementwise HLO ops reading/writing HBM 7x
(3 reads + mix write + sub write + abs/sign/max temporaries); fused it is
4 reads + 2 writes, a ~2x HBM traffic cut on a purely memory-bound op —
exactly the kind of win the roofline analysis targets for the memory term.

Tiling: parameters are flattened to (rows, 128) with rows padded to a
multiple of 8 (f32 VPU tile (8, 128)). Block = (block_rows, 128), grid over
row blocks; no MXU use — VPU-only elementwise kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
DEFAULT_BLOCK_ROWS = 512  # 512*128*4B = 256 KiB per operand; 6 operands ~ 1.5 MiB VMEM


def _kernel(theta_ref, prev_ref, nxt_ref, g_ref, scal_ref, w_ref, theta_out_ref):
    """scal_ref: (1, 4) f32 in SMEM-like layout: [alpha, lam, self_w, nbr_w]."""
    alpha = scal_ref[0, 0]
    lam = scal_ref[0, 1]
    sw = scal_ref[0, 2]
    nw = scal_ref[0, 3]
    mixed = sw * theta_ref[...] + nw * prev_ref[...] + nw * nxt_ref[...]
    theta_new = mixed - alpha * g_ref[...]
    theta_out_ref[...] = theta_new
    w_ref[...] = jnp.sign(theta_new) * jnp.maximum(jnp.abs(theta_new) - lam, 0.0)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def pdomd_update(
    theta_self: jax.Array,   # (rows, 128) f32 — own theta~ (noised if noise_self)
    theta_prev: jax.Array,   # (rows, 128) f32 — left neighbor's theta~
    theta_next: jax.Array,   # (rows, 128) f32 — right neighbor's theta~
    grad: jax.Array,         # (rows, 128) f32 — clipped local subgradient
    alpha: jax.Array,        # scalar f32 — step size alpha_t
    lam: jax.Array,          # scalar f32 — lambda_t = alpha_t * lambda
    self_weight: jax.Array,  # scalar f32 — a_ii
    nbr_weight: jax.Array,   # scalar f32 — a_i,i±1
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
):
    """Returns (w, theta_new), both (rows, 128) f32."""
    rows, lanes = theta_self.shape
    if lanes != LANE:
        raise ValueError(f"last dim must be {LANE}, got {lanes}")
    if rows % SUBLANE:
        raise ValueError(f"rows must be a multiple of {SUBLANE}, got {rows}")
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        # fall back to a divisor block
        while rows % block_rows:
            block_rows //= 2
        block_rows = max(block_rows, SUBLANE)

    scal = jnp.stack([alpha, lam, self_weight, nbr_weight]).astype(jnp.float32).reshape(1, 4)
    grid = (rows // block_rows,)
    blk = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    scal_spec = pl.BlockSpec((1, 4), lambda i: (0, 0))

    w, theta_new = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[blk, blk, blk, blk, scal_spec],
        out_specs=[blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        ],
        interpret=interpret,
    )(theta_self.astype(jnp.float32), theta_prev.astype(jnp.float32),
      theta_next.astype(jnp.float32), grad.astype(jnp.float32), scal)
    return w, theta_new
