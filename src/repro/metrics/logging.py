"""Scalar metric logging: CSV files + in-memory moving windows."""
from __future__ import annotations

import collections
import os
from typing import Mapping

import numpy as np


class CSVLogger:
    def __init__(self, path: str, fieldnames: list[str] | None = None):
        self.path = path
        self.fieldnames = fieldnames
        self._fh = None

    def log(self, step: int, metrics: Mapping[str, float]) -> None:
        if self._fh is None:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            self.fieldnames = self.fieldnames or ["step", *sorted(metrics)]
            self._fh = open(self.path, "w")
            self._fh.write(",".join(self.fieldnames) + "\n")
        row = {"step": step, **{k: float(v) for k, v in metrics.items()}}
        self._fh.write(",".join(str(row.get(f, "")) for f in self.fieldnames) + "\n")
        self._fh.flush()

    def close(self):
        if self._fh:
            self._fh.close()


class MetricTracker:
    """Windowed means for console reporting."""

    def __init__(self, window: int = 50):
        self.window = window
        self.data: dict[str, collections.deque] = {}

    def update(self, metrics: Mapping[str, float]) -> None:
        for k, v in metrics.items():
            self.data.setdefault(k, collections.deque(maxlen=self.window)).append(float(v))

    def means(self) -> dict[str, float]:
        return {k: float(np.mean(v)) for k, v in self.data.items() if v}
