"""Scalar metric logging: CSV files + in-memory moving windows.

`CSVLogger` is resume-safe: logging into an existing file APPENDS under the
file's own header instead of clobbering it (a resumed run used to truncate
the rows the first pass wrote), and a row carrying keys outside the header
raises instead of silently dropping them — a schema change between passes
is a bug to surface, not data to lose. Each logged row is also mirrored
into the ambient `repro.obs` metrics registry (``log.<field>`` gauges), so
the CSV file and the telemetry snapshot can never disagree.

>>> import os, tempfile
>>> path = os.path.join(tempfile.mkdtemp(), "m.csv")
>>> lg = CSVLogger(path)
>>> lg.log(0, {"loss": 1.0}); lg.close()
>>> lg2 = CSVLogger(path)                      # "resume": same file
>>> lg2.log(1, {"loss": 0.5}); lg2.close()
>>> print(open(path).read().strip())
step,loss
0,1.0
1,0.5
>>> lg3 = CSVLogger(path)
>>> lg3.log(2, {"loss": 0.2, "extra": 9.0})
Traceback (most recent call last):
    ...
ValueError: CSVLogger: row keys ['extra'] are not in the header ['step', 'loss'] of ...m.csv
>>> tr = MetricTracker(window=2)
>>> tr.means()                                 # empty window: no keys
{}
>>> for v in (1.0, 2.0, 3.0):
...     tr.update({"loss": v})
>>> tr.means()                                 # only the last `window` values
{'loss': 2.5}
"""
from __future__ import annotations

import collections
import os
from typing import Mapping

import numpy as np

from repro import obs as obslib


class CSVLogger:
    def __init__(self, path: str, fieldnames: list[str] | None = None):
        self.path = path
        self.fieldnames = fieldnames
        self._fh = None

    def _open(self, metrics: Mapping[str, float]) -> None:
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        header = None
        if os.path.exists(self.path) and os.path.getsize(self.path) > 0:
            with open(self.path) as f:
                header = f.readline().strip()
        if header:
            # resume: the file's own header is the schema — appending under
            # a different one would silently misalign every later column
            existing = header.split(",")
            if self.fieldnames is not None and self.fieldnames != existing:
                raise ValueError(
                    f"CSVLogger: requested fieldnames {self.fieldnames} do "
                    f"not match the existing header {existing} of {self.path}")
            self.fieldnames = existing
            self._fh = open(self.path, "a")
        else:
            self.fieldnames = self.fieldnames or ["step", *sorted(metrics)]
            self._fh = open(self.path, "a")
            self._fh.write(",".join(self.fieldnames) + "\n")

    def log(self, step: int, metrics: Mapping[str, float]) -> None:
        if self._fh is None:
            self._open(metrics)
        row = {"step": step, **{k: float(v) for k, v in metrics.items()}}
        extra = sorted(set(row) - set(self.fieldnames))
        if extra:
            raise ValueError(
                f"CSVLogger: row keys {extra} are not in the header "
                f"{self.fieldnames} of {self.path}")
        self._fh.write(",".join(str(row.get(f, ""))
                                for f in self.fieldnames) + "\n")
        self._fh.flush()
        tel = obslib.active()
        if tel.enabled:
            for k, v in metrics.items():
                tel.metrics.gauge(f"log.{k}").set(float(v))

    def close(self):
        if self._fh:
            self._fh.close()
            self._fh = None


class MetricTracker:
    """Windowed means for console reporting."""

    def __init__(self, window: int = 50):
        self.window = window
        self.data: dict[str, collections.deque] = {}

    def update(self, metrics: Mapping[str, float]) -> None:
        for k, v in metrics.items():
            self.data.setdefault(k, collections.deque(maxlen=self.window)).append(float(v))

    def means(self) -> dict[str, float]:
        return {k: float(np.mean(v)) for k, v in self.data.items() if v}
