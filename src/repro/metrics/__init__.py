from repro.metrics.logging import CSVLogger, MetricTracker
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry

__all__ = ["CSVLogger", "MetricTracker",
           "Counter", "Gauge", "Histogram", "MetricsRegistry"]
