from repro.metrics.logging import CSVLogger, MetricTracker

__all__ = ["CSVLogger", "MetricTracker"]
