"""Regret measurement (paper Definition 3 + Theorem 2 bound).

R = sum_t sum_i f_t^i(w_bar_t)  -  min_w sum_t sum_i f_t^i(w)

The comparator min_w needs the best FIXED parameter in hindsight; we compute
it by full-batch subgradient descent over the replayed stream (the stream is
synthetic and replayable, so this is exact up to optimizer tolerance).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["best_fixed_hinge", "cumulative_regret", "theorem2_bound"]


def best_fixed_hinge(
    xs: jax.Array, ys: jax.Array, steps: int = 1500, lr: float = 2.0, lam: float = 0.0
) -> jax.Array:
    """argmin_w mean hinge loss over the whole stream (full batch, replayed).

    xs (T*m, n) flattened stream, ys (T*m,). Subgradient descent with
    1/sqrt(k) steps; convex problem => converges to the comparator.
    """
    X = xs.reshape(-1, xs.shape[-1])
    Y = ys.reshape(-1)
    n = X.shape[-1]

    def loss_fn(w):
        margins = Y * (X @ w)
        return jnp.mean(jnp.maximum(1.0 - margins, 0.0)) + lam * jnp.sum(jnp.abs(w))

    grad_fn = jax.grad(loss_fn)

    def body(k, w):
        g = grad_fn(w)
        return w - (lr / jnp.sqrt(k + 1.0)) * g

    w0 = jnp.zeros((n,), jnp.float32)
    w = jax.lax.fori_loop(0, steps, body, w0)
    return w


def cumulative_regret(per_round_wbar_loss: jax.Array, xs: jax.Array, ys: jax.Array,
                      m: int, w_star: jax.Array | None = None) -> np.ndarray:
    """Cumulative regret curve (length T), per Definition 3.

    per_round_wbar_loss: (T,) mean-over-nodes loss of w_bar_t (so *m gives the
    sum over i). xs (T, m, n), ys (T, m).
    """
    if w_star is None:
        w_star = best_fixed_hinge(xs, ys)
    margins = ys * jnp.einsum("n,tmn->tm", w_star, xs)
    star_loss = jnp.sum(jnp.maximum(1.0 - margins, 0.0), axis=1)  # (T,) summed over m
    alg_loss = per_round_wbar_loss * m
    return np.asarray(jnp.cumsum(alg_loss - star_loss))


def theorem2_bound(T: int, m: int, n: int, L: float, lam: float, R_diam: float, eps: float) -> float:
    """Paper Eq. (17):  R <= R*sqrt((L+lam) m T L) + (2*sqrt2 m^2 n T L / eps)(sqrt T - 1/2).

    Returned for reporting; see DESIGN.md deviation #2 about the noise-term
    constant being extremely loose for the paper's own m, n.
    """
    s1 = R_diam * math.sqrt((L + lam) * m * T * L)
    if math.isinf(eps):
        return s1
    s2 = (2.0 * math.sqrt(2.0) * m * m * n * T * L / eps) * (math.sqrt(T) - 0.5)
    return s1 + s2
