"""Core library: the paper's contribution (private distributed online learning).

Modules:
  graph      — communication topologies + doubly-stochastic mixing matrices
  privacy    — Laplace mechanism, Lemma-1 sensitivity, accountant
  prox       — L1 / group / elastic-net proximal operators (Lasso step)
  omd        — online mirror descent local optimizer
  algorithm1 — faithful m-node simulator of the paper's Algorithm 1
  gossip     — distributed GossipDP strategy (shardable node-parallel update)
  regret     — Definition-3 regret measurement + Theorem-2 bound

Both engines are thin compositions over the `repro.api` protocol layer
(Mixer / Mechanism / LocalRule / Clipper); build them declaratively with
`repro.api.RunSpec`. The legacy constructors (graph=/privacy=/method= and
gossip=/privacy=) were removed after their one-release deprecation window;
see README §Migrating for the RunSpec equivalents.
"""
from repro.core.graph import GossipGraph
from repro.core.omd import OMDConfig, OnlineMirrorDescent
from repro.core.privacy import PrivacyConfig, PrivacyAccountant
from repro.core.gossip import GossipDP, GossipState
from repro.core.algorithm1 import Algorithm1

__all__ = [
    "GossipGraph",
    "OMDConfig",
    "OnlineMirrorDescent",
    "PrivacyConfig",
    "PrivacyAccountant",
    "GossipDP",
    "GossipState",
    "Algorithm1",
]
