"""Proximal operators / mirror maps (paper step 6-7 of Algorithm 1).

Step 7 of Algorithm 1:
    w = argmin_w  1/2 ||p - w||_2^2 + lambda ||w||_1
has the closed form soft-threshold  w = sign(p) * max(|p| - lambda, 0).

With phi_t = 1/2 ||.||_2^2 (the paper's Theorem 2 choice), the mirror map
grad phi*(theta) = theta, so p == theta and the whole primal recovery is
the soft-threshold — which is why `kernels/pdomd_update` can fuse the entire
round update into one VMEM pass.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "soft_threshold",
    "soft_threshold_tree",
    "elastic_net_prox",
    "group_soft_threshold",
    "l2_mirror_map",
    "sparsity",
    "sparsity_tree",
]


def soft_threshold(p: jax.Array, lam) -> jax.Array:
    """Closed-form Lasso prox: sign(p) * relu(|p| - lam)."""
    lam = jnp.asarray(lam, p.dtype)
    return jnp.sign(p) * jnp.maximum(jnp.abs(p) - lam, 0.0)


def soft_threshold_tree(tree: Any, lam) -> Any:
    return jax.tree_util.tree_map(lambda p: soft_threshold(p, lam), tree)


def elastic_net_prox(p: jax.Array, lam_l1, lam_l2) -> jax.Array:
    """prox of lam_l1 ||.||_1 + lam_l2/2 ||.||_2^2 (beyond-paper option)."""
    return soft_threshold(p, lam_l1) / (1.0 + jnp.asarray(lam_l2, p.dtype))


def group_soft_threshold(p: jax.Array, lam, axis: int = -1) -> jax.Array:
    """Group-lasso prox: shrink whole rows/groups by their L2 norm.

    Beyond-paper: structured sparsity (zeros entire feature groups), more
    hardware-friendly than unstructured for downstream sparse compute.
    """
    norm = jnp.sqrt(jnp.sum(jnp.square(p), axis=axis, keepdims=True))
    scale = jnp.maximum(norm - lam, 0.0) / jnp.maximum(norm, 1e-12)
    return p * scale


def l2_mirror_map(theta: jax.Array) -> jax.Array:
    """grad phi*(theta) for phi = 1/2||.||_2^2 : identity (Thm 2 setting)."""
    return theta


def sparsity(w: jax.Array, atol: float = 0.0) -> jax.Array:
    """Fraction of exactly-zero (or |.|<=atol) coordinates."""
    return jnp.mean((jnp.abs(w) <= atol).astype(jnp.float32))


def sparsity_tree(tree: Any, atol: float = 0.0) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    total = float(sum(leaf.size for leaf in leaves))  # float: avoid int32 overflow in jit
    zeros = sum(jnp.sum((jnp.abs(l) <= atol).astype(jnp.float32)) for l in leaves)
    return zeros / total
