"""Differential privacy machinery (paper §III).

Implements:
  * Lemma 1 sensitivity:  S(t) <= 2 * alpha_t * sqrt(n) * L
  * Laplace noise with scale mu = S(t) / eps       (Eq. 8)
  * per-round eps-DP (Lemma 2) + parallel composition across rounds (Thm 1,
    valid because each round consumes disjoint stream entries)
  * gradient clipping that ENFORCES the bound ||g||_2 <= L that the paper
    assumes (Assumption 2.3) — without clipping the DP guarantee is vacuous
    for unbounded losses.

TPU adaptation: Laplace sampling uses the inverse-CDF transform of a uniform
(threefry) sample — branch-free and vectorizes on VPU; no rejection sampling.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "sensitivity",
    "laplace_scale",
    "sample_laplace",
    "sample_laplace_tree",
    "clip_by_l2",
    "PrivacyConfig",
    "PrivacyAccountant",
]


def sensitivity(alpha_t: float | jax.Array, n: int, L: float) -> jax.Array:
    """Lemma 1: S(t) <= 2 * alpha_t * sqrt(n) * L  (L1 sensitivity of theta)."""
    return 2.0 * jnp.asarray(alpha_t) * math.sqrt(n) * L


def laplace_scale(alpha_t: float | jax.Array, n: int, L: float, eps: float) -> jax.Array:
    """mu = S(t) / eps (Eq. 8). eps = inf => scale 0 (non-private)."""
    if math.isinf(eps):
        return jnp.zeros(())
    return sensitivity(alpha_t, n, L) / eps


def sample_laplace(key: jax.Array, shape, scale, dtype=jnp.float32) -> jax.Array:
    """Laplace(0, scale) via inverse CDF: x = -scale * sign(u) * log1p(-2|u|).

    u ~ Uniform(-1/2, 1/2). Branch-free; exact for scale == 0 (returns zeros).
    """
    u = jax.random.uniform(key, shape, dtype=dtype, minval=-0.5 + 1e-7, maxval=0.5)
    noise = -jnp.sign(u) * jnp.log1p(-2.0 * jnp.abs(u))
    return jnp.asarray(scale, dtype) * noise


def sample_laplace_tree(key: jax.Array, tree: Any, scale, dtype=None) -> Any:
    """One independent Laplace sample per leaf of a pytree (same scale)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noisy = [
        sample_laplace(k, jnp.shape(leaf), scale, dtype or jnp.result_type(leaf))
        for k, leaf in zip(keys, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, noisy)


def clip_by_l2(tree: Any, max_norm: float) -> tuple[Any, jax.Array]:
    """Scale the whole pytree so its global L2 norm is <= max_norm.

    Enforces Assumption 2.3 (||g|| <= L); returns (clipped, pre-clip norm).
    """
    sq = sum(jnp.sum(jnp.square(leaf.astype(jnp.float32))) for leaf in jax.tree_util.tree_leaves(tree))
    norm = jnp.sqrt(sq)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda x: (x * factor).astype(x.dtype), tree), norm


@dataclasses.dataclass(frozen=True)
class PrivacyConfig:
    """All knobs of the paper's privacy mechanism.

    eps:        per-round privacy budget (paper sweeps 0.1 / 1 / 10 / inf).
    L:          subgradient bound (Assumption 2.3), enforced by clipping.
    noise_self: faithful default True — Algorithm 1 mixes the *noisy* theta
                for every j including j == i. False is the beyond-paper
                variant (own theta needs no network hop => no noise).
    clip_style: 'global' = paper's Lemma 1 scale 2*alpha*sqrt(n)*L on the
                whole vector; 'coordinate' = beyond-paper per-coordinate
                sensitivity 2*alpha*L_inf (tighter when gradients are dense).
    """

    eps: float = 1.0
    L: float = 1.0
    noise_self: bool = True
    clip_style: str = "global"

    @property
    def is_private(self) -> bool:
        return not math.isinf(self.eps)

    def scale_for(self, alpha_t, n: int) -> jax.Array:
        if not self.is_private:
            return jnp.zeros(())
        if self.clip_style == "coordinate":
            return 2.0 * jnp.asarray(alpha_t) * self.L / self.eps
        return laplace_scale(alpha_t, n, self.L, self.eps)


@dataclasses.dataclass
class PrivacyAccountant:
    """Tracks the cumulative guarantee.

    Theorem 1 (parallel composition, McSherry): because round t touches only
    the stream entries that arrive at round t (disjoint across rounds), the
    T-round algorithm is eps-DP overall, NOT T*eps. We additionally track the
    pessimistic sequential-composition number for transparency.

    `repro.api.run` threads one accountant through every run: ``step(k)``
    after each chunk of k rounds, ``ledger(T)`` for the per-round eps
    trajectory in the RunResult, ``summary()`` for the final record.
    """

    eps_per_round: float
    rounds: int = 0
    disjoint_streams: bool = True
    node_rounds: Any = None   # optional (m,) per-node participated rounds

    def __post_init__(self):
        if self.eps_per_round < 0:
            raise ValueError("eps_per_round must be >= 0")
        if self.rounds < 0:
            raise ValueError("rounds must be >= 0")

    def step(self, k: int = 1, participation: Any = None) -> None:
        """Advance ``k`` rounds; ``participation`` (optional, (m,) ints)
        says how many of them each node actually spent eps in.

        A node only releases a noised broadcast in rounds it participates
        in (repro.faults: crashed rounds draw no attention from the
        adversary), so charging it for the full chunk overstates its spend.
        The first masked call starts per-node tracking, back-filling rounds
        stepped before it as full participation.
        """
        if k < 0:
            raise ValueError("cannot step a negative number of rounds")
        prior = self.rounds
        self.rounds += k
        if participation is not None:
            import numpy as np
            part = np.asarray(participation, np.int64).ravel()
            if part.size and ((part < 0).any() or (part > k).any()):
                raise ValueError(
                    f"participation counts must be in [0, {k}] for a "
                    f"{k}-round step; got range "
                    f"[{part.min()}, {part.max()}]")
            if self.node_rounds is None:
                self.node_rounds = np.full(part.shape, prior, np.int64)
            self.node_rounds = self.node_rounds + part
        elif self.node_rounds is not None:
            self.node_rounds = self.node_rounds + k

    def guarantee_at(self, rounds: int) -> float:
        """Cumulative eps after ``rounds`` rounds.

        0 rounds => 0.0 (nothing has been released yet — the pre-fix code
        claimed eps_per_round before the first broadcast). Under Theorem 1
        the guarantee is flat at eps_per_round for every rounds >= 1; the
        sequential fallback composes linearly.
        """
        if rounds == 0:
            return 0.0
        if self.disjoint_streams:
            return self.eps_per_round  # Thm 1
        return self.eps_per_round * rounds  # sequential fallback

    @property
    def guarantee(self) -> float:
        return self.guarantee_at(self.rounds)

    def ledger(self, rounds: int | None = None) -> list[float]:
        """Per-round cumulative eps trajectory [guarantee_at(1) ..
        guarantee_at(T)] — what `repro.api.run` records in RunResult."""
        T = self.rounds if rounds is None else rounds
        return [self.guarantee_at(t) for t in range(1, T + 1)]

    def per_node_guarantee(self):
        """(m,) cumulative eps per node, or None without participation
        tracking. Parallel composition: a node that ever participated is at
        eps_per_round, one that never did is at 0; sequential composes its
        own participated rounds linearly."""
        if self.node_rounds is None:
            return None
        import numpy as np
        counts = np.asarray(self.node_rounds, np.int64)
        if self.disjoint_streams:
            return np.where(counts > 0, self.eps_per_round, 0.0)
        return self.eps_per_round * counts.astype(np.float64)

    def summary(self) -> dict:
        out = {
            "eps_per_round": self.eps_per_round,
            "rounds": self.rounds,
            "eps_total": self.guarantee,
            "composition": "parallel (disjoint)" if self.disjoint_streams else "sequential",
        }
        if self.node_rounds is not None:
            per_node = self.per_node_guarantee()
            out["participated_rounds"] = [int(v) for v in self.node_rounds]
            out["eps_per_node_max"] = float(per_node.max())
            out["eps_per_node_min"] = float(per_node.min())
        return out
