"""Algorithm 1, faithful simulator (paper §II-D).

Runs m virtual data-center nodes inside one device via vectorized ops:
theta is an (m, n) matrix, mixing is the dense product A @ theta_tilde,
so ANY doubly-stochastic A (fixed or time-varying) is supported — this is
the reference implementation that the distributed shard_map strategy
(core/gossip.py) is tested against for ring topologies.

The default workload is the paper's: hinge loss f(w,x,y) = [1 - y<w,x>]_+,
high-dimension sparse data. Everything runs under one lax.scan over rounds,
so a 100k-round x 64-node x 10k-dim simulation JITs into a single program.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import prox
from repro.core.graph import GossipGraph
from repro.core.omd import OMDConfig
from repro.core.privacy import PrivacyConfig, sample_laplace

__all__ = ["Algorithm1", "SimState", "RoundOutput", "hinge_loss_and_grad"]


def hinge_loss_and_grad(w: jax.Array, x: jax.Array, y: jax.Array):
    """Paper's loss: f = [1 - y <w,x>]_+ ; subgradient -y x when margin<1.

    Shapes: w (m,n), x (m,n), y (m,) -> loss (m,), grad (m,n).
    """
    margin = y * jnp.einsum("mn,mn->m", w, x)
    loss = jnp.maximum(1.0 - margin, 0.0)
    active = (margin < 1.0).astype(w.dtype)
    grad = -(active * y)[:, None] * x
    return loss, grad


class SimState(NamedTuple):
    theta: jax.Array   # (m, n) dual parameters, one row per node
    t: jax.Array       # round counter
    key: jax.Array     # PRNG
    history: jax.Array | None = None  # (delay+1, m, n) ring of past theta~


class RoundOutput(NamedTuple):
    loss: jax.Array        # (m,) per-node losses this round
    w_bar_loss: jax.Array  # scalar: loss of the averaged parameter (Def. 3 regret uses it)
    sparsity: jax.Array    # scalar: zero-fraction of w across nodes
    correct: jax.Array     # (m,) prediction correctness (sign match)


@dataclasses.dataclass
class Algorithm1:
    """Private Distributed Online Learning (paper Algorithm 1).

    graph:   mixing topology (Assumption 1).
    omd:     local online-mirror-descent config (alpha/lambda schedules).
    privacy: Laplace mechanism config (eps, L, Lemma-1 scaling).
    loss_and_grad: (w, x, y) -> (loss (m,), grad (m,n)); default hinge.
    method:  local sparse-online-learning rule. 'omd' is the paper's
             (mirror descent + Lasso prox). The paper's §I cites two prior
             families, implemented as comparable baselines:
             'tg'  — truncated gradient (Langford, Li & Zhang '09, ref [11]):
                     gossip mixes w itself; w <- shrink(w_mixed - a g, a*lam)
             'rda' — l1 regularized dual averaging (Xiao '10, ref [12]):
                     gossip mixes the cumulative gradient G;
                     w = -(sqrt(t)/gamma) * shrink(G/t, lam)
    """

    graph: GossipGraph
    omd: OMDConfig
    privacy: PrivacyConfig
    n: int
    loss_and_grad: Callable = staticmethod(hinge_loss_and_grad)
    method: str = "omd"
    rda_gamma: float = 1.0
    # Communication DELAY in rounds (the paper's stated future work §VI):
    # neighbors' theta~ arrive `delay` rounds late (own state is current).
    delay: int = 0

    def __post_init__(self):
        if self.method not in ("omd", "tg", "rda"):
            raise ValueError(self.method)
        if self.delay < 0:
            raise ValueError("delay must be >= 0")

    def init(self, key: jax.Array) -> SimState:
        m = self.graph.m
        hist = (jnp.zeros((self.delay + 1, m, self.n), jnp.float32)
                if self.delay else None)
        return SimState(
            theta=jnp.zeros((m, self.n), jnp.float32),
            t=jnp.zeros((), jnp.int32),
            key=key,
            history=hist,
        )

    def _primal(self, theta: jax.Array, alpha_t, lam_t, t) -> jax.Array:
        """State -> prediction weights, per method."""
        if self.method == "omd":
            return prox.soft_threshold(theta, lam_t)
        if self.method == "tg":
            return theta  # state IS w
        # rda: theta is the cumulative gradient sum G; w from the RDA rule
        tf = jnp.maximum(t.astype(jnp.float32), 1.0)
        gbar = theta / tf
        return -(jnp.sqrt(tf) / self.rda_gamma) * prox.soft_threshold(gbar, self.omd.lam)

    def _dual_step(self, mixed: jax.Array, grad: jax.Array, alpha_t, lam_t) -> jax.Array:
        if self.method == "omd":
            return mixed - alpha_t * grad
        if self.method == "tg":
            return prox.soft_threshold(mixed - alpha_t * grad, lam_t)
        return mixed + grad  # rda accumulates

    # -- one round -----------------------------------------------------------
    def round(self, state: SimState, batch) -> tuple[SimState, RoundOutput]:
        """One synchronous round across all m nodes.

        batch: (x, y) with x (m, n), y (m,) — node i sees only row i
        (disjoint streams => parallel composition, Thm 1).
        """
        x, y = batch
        m = self.graph.m
        alpha_t = self.omd.alpha()(state.t + 1)
        lam_t = self.omd.lam_t(alpha_t)

        # Steps 6-7: primal recovery (per method; 'omd' = the paper's Lasso prox).
        w = self._primal(state.theta, alpha_t, lam_t, state.t + 1)

        # Steps 8-9: predict, receive label, suffer loss.
        loss, grad = self.loss_and_grad(w, x, y)
        margin_sign = jnp.sign(jnp.einsum("mn,mn->m", w, x))
        correct = (margin_sign == y).astype(jnp.float32)

        # Clip to enforce Assumption 2.3 (||g|| <= L) — required for Lemma 1.
        gnorm = jnp.linalg.norm(grad, axis=1, keepdims=True)
        grad = grad * jnp.minimum(1.0, self.privacy.L / jnp.maximum(gnorm, 1e-12))

        # Step 11 (previous round's broadcast): add Laplace noise to egress.
        key, sub = jax.random.split(state.key)
        scale = self.privacy.scale_for(alpha_t, self.n)
        delta = sample_laplace(sub, (m, self.n), scale)
        theta_tilde = state.theta + delta

        # Optional WAN delay: neighbors see theta~ from `delay` rounds ago
        # (own state stays current). History is a ring buffer.
        new_history = state.history
        if self.delay:
            slot = state.t % (self.delay + 1)
            new_history = state.history.at[slot].set(theta_tilde)
            recv_slot = (state.t + 1) % (self.delay + 1)  # oldest = t - delay
            theta_recv = jnp.where(state.t >= self.delay,
                                   state.history[recv_slot], theta_tilde)
        else:
            theta_recv = theta_tilde

        # Step 10: gossip mixing with doubly-stochastic A(t), minus grad step.
        mats = jnp.stack([jnp.asarray(A) for A in self.graph.matrices])
        A = mats[state.t % len(self.graph.matrices)]
        diag = jnp.diag(A)[:, None]
        if self.delay:
            # off-diagonal terms use delayed copies; self term is current
            mixed = (A @ theta_recv) - diag * theta_recv + diag * (
                theta_tilde if self.privacy.noise_self else state.theta)
        elif self.privacy.noise_self:
            mixed = A @ theta_tilde
        else:
            mixed = (A @ theta_tilde) - diag * delta  # remove own-noise contribution
        theta_next = self._dual_step(mixed, grad, alpha_t, lam_t)

        # Definition 3 regret is w.r.t. the average parameter w_bar.
        w_bar = jnp.mean(w, axis=0, keepdims=True)
        wb_loss = jnp.mean(
            jnp.maximum(1.0 - y * jnp.einsum("n,mn->m", w_bar[0], x), 0.0)
        )

        out = RoundOutput(
            loss=loss,
            w_bar_loss=wb_loss,
            sparsity=prox.sparsity(w),
            correct=correct,
        )
        return SimState(theta=theta_next, t=state.t + 1, key=key,
                        history=new_history), out

    # -- full horizon via scan ------------------------------------------------
    def run(self, key: jax.Array, xs: jax.Array, ys: jax.Array) -> RoundOutput:
        """Run T rounds. xs (T, m, n), ys (T, m). Returns stacked outputs."""
        state = self.init(key)

        def body(st, batch):
            st, out = self.round(st, batch)
            return st, out

        _, outs = jax.lax.scan(body, state, (xs, ys))
        return outs

    def final_params(self, key: jax.Array, xs: jax.Array, ys: jax.Array):
        """Like run() but also returns the final primal parameters (m, n)."""
        state = self.init(key)

        def body(st, batch):
            st, out = self.round(st, batch)
            return st, out

        state, outs = jax.lax.scan(body, state, (xs, ys))
        alpha_T = self.omd.alpha()(state.t)
        w = self._primal(state.theta, alpha_T, self.omd.lam_t(alpha_T), state.t)
        return w, outs
