"""Algorithm 1, faithful simulator (paper §II-D).

Runs m virtual data-center nodes inside one device via vectorized ops:
theta is an (m, n) matrix and the whole horizon runs under one lax.scan,
so a 100k-round x 64-node x 10k-dim simulation JITs into a single program.

The engine is a thin composition over the `repro.api` protocol stages —
Clipper -> Mechanism -> Mixer -> LocalRule — and contains no topology /
method / mechanism branching of its own: new scenarios register in the
`repro.api` registries (or are passed as instances, usually via
`repro.api.RunSpec.build_simulator`) and plug in without touching this
file. The distributed strategy (core/gossip.py) composes the SAME protocol
instances over node-stacked pytrees, which is what the cross-engine
equivalence tests rely on. The pre-registry constructor kwargs
(graph=/privacy=/method=) were removed; see README §Migrating.

Delayed (WAN) gossip: a mixer with ``delay > 0`` makes :class:`SimState`
carry a (delay+1, m, n) history ring of past theta~ broadcasts, rotated
each round with the same jit/scan-safe ring primitives the distributed
engine uses (`repro.api.mixers.ring_write` / `ring_read`); the equation-to-
code mapping lives in docs/algorithm.md and docs/delayed_gossip.md.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.api.clippers import Clipper, PerNodeL2Clipper
from repro.api.mechanisms import Mechanism
from repro.api.mixers import DelayedMixer, Mixer, ring_write
from repro.api.rules import LocalRule, OMDLassoRule, StepContext
from repro.core import prox
from repro.core.omd import OMDConfig

__all__ = ["Algorithm1", "SimState", "RoundOutput", "hinge_loss_and_grad"]


def hinge_loss_and_grad(w: jax.Array, x: jax.Array, y: jax.Array):
    """Paper's loss: f = [1 - y <w,x>]_+ ; subgradient -y x when margin<1.

    Shapes: w (m,n), x (m,n), y (m,) -> loss (m,), grad (m,n).
    """
    margin = y * jnp.einsum("mn,mn->m", w, x)
    loss = jnp.maximum(1.0 - margin, 0.0)
    active = (margin < 1.0).astype(w.dtype)
    grad = -(active * y)[:, None] * x
    return loss, grad


class SimState(NamedTuple):
    theta: jax.Array   # (m, n) dual parameters, one row per node
    t: jax.Array       # round counter
    key: jax.Array     # PRNG
    history: jax.Array | None = None  # (delay+1, m, n) ring of past theta~


class RoundOutput(NamedTuple):
    loss: jax.Array        # (m,) per-node losses this round
    w_bar_loss: jax.Array  # scalar: loss of the averaged parameter (Def. 3 regret uses it)
    sparsity: jax.Array    # scalar: zero-fraction of w across nodes
    correct: jax.Array     # (m,) prediction correctness (sign match)


@dataclasses.dataclass
class Algorithm1:
    """Private Distributed Online Learning (paper Algorithm 1).

    Protocol stages (see `repro.api`; usually built via RunSpec):
      mixer:      topology — applies the doubly-stochastic A(t).
      mechanism:  privacy — noise scale + sampler for the theta~ broadcast.
      local_rule: sparse update — primal recovery + dual step
                  ('omd' is the paper's; 'tg'/'rda' are the §I baselines).
      clipper:    enforces Assumption 2.3 (||g|| <= L) pre-noise.

    omd supplies the alpha_t / lambda_t schedules (Theorem 2) shared by all
    rules; n is the feature dimension; loss_and_grad defaults to the
    paper's hinge workload.

    delay: WAN staleness in rounds. Usually declared by the mixer itself
    (`DelayedMixer` / `HeterogeneousDelayMixer` / any mixer with a delay=
    option); the engine kwarg remains for direct construction and must
    agree with a delay-carrying mixer.
    """

    omd: OMDConfig
    n: int
    mixer: Mixer | None = None
    mechanism: Mechanism | None = None
    local_rule: LocalRule | None = None
    clipper: Clipper | None = None
    loss_and_grad: Callable = staticmethod(hinge_loss_and_grad)
    delay: int = 0

    def __post_init__(self):
        if self.mixer is None:
            raise ValueError("Algorithm1 needs mixer= (a repro.api Mixer)")
        if self.mechanism is None:
            raise ValueError("Algorithm1 needs mechanism= (a repro.api Mechanism)")
        if self.clipper is None:
            # default to the bound the mechanism's sensitivity is calibrated
            # against — a mismatch would silently void the DP guarantee
            self.clipper = PerNodeL2Clipper(
                max_norm=getattr(self.mechanism, "L", 1.0))
        if self.local_rule is None:
            self.local_rule = OMDLassoRule(prox_kind=self.omd.prox_kind)
        if self.delay < 0:
            raise ValueError("delay must be >= 0")
        # staleness can come from the engine kwarg or a delay-carrying mixer
        mixer_delay = int(getattr(self.mixer, "delay", 0))
        if self.delay and mixer_delay and self.delay != mixer_delay:
            raise ValueError(
                f"conflicting delays: Algorithm1(delay={self.delay}) but the "
                f"mixer already carries delay={mixer_delay}")
        if self.delay and not mixer_delay:
            # mix_history dispatches on the MIXER's delay, so a bare engine
            # kwarg must wrap the mixer or the run would silently stay
            # synchronous while paying for the ring
            self.mixer = DelayedMixer(inner=self.mixer, delay=self.delay)
        self.delay = max(self.delay, mixer_delay)

    @property
    def m(self) -> int:
        return self.mixer.m

    def init(self, key: jax.Array) -> SimState:
        m = self.m
        hist = (jnp.zeros((self.delay + 1, m, self.n), jnp.float32)
                if self.delay else None)
        return SimState(
            theta=jnp.zeros((m, self.n), jnp.float32),
            t=jnp.zeros((), jnp.int32),
            key=key,
            history=hist,
        )

    def _ctx(self, t: jax.Array) -> StepContext:
        return self.omd.step_context(t)

    # -- one round -----------------------------------------------------------
    def round(self, state: SimState, batch) -> tuple[SimState, RoundOutput]:
        """One synchronous round across all m nodes.

        batch: (x, y) with x (m, n), y (m,) — node i sees only row i
        (disjoint streams => parallel composition, Thm 1).
        """
        x, y = batch
        m = self.m
        ctx = self._ctx(state.t + 1)

        # Steps 6-7: primal recovery (the paper's rule = Lasso prox).
        w = self.local_rule.primal(state.theta, ctx)

        # Steps 8-9: predict, receive label, suffer loss.
        loss, grad = self.loss_and_grad(w, x, y)
        margin_sign = jnp.sign(jnp.einsum("mn,mn->m", w, x))
        correct = (margin_sign == y).astype(jnp.float32)

        # Clip to enforce Assumption 2.3 (||g|| <= L) — required for Lemma 1.
        grad, _ = self.clipper.clip(grad)

        # Step 11 (previous round's broadcast): perturb the egress copies.
        key, sub = jax.random.split(state.key)
        scale = self.mechanism.scale(ctx.alpha_t, self.n)
        delta = self.mechanism.sample(sub, (m, self.n), scale)
        theta_tilde = state.theta + delta

        # Step 10: gossip mixing with doubly-stochastic A(t).
        new_history = state.history
        if self.delay:
            # WAN staleness: neighbor terms are read from the history ring
            # (theta~ from `delay` rounds ago; own state stays current).
            new_history = ring_write(state.history, state.t, theta_tilde)
            mixed = self.mixer.mix_history(state.theta, theta_tilde,
                                           new_history,
                                           self.mechanism.noise_self, state.t)
        else:
            mixed = self.mixer.mix(state.theta, theta_tilde,
                                   self.mechanism.noise_self, state.t)
        theta_next = self.local_rule.dual_step(mixed, grad, ctx)

        # Fault injection (repro.faults): a crashed node freezes its local
        # update and rejoins from this very state once its window ends. The
        # branch is python-static — specs without crash windows pay nothing.
        fault_sched = getattr(self.mixer, "schedule", None)
        if fault_sched is not None and fault_sched.has_crashes:
            alive = fault_sched.alive_mask(state.t)
            theta_next = jnp.where(alive[:, None], theta_next, state.theta)

        # Definition 3 regret is w.r.t. the average parameter w_bar. The
        # margin is an explicit multiply+reduce (not a matvec einsum) so the
        # op lowers identically with or without a leading vmapped seed axis —
        # run_batch's seed-vmap equivalence holds this metric to the bit.
        w_bar = jnp.mean(w, axis=0, keepdims=True)
        wb_loss = jnp.mean(
            jnp.maximum(1.0 - y * jnp.sum(w_bar * x, axis=-1), 0.0)
        )

        out = RoundOutput(
            loss=loss,
            w_bar_loss=wb_loss,
            sparsity=prox.sparsity(w),
            correct=correct,
        )
        return SimState(theta=theta_next, t=state.t + 1, key=key,
                        history=new_history), out

    # -- full horizon via scan ------------------------------------------------
    def run(self, key: jax.Array, xs: jax.Array, ys: jax.Array) -> RoundOutput:
        """Run T rounds. xs (T, m, n), ys (T, m). Returns stacked outputs."""
        state = self.init(key)

        def body(st, batch):
            st, out = self.round(st, batch)
            return st, out

        _, outs = jax.lax.scan(body, state, (xs, ys))
        return outs

    def final_params(self, key: jax.Array, xs: jax.Array, ys: jax.Array):
        """Like run() but also returns the final primal parameters (m, n)."""
        state = self.init(key)

        def body(st, batch):
            st, out = self.round(st, batch)
            return st, out

        state, outs = jax.lax.scan(body, state, (xs, ys))
        w = self.local_rule.primal(state.theta, self._ctx(state.t))
        return w, outs
