"""GossipDP — the paper's Algorithm 1 as a production distribution strategy.

Node-parallel formulation
-------------------------
Every parameter leaf carries a leading **node axis** of size ``m`` (the number
of gossip "data centers"), sharded over a mesh axis ("data" on the single-pod
mesh; "pod" on the multi-pod mesh, where each pod is one data center and
within-pod data parallelism is ordinary all-reduce handled by GSPMD).

The engine is a thin composition over the SAME `repro.api` protocol stages
as the dense simulator — Clipper -> Mechanism -> Mixer -> LocalRule applied
per node-stacked leaf — and contains no topology / mechanism / method
branching of its own. Roll-based mixers (`RingRollMixer`,
`AlternatingRingMixer`) express the exchange as ``jnp.roll`` along the node
axis: under GSPMD a roll of a sharded axis lowers to ``collective-permute``
— the neighbor exchange of the paper's communication graph mapped onto the
physical ICI ring, with no all-reduce for theta (verifiable in the dry-run
HLO, see EXPERIMENTS.md §Dry-run). Dense-matrix mixers also work (they
tensordot the node axis) for arbitrary topologies, at all-gather cost.

Memory note: node-parallel params cost the same per chip as replicated data
parallelism (replication redundancy is repurposed as per-node state), but the
technique precludes ZeRO-style optimizer-state sharding — each node owns its
theta. Recorded as a finding in EXPERIMENTS.md.

The legacy constructor (gossip=GossipConfig(...), privacy=PrivacyConfig(...))
still works for one release and maps onto the protocol stages with a
DeprecationWarning; build new code through `repro.api.RunSpec`.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.api.clippers import Clipper, PerNodeL2Clipper
from repro.api.mechanisms import LaplaceMechanism, Mechanism
from repro.api.mixers import Mixer
from repro.api.registry import MIXERS
from repro.api.rules import LocalRule, OMDLassoRule, StepContext
from repro.core import prox
from repro.core.omd import OMDConfig
from repro.core.privacy import PrivacyConfig

__all__ = ["GossipConfig", "GossipState", "GossipDP", "gossip_mix_tree",
           "per_node_clip"]

# Legacy names restricted to the shard-friendly (roll/mean based) mixers —
# no dense matrix, so the node axis never needs an all-gather.
DISTRIBUTED_TOPOLOGIES = ("ring", "complete", "disconnected", "ring_alternating")


@dataclasses.dataclass(frozen=True)
class GossipConfig:
    """DEPRECATED distributed gossip knobs — use `repro.api.RunSpec` /
    `MIXERS` registry names instead. Retained for one release.

    topology:    one of DISTRIBUTED_TOPOLOGIES (legacy names; each maps to a
                 `repro.api.mixers` class via ``to_mixer``).
    self_weight: a_ii for the ring ((1-a_ii)/2 per neighbor).
    nodes:       m — must equal the mesh axis size the node dim is sharded on.
    """

    topology: str = "ring"
    self_weight: float = 0.5
    nodes: int = 16

    def __post_init__(self):
        if self.topology not in DISTRIBUTED_TOPOLOGIES:
            raise ValueError(f"topology {self.topology!r} not in {DISTRIBUTED_TOPOLOGIES}")

    def to_mixer(self) -> Mixer:
        return MIXERS.build(self.topology, m=self.nodes,
                            self_weight=self.self_weight)  # injected: non-ring
                                                           # mixers ignore it


class GossipState(NamedTuple):
    theta: Any          # pytree; every leaf (m, ...) float32
    t: jax.Array        # round counter
    key: jax.Array      # PRNG key for the Laplace mechanism


def gossip_mix_tree(theta: Any, key: jax.Array, noise_scale: jax.Array,
                    mixer: Mixer | GossipConfig, noise_self: bool = True,
                    t: jax.Array = 0, mechanism: Mechanism | None = None) -> Any:
    """Noise + mix every (m, ...) leaf. Returns the post-mixing theta pytree.

    ``mixer`` may be a `repro.api` Mixer or a legacy GossipConfig. When a
    ``mechanism`` is given, its own ``noise_self`` wins (the positional flag
    exists for the legacy mechanism-less call style and must not contradict
    an explicit mechanism); otherwise the Laplace sampler at ``noise_scale``
    is used with the flag as passed.
    """
    if isinstance(mixer, GossipConfig):
        mixer = mixer.to_mixer()
    if mechanism is not None:
        mech, noise_self = mechanism, mechanism.noise_self
    else:
        mech = LaplaceMechanism(noise_self=noise_self)
    leaves, treedef = jax.tree_util.tree_flatten(theta)
    keys = jax.random.split(key, len(leaves))
    mixed = []
    for k, leaf in zip(keys, leaves):
        delta = mech.sample(k, leaf.shape, noise_scale, leaf.dtype)
        mixed.append(mixer.mix(leaf, leaf + delta, noise_self, t))
    return jax.tree_util.tree_unflatten(treedef, mixed)


def per_node_clip(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    """Clip each node's gradient slice (axis 0) to L2 norm <= max_norm.

    Enforces Assumption 2.3 per node. Returns (clipped, (m,) pre-clip norms).
    Thin wrapper over `repro.api.PerNodeL2Clipper` (kept as a public name).
    """
    return PerNodeL2Clipper(max_norm=max_norm).clip(grads)


@dataclasses.dataclass(frozen=True)
class GossipDP:
    """The full per-round update: clip -> noise -> gossip-mix -> local rule.

    Works on node-stacked pytrees; pure function of state so it jits/lowers
    under any mesh. The training driver computes per-node grads (vmapped
    model) and calls :meth:`update`. Protocol stages come from `repro.api`
    (usually via ``RunSpec.build_distributed()``); the legacy
    gossip=/privacy= kwargs still resolve to them for one release.
    """

    omd: OMDConfig
    mixer: Mixer | None = None
    mechanism: Mechanism | None = None
    local_rule: LocalRule | None = None
    clipper: Clipper | None = None
    # -- deprecated legacy surface ------------------------------------------
    gossip: GossipConfig | None = None
    privacy: PrivacyConfig | None = None

    def __post_init__(self):
        legacy = [k for k, v in (("gossip", self.gossip),
                                 ("privacy", self.privacy)) if v is not None]
        if legacy:
            warnings.warn(
                f"GossipDP({', '.join(legacy)}=...) is deprecated; build "
                "protocol stages via repro.api.RunSpec instead",
                DeprecationWarning, stacklevel=3)
        set_ = lambda k, v: object.__setattr__(self, k, v)
        if self.mixer is None:
            if self.gossip is None:
                raise ValueError("GossipDP needs mixer= (or legacy gossip=)")
            set_("mixer", self.gossip.to_mixer())
        if self.mechanism is None:
            if self.privacy is None:
                raise ValueError("GossipDP needs mechanism= (or legacy privacy=)")
            set_("mechanism", LaplaceMechanism(
                eps=self.privacy.eps, L=self.privacy.L,
                calibration=self.privacy.clip_style,
                noise_self=self.privacy.noise_self))
        if self.clipper is None:
            # default to the bound the mechanism's sensitivity is calibrated
            # against — a mismatch would silently void the DP guarantee
            set_("clipper", PerNodeL2Clipper(
                max_norm=getattr(self.mechanism, "L", 1.0)))
        if self.local_rule is None:
            set_("local_rule", OMDLassoRule(prox_kind=self.omd.prox_kind))
        if getattr(self.mixer, "delay", 0):
            raise ValueError(
                "delayed mixing is simulator-only for now — GossipState has "
                "no history buffer; use Algorithm1 / RunSpec.build_simulator")

    def init(self, node_params: Any, key: jax.Array) -> GossipState:
        theta = jax.tree_util.tree_map(
            lambda p: self.local_rule.init_state(p.astype(jnp.float32)),
            node_params)
        return GossipState(theta=theta, t=jnp.zeros((), jnp.int32), key=key)

    def param_count_per_node(self, theta: Any) -> int:
        return sum(
            int(l.size // l.shape[0]) for l in jax.tree_util.tree_leaves(theta)
        )

    def _ctx(self, t: jax.Array) -> StepContext:
        return self.omd.step_context(t)

    def primal(self, state: GossipState) -> Any:
        """w_t from theta_t (steps 6-7) via the local rule, per leaf."""
        ctx = self._ctx(state.t + 1)
        return jax.tree_util.tree_map(
            lambda th: self.local_rule.primal(th, ctx), state.theta)

    def update(self, state: GossipState, grads: Any) -> tuple[GossipState, dict]:
        """Steps 10-11 for every node at once."""
        ctx = self._ctx(state.t + 1)
        grads, gnorms = self.clipper.clip(grads)

        n = self.param_count_per_node(state.theta)
        scale = self.mechanism.scale(ctx.alpha_t, n)

        key, sub = jax.random.split(state.key)
        mixed = gossip_mix_tree(state.theta, sub, scale, self.mixer,
                                t=state.t, mechanism=self.mechanism)
        theta_next = jax.tree_util.tree_map(
            lambda th, g: self.local_rule.dual_step(th, g, ctx), mixed, grads)
        new_state = GossipState(theta=theta_next, t=state.t + 1, key=key)
        metrics = {
            "alpha_t": ctx.alpha_t,
            "noise_scale": scale,
            "grad_norm_mean": jnp.mean(gnorms),
            "theta_sparsity": prox.sparsity_tree(self.primal(new_state)),
        }
        return new_state, metrics
