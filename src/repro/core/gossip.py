"""GossipDP — the paper's Algorithm 1 as a production distribution strategy.

Node-parallel formulation
-------------------------
Every parameter leaf carries a leading **node axis** of size ``m`` (the number
of gossip "data centers"), sharded over a mesh axis ("data" on the single-pod
mesh; "pod" on the multi-pod mesh, where each pod is one data center and
within-pod data parallelism is ordinary all-reduce handled by GSPMD).

The engine is a thin composition over the SAME `repro.api` protocol stages
as the dense simulator — Clipper -> Mechanism -> Mixer -> LocalRule applied
per node-stacked leaf — and contains no topology / mechanism / method
branching of its own. Stages are protocol instances built through the
`repro.api` registries, usually via ``RunSpec.build_distributed()``; the
pre-registry string/config constructor kwargs were removed (see README
§Migrating). Roll-based mixers (`RingRollMixer`, `AlternatingRingMixer`)
express the exchange as ``jnp.roll`` along the node axis: under GSPMD a roll
of a sharded axis lowers to ``collective-permute`` — the neighbor exchange
of the paper's communication graph mapped onto the physical ICI ring, with
no all-reduce for theta (verifiable in the dry-run HLO, see EXPERIMENTS.md
§Dry-run). Dense-matrix mixers also work (they tensordot the node axis) for
arbitrary topologies, at all-gather cost.

Delayed (WAN) gossip: when the installed mixer declares ``delay > 0``
(`DelayedMixer`, `HeterogeneousDelayMixer`, or any mixer built with a
``delay=`` option), :class:`GossipState` carries a fixed-depth parameter
**history ring** — every theta leaf gains a stacked leading axis of
``delay + 1`` past broadcast copies, rotated each round with jit/scan-safe
dynamic indexing — and the update mixes against views from ``delay`` rounds
ago. Memory cost is O(delay x params) per node; see docs/delayed_gossip.md.

Memory note: node-parallel params cost the same per chip as replicated data
parallelism (replication redundancy is repurposed as per-node state), but the
technique precludes ZeRO-style optimizer-state sharding — each node owns its
theta. Recorded as a finding in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.api.clippers import Clipper, PerNodeL2Clipper
from repro.api.mechanisms import LaplaceMechanism, Mechanism
from repro.api.mixers import Mixer, ring_write
from repro.api.rules import LocalRule, OMDLassoRule, StepContext
from repro.core import prox
from repro.core.omd import OMDConfig

__all__ = ["GossipState", "GossipDP", "gossip_mix_tree", "per_node_clip"]


class GossipState(NamedTuple):
    theta: Any          # pytree; every leaf (m, ...) float32
    t: jax.Array        # round counter
    key: jax.Array      # PRNG key for the privacy mechanism
    history: Any = None  # pytree like theta with leaves (delay+1, m, ...)
    #                      — ring of past theta~ broadcasts; None when the
    #                      mixer is synchronous (delay == 0)


def gossip_mix_tree(theta: Any, key: jax.Array, noise_scale: jax.Array,
                    mixer: Mixer, noise_self: bool = True,
                    t: jax.Array = 0, mechanism: Mechanism | None = None,
                    history: Any = None) -> Any:
    """Noise + mix every (m, ...) leaf of a node-stacked pytree.

    When a ``mechanism`` is given, its own ``noise_self`` wins (the
    positional flag exists for the mechanism-less call style and must not
    contradict an explicit mechanism); otherwise the Laplace sampler at
    ``noise_scale`` is used with the flag as passed.

    ``history`` is the per-leaf ring of past broadcasts (leaves
    (delay+1, m, ...)). When given, each leaf's current theta~ is written
    into its ring slot and the mixer's :meth:`Mixer.mix_history` consumes
    the updated ring; the return value is then ``(mixed, new_history)``.
    Without it the mix is synchronous and only the mixed pytree is returned.
    """
    if mechanism is not None:
        mech, noise_self = mechanism, mechanism.noise_self
    else:
        mech = LaplaceMechanism(noise_self=noise_self)
    leaves, treedef = jax.tree_util.tree_flatten(theta)
    hist_leaves = (jax.tree_util.tree_leaves(history)
                   if history is not None else [None] * len(leaves))
    # single-leaf trees consume `key` directly (split(key, 1)[0] != key):
    # the dense simulator samples its one (m, n) matrix straight from the
    # per-round key, so this keeps the two engines' noise streams — and
    # therefore their iterates — bit-identical for the linear workload
    keys = jax.random.split(key, len(leaves)) if len(leaves) > 1 else [key]
    mixed, new_hist = [], []
    for k, leaf, hist in zip(keys, leaves, hist_leaves):
        delta = mech.sample(k, leaf.shape, noise_scale, leaf.dtype)
        tilde = leaf + delta
        if hist is None:
            # mix_history == mix for synchronous mixers, and raises for a
            # delay-carrying mixer whose ring the caller forgot to pass —
            # a bare mix() here would silently drop the declared staleness
            mixed.append(mixer.mix_history(leaf, tilde, None, noise_self, t))
        else:
            hist = ring_write(hist, t, tilde)
            new_hist.append(hist)
            mixed.append(mixer.mix_history(leaf, tilde, hist, noise_self, t))
    mixed = jax.tree_util.tree_unflatten(treedef, mixed)
    if history is None:
        return mixed
    return mixed, jax.tree_util.tree_unflatten(treedef, new_hist)


def per_node_clip(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    """Clip each node's gradient slice (axis 0) to L2 norm <= max_norm.

    Enforces Assumption 2.3 per node. Returns (clipped, (m,) pre-clip norms).
    Thin wrapper over `repro.api.PerNodeL2Clipper` (kept as a public name).
    """
    return PerNodeL2Clipper(max_norm=max_norm).clip(grads)


@dataclasses.dataclass(frozen=True)
class GossipDP:
    """The full per-round update: clip -> noise -> gossip-mix -> local rule.

    Works on node-stacked pytrees; pure function of state so it jits/lowers
    under any mesh. The training driver computes per-node grads (vmapped
    model) and calls :meth:`update`. Protocol stages come from `repro.api`,
    usually via ``RunSpec.build_distributed()``.
    """

    omd: OMDConfig
    mixer: Mixer | None = None
    mechanism: Mechanism | None = None
    local_rule: LocalRule | None = None
    clipper: Clipper | None = None

    def __post_init__(self):
        if self.mixer is None:
            raise ValueError("GossipDP needs mixer= (a repro.api Mixer)")
        if self.mechanism is None:
            raise ValueError("GossipDP needs mechanism= (a repro.api Mechanism)")
        set_ = lambda k, v: object.__setattr__(self, k, v)
        if self.clipper is None:
            # default to the bound the mechanism's sensitivity is calibrated
            # against — a mismatch would silently void the DP guarantee
            set_("clipper", PerNodeL2Clipper(
                max_norm=getattr(self.mechanism, "L", 1.0)))
        if self.local_rule is None:
            set_("local_rule", OMDLassoRule(prox_kind=self.omd.prox_kind))

    @property
    def delay(self) -> int:
        """Staleness depth declared by the mixer (0 = synchronous)."""
        return int(getattr(self.mixer, "delay", 0))

    def init(self, node_params: Any, key: jax.Array) -> GossipState:
        theta = jax.tree_util.tree_map(
            lambda p: self.local_rule.init_state(p.astype(jnp.float32)),
            node_params)
        history = None
        if self.delay:
            depth = self.delay + 1
            history = jax.tree_util.tree_map(
                lambda th: jnp.zeros((depth,) + th.shape, th.dtype), theta)
        return GossipState(theta=theta, t=jnp.zeros((), jnp.int32), key=key,
                           history=history)

    def param_count_per_node(self, theta: Any) -> int:
        return sum(
            int(l.size // l.shape[0]) for l in jax.tree_util.tree_leaves(theta)
        )

    def _ctx(self, t: jax.Array) -> StepContext:
        return self.omd.step_context(t)

    def primal(self, state: GossipState) -> Any:
        """w_t from theta_t (steps 6-7) via the local rule, per leaf."""
        ctx = self._ctx(state.t + 1)
        return jax.tree_util.tree_map(
            lambda th: self.local_rule.primal(th, ctx), state.theta)

    def update(self, state: GossipState, grads: Any) -> tuple[GossipState, dict]:
        """Steps 10-11 for every node at once."""
        ctx = self._ctx(state.t + 1)
        grads, gnorms = self.clipper.clip(grads)

        n = self.param_count_per_node(state.theta)
        scale = self.mechanism.scale(ctx.alpha_t, n)

        key, sub = jax.random.split(state.key)
        new_history = state.history
        if self.delay:
            mixed, new_history = gossip_mix_tree(
                state.theta, sub, scale, self.mixer, t=state.t,
                mechanism=self.mechanism, history=state.history)
        else:
            mixed = gossip_mix_tree(state.theta, sub, scale, self.mixer,
                                    t=state.t, mechanism=self.mechanism)
        theta_next = jax.tree_util.tree_map(
            lambda th, g: self.local_rule.dual_step(th, g, ctx), mixed, grads)
        # Fault injection (repro.faults): crashed nodes freeze every leaf of
        # their local state until the crash window ends (python-static check).
        fault_sched = getattr(self.mixer, "schedule", None)
        if fault_sched is not None and fault_sched.has_crashes:
            alive = fault_sched.alive_mask(state.t)
            theta_next = jax.tree_util.tree_map(
                lambda nxt, cur: jnp.where(
                    alive.reshape((-1,) + (1,) * (nxt.ndim - 1)), nxt, cur),
                theta_next, state.theta)
        new_state = GossipState(theta=theta_next, t=state.t + 1, key=key,
                                history=new_history)
        metrics = {
            "alpha_t": ctx.alpha_t,
            "noise_scale": scale,
            "grad_norm_mean": jnp.mean(gnorms),
            "theta_sparsity": prox.sparsity_tree(self.primal(new_state)),
        }
        return new_state, metrics
