"""GossipDP — the paper's Algorithm 1 as a production distribution strategy.

Node-parallel formulation
-------------------------
Every parameter leaf carries a leading **node axis** of size ``m`` (the number
of gossip "data centers"), sharded over a mesh axis ("data" on the single-pod
mesh; "pod" on the multi-pod mesh, where each pod is one data center and
within-pod data parallelism is ordinary all-reduce handled by GSPMD).

Gossip mixing is expressed as ``jnp.roll`` along the node axis: under GSPMD,
a roll of a sharded axis lowers to ``collective-permute`` — the neighbor
exchange of the paper's communication graph mapped onto the physical ICI
ring. No all-reduce is issued for theta; this is verifiable in the dry-run
HLO (see EXPERIMENTS.md §Dry-run) and is exactly the paper's "communicate
with adjacent data centers only" constraint.

Memory note: node-parallel params cost the same per chip as replicated data
parallelism (replication redundancy is repurposed as per-node state), but the
technique precludes ZeRO-style optimizer-state sharding — each node owns its
theta. Recorded as a finding in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import prox
from repro.core.omd import OMDConfig
from repro.core.privacy import PrivacyConfig, sample_laplace

__all__ = ["GossipConfig", "GossipState", "GossipDP", "gossip_mix_tree", "per_node_clip"]

DISTRIBUTED_TOPOLOGIES = ("ring", "complete", "disconnected", "ring_alternating")


@dataclasses.dataclass(frozen=True)
class GossipConfig:
    """Distributed gossip knobs.

    topology:    one of DISTRIBUTED_TOPOLOGIES. 'ring' is the TPU-native
                 default (ICI neighbors). 'complete' degenerates to the
                 all-reduce average (useful as the "classic DP" baseline with
                 noise). 'ring_alternating' is the time-varying graph.
    self_weight: a_ii for the ring ((1-a_ii)/2 per neighbor).
    nodes:       m — must equal the mesh axis size the node dim is sharded on.
    """

    topology: str = "ring"
    self_weight: float = 0.5
    nodes: int = 16

    def __post_init__(self):
        if self.topology not in DISTRIBUTED_TOPOLOGIES:
            raise ValueError(f"topology {self.topology!r} not in {DISTRIBUTED_TOPOLOGIES}")


class GossipState(NamedTuple):
    theta: Any          # pytree; every leaf (m, ...) float32
    t: jax.Array        # round counter
    key: jax.Array      # PRNG key for the Laplace mechanism


def _leaf_mix(leaf: jax.Array, tilde: jax.Array, cfg: GossipConfig,
              noise_self: bool, t: jax.Array) -> jax.Array:
    """Mix one (m, ...) leaf according to the topology.

    ``leaf`` is the clean theta, ``tilde`` the noised broadcast copy. With
    the faithful ``noise_self=True`` the self-term also uses ``tilde``
    (Algorithm 1 line 10 sums a_ij * theta~ over ALL j).
    """
    self_term = tilde if noise_self else leaf
    if cfg.topology == "disconnected":
        return leaf
    if cfg.topology == "complete":
        m = cfg.nodes
        mean_tilde = jnp.mean(tilde, axis=0, keepdims=True)
        mixed = jnp.broadcast_to(mean_tilde, tilde.shape)
        if not noise_self:
            mixed = mixed + (leaf - tilde) / m
        return mixed
    if cfg.topology == "ring":
        sw = cfg.self_weight
        nw = (1.0 - sw) / 2.0
        return (
            sw * self_term
            + nw * jnp.roll(tilde, 1, axis=0)
            + nw * jnp.roll(tilde, -1, axis=0)
        )
    if cfg.topology == "ring_alternating":
        # time-varying: even rounds exchange with +1 neighbor, odd with -1;
        # each round's matrix is a circulant with (1/2, 1/2) — doubly stochastic.
        fwd = 0.5 * self_term + 0.5 * jnp.roll(tilde, 1, axis=0)
        bwd = 0.5 * self_term + 0.5 * jnp.roll(tilde, -1, axis=0)
        return jnp.where((t % 2) == 0, fwd, bwd)
    raise AssertionError(cfg.topology)


def gossip_mix_tree(theta: Any, key: jax.Array, noise_scale: jax.Array,
                    cfg: GossipConfig, noise_self: bool, t: jax.Array) -> Any:
    """Noise + mix every leaf. Returns the post-mixing theta pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(theta)
    keys = jax.random.split(key, len(leaves))
    mixed = []
    for k, leaf in zip(keys, leaves):
        delta = sample_laplace(k, leaf.shape, noise_scale, leaf.dtype)
        mixed.append(_leaf_mix(leaf, leaf + delta, cfg, noise_self, t))
    return jax.tree_util.tree_unflatten(treedef, mixed)


def per_node_clip(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    """Clip each node's gradient slice (axis 0) to L2 norm <= max_norm.

    Enforces Assumption 2.3 per node. Returns (clipped, (m,) pre-clip norms).
    """
    leaves = jax.tree_util.tree_leaves(grads)
    sq = sum(
        jnp.sum(jnp.square(l.astype(jnp.float32)), axis=tuple(range(1, l.ndim)))
        for l in leaves
    )
    norms = jnp.sqrt(sq)  # (m,)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norms, 1e-12))

    def scale(l):
        f = factor.reshape((-1,) + (1,) * (l.ndim - 1))
        return (l * f).astype(l.dtype)

    return jax.tree_util.tree_map(scale, grads), norms


@dataclasses.dataclass(frozen=True)
class GossipDP:
    """The full per-round update: clip -> noise -> gossip-mix -> OMD -> prox.

    Works on node-stacked pytrees; pure function of state so it jits/lowers
    under any mesh. The training driver computes per-node grads (vmapped
    model) and calls :meth:`update`.
    """

    gossip: GossipConfig
    omd: OMDConfig
    privacy: PrivacyConfig

    def init(self, node_params: Any, key: jax.Array) -> GossipState:
        theta = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), node_params)
        return GossipState(theta=theta, t=jnp.zeros((), jnp.int32), key=key)

    def param_count_per_node(self, theta: Any) -> int:
        return sum(
            int(l.size // l.shape[0]) for l in jax.tree_util.tree_leaves(theta)
        )

    def primal(self, state: GossipState) -> Any:
        """w_t from theta_t (steps 6-7): identity mirror map + L1 prox."""
        alpha_t = self.omd.alpha()(state.t + 1)
        lam_t = self.omd.lam_t(alpha_t)
        if self.omd.prox_kind == "none":
            return state.theta
        return prox.soft_threshold_tree(state.theta, lam_t)

    def update(self, state: GossipState, grads: Any) -> tuple[GossipState, dict]:
        """Steps 10-11 for every node at once."""
        alpha_t = self.omd.alpha()(state.t + 1)
        grads, gnorms = per_node_clip(grads, self.privacy.L)

        n = self.param_count_per_node(state.theta)
        scale = self.privacy.scale_for(alpha_t, n)

        key, sub = jax.random.split(state.key)
        mixed = gossip_mix_tree(
            state.theta, sub, scale, self.gossip, self.privacy.noise_self, state.t
        )
        theta_next = jax.tree_util.tree_map(
            lambda th, g: th - alpha_t * g.astype(th.dtype), mixed, grads
        )
        new_state = GossipState(theta=theta_next, t=state.t + 1, key=key)
        metrics = {
            "alpha_t": alpha_t,
            "noise_scale": scale,
            "grad_norm_mean": jnp.mean(gnorms),
            "theta_sparsity": prox.sparsity_tree(self.primal(new_state)),
        }
        return new_state, metrics
