"""Online Mirror Descent with composite L1 term — the paper's local update.

Structured like optax (init/update pair) so it composes with the rest of the
framework's optimizers: the GossipDP strategy wraps ANY LocalOptimizer whose
state carries the dual parameter theta, but the paper's instance is this OMD.

Per Algorithm 1 (node-local part, steps 6-10):
    p_t   = grad phi*(theta_t)            # identity for phi = 1/2||.||^2
    w_t   = soft_threshold(p_t, lambda_t) # Lasso prox
    g_t   = grad f_t(w_t)
    theta_{t+1} = mix(theta~_t) - alpha_t * g_t

The *mixing* lives in core/gossip.py (distributed) / core/algorithm1.py
(simulator); this module provides the pure local math plus the step-size
schedules alpha_t, lambda_t = alpha_t * lambda from Theorem 2.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import prox

__all__ = ["OMDConfig", "OMDState", "omd_primal", "omd_dual_step", "alpha_schedule", "OnlineMirrorDescent"]

Schedule = Callable[[jax.Array], jax.Array]


def alpha_schedule(kind: str, alpha0: float, T: int | None = None) -> Schedule:
    """Step-size schedules.

    'theorem2'  : constant alpha = alpha0 / sqrt(T)  — the paper's Theorem 2
                  choice  alpha_t = ||w||_2 / (2 sqrt((L+lambda) m T L))
                  folded into alpha0 (caller computes the constant).
    'sqrt_t'    : alpha_t = alpha0 / sqrt(t)         — anytime variant.
    'constant'  : alpha_t = alpha0.
    """
    if kind == "theorem2":
        if T is None:
            raise ValueError("theorem2 schedule needs horizon T")
        a = alpha0 / math.sqrt(T)
        return lambda t: jnp.full((), a, jnp.float32)
    if kind == "sqrt_t":
        return lambda t: alpha0 / jnp.sqrt(jnp.maximum(t.astype(jnp.float32), 1.0))
    if kind == "constant":
        return lambda t: jnp.full((), alpha0, jnp.float32)
    raise ValueError(f"unknown schedule {kind!r}")


@dataclasses.dataclass(frozen=True)
class OMDConfig:
    """Local-optimizer knobs (paper Theorem 2 defaults)."""

    alpha0: float = 0.1
    schedule: str = "sqrt_t"
    lam: float = 0.01          # lambda; lambda_t = alpha_t * lambda (Thm 2)
    T: int | None = None       # horizon, needed by 'theorem2'
    prox_kind: str = "l1"      # 'l1' | 'none' | 'group'

    def alpha(self) -> Schedule:
        return alpha_schedule(self.schedule, self.alpha0, self.T)

    def lam_t(self, alpha_t: jax.Array) -> jax.Array:
        return alpha_t * self.lam

    def step_context(self, t: jax.Array):
        """Schedule values for 1-based round t, shared by both engines
        (the Theorem-2 coupling lam_t = alpha_t * lam lives only here)."""
        from repro.api.rules import StepContext
        alpha_t = self.alpha()(t)
        return StepContext(t=t, alpha_t=alpha_t, lam_t=self.lam_t(alpha_t),
                           lam=self.lam)


class OMDState(NamedTuple):
    theta: Any        # dual parameter pytree (same structure as params)
    t: jax.Array      # round counter (int32 scalar)


def omd_primal(theta: Any, lam_t, prox_kind: str = "l1") -> Any:
    """Steps 6-7: primal recovery w = prox_{lam ||.||_1}(grad phi*(theta))."""
    p = jax.tree_util.tree_map(prox.l2_mirror_map, theta)
    if prox_kind == "none":
        return p
    if prox_kind == "l1":
        return prox.soft_threshold_tree(p, lam_t)
    if prox_kind == "group":
        return jax.tree_util.tree_map(lambda x: prox.group_soft_threshold(x, lam_t), p)
    raise ValueError(prox_kind)


def omd_dual_step(theta_mixed: Any, grads: Any, alpha_t) -> Any:
    """Step 10 minus the mixing: theta' = theta_mixed - alpha_t * g."""
    return jax.tree_util.tree_map(
        lambda th, g: (th - alpha_t * g.astype(th.dtype)).astype(th.dtype), theta_mixed, grads
    )


class OnlineMirrorDescent:
    """optax-style wrapper: init(params) -> state; the gossip strategy calls
    primal()/dual_step() around its own mixing+noise stage."""

    def __init__(self, config: OMDConfig):
        self.config = config
        self._alpha = config.alpha()

    def init(self, params: Any) -> OMDState:
        theta = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
        return OMDState(theta=theta, t=jnp.zeros((), jnp.int32))

    def alpha_t(self, state: OMDState) -> jax.Array:
        return self._alpha(state.t + 1)

    def primal(self, state: OMDState) -> Any:
        a = self.alpha_t(state)
        return omd_primal(state.theta, self.config.lam_t(a), self.config.prox_kind)

    def dual_step(self, state: OMDState, theta_mixed: Any, grads: Any) -> OMDState:
        a = self.alpha_t(state)
        theta = omd_dual_step(theta_mixed, grads, a)
        return OMDState(theta=theta, t=state.t + 1)
