"""Communication graphs for gossip learning (paper §II-A).

The paper requires a doubly-stochastic mixing matrix A (Assumption 1):
  (1) a_ij > 0 on edges, (2) rows and columns sum to 1, (3) positive entries >= eta.

We provide the standard topologies used in the paper's Fig. 3 (topology-invariance
experiment) plus the TPU-native ring/torus that the distributed ppermute strategy
uses. Every constructor returns a dense (m, m) float32 matrix satisfying
Assumption 1; `assert_doubly_stochastic` verifies it.

Time-varying graphs (paper allows A(t)) are modelled as a finite cycle of
matrices indexed by ``t % len(schedule)``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

__all__ = [
    "ring_matrix",
    "torus_matrix",
    "complete_matrix",
    "hypercube_matrix",
    "random_regular_matrix",
    "disconnected_matrix",
    "metropolis_hastings",
    "time_varying_schedule",
    "assert_doubly_stochastic",
    "spectral_gap",
    "GossipGraph",
    "SparseGraph",
    "ring_edges",
    "torus_edges",
    "ring_neighbor_weights",
    "torus_neighbor_weights",
]


def assert_doubly_stochastic(A: np.ndarray, eta: float = 1e-6, atol: float = 1e-6) -> None:
    """Check the paper's Assumption 1 on a mixing matrix."""
    A = np.asarray(A)
    if A.ndim != 2 or A.shape[0] != A.shape[1]:
        raise ValueError(f"A must be square, got {A.shape}")
    if np.any(A < -atol):
        raise ValueError("A has negative entries")
    rows = A.sum(axis=1)
    cols = A.sum(axis=0)
    if not np.allclose(rows, 1.0, atol=atol):
        raise ValueError(f"rows do not sum to 1: {rows}")
    if not np.allclose(cols, 1.0, atol=atol):
        raise ValueError(f"cols do not sum to 1: {cols}")
    pos = A[A > atol]
    if pos.size and pos.min() < eta - atol:
        raise ValueError(f"positive entries below eta={eta}: min={pos.min()}")


def ring_matrix(m: int, self_weight: float = 0.5) -> np.ndarray:
    """Bidirectional ring: each node mixes with its two ring neighbors.

    Doubly stochastic by symmetry. ``self_weight`` in (0, 1); the remainder is
    split equally between the two neighbors. m == 1 and m == 2 degenerate
    gracefully.
    """
    if m == 1:
        return np.ones((1, 1), dtype=np.float32)
    A = np.zeros((m, m), dtype=np.float64)
    nbr = (1.0 - self_weight) / 2.0
    for i in range(m):
        A[i, i] += self_weight
        A[i, (i - 1) % m] += nbr
        A[i, (i + 1) % m] += nbr
    return A.astype(np.float32)


def torus_matrix(rows: int, cols: int, self_weight: float = 1.0 / 3.0) -> np.ndarray:
    """2D torus (the physical TPU ICI topology): 4 neighbors per node."""
    m = rows * cols
    if m == 1:
        return np.ones((1, 1), dtype=np.float32)
    A = np.zeros((m, m), dtype=np.float64)
    nbr = (1.0 - self_weight) / 4.0

    def idx(r: int, c: int) -> int:
        return (r % rows) * cols + (c % cols)

    for r in range(rows):
        for c in range(cols):
            i = idx(r, c)
            A[i, i] += self_weight
            for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                A[i, idx(r + dr, c + dc)] += nbr
    return A.astype(np.float32)


def complete_matrix(m: int) -> np.ndarray:
    """Fully connected: exact consensus every round (upper bound on mixing)."""
    return np.full((m, m), 1.0 / m, dtype=np.float32)


def hypercube_matrix(m: int, self_weight: float = 0.5) -> np.ndarray:
    """Hypercube graph; m must be a power of two. log2(m) neighbors per node."""
    d = int(np.log2(m))
    if 2**d != m:
        raise ValueError(f"hypercube needs power-of-two m, got {m}")
    if m == 1:
        return np.ones((1, 1), dtype=np.float32)
    A = np.zeros((m, m), dtype=np.float64)
    nbr = (1.0 - self_weight) / d
    for i in range(m):
        A[i, i] = self_weight
        for b in range(d):
            A[i, i ^ (1 << b)] = nbr
    return A.astype(np.float32)


def random_regular_matrix(m: int, degree: int = 4, seed: int = 0) -> np.ndarray:
    """Random regular graph via repeated perfect matchings; Metropolis weights.

    Used for the paper's Fig. 3 'random topology' curve.
    """
    rng = np.random.default_rng(seed)
    adj = np.zeros((m, m), dtype=bool)
    attempts = 0
    while adj.sum(axis=1).min() < degree and attempts < 200:
        perm = rng.permutation(m)
        for a, b in zip(perm[::2], perm[1::2]):
            if a != b and not adj[a, b] and adj[a].sum() < degree and adj[b].sum() < degree:
                adj[a, b] = adj[b, a] = True
        attempts += 1
    # Guarantee connectivity by overlaying a ring.
    for i in range(m):
        adj[i, (i + 1) % m] = adj[(i + 1) % m, i] = True
    np.fill_diagonal(adj, False)
    return metropolis_hastings(adj)


def disconnected_matrix(m: int) -> np.ndarray:
    """Identity = no communication. Baseline for 'local only' ablation."""
    return np.eye(m, dtype=np.float32)


def metropolis_hastings(adj: np.ndarray) -> np.ndarray:
    """Doubly-stochastic weights from an undirected adjacency matrix.

    a_ij = 1 / (1 + max(deg_i, deg_j)) on edges; diagonal takes the slack.
    Symmetric + rows sum to 1 => doubly stochastic.
    """
    adj = np.asarray(adj, dtype=bool)
    m = adj.shape[0]
    deg = adj.sum(axis=1)
    A = np.zeros((m, m), dtype=np.float64)
    for i in range(m):
        for j in range(m):
            if adj[i, j]:
                A[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        A[i, i] = 1.0 - A[i].sum()
    return A.astype(np.float32)


def time_varying_schedule(m: int, kind: str = "ring_alternating", seed: int = 0) -> list[np.ndarray]:
    """A finite cycle of doubly-stochastic matrices, used as A(t % k).

    The paper proves topology (fixed or time-variant) does not change the
    regret order; Fig. 3 compares them empirically.
    """
    if kind == "ring_alternating":
        # Alternate between even-edge and odd-edge pairwise averaging on a ring.
        mats = []
        for parity in (0, 1):
            A = np.eye(m, dtype=np.float64)
            for i in range(parity, m - (m % 2 == 1), 2):
                j = (i + 1) % m
                if i == j:
                    continue
                A[i, i] = A[j, j] = 0.5
                A[i, j] = A[j, i] = 0.5
            mats.append(A.astype(np.float32))
        return mats
    if kind == "random_matching":
        rng = np.random.default_rng(seed)
        mats = []
        for _ in range(4):
            A = np.eye(m, dtype=np.float64)
            perm = rng.permutation(m)
            for a, b in zip(perm[::2], perm[1::2]):
                A[a, a] = A[b, b] = 0.5
                A[a, b] = A[b, a] = 0.5
            mats.append(A.astype(np.float32))
        return mats
    raise ValueError(f"unknown time-varying kind: {kind}")


def spectral_gap(A: np.ndarray) -> float:
    """1 - |lambda_2(A)|: governs gossip mixing speed (consensus rate)."""
    ev = np.sort(np.abs(np.linalg.eigvals(np.asarray(A, dtype=np.float64))))
    return float(1.0 - (ev[-2] if len(ev) > 1 else 0.0))


# ---------------------------------------------------------------------------
# Sparse (edge-list / CSR) topologies — the social-big-data regime.
# A dense (m, m) mixing matrix caps m at a few thousand nodes; the paper's
# "distributed data centers" setting needs m in the 10^5..10^6 range, where
# only the O(edges) form fits. SparseGraph is the canonical sparse view both
# the segment_sum mixer (repro.api.mixers.SparseMixer) and the node-sharded
# gossip exchange (repro.api.shard_node) consume.
# ---------------------------------------------------------------------------

def ring_edges(m: int, self_weight: float = 0.5) -> "SparseGraph":
    """Edge-list form of :func:`ring_matrix`, built natively in O(m).

    Never materialises the dense matrix, so it scales to millions of nodes
    (``SparseGraph.from_dense(ring_matrix(m))`` would need O(m^2) memory).
    ``to_dense()`` of the result equals ``ring_matrix(m, self_weight)``
    exactly for m >= 3; m in {1, 2} degenerate the same way (neighbor
    weights fold onto the single/self edge).
    """
    if m == 1:
        return SparseGraph(dst=np.zeros(1, np.int64), src=np.zeros(1, np.int64),
                           weight=np.ones(1, np.float32), m=1, name="ring")
    i = np.arange(m, dtype=np.int64)
    nbr = np.float32((1.0 - self_weight) / 2.0)
    dst = np.concatenate([i, i, i])
    src = np.concatenate([i, (i - 1) % m, (i + 1) % m])
    w = np.concatenate([np.full(m, np.float32(self_weight)),
                        np.full(m, nbr), np.full(m, nbr)])
    # m == 2: the two "neighbors" are the same node; duplicates merge in
    # the canonical sort below exactly like the dense constructor's +=
    return SparseGraph(dst=dst, src=src, weight=w.astype(np.float32), m=m,
                       name="ring")


def torus_edges(rows: int, cols: int,
                self_weight: float = 1.0 / 3.0) -> "SparseGraph":
    """Edge-list form of :func:`torus_matrix`, built natively in O(m)."""
    m = rows * cols
    if m == 1:
        return SparseGraph(dst=np.zeros(1, np.int64), src=np.zeros(1, np.int64),
                           weight=np.ones(1, np.float32), m=1, name="torus")
    r, c = np.divmod(np.arange(m, dtype=np.int64), cols)
    nbr = np.float32((1.0 - self_weight) / 4.0)
    dsts, srcs, ws = [np.arange(m, dtype=np.int64)], [np.arange(m, dtype=np.int64)], \
        [np.full(m, np.float32(self_weight))]
    for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
        dsts.append(np.arange(m, dtype=np.int64))
        srcs.append(((r + dr) % rows) * cols + (c + dc) % cols)
        ws.append(np.full(m, nbr))
    return SparseGraph(dst=np.concatenate(dsts), src=np.concatenate(srcs),
                       weight=np.concatenate(ws).astype(np.float32), m=m,
                       name="torus")


@dataclasses.dataclass(frozen=True)
class SparseGraph:
    """Edge-list / CSR view of a (fixed) mixing matrix.

    Edges are (dst, src, weight) triples meaning ``A[dst, src] = weight``;
    ``apply`` semantics are ``out[i] = sum_j A[i, j] x[j]`` — exactly the
    dense matvec, restricted to stored entries. Construction canonicalizes:
    edges are sorted by (dst, src) and DUPLICATE (dst, src) pairs are summed
    into one edge, which is precisely what the dense form does when the same
    entry is written twice — so conversions and aggregations stay
    dense-equivalent by construction. Entries with weight exactly 0.0 are
    kept (they round-trip from a dense matrix's explicit zeros as absent —
    ``from_dense`` drops them — but a caller may store them).

    ``validate()`` checks the paper's Assumption 1 (doubly stochastic,
    nonneg, entries >= eta) in O(edges); a zero-degree (isolated) node makes
    its row sum 0 and is rejected there with a clear message.
    """

    dst: np.ndarray       # (E,) int — destination / row index
    src: np.ndarray       # (E,) int — source / column index
    weight: np.ndarray    # (E,) float32 — A[dst, src]
    m: int
    name: str = "sparse"

    def __post_init__(self):
        dst = np.asarray(self.dst, np.int64).ravel()
        src = np.asarray(self.src, np.int64).ravel()
        w = np.asarray(self.weight, np.float32).ravel()
        if not (dst.shape == src.shape == w.shape):
            raise ValueError(
                f"edge arrays disagree: dst {dst.shape}, src {src.shape}, "
                f"weight {w.shape}")
        if self.m < 1:
            raise ValueError(f"m must be >= 1, got {self.m}")
        if dst.size and (dst.min() < 0 or dst.max() >= self.m
                         or src.min() < 0 or src.max() >= self.m):
            raise ValueError(
                f"edge indices out of range for m={self.m}: "
                f"dst in [{dst.min()}, {dst.max()}], "
                f"src in [{src.min()}, {src.max()}]")
        # canonical form: sort by (dst, src), merge duplicate edges by
        # summing their weights (the dense-equivalent reading of a repeated
        # (i, j) entry). float32 sums of float32 duplicates match the dense
        # np.add.at accumulation exactly.
        flat = dst * self.m + src
        order = np.argsort(flat, kind="stable")
        flat, dst, src, w = flat[order], dst[order], src[order], w[order]
        uniq, first = np.unique(flat, return_index=True)
        if uniq.size != flat.size:
            w = np.add.reduceat(w.astype(np.float32), first)
            dst, src = dst[first], src[first]
        object.__setattr__(self, "dst", dst)
        object.__setattr__(self, "src", src)
        object.__setattr__(self, "weight", w.astype(np.float32))

    # -- shape/views ---------------------------------------------------------

    @property
    def edges(self) -> int:
        return int(self.dst.size)

    @property
    def indptr(self) -> np.ndarray:
        """(m + 1,) CSR row pointers: edges of row i live in
        ``[indptr[i], indptr[i+1])`` of the (dst, src)-sorted edge arrays."""
        counts = np.bincount(self.dst, minlength=self.m)
        return np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)

    def diag(self) -> np.ndarray:
        """(m,) self-weights A[i, i] (0 where no self-loop is stored)."""
        d = np.zeros(self.m, np.float32)
        loop = self.dst == self.src
        d[self.dst[loop]] = self.weight[loop]
        return d

    def degree(self) -> np.ndarray:
        """(m,) number of stored in-edges per destination node."""
        return np.bincount(self.dst, minlength=self.m).astype(np.int64)

    # -- conversions (exact round trips) -------------------------------------

    @classmethod
    def from_dense(cls, A: np.ndarray, name: str | None = None) -> "SparseGraph":
        """Edge list of every nonzero entry; float32 values are preserved
        exactly, so ``to_dense()`` round-trips bit-for-bit."""
        A = np.asarray(A, np.float32)
        if A.ndim != 2 or A.shape[0] != A.shape[1]:
            raise ValueError(f"A must be square, got {A.shape}")
        dst, src = np.nonzero(A)
        return cls(dst=dst.astype(np.int64), src=src.astype(np.int64),
                   weight=A[dst, src], m=A.shape[0],
                   name=name or "sparse")

    def to_dense(self) -> np.ndarray:
        """(m, m) float32 dense form (duplicates were already merged)."""
        A = np.zeros((self.m, self.m), np.float32)
        np.add.at(A, (self.dst, self.src), self.weight)
        return A

    # -- checks --------------------------------------------------------------

    def validate(self, eta: float = 1e-6, atol: float = 1e-6) -> "SparseGraph":
        """Assumption 1 in O(edges): nonneg entries >= eta, every row and
        column sums to 1 (a zero-degree node fails its row sum). Returns
        self so construction sites can chain ``SparseGraph(...).validate()``."""
        if np.any(self.weight < -atol):
            raise ValueError("sparse A has negative entries")
        pos = self.weight[self.weight > atol]
        if pos.size and pos.min() < eta - atol:
            raise ValueError(
                f"positive entries below eta={eta}: min={pos.min()}")
        rows = np.zeros(self.m, np.float64)
        cols = np.zeros(self.m, np.float64)
        np.add.at(rows, self.dst, self.weight.astype(np.float64))
        np.add.at(cols, self.src, self.weight.astype(np.float64))
        bad_r = np.flatnonzero(~np.isclose(rows, 1.0, atol=atol))
        if bad_r.size:
            raise ValueError(
                f"rows do not sum to 1 (isolated/underweighted nodes?): "
                f"rows {bad_r[:8].tolist()} sum to "
                f"{rows[bad_r[:8]].tolist()}")
        bad_c = np.flatnonzero(~np.isclose(cols, 1.0, atol=atol))
        if bad_c.size:
            raise ValueError(
                f"cols do not sum to 1: cols {bad_c[:8].tolist()} sum to "
                f"{cols[bad_c[:8]].tolist()}")
        return self

    def is_symmetric(self, atol: float = 0.0) -> bool:
        """True iff A[i, j] == A[j, i] for every stored edge (O(E log E))."""
        fwd = {(int(d), int(s)): float(w)
               for d, s, w in zip(self.dst, self.src, self.weight)}
        return all(abs(w - fwd.get((s, d), 0.0)) <= atol
                   for (d, s), w in fwd.items())

    # -- constructors --------------------------------------------------------

    @classmethod
    def make(cls, topology: str, m: int, seed: int = 0,
             **kw) -> "SparseGraph":
        """Sparse mixing graph by topology name.

        'ring' and 'torus' build natively in O(m) (any m, including the
        n >= 10^5 regime); every other fixed GossipGraph topology goes
        through its dense form (small m only). Time-varying schedules have
        no sparse form here — the sparse path assumes one fixed A.
        """
        if topology == "ring":
            return ring_edges(m, **kw).validate()
        if topology == "torus":
            rows = kw.pop("rows", int(np.sqrt(m)))
            if rows * (m // rows) != m:
                raise ValueError(f"torus needs factorable m, got {m}")
            return torus_edges(rows, m // rows, **kw).validate()
        if topology == "time_varying":
            raise ValueError(
                "time_varying schedules have no sparse form — the sparse "
                "gossip path assumes one fixed topology (use the dense "
                "mixer for A(t) schedules)")
        graph = GossipGraph.make(topology, m, seed=seed, **kw)
        return cls.from_dense(graph.at(0), name=topology).validate()


# ---------------------------------------------------------------------------
# Neighbor-weight views for the distributed (ppermute) strategy.
# A ring/torus row of A is fully described by (shift -> weight); the shard_map
# gossip implementation consumes these instead of the dense matrix.
# ---------------------------------------------------------------------------

def ring_neighbor_weights(self_weight: float = 0.5) -> dict[int, float]:
    """Shift->weight map matching :func:`ring_matrix` (shift along the axis)."""
    nbr = (1.0 - self_weight) / 2.0
    return {0: self_weight, 1: nbr, -1: nbr}


def torus_neighbor_weights(self_weight: float = 1.0 / 3.0) -> dict[tuple[int, int], float]:
    """(dr, dc)->weight map matching :func:`torus_matrix` on a 2D mesh."""
    nbr = (1.0 - self_weight) / 4.0
    return {(0, 0): self_weight, (1, 0): nbr, (-1, 0): nbr, (0, 1): nbr, (0, -1): nbr}


@dataclasses.dataclass(frozen=True)
class GossipGraph:
    """A (possibly time-varying) communication graph for m gossip nodes."""

    matrices: tuple  # tuple[np.ndarray, ...]; len 1 => fixed topology
    name: str = "ring"

    def __post_init__(self):
        for A in self.matrices:
            assert_doubly_stochastic(A)

    @property
    def m(self) -> int:
        return self.matrices[0].shape[0]

    def at(self, t: int) -> np.ndarray:
        return self.matrices[t % len(self.matrices)]

    @classmethod
    def make(cls, topology: str, m: int, seed: int = 0, **kw) -> "GossipGraph":
        builders: dict[str, Callable[..., Sequence[np.ndarray]]] = {
            "ring": lambda: [ring_matrix(m, **kw)],
            "complete": lambda: [complete_matrix(m)],
            "hypercube": lambda: [hypercube_matrix(m, **kw)],
            "random": lambda: [random_regular_matrix(m, seed=seed, **kw)],
            "disconnected": lambda: [disconnected_matrix(m)],
            "time_varying": lambda: time_varying_schedule(m, seed=seed, **kw),
        }
        if topology == "torus":
            rows = kw.pop("rows", int(np.sqrt(m)))
            if rows * (m // rows) != m:
                raise ValueError(f"torus needs factorable m, got {m}")
            return cls(matrices=(torus_matrix(rows, m // rows, **kw),), name="torus")
        if topology not in builders:
            raise ValueError(f"unknown topology {topology!r}; options: {sorted(builders)} + torus")
        return cls(matrices=tuple(builders[topology]()), name=topology)
