"""LR schedules. ``wsd`` is the MiniCPM warmup-stable-decay schedule
[arXiv:2404.06395] used by the minicpm-2b recipe."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)


def cosine(lr: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step / total_steps, 0.0, 1.0)
        return lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return f


def warmup_cosine(lr: float, warmup: int, total_steps: int, final_frac: float = 0.1):
    cos = cosine(lr, max(total_steps - warmup, 1), final_frac)
    def f(step):
        w = jnp.clip(step / jnp.maximum(warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, lr * w, cos(step - warmup))
    return f


def wsd(lr: float, warmup: int, stable: int, decay: int, final_frac: float = 0.1):
    """Warmup-Stable-Decay (MiniCPM): linear warmup, flat stable phase,
    exponential-ish decay over the last `decay` steps."""
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup - stable) / jnp.maximum(decay, 1), 0.0, 1.0)
        dec = lr * (final_frac ** t)
        return jnp.where(step < warmup, warm,
                         jnp.where(step < warmup + stable, lr, dec))
    return f
