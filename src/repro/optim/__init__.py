"""Optimizers + LR schedules (self-contained; no optax in this container)."""
from repro.optim.optimizers import sgd, adamw, apply_updates, Optimizer
from repro.optim.schedules import constant, cosine, warmup_cosine, wsd

__all__ = ["sgd", "adamw", "apply_updates", "Optimizer",
           "constant", "cosine", "warmup_cosine", "wsd"]
