"""Minimal optax-style optimizers: init/update pairs over pytrees.

Used for the non-private all-reduce baseline runs; the paper's OMD/GossipDP
optimizer lives in repro.core (it needs the mixing/noise stage between the
gradient and the parameter update).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]  # (grads, state, params) -> (updates, state)


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates)


def sgd(lr_schedule, momentum: float = 0.0) -> Optimizer:
    def init(params):
        mu = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params) \
            if momentum else None
        return {"step": jnp.zeros((), jnp.int32), "mu": mu}

    def update(grads, state, params=None):
        lr = lr_schedule(state["step"])
        if momentum:
            mu = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads)
            upd = jax.tree_util.tree_map(lambda m: -lr * m, mu)
            return upd, {"step": state["step"] + 1, "mu": mu}
        upd = jax.tree_util.tree_map(lambda g: -lr * g.astype(jnp.float32), grads)
        return upd, {"step": state["step"] + 1, "mu": None}

    return Optimizer(init, update)


def adamw(lr_schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr = lr_schedule(state["step"])
        f32 = lambda g: g.astype(jnp.float32)
        m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * f32(g), state["m"], grads)
        v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * jnp.square(f32(g)),
                                   state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        upd = jax.tree_util.tree_map(
            lambda m, v, p: -lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps)
                                   + weight_decay * p.astype(jnp.float32)),
            m, v, params)
        return upd, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)
