from repro.sharding.rules import (
    param_pspecs, batch_pspec, cache_pspecs, with_node_axis, NODE_AXES, MODEL_AXIS,
)

__all__ = ["param_pspecs", "batch_pspec", "cache_pspecs", "with_node_axis",
           "NODE_AXES", "MODEL_AXIS"]
