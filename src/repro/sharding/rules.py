"""Sharding rules: parameter-path regex -> PartitionSpec.

2D layout on the ("data", "model") mesh (+"pod" in front on the multi-pod
mesh). Tensor parallelism over "model":
  * embed/unembed: vocab axis sharded (Megatron-style)
  * attention: head projections sharded on the head (output) axis, wo on
    its input axis
  * FFN: up/gate sharded on d_ff out, down on d_ff in
  * MoE expert stacks: sharded on the d_ff axis within each expert
    (tensor-parallel experts; expert-parallel is the hillclimb variant)
  * norms / small vectors: replicated
Under GossipDP every param leaf gains a LEADING node axis, sharded over the
gossip mesh axes ("data", or ("pod",) multi-pod) — see core/gossip.py.
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

MODEL_AXIS = "model"
NODE_AXES = {"single": ("data",), "multi": ("pod",)}

# (regex over flattened path, spec builder given leaf ndim)
_RULES: list[tuple[str, Any]] = [
    # embedding / unembedding — shard the vocab axis
    (r"embed/table$", lambda nd: P(MODEL_AXIS, None)),
    (r"unembed/w$", lambda nd: P(None, MODEL_AXIS)),
    # attention projections
    (r"(attn|cross)/w[qkv]/w$", lambda nd: P(None, MODEL_AXIS)),
    (r"(attn|cross)/w[qkv]/b$", lambda nd: P(MODEL_AXIS)),
    (r"(attn|cross)/wo/w$", lambda nd: P(MODEL_AXIS, None)),
    # dense FFN
    (r"(ffn|shared)/(gate|up)/w$", lambda nd: P(None, MODEL_AXIS)),
    (r"(ffn|shared)/down/w$", lambda nd: P(MODEL_AXIS, None)),
    # MoE expert stacks (E, d, f) / (E, f, d): shard f
    (r"moe/(gate|up)$", lambda nd: P(None, None, MODEL_AXIS)),
    (r"moe/down$", lambda nd: P(None, MODEL_AXIS, None)),
    (r"moe/router/w$", lambda nd: P(None, None)),
    # RWKV6 matrices (D, D) / (D, F)
    (r"tm/w[rkvgo]/w$", lambda nd: P(None, MODEL_AXIS)),
    (r"cm/wk/w$", lambda nd: P(None, MODEL_AXIS)),
    (r"cm/wv/w$", lambda nd: P(MODEL_AXIS, None)),
    (r"cm/wr/w$", lambda nd: P(None, MODEL_AXIS)),
    # RG-LRU blocks
    (r"rec/(gate|inp)/w$", lambda nd: P(None, MODEL_AXIS)),
    (r"rec/out/w$", lambda nd: P(MODEL_AXIS, None)),
    (r"rec/lru/w[ax]/w$", lambda nd: P(None, MODEL_AXIS)),
    (r"rec/lru/w[ax]/b$", lambda nd: P(MODEL_AXIS)),
    (r"rec/lru/lam$", lambda nd: P(MODEL_AXIS)),
    (r"rec/conv/w$", lambda nd: P(None, MODEL_AXIS)),
    (r"rec/conv/b$", lambda nd: P(MODEL_AXIS)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(re.sub(r"[^\w]", "", str(p)))
    return "/".join(parts)


def spec_for(path_str: str, leaf) -> P:
    for pattern, builder in _RULES:
        if re.search(pattern, path_str):
            spec = builder(leaf.ndim)
            # layer-stacked params have a leading L axis -> prepend None
            extra = leaf.ndim - len(spec)
            if extra > 0:
                spec = P(*([None] * extra + list(spec)))
            return spec
    return P()  # replicate


def _axis_size(mesh, axis) -> int:
    if mesh is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _sanitize(spec: P, shape: tuple[int, ...], mesh) -> P:
    """Drop (or relocate) mesh axes whose size doesn't divide the dim.

    Non-divisible cases (odd vocabs like 122753) first try the OTHER dim of
    a 2D param; otherwise the dim is replicated.
    """
    if mesh is None:
        return spec
    dims = list(spec) + [None] * (len(shape) - len(spec))
    for i, ax in enumerate(dims):
        if ax is None:
            continue
        if shape[i] % _axis_size(mesh, ax) != 0:
            dims[i] = None
            # fallback: move to another free, divisible dim — LATER dims
            # first (e.g. kv_heads 8 % 16 != 0 -> shard head_dim), never a
            # leading layer-stack dim (sharding L would all-gather every
            # scan iteration; found via the roofline table, see EXPERIMENTS)
            for j in list(range(i + 1, len(dims))) + list(range(i - 1, -1, -1)):
                if dims[j] is None and shape[j] % _axis_size(mesh, ax) == 0 \
                        and shape[j] >= _axis_size(mesh, ax):
                    dims[j] = ax
                    break
    while dims and dims[-1] is None:
        dims.pop()
    return P(*dims)


def param_pspecs(params: Any, node_axes: tuple[str, ...] = (), mesh=None) -> Any:
    """PartitionSpec tree for a param tree. node_axes prepends the gossip
    node dimension's axes (params must already carry the leading node dim).
    Pass ``mesh`` to validate divisibility (falls back per _sanitize)."""
    if node_axes:
        # leaf.ndim includes the node axis; spec computed on ndim-1
        def one_node(path, leaf):
            class _V:  # shim: rules see the per-node ndim
                ndim = leaf.ndim - 1
            spec = _sanitize(spec_for(_path_str(path), _V), leaf.shape[1:], mesh)
            inner = list(spec) + [None] * (leaf.ndim - 1 - len(spec))
            return P(node_axes if len(node_axes) > 1 else node_axes[0], *inner)
        return jax.tree_util.tree_map_with_path(one_node, params)

    def one(path, leaf):
        return _sanitize(spec_for(_path_str(path), leaf), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(one, params)


def batch_pspec(batch_axes: tuple[str, ...], ndim: int) -> P:
    """Batch arrays: leading axis over the data axes, rest replicated."""
    lead = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    return P(lead, *([None] * (ndim - 1)))


def cache_pspecs(cache: Any, batch_axes: tuple[str, ...], mesh=None) -> Any:
    """KV caches: (L, B, ...) or (B, ...) — shard the batch dim over data
    axes; attention head dims over model where shaped like (.., kv, hd).
    Pass ``mesh`` to drop non-divisible axes (e.g. 40 WKV heads on a
    16-way model axis)."""
    lead = batch_axes if len(batch_axes) > 1 else batch_axes[0]

    def one(path, leaf):
        ps = _path_str(path)
        # caches from init_cache are stacked (L, B, ...) for scan models,
        # plain (B, ...) inside per-layer lists; the -k indexing below works
        # for both.
        if re.search(r"(^|/)(k|v|cross_k|cross_v)$", ps) and leaf.ndim >= 4:
            # (..., B, C, kv, hd): shard B over data, kv over model
            spec = [None] * leaf.ndim
            spec[-4] = lead
            spec[-2] = MODEL_AXIS
            return _sanitize(P(*spec), leaf.shape, mesh)
        if re.search(r"wkv$", ps) and leaf.ndim >= 4:
            spec = [None] * leaf.ndim
            spec[-4] = lead
            spec[-3] = MODEL_AXIS  # heads
            return _sanitize(P(*spec), leaf.shape, mesh)
        if re.search(r"/conv$", ps) and leaf.ndim >= 3:
            # (.., B, W-1, R): batch at -3
            spec = [None] * leaf.ndim
            spec[-3] = lead
            return _sanitize(P(*spec), leaf.shape, mesh)
        if re.search(r"(slot_pos|tm_shift|cm_shift|^h$|/h$)", ps) and leaf.ndim >= 2:
            # (.., B, X): batch at -2
            spec = [None] * leaf.ndim
            spec[-2] = lead
            return _sanitize(P(*spec), leaf.shape, mesh)
        return P()

    return jax.tree_util.tree_map_with_path(one, cache)


def with_node_axis(tree: Any, nodes: int) -> Any:
    """Tile a param tree with a leading node axis (replicated start state)."""
    import jax.numpy as jnp
    return jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (nodes,) + l.shape), tree)
