"""ServeService — the assembled predict-while-learning loop.

Wires the four serving pieces together behind one object:

    ServeState      current snapshot + jitted batched predict
    BackgroundTrainer   continuous gossip rounds -> atomic publications
    AdmissionQueue/Batcher   bounded queue, max-batch/max-wait batching,
                             shedding, eps-exhaustion refusal
    AsyncCheckpointer   threaded `repro.checkpoint` writes of the serving
                        state (never blocks a publication on disk I/O)

>>> from repro.serve import ServeConfig, ServeService
>>> from repro.api import RunSpec
>>> spec = RunSpec(nodes=2, dim=8, horizon=8, eps=1.0, alpha0=0.5, lam=0.01,
...                stream="bursty")
>>> svc = ServeService(ServeConfig(spec=spec, chunk_rounds=4, max_batch=4,
...                                max_wait_ms=0.5, train=False,
...                                warmup=False))
>>> svc = svc.start()                  # round-0 snapshot, no trainer
>>> r = svc.predict([1.0] * 8, node=0, timeout=10.0)
>>> r.status, r.margin, r.snapshot_round
('ok', 0.0, 0)
>>> svc.stop()
>>> svc.stats()["admission"]["served"]
1
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro import obs as obslib
from repro.api.spec import RunSpec
from repro.checkpoint import AsyncCheckpointer
from repro.serve.admission import AdmissionQueue, Batcher, Request, ServeStats
from repro.serve.state import ServeState, verify_snapshot
from repro.serve.trainer import BackgroundTrainer

__all__ = ["ServeConfig", "ServeService"]


@dataclasses.dataclass
class ServeConfig:
    """Everything the serving loop needs, declaratively.

    spec:           the RunSpec the background trainer advances (its
                    ``stream`` also seeds the replay client's arrivals).
    engine:         'sim' | 'dist' — which engine trains.
    mode:           'node' (per-data-center model) | 'average' (w_bar).
    chunk_rounds:   trainer publication cadence in rounds.
    max_batch / max_wait_ms / queue_capacity: the admission layer.
    max_age_s:      request deadline — a request older than this at dequeue
                    is shed with reason 'timeout' (None never expires).
    crash_at_round: fault injection (repro.faults): kill the trainer at the
                    first chunk boundary >= this round; it auto-restarts
                    from its last async checkpoint (needs checkpoint_dir).
    eps_budget / composition: serving-side privacy ledger (see
                    `repro.serve.trainer`); budget None never refuses.
    checkpoint_dir / checkpoint_every: async-checkpoint every N
                    publications into the directory (None = off).
    keep_snapshots: history ring depth for by-version verification.
    train:          False serves the round-0 model only (tests/doctests).
    warmup:         compile the trainer's first chunk before its timed loop.
    """

    spec: RunSpec
    engine: str = "sim"
    mode: str = "node"
    chunk_rounds: int = 64
    max_batch: int = 32
    max_wait_ms: float = 2.0
    max_age_s: float | None = None
    queue_capacity: int = 1024
    crash_at_round: int | None = None
    eps_budget: float | None = None
    composition: str = "parallel"
    checkpoint_dir: str | None = None
    checkpoint_every: int = 1
    keep_snapshots: int = 8
    train: bool = True
    warmup: bool = True


class ServeService:
    """start() -> submit()/predict() under load -> stop() -> stats()."""

    def __init__(self, config: ServeConfig):
        if config.crash_at_round is not None and not config.checkpoint_dir:
            raise ValueError("crash_at_round needs checkpoint_dir= (the "
                             "trainer restarts from its last checkpoint)")
        self.config = config
        self.stats_ = ServeStats()
        self.state = ServeState(config.spec, engine=config.engine,
                                mode=config.mode, keep=config.keep_snapshots)
        self.admission = AdmissionQueue(config.queue_capacity, self.stats_)
        self.checkpointer = (
            AsyncCheckpointer(config.checkpoint_dir)
            if config.checkpoint_dir else None)
        # the trainer's engine-state checkpoints live in a subdirectory so
        # they never collide with the service's theta-only snapshot files
        trainer_ckpt = (os.path.join(config.checkpoint_dir, "trainer")
                        if config.checkpoint_dir else None)
        self.trainer = BackgroundTrainer(
            config.spec, self.state, engine=config.engine,
            chunk_rounds=config.chunk_rounds, composition=config.composition,
            eps_budget=config.eps_budget, warmup=config.warmup,
            on_publish=self._on_publish,
            checkpoint_dir=trainer_ckpt,
            crash_at_round=config.crash_at_round) if config.train else None
        self.batcher = Batcher(
            self.state, self.admission, self.stats_,
            max_batch=config.max_batch,
            max_wait_s=config.max_wait_ms / 1e3,
            max_age_s=config.max_age_s,
            exhausted=self.exhausted,
            train_round=lambda: (self.trainer.round if self.trainer else None))
        self._started = False

    # -- trainer-side hooks --------------------------------------------------

    def _on_publish(self, snapshot) -> None:
        if (self.checkpointer is not None
                and snapshot.version % self.config.checkpoint_every == 0):
            # the engine-agnostic serving state: theta at the published round
            self.checkpointer.save(snapshot.round, {"theta": snapshot.theta})

    def exhausted(self) -> bool:
        return self.trainer is not None and self.trainer.exhausted

    def eps_spent(self) -> float:
        if self.trainer is not None:
            return self.trainer.eps_spent
        snap = self.state.current
        return snap.eps_spent if snap is not None else 0.0

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "ServeService":
        if self._started:
            raise RuntimeError("service already started")
        self._started = True
        self.state.publish_initial()
        self.batcher.start()
        if self.trainer is not None:
            self.trainer.start()
        return self

    def stop(self, timeout: float = 120.0) -> None:
        """Stop the trainer at its next chunk boundary, drain the queue,
        stop the batcher and flush pending checkpoints."""
        if self.trainer is not None:
            self.trainer.stop()
            self.trainer.join(timeout)
        self.batcher.stop()
        self.batcher.join(timeout)
        if self.batcher.is_alive():
            raise TimeoutError("batcher did not drain within timeout")
        if self.checkpointer is not None:
            self.checkpointer.close()
        tel = obslib.active()
        if tel.enabled:
            # durable exit record: the full serving summary (served / shed
            # with reasons / refused / latencies) lands in the event stream
            # so `obs report` can render it after the service is gone
            tel.emit("serve_summary", **self.stats())

    # -- request path --------------------------------------------------------

    def submit(self, features, node: int,
               max_age_s: float | None = None) -> Request:
        """Non-blocking admission; the returned Request resolves to
        'ok' | 'shed' | 'refused' (wait()/done()). ``max_age_s`` overrides
        the service-wide deadline for this request."""
        req = Request(features=features, node=int(node), max_age_s=max_age_s)
        return self.admission.submit(req, refuse=self.exhausted())

    def predict(self, features, node: int,
                timeout: float | None = 30.0) -> Request:
        """Submit and wait — the synchronous convenience path."""
        return self.submit(features, node).wait(timeout)

    # -- introspection -------------------------------------------------------

    def verify(self, request: Request) -> bool:
        """Re-derive ``request``'s prediction from a fresh reference run at
        its recorded snapshot round; True iff bit-identical.

        Proves the atomic-publication contract end-to-end: the snapshot the
        response names is exactly `repro.api.run(spec, horizon=round)`'s
        model, and the served margin is exactly what the predict step says
        on that model.
        """
        if request.status != "ok":
            raise ValueError(f"cannot verify a {request.status!r} request")
        snap = self.state.snapshot(request.snapshot_version)
        if snap is None:
            return False        # pruned past keep_snapshots
        if not verify_snapshot(self.config.spec, self.config.engine, snap,
                               chunk_rounds=self.config.chunk_rounds):
            return False
        feats = np.zeros((self.config.max_batch, self.config.spec.dim),
                         np.float32)
        feats[0] = np.asarray(request.features, np.float32)
        nodes = np.zeros((self.config.max_batch,), np.int32)
        nodes[0] = request.node
        margins, labels = self.state.predict_fn(
            snap.w, snap.w_bar, feats, nodes)
        return (float(np.asarray(margins)[0]) == request.margin
                and float(np.asarray(labels)[0]) == request.label)

    def stats(self) -> dict:
        out = {"admission": self.stats_.summary()}
        snap = self.state.current
        out["serving"] = {
            "snapshot_round": None if snap is None else snap.round,
            "snapshot_version": None if snap is None else snap.version,
            "snapshots_published": self.state.published,
            "eps_spent": self.eps_spent(),
            "exhausted": self.exhausted(),
            "queue_depth": self.admission.qsize(),
        }
        if self.trainer is not None:
            out["trainer"] = {
                "round": self.trainer.round,
                "running": self.trainer.running,
                "composition": self.trainer.composition,
                "eps_budget": self.config.eps_budget,
                "restarts": self.trainer.restarts,
            }
        return out
