"""Background trainer: continuous gossip rounds -> published snapshots.

Wraps `repro.api.run`'s chunked scan in a thread and hangs on its
``on_chunk`` hook: after every ``chunk_rounds`` gossip/update rounds the
engine state is host-synchronized, turned into an immutable
:class:`~repro.serve.state.Snapshot` and atomically published to the
predictor — the serving side keeps answering against the previous snapshot
until the swap, so training never blocks a prediction and a prediction
never sees a half-updated model.

Privacy accounting for SERVING is explicit about composition across
publications:

  * ``composition='parallel'`` (default, faithful to Theorem 1 when the
    stream declares disjoint rounds): the cumulative guarantee stays flat
    at eps_per_round — the broadcasts the accountant already covers are the
    only releases.
  * ``composition='sequential'`` is the pessimistic stance that every
    published snapshot is a separate eps-DP release: the ledger grows by
    eps_per_round per ROUND, so a finite ``eps_budget`` is eventually
    SPENT. The trainer then stops advancing, refuses to publish the
    over-budget snapshot, and flips ``exhausted`` — the admission layer
    refuses every later request.

>>> from repro.api import RunSpec
>>> from repro.serve.state import ServeState
>>> from repro.serve.trainer import BackgroundTrainer
>>> spec = RunSpec(nodes=2, dim=8, horizon=12, eps=1.0, alpha0=0.5, lam=0.01,
...                stream="bursty")
>>> state = ServeState(spec)
>>> _ = state.publish_initial()
>>> tr = BackgroundTrainer(spec, state, chunk_rounds=4, warmup=False)
>>> tr.run_blocking()                  # inline (no thread): 12 rounds
>>> tr.round, state.current.round, state.published
(12, 12, 4)
>>> budget = BackgroundTrainer(spec, ServeState(spec), chunk_rounds=4,
...                            composition="sequential", eps_budget=5.0,
...                            warmup=False)
>>> budget.run_blocking()              # 4 rounds cost 4.0, 8 would cost 8.0
>>> budget.round, budget.exhausted
(4, True)
"""
from __future__ import annotations

import math
import threading
from typing import Callable

from repro import obs as obslib
from repro.api.exec_config import ExecConfig
from repro.api.runner import RunResult, run
from repro.api.spec import RunSpec
from repro.checkpoint import AsyncCheckpointer
from repro.core.privacy import PrivacyAccountant
from repro.serve.state import ServeState, Snapshot, snapshot_from_state

__all__ = ["BackgroundTrainer", "TrainerCrash"]


class TrainerCrash(RuntimeError):
    """Injected trainer failure (repro.faults): raised inside the chunk hook
    at ``crash_at_round`` to sever the training run mid-horizon. The trainer
    catches it, replays from its last async checkpoint and finishes the
    horizon bit-identically (streams are keyed per absolute round)."""

    def __init__(self, round_end: int):
        super().__init__(f"injected trainer crash at round {round_end}")
        self.round_end = round_end


class BackgroundTrainer:
    """Advance gossip rounds in fixed chunks; publish snapshots atomically.

    spec / engine / chunk_rounds: what `repro.api.run` drives — publication
        happens at every chunk boundary, so ``chunk_rounds`` IS the
        publication cadence (and the upper bound on served staleness while
        the trainer keeps up).
    composition / eps_budget: the serving-side privacy ledger (see module
        docstring). ``eps_budget=None`` never refuses.
    on_publish: optional callback fired with each published Snapshot —
        the service uses it for async checkpointing.
    checkpoint_dir: directory for the trainer's OWN engine-state
        checkpoints (async, one per chunk) — the recovery substrate for
        crash restarts, separate from the service's snapshot checkpoints.
    crash_at_round: fault injection (repro.faults): raise
        :class:`TrainerCrash` at the first chunk boundary >= this round,
        then auto-restart from the last checkpoint and resume
        bit-identically. Requires ``checkpoint_dir``; ``restarts`` counts
        recoveries.
    """

    def __init__(self, spec: RunSpec, state: ServeState, *,
                 engine: str = "sim", chunk_rounds: int = 64,
                 composition: str = "parallel",
                 eps_budget: float | None = None,
                 warmup: bool = True,
                 on_publish: Callable[[Snapshot], None] | None = None,
                 checkpoint_dir: str | None = None,
                 crash_at_round: int | None = None):
        if composition not in ("parallel", "sequential"):
            raise ValueError(f"unknown composition {composition!r}")
        if crash_at_round is not None and checkpoint_dir is None:
            raise ValueError(
                "crash_at_round needs checkpoint_dir= — without a "
                "checkpoint there is nothing to restart from")
        self.spec = spec
        self.state = state
        self.engine = engine
        self.chunk_rounds = chunk_rounds
        self.composition = composition
        self.eps_budget = eps_budget
        self.warmup = warmup
        self.on_publish = on_publish
        stream = spec.resolve_stream()
        mech = spec.resolve_mechanism()
        self._accountant = PrivacyAccountant(
            eps_per_round=spec.eps if mech.is_private else math.inf,
            disjoint_streams=(composition == "parallel"
                              and getattr(stream, "disjoint", False)))
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._round = 0
        self._exhausted = False
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        self.result: RunResult | None = None
        self.checkpoint_dir = checkpoint_dir
        self.crash_at_round = crash_at_round
        self.restarts = 0
        self._crashed_once = False
        self._checkpointer = (AsyncCheckpointer(checkpoint_dir)
                              if checkpoint_dir else None)

    # -- ledger --------------------------------------------------------------

    def eps_at(self, rounds: int) -> float:
        """Cumulative guarantee charged for serving a snapshot at ``rounds``
        under this trainer's composition policy."""
        return self._accountant.guarantee_at(rounds)

    @property
    def eps_spent(self) -> float:
        return self.eps_at(self.round)

    @property
    def round(self) -> int:
        with self._lock:
            return self._round

    @property
    def exhausted(self) -> bool:
        with self._lock:
            return self._exhausted

    # -- the on_chunk hook ---------------------------------------------------

    def _on_chunk(self, round_end: int, eng_state, accountant) -> bool:
        eps = self.eps_at(round_end)
        if self.eps_budget is not None and eps > self.eps_budget:
            # publishing this snapshot would overspend the ledger: drop it,
            # stop training, and flip the flag the admission layer refuses on
            with self._lock:
                self._exhausted = True
            return True
        if (self.crash_at_round is not None and not self._crashed_once
                and round_end >= self.crash_at_round):
            # crash BEFORE checkpointing or publishing this chunk: recovery
            # must come from the previous boundary, like a real process death
            self._crashed_once = True
            raise TrainerCrash(round_end)
        if self._checkpointer is not None:
            self._checkpointer.save(round_end, eng_state)
        tel = obslib.active()
        with tel.span("serve.publish", round=round_end):
            snap = snapshot_from_state(
                self.spec, self.engine, eng_state,
                version=self.state.published, eps_spent=eps)
            self.state.publish(snap)
        with self._lock:
            self._round = round_end
        if tel.enabled:
            tel.metrics.gauge("serve.train_round").set(round_end)
            tel.metrics.counter("serve.published").inc()
            tel.emit("publish", round=round_end, version=snap.version,
                     eps=eps)
        if self.on_publish is not None:
            self.on_publish(snap)
        return self._stop.is_set()

    def _drive(self) -> None:
        try:
            while True:
                try:
                    # resume= replays from the last trainer checkpoint —
                    # a no-op on the first pass of an empty directory
                    self.result = run(self.spec, engine=self.engine,
                                      on_chunk=self._on_chunk,
                                      exec=ExecConfig(
                                          chunk_rounds=self.chunk_rounds,
                                          compute_regret=False,
                                          warmup=self.warmup,
                                          resume=self._checkpointer
                                          is not None,
                                          checkpoint_dir=self.checkpoint_dir))
                    return
                except TrainerCrash:
                    # the injected death: flush pending writes, then restart
                    # from the latest checkpoint. Streams are keyed per
                    # absolute round, so the replayed rounds are bit-identical
                    # to the uncrashed run.
                    self._checkpointer.wait()
                    with self._lock:
                        self.restarts += 1
        except BaseException as err:        # surfaced by join()
            self._error = err

    # -- lifecycle -----------------------------------------------------------

    def run_blocking(self) -> None:
        """Drive the whole horizon inline (tests, doctests, benchmarks that
        want training isolated from serving)."""
        self._drive()
        if self._checkpointer is not None:
            self._checkpointer.close()
        if self._error is not None:
            raise self._error

    def start(self) -> "BackgroundTrainer":
        if self._thread is not None:
            raise RuntimeError("trainer already started")
        self._thread = threading.Thread(target=self._drive, daemon=True,
                                        name="repro-serve-trainer")
        self._thread.start()
        return self

    def stop(self) -> None:
        """Request a stop at the next chunk boundary."""
        self._stop.set()

    def join(self, timeout: float | None = None) -> None:
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():
                raise TimeoutError("trainer did not stop within timeout")
        if self._checkpointer is not None:
            self._checkpointer.close()
        if self._error is not None:
            raise self._error

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()
