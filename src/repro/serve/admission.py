"""Admission control: bounded queue, max-batch/max-wait batcher, shedding.

The front door of the serving layer. Requests enter a BOUNDED queue — a
full queue sheds the request immediately (counted, never silently dropped)
instead of letting latency grow without bound under a burst. A batcher
thread drains the queue into fixed-size batches: it waits at most
``max_wait_s`` after the first request of a batch (latency bound) and never
packs more than ``max_batch`` (compute bound), then pads the batch to
exactly ``max_batch`` so the jitted predict step compiles ONCE for one
static shape.

Requests are also REFUSED (distinct from shed) when the privacy ledger is
exhausted — `repro.serve.trainer` flips the shared flag once the eps budget
is spent, and from that point the service returns ``status='refused'``
rather than serving a model whose release the budget no longer covers.

>>> from repro.serve.admission import Request, ServeStats
>>> stats = ServeStats()
>>> stats.shed_total, stats.served_total
(0, 0)
>>> r = Request(features=[1.0, 0.0], node=0)
>>> r.status, r.done()
('pending', False)
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any

import jax
import numpy as np

from repro import obs as obslib
from repro.serve.state import ServeState

__all__ = ["Request", "ServeStats", "AdmissionQueue", "Batcher"]


@dataclasses.dataclass
class Request:
    """One prediction request and, once fulfilled, its response.

    status: 'pending' -> 'ok' | 'shed' (queue full, or deadline expired) |
    'refused' (eps spent). A shed request says WHY in ``shed_reason``:
    'full' (no queue room at submit) vs 'timeout' (sat in the queue past its
    ``max_age_s`` deadline — the degraded-fabric signature, where a crashed
    trainer or a compute stall ages the queue instead of overflowing it).
    Timing: ``submitted_at``/``completed_at`` are perf_counter stamps taken
    after the batch's arrays are host-ready (`jax.block_until_ready`), so
    ``latency_s`` measures admission wait + batching wait + compute — not
    async dispatch.
    """

    features: Any
    node: int
    max_age_s: float | None = None       # per-request deadline override
    shed_reason: str | None = None       # 'full' | 'timeout' once shed
    status: str = "pending"
    margin: float | None = None
    label: float | None = None
    snapshot_version: int | None = None
    snapshot_round: int | None = None
    train_round: int | None = None       # trainer progress at completion
    eps_spent: float | None = None
    submitted_at: float | None = None
    completed_at: float | None = None
    _event: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False)

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: float | None = None) -> "Request":
        if not self._event.wait(timeout):
            raise TimeoutError(f"request not served within {timeout}s")
        return self

    @property
    def latency_s(self) -> float | None:
        if self.completed_at is None or self.submitted_at is None:
            return None
        return self.completed_at - self.submitted_at

    @property
    def staleness_rounds(self) -> int | None:
        """How many rounds the served snapshot lagged the trainer."""
        if self.train_round is None or self.snapshot_round is None:
            return None
        return self.train_round - self.snapshot_round

    def _finish(self, status: str, reason: str | None = None) -> None:
        self.status = status
        if reason is not None:
            self.shed_reason = reason
        self.completed_at = time.perf_counter()
        self._event.set()


class ServeStats:
    """Thread-safe serving counters + latency/staleness samples."""

    def __init__(self, max_samples: int = 200_000):
        self._lock = threading.Lock()
        self.max_samples = max_samples
        self.served_total = 0
        self.shed_total = 0
        self.shed_reasons: dict[str, int] = {}
        self.refused_total = 0
        self.batches_total = 0
        self.latencies_s: list[float] = []
        self.staleness: list[int] = []

    def record_served(self, requests: list[Request]) -> None:
        with self._lock:
            self.served_total += len(requests)
            self.batches_total += 1
            room = self.max_samples - len(self.latencies_s)
            for r in requests[:max(room, 0)]:
                if r.latency_s is not None:
                    self.latencies_s.append(r.latency_s)
                if r.staleness_rounds is not None:
                    self.staleness.append(r.staleness_rounds)
        # mirror into the ambient obs registry (repro.obs) — a no-op unless
        # telemetry is enabled, so the serving hot path stays one check
        tel = obslib.active()
        if tel.enabled:
            tel.metrics.counter("serve.served").inc(len(requests))
            tel.metrics.counter("serve.batches").inc()
            hist = tel.metrics.histogram("serve.latency_s")
            for r in requests:
                if r.latency_s is not None:
                    hist.observe(r.latency_s)

    def record_shed(self, n: int = 1, reason: str | None = None) -> None:
        with self._lock:
            self.shed_total += n
            if reason is not None:
                self.shed_reasons[reason] = self.shed_reasons.get(reason, 0) + n
        tel = obslib.active()
        if tel.enabled:
            name = f"serve.shed.{reason}" if reason else "serve.shed"
            tel.metrics.counter(name).inc(n)

    def record_refused(self, n: int = 1) -> None:
        with self._lock:
            self.refused_total += n
        tel = obslib.active()
        if tel.enabled:
            tel.metrics.counter("serve.refused").inc(n)

    def summary(self) -> dict:
        with self._lock:
            lat = np.asarray(self.latencies_s, np.float64)
            stale = np.asarray(self.staleness, np.float64)
            out = {
                "served": self.served_total,
                "shed": self.shed_total,
                "shed_reasons": dict(self.shed_reasons),
                "refused": self.refused_total,
                "batches": self.batches_total,
                "mean_batch": (self.served_total / self.batches_total
                               if self.batches_total else None),
            }
        out["p50_latency_ms"] = (round(float(np.percentile(lat, 50)) * 1e3, 3)
                                 if lat.size else None)
        out["p99_latency_ms"] = (round(float(np.percentile(lat, 99)) * 1e3, 3)
                                 if lat.size else None)
        out["staleness_mean_rounds"] = (round(float(stale.mean()), 2)
                                        if stale.size else None)
        out["staleness_max_rounds"] = (int(stale.max()) if stale.size
                                       else None)
        return out


class AdmissionQueue:
    """Bounded FIFO with shed-on-full and refuse-on-exhaustion semantics."""

    def __init__(self, capacity: int, stats: ServeStats):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self._q: queue.Queue[Request] = queue.Queue(maxsize=capacity)
        self.capacity = capacity
        self.stats = stats

    def submit(self, request: Request, *, refuse: bool = False) -> Request:
        request.submitted_at = time.perf_counter()
        if refuse:
            self.stats.record_refused()
            request._finish("refused")
            return request
        try:
            self._q.put_nowait(request)
        except queue.Full:
            self.stats.record_shed(reason="full")
            request._finish("shed", reason="full")
        return request

    def get(self, timeout: float) -> Request | None:
        try:
            return self._q.get(timeout=timeout)
        except queue.Empty:
            return None

    def empty(self) -> bool:
        return self._q.empty()

    def qsize(self) -> int:
        return self._q.qsize()


class Batcher(threading.Thread):
    """Drains the admission queue into padded fixed-shape predict batches.

    One batch = the first waiting request plus whatever else arrives within
    ``max_wait_s`` of it (up to ``max_batch``). Features are packed into a
    fresh (max_batch, n) buffer — rows beyond the real batch are zero — so
    the jitted predict step sees ONE static shape for the whole lifetime of
    the service, and the feature buffer can be donated on accelerators.

    ``max_age_s`` is the request DEADLINE: a request dequeued more than
    ``max_age_s`` (or its own ``Request.max_age_s``) after submission is
    shed with reason 'timeout' instead of served — a stale prediction to a
    client that already gave up wastes a predict-batch slot. None (default)
    never expires.
    """

    def __init__(self, state: ServeState, admission: AdmissionQueue,
                 stats: ServeStats, *, max_batch: int = 32,
                 max_wait_s: float = 0.002, max_age_s: float | None = None,
                 exhausted=None, train_round=None, poll_s: float = 0.05):
        super().__init__(daemon=True, name="repro-serve-batcher")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_age_s is not None and max_age_s < 0:
            raise ValueError("max_age_s must be >= 0 (None disables)")
        self.state = state
        self.admission = admission
        self.stats = stats
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.max_age_s = max_age_s
        self.poll_s = poll_s
        self._exhausted = exhausted or (lambda: False)
        self._train_round = train_round or (lambda: None)
        self._stopping = threading.Event()
        self._dim = state.spec.dim

    def stop(self) -> None:
        self._stopping.set()

    def _admit(self, request: Request) -> bool:
        """False (and shed with reason 'timeout') iff the request's deadline
        passed while it waited in the queue."""
        limit = (request.max_age_s if request.max_age_s is not None
                 else self.max_age_s)
        if (limit is not None and request.submitted_at is not None
                and time.perf_counter() - request.submitted_at > limit):
            self.stats.record_shed(reason="timeout")
            request._finish("shed", reason="timeout")
            return False
        return True

    def run(self) -> None:
        while True:
            first = self.admission.get(timeout=self.poll_s)
            if first is None:
                if self._stopping.is_set() and self.admission.empty():
                    return
                continue
            if not self._admit(first):
                continue
            batch = [first]
            deadline = time.perf_counter() + self.max_wait_s
            while len(batch) < self.max_batch:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                nxt = self.admission.get(timeout=remaining)
                if nxt is None:
                    break
                if self._admit(nxt):
                    batch.append(nxt)
            self._serve(batch)

    def _serve(self, batch: list[Request]) -> None:
        if self._exhausted():
            # the budget ran out while these sat in the queue: refuse late
            # rather than serve a release the ledger no longer covers
            self.stats.record_refused(len(batch))
            for r in batch:
                r._finish("refused")
            return
        with obslib.active().span("serve.batch", size=len(batch)):
            feats = np.zeros((self.max_batch, self._dim), np.float32)
            nodes = np.zeros((self.max_batch,), np.int32)
            for i, r in enumerate(batch):
                feats[i] = np.asarray(r.features, np.float32)
                nodes[i] = r.node
            margins, labels, snap = self.state.predict(feats, nodes)
            # latency must measure COMPUTE, not async dispatch: block before
            # stamping completion times
            jax.block_until_ready((margins, labels))
        margins = np.asarray(margins)
        labels = np.asarray(labels)
        train_round = self._train_round()
        for i, r in enumerate(batch):
            r.margin = float(margins[i])
            r.label = float(labels[i])
            r.snapshot_version = snap.version
            r.snapshot_round = snap.round
            r.train_round = (train_round if train_round is not None
                             else snap.round)
            r.eps_spent = snap.eps_spent
            r._finish("ok")
        self.stats.record_served(batch)
