"""Replay client: heavy-tailed request arrivals from the `bursty` stream.

The paper's regime is millions of user events arriving at data centers in
bursts. The existing `bursty` STREAMS scenario already owns a seeded
heavy-tailed arrival process — per-(round, node) counts from a capped
discrete Pareto (P(c >= k) ~ k^-tail) — so the replay client derives the
REQUEST load from exactly that process instead of inventing a second one:
tick t fires ``counts(t, i)`` prediction requests at node i, each carrying
that round's feature vector. The same seed therefore replays the same
burst pattern, and the admission layer is exercised by genuinely bursty
(not Poisson-smooth) arrivals.

>>> from repro.api.streams import STREAMS
>>> from repro.serve.replay import BurstyReplay
>>> stream = STREAMS.build("bursty", n=8, nodes=2, rounds=16, seed=3)
>>> replay = BurstyReplay(stream)
>>> ticks = list(replay.ticks(0, 16))
>>> len(ticks), replay.total_requests(0, 16) == sum(len(t) for t in ticks)
(16, True)
>>> max(len(t) for t in ticks) > min(len(t) for t in ticks)   # bursty
True
"""
from __future__ import annotations

import time
from typing import Iterator

import numpy as np

__all__ = ["BurstyReplay"]


class BurstyReplay:
    """Generates per-tick request groups from a BurstyStream-like stream.

    The stream must expose ``counts(t0, t1) -> (T, m)`` burst sizes and
    ``chunk(t0, t1) -> (xs, ys)`` features — i.e. the `bursty` STREAMS
    entry (or anything protocol-compatible).
    """

    def __init__(self, stream):
        if not hasattr(stream, "counts"):
            raise ValueError(
                "BurstyReplay needs a stream with a counts(t0, t1) arrival "
                "process (the 'bursty' STREAMS scenario)")
        self.stream = stream

    def total_requests(self, t0: int, t1: int) -> int:
        return int(np.asarray(self.stream.counts(t0, t1)).sum())

    def ticks(self, t0: int, t1: int) -> Iterator[list[tuple[np.ndarray, int]]]:
        """One list of (features, node) requests per tick in [t0, t1).

        A (tick, node) with burst size c contributes c requests carrying
        that round's feature row — the arrival pattern the admission layer
        must absorb or shed.
        """
        counts = np.asarray(self.stream.counts(t0, t1))        # (T, m)
        xs, _ = self.stream.chunk(t0, t1)
        xs = np.asarray(xs)                                    # (T, m, n)
        for t in range(t1 - t0):
            group = []
            for i in range(counts.shape[1]):
                group.extend((xs[t, i], i) for _ in range(counts[t, i]))
            yield group

    def drive(self, service, t0: int, t1: int, *,
              rate_ticks_per_s: float | None = None,
              timeout_s: float = 60.0) -> dict:
        """Submit every tick's burst to ``service`` and wait for the tail.

        ``rate_ticks_per_s`` paces the replay (None = open throttle, the
        sustained-QPS measurement); the wall-clock window runs from the
        first submit to the last completion, so QPS counts COMPLETED
        requests per second.
        """
        requests = []
        tick_period = (1.0 / rate_ticks_per_s) if rate_ticks_per_s else 0.0
        t_start = time.perf_counter()
        next_tick = t_start
        for group in self.ticks(t0, t1):
            if tick_period:
                delay = next_tick - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
                next_tick += tick_period
            for features, node in group:
                requests.append(service.submit(features, node))
        for r in requests:
            if not r.done():
                r.wait(timeout=timeout_s)
        wall = time.perf_counter() - t_start
        served = [r for r in requests if r.status == "ok"]
        return {
            "ticks": t1 - t0,
            "submitted": len(requests),
            "served": len(served),
            "shed": sum(r.status == "shed" for r in requests),
            "refused": sum(r.status == "refused" for r in requests),
            "wall_s": wall,
            "qps": len(served) / wall if wall > 0 else float("inf"),
            "requests": requests,
        }
