"""Published model snapshots + the jitted batched-predict step.

The serving layer never reads the trainer's live engine state: the trainer
PUBLISHES immutable :class:`Snapshot` objects (per-node primal models ``w``,
the running average ``w_bar``, the round they were trained to and the eps
spent releasing them) into a :class:`ServeState`, and every prediction is
served against exactly one published snapshot — an atomic reference swap,
so a request can never observe half of round t and half of round t+k.

A snapshot at round r is bit-identical to ``repro.api.run(spec,
horizon=r)``'s final state (streams are keyed per absolute round and
chunking never changes the per-round math), which is what
`verify_snapshot` — and the BENCH_serve.json ``snapshot_identical`` gate —
check end-to-end.

>>> import jax.numpy as jnp
>>> from repro.api import RunSpec
>>> from repro.serve.state import ServeState, Snapshot
>>> spec = RunSpec(nodes=2, dim=4, horizon=8, eps=1.0, alpha0=0.5, lam=0.01)
>>> state = ServeState(spec)
>>> snap = state.publish_initial()        # round-0 model: w == 0
>>> snap.round, snap.version, snap.eps_spent
(0, 0, 0.0)
>>> margins, labels, used = state.predict(jnp.ones((3, 4)),
...                                       jnp.asarray([0, 1, 0]))
>>> [float(m) for m in margins], [float(l) for l in labels], used.version
([0.0, 0.0, 0.0], [1.0, 1.0, 1.0], 0)
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.spec import RunSpec

__all__ = ["Snapshot", "ServeState", "make_predict_fn", "snapshot_from_state",
           "verify_snapshot"]


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """One immutable published model: what a prediction is served against.

    version:   monotone publication counter (0 = the initial round-0 model).
    round:     absolute training round the snapshot was taken at.
    theta:     (m, n) dual parameters at ``round`` (kept for audit/resume).
    w:         (m, n) per-node primal models (local rule's Lasso prox).
    w_bar:     (n,) running-average model (Definition-3's comparator view).
    eps_spent: cumulative privacy guarantee charged for releasing this
               snapshot (see `repro.serve.trainer` for the composition
               policy).
    """

    version: int
    round: int
    theta: jax.Array
    w: jax.Array
    w_bar: jax.Array
    eps_spent: float


def snapshot_from_state(spec: RunSpec, engine: str, state, *, version: int,
                        eps_spent: float) -> Snapshot:
    """Snapshot of one engine state — the same primal-recovery convention as
    `repro.api.runner`'s ``RunResult.final_w``, so a published snapshot and
    a reference run at the same round agree to the bit."""
    rule = spec.resolve_local_rule()
    ctx = spec.omd_config().step_context(state.t)
    theta = state.theta if engine == "sim" else state.theta["w"]
    w = rule.primal(theta, ctx)
    return Snapshot(version=version, round=int(state.t),
                    theta=jnp.asarray(theta), w=jnp.asarray(w),
                    w_bar=jnp.mean(w, axis=0), eps_spent=float(eps_spent))


def make_predict_fn(mode: str = "node") -> Callable:
    """The jitted batched-predict step: (w, w_bar, features, node_ids) ->
    (margins, labels) for a (B, n) feature batch.

    mode='node' serves each request against its data center's own model
    row ``w[node]``; mode='average' serves everyone the consensus ``w_bar``.
    The feature batch is DONATED (it is created per batch by the batcher and
    never read again), so steady-state serving allocates no new buffer for
    it. Labels follow the stream convention: +1 iff margin >= 0.
    """
    if mode == "node":
        def predict(w, w_bar, features, node_ids):
            rows = jnp.take(w, node_ids, axis=0)              # (B, n)
            margins = jnp.sum(rows * features, axis=-1)
            return margins, jnp.where(margins >= 0, 1.0, -1.0)
    elif mode == "average":
        def predict(w, w_bar, features, node_ids):
            margins = jnp.sum(w_bar[None, :] * features, axis=-1)
            return margins, jnp.where(margins >= 0, 1.0, -1.0)
    else:
        raise ValueError(f"unknown predict mode {mode!r}; "
                         "expected 'node' or 'average'")
    # donation is a no-op on CPU and would only emit a warning per compile;
    # the buffer reuse matters on accelerator backends
    donate = () if jax.default_backend() == "cpu" else (2,)
    return jax.jit(predict, donate_argnums=donate)


class ServeState:
    """Current snapshot + a bounded history ring of recent publications.

    ``publish`` swaps the current-snapshot reference under a lock (readers
    see the old model or the new one, never a mix); the last ``keep``
    snapshots stay reachable by version so a response recorded against
    version v can be re-verified after later publications.
    """

    def __init__(self, spec: RunSpec, engine: str = "sim",
                 mode: str = "node", keep: int = 8):
        if keep < 1:
            raise ValueError("keep must be >= 1")
        self.spec = spec
        self.engine = engine
        self.mode = mode
        self.keep = keep
        self.predict_fn = make_predict_fn(mode)
        self._lock = threading.Lock()
        self._current: Snapshot | None = None
        self._history: collections.OrderedDict[int, Snapshot] = \
            collections.OrderedDict()
        self._published = 0

    # -- publication ---------------------------------------------------------

    def publish(self, snapshot: Snapshot) -> None:
        with self._lock:
            self._current = snapshot
            self._history[snapshot.version] = snapshot
            while len(self._history) > self.keep:
                self._history.popitem(last=False)
            self._published += 1

    def publish_initial(self) -> Snapshot:
        """Publish the round-0 model (w = 0, eps 0) so the service answers
        from the first request, before the trainer's first chunk lands."""
        from repro.api.runner import make_chunk_program
        _, init_fn = make_chunk_program(self.spec, self.engine)
        state = init_fn(jax.random.PRNGKey(self.spec.seed))
        snap = snapshot_from_state(self.spec, self.engine, state,
                                   version=0, eps_spent=0.0)
        self.publish(snap)
        return snap

    # -- reads ---------------------------------------------------------------

    @property
    def current(self) -> Snapshot | None:
        with self._lock:
            return self._current

    @property
    def published(self) -> int:
        with self._lock:
            return self._published

    def snapshot(self, version: int) -> Snapshot | None:
        """A recent snapshot by version (None once pruned past ``keep``)."""
        with self._lock:
            return self._history.get(version)

    def predict(self, features, node_ids):
        """(margins, labels, snapshot) for one feature batch against the
        CURRENT snapshot — one atomic snapshot read per batch."""
        snap = self.current
        if snap is None:
            raise RuntimeError("no snapshot published yet — call "
                               "publish_initial() (ServeService.start does)")
        feats = jnp.asarray(features, jnp.float32)
        margins, labels = self.predict_fn(snap.w, snap.w_bar, feats,
                                          jnp.asarray(node_ids, jnp.int32))
        return margins, labels, snap


def verify_snapshot(spec: RunSpec, engine: str, snapshot: Snapshot, *,
                    chunk_rounds: int = 128,
                    node_devices: int | str | None = None,
                    atol: float = 0.0) -> bool:
    """True iff ``snapshot`` is bit-identical to a fresh reference run.

    Re-runs ``repro.api.run(spec, horizon=snapshot.round)`` from scratch
    (any chunking — the per-round math is chunk-invariant) and compares the
    recovered primal models bit-for-bit. The serving acceptance gate: a
    served prediction is exactly what the reference model at the recorded
    snapshot round would have said.

    A NODE-SHARDED trainer (``run(..., node_devices=D)``, see
    `repro.api.shard_node`) is verified by replaying under the same
    ``node_devices`` — the sharded program is deterministic, so the replay
    is still bit-identical. Cross-layout verification (sharded snapshot vs
    dense replay or vice versa) differs by float32 reduction order only;
    pass ``atol`` to bound it instead of requiring equal bits.
    """
    from repro.api.exec_config import ExecConfig
    from repro.api.runner import run
    if snapshot.round == 0:
        return bool(np.all(np.asarray(snapshot.w) == 0.0))
    ref = run(spec, engine=engine, horizon=snapshot.round,
              exec=ExecConfig(chunk_rounds=chunk_rounds, compute_regret=False,
                              warmup=False, node_devices=node_devices))
    ref_snap = snapshot_from_state(spec, engine, ref.final_state,
                                   version=-1, eps_spent=0.0)
    w, ref_w = np.asarray(snapshot.w), np.asarray(ref_snap.w)
    wb, ref_wb = np.asarray(snapshot.w_bar), np.asarray(ref_snap.w_bar)
    if atol:
        return (bool(np.abs(w - ref_w).max() <= atol)
                and bool(np.abs(wb - ref_wb).max() <= atol))
    return (bool(np.array_equal(w, ref_w))
            and bool(np.array_equal(wb, ref_wb)))
