"""repro.serve — online prediction while the model keeps learning.

The paper's premise is serving social predictions to millions of users
from distributed data centers while the model trains online under
differential privacy; this subsystem closes that loop on top of
`repro.api`:

  `ServeState`     — atomically-published model snapshots + a jitted,
                     batch-shaped predict step (per-node ``w`` or the
                     running average ``w_bar``).
  `BackgroundTrainer` — continuous gossip/update rounds in fixed chunks
                     (the runner's ``on_chunk`` hook), each chunk boundary
                     publishing a fresh snapshot; serving-side eps ledger
                     with an optional budget that, once spent, refuses
                     further requests.
  `AdmissionQueue`/`Batcher` — bounded queue, max-batch/max-wait batching,
                     load shedding with counters.
  `BurstyReplay`   — heavy-tailed request arrivals derived from the
                     `bursty` stream's seeded Pareto burst process.
  `ServeService`   — the assembled service (plus threaded checkpointing of
                     the serving state via `repro.checkpoint`).

>>> from repro.serve import ServeConfig, ServeService
>>> from repro.api import RunSpec
>>> spec = RunSpec(nodes=2, dim=4, horizon=8, eps=1.0, alpha0=0.5, lam=0.01,
...                stream="bursty")
>>> svc = ServeService(ServeConfig(spec=spec, train=False, warmup=False,
...                                max_wait_ms=0.5)).start()
>>> svc.predict([0.5] * 4, node=1).status
'ok'
>>> svc.stop()
"""
from repro.serve.admission import AdmissionQueue, Batcher, Request, ServeStats
from repro.serve.replay import BurstyReplay
from repro.serve.service import ServeConfig, ServeService
from repro.serve.state import (ServeState, Snapshot, make_predict_fn,
                               snapshot_from_state, verify_snapshot)
from repro.serve.trainer import BackgroundTrainer, TrainerCrash

__all__ = [
    "AdmissionQueue", "Batcher", "Request", "ServeStats",
    "BurstyReplay",
    "ServeConfig", "ServeService",
    "ServeState", "Snapshot", "make_predict_fn", "snapshot_from_state",
    "verify_snapshot",
    "BackgroundTrainer", "TrainerCrash",
]
