"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

Used by mixtral-8x7b (8 experts, top-2, softmax gate) and llama4-scout
(16 experts, top-1, sigmoid gate + always-on shared expert).

Dispatch strategy (TPU-friendly, FLOP-faithful): assignments are sorted by
expert id, each expert processes a fixed-capacity (E, C, D) buffer with a
batched matmul — compiled FLOPs are proportional to *active* expert compute
(C ~ N*k/E * capacity_factor), not to E * dense like the naive one-hot
einsum. Overflowed tokens (> capacity) are dropped (standard practice); the
router aux loss keeps load balanced so drops are rare.

Expert buffers have a leading E axis that the sharding rules may place on
the model axis (expert parallelism) or keep replicated with tensor-parallel
experts — the hillclimb compares both.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig


import contextlib

# §Perf: grouped-dispatch context (set by serving/dry-run perf variants).
# value = (groups, mesh_axis_for_group_dim or None)
_DISPATCH_GROUPS: list = [1]
_DISPATCH_AXIS: list = [None]


@contextlib.contextmanager
def grouped_dispatch(groups: int, axis: str | None = None):
    _DISPATCH_GROUPS.append(groups)
    _DISPATCH_AXIS.append(axis)
    try:
        yield
    finally:
        _DISPATCH_GROUPS.pop()
        _DISPATCH_AXIS.pop()


def current_dispatch_groups() -> int:
    return _DISPATCH_GROUPS[-1]


def moe_init(key, cfg: ModelConfig) -> dict:
    E = cfg.num_experts
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    d, f = cfg.d_model, cfg.d_ff
    std_in = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    std_out = 1.0 / jnp.sqrt(f).astype(jnp.float32)
    p = {
        "router": layers.linear_init(kr, d, E, jnp.float32),  # router in f32
        "gate": (jax.random.normal(kg, (E, d, f), jnp.float32) * std_in).astype(cfg.jdtype),
        "up": (jax.random.normal(ku, (E, d, f), jnp.float32) * std_in).astype(cfg.jdtype),
        "down": (jax.random.normal(kd, (E, f, d), jnp.float32) * std_out).astype(cfg.jdtype),
    }
    if cfg.shared_expert:
        from repro.models import mlp as mlp_mod
        p["shared"] = mlp_mod.mlp_init(ks, cfg)
    return p


def _router(p, cfg: ModelConfig, xf: jax.Array):
    """Returns (weights (N, k), expert_idx (N, k), aux_loss scalar)."""
    logits = layers.linear(p["router"], xf.astype(jnp.float32))  # (N, E)
    k = cfg.num_experts_per_tok
    top_logits, top_idx = jax.lax.top_k(logits, k)
    if k == 1:
        weights = jax.nn.sigmoid(top_logits)  # llama4-style gate
    else:
        weights = jax.nn.softmax(top_logits, axis=-1)  # mixtral renormalized

    # Switch-style load-balance loss: E * sum_e f_e * P_e
    probs = jax.nn.softmax(logits, axis=-1)
    E = cfg.num_experts
    assign = jax.nn.one_hot(top_idx[:, 0], E)  # primary assignment fraction
    f_e = assign.mean(axis=0)
    P_e = probs.mean(axis=0)
    aux = E * jnp.sum(f_e * P_e)
    return weights, top_idx, aux


def moe_apply(p: dict, cfg: ModelConfig, x: jax.Array,
              dispatch_groups: int = 1) -> tuple[jax.Array, jax.Array]:
    """x (B, T, D) -> (y (B, T, D), aux_loss).

    dispatch_groups > 1 splits tokens into G independent dispatch groups
    (vmapped); with G = the data-axis size and the group dim sharded over
    "data", the argsort/scatter/gather become shard-local instead of
    replicated giant scatters — §Perf hillclimb H1 iter 5. Capacity per
    group is C/G (same total).
    """
    if dispatch_groups > 1:
        B, T, D = x.shape
        N = B * T
        G = dispatch_groups
        assert N % G == 0, (N, G)
        xg = x.reshape(G, 1, N // G, D)
        if _DISPATCH_AXIS[-1] is not None:
            from jax.sharding import PartitionSpec as P
            xg = jax.lax.with_sharding_constraint(
                xg, P(_DISPATCH_AXIS[-1], None, None, None))
        yg, auxg = jax.vmap(lambda xx: moe_apply(p, cfg, xx, 1))(xg)
        return yg.reshape(B, T, D), jnp.mean(auxg)

    B, T, D = x.shape
    N = B * T
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    xf = x.reshape(N, D)

    weights, top_idx, aux = _router(p, cfg, xf)

    # capacity per expert (static)
    C = int(max(1, round(N * k / E * cfg.moe_capacity_factor)))
    C = min(C, N)

    # ---- sort assignments by expert ----
    Nk = N * k
    eid = top_idx.reshape(Nk)
    tok = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)
    wgt = weights.reshape(Nk)
    order = jnp.argsort(eid, stable=True)
    eid_s, tok_s, wgt_s = eid[order], tok[order], wgt[order]

    # position of each assignment within its expert group
    starts = jnp.searchsorted(eid_s, jnp.arange(E), side="left")
    pos_s = jnp.arange(Nk, dtype=jnp.int32) - starts[eid_s].astype(jnp.int32)
    keep = pos_s < C
    slot = jnp.where(keep, pos_s, C)  # overflow slot C is discarded

    # ---- scatter tokens into (E, C+1, D) buffers ----
    buf = jnp.zeros((E, C + 1, D), x.dtype)
    buf = buf.at[eid_s, slot].set(xf[tok_s].astype(x.dtype), mode="drop")
    buf = buf[:, :C]  # (E, C, D)

    # ---- expert FFN: batched SwiGLU over the expert axis ----
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["up"]
    )
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["down"])  # (E, C, D)

    # ---- gather back + weighted combine ----
    y_assign = y_buf[eid_s, jnp.minimum(slot, C - 1)]
    y_assign = jnp.where(keep[:, None], y_assign, 0.0) * wgt_s[:, None].astype(x.dtype)
    y = jnp.zeros((N, D), x.dtype).at[tok_s].add(y_assign)

    if cfg.shared_expert:
        from repro.models import mlp as mlp_mod
        y = y + mlp_mod.mlp(p["shared"], cfg, xf)
    return y.reshape(B, T, D), aux * cfg.router_aux_coef
