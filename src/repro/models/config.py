"""Model configuration shared by every architecture in the framework.

One dataclass covers the 6 assigned architecture families (dense / moe /
vlm / ssm / hybrid / audio enc-dec); family-specific fields default to
"off". Each assigned architecture instantiates this in
``src/repro/configs/<id>.py`` with the exact published numbers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # 'dense' | 'moe' | 'rwkv6' | 'rglru_hybrid' | 'encdec'
    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    vocab_size: int
    num_kv_heads: int = 0            # 0 => == num_heads (MHA)
    head_dim: int = 0                # 0 => d_model // num_heads

    # ---- attention options ----
    rope_theta: float = 10_000.0
    rope_style: str = "standard"     # 'standard' | 'mrope' | 'none'
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # qwen2-vl (sum = head_dim//2)
    use_qkv_bias: bool = False       # qwen2 family
    use_qk_norm: bool = False        # qwen3
    sliding_window: Optional[int] = None   # SWA (mixtral 4096); None = full causal
    attn_logit_softcap: Optional[float] = None

    # ---- MoE ----
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    shared_expert: bool = False      # llama4: one always-on shared expert
    router_aux_coef: float = 0.01    # load-balance loss coefficient

    # ---- RWKV6 (Finch) ----
    rwkv_head_dim: int = 64

    # ---- RG-LRU hybrid (RecurrentGemma) ----
    rglru_width: int = 0             # recurrence width (d_rnn); 0 => d_model
    rglru_conv_width: int = 4
    local_attn_window: int = 2048    # window of the 1-in-3 local attention blocks
    hybrid_pattern: tuple[str, ...] = ("rec", "rec", "attn")  # 1:2 attn:rec

    # ---- encoder-decoder (seamless-m4t backbone) ----
    encoder_layers: int = 0          # >0 => enc-dec; num_layers = decoder layers

    # ---- modality frontend STUB (carve-out) ----
    frontend: Optional[str] = None   # None | 'vision' | 'audio'
    frontend_tokens: int = 0         # embeddings prepended by the stub
    # ---- misc ----
    norm: str = "rmsnorm"            # 'rmsnorm' | 'layernorm'
    act: str = "silu"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    # long_500k support: window to use for the 500k decode variant; None and
    # sliding_window None and family dense => long_500k skipped.
    window_500k: Optional[int] = None
    # layer stacking strategy: homogeneous families scan over stacked layer
    # params (fast compile at 64 layers); heterogeneous loop python-side.
    scan_layers: bool = True

    # remat each layer's forward in the backward pass (production default;
    # without it the saved attention probabilities of a 40L x 4k-seq train
    # step are ~400 GB/device — see EXPERIMENTS.md §Dry-run)
    remat: bool = True

    # ------------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 256 (16 model x 16 data) so the
        embedding/unembedding shard cleanly (Megatron-style padding).
        Padded logit columns are masked to -inf before the softmax."""
        return -(-self.vocab_size // 256) * 256

    @property
    def kv_heads(self) -> int:
        return self.num_kv_heads or self.num_heads

    @property
    def dims_per_head(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def rnn_width(self) -> int:
        return self.rglru_width or self.d_model

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_subquadratic(self) -> bool:
        """Can this config decode at 524k context without a 524k KV cache?"""
        if self.family in ("rwkv6", "rglru_hybrid"):
            return True
        if self.sliding_window is not None or self.window_500k is not None:
            return True
        return False

    @property
    def supports_decode(self) -> bool:
        return True  # all assigned archs decode (enc-dec via its decoder)

    def reduced(self, layers: int = 2, d_model: int = 256, d_ff: int = 512,
                vocab: int = 512, experts: int = 4) -> "ModelConfig":
        """Smoke-test variant of the same family (<=512 width, <=4 experts)."""
        heads = max(2, min(4, self.num_heads))
        kvh = max(1, min(self.kv_heads, heads))
        while heads % kvh:
            kvh -= 1
        head_dim = max(16, d_model // heads)
        sec = None
        if self.rope_style == "mrope":
            half = head_dim // 2
            a = half // 4
            sec = (a, (half - a) // 2, half - a - (half - a) // 2)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kvh,
            head_dim=head_dim,
            d_ff=d_ff,
            vocab_size=vocab,
            num_experts=min(self.num_experts, experts) if self.num_experts else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2) if self.num_experts_per_tok else 0,
            # drop-free capacity at smoke scale so decode == apply exactly;
            # the 1.25 production factor (with drops) is covered by test_moe
            moe_capacity_factor=float(max(experts, 1)),
            encoder_layers=min(self.encoder_layers, layers) if self.encoder_layers else 0,
            sliding_window=min(self.sliding_window, 128) if self.sliding_window else None,
            local_attn_window=min(self.local_attn_window, 64),
            rglru_width=0,
            rwkv_head_dim=32,
            frontend_tokens=min(self.frontend_tokens, 8) if self.frontend_tokens else 0,
            mrope_sections=sec if sec else self.mrope_sections,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
