"""Encoder-decoder backbone (seamless-m4t-medium, arXiv:2308.11596).

Transformer backbone ONLY (per carve-out): the speech frontend
(mel-spectrogram + conv feature extractor) is stubbed — ``apply`` consumes
precomputed frame embeddings (B, S_enc, D). Encoder = bidirectional
self-attention; decoder = causal self-attention + cross-attention over the
encoder memory + FFN. M4T's relative positional scheme is approximated by
RoPE on self-attention (documented deviation; shape/FLOP-faithful).

Decode: self-attn ring cache + cross-attn K/V precomputed once from memory.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention, layers, mlp
from repro.models.config import ModelConfig
from repro.models.transformer import Model

NEG_INF = attention.NEG_INF


# ---------------------------------------------------------------------------
# cross attention
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg: ModelConfig) -> dict:
    hd = cfg.dims_per_head
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": layers.linear_init(kq, cfg.d_model, cfg.num_heads * hd, cfg.jdtype),
        "wk": layers.linear_init(kk, cfg.d_model, cfg.kv_heads * hd, cfg.jdtype),
        "wv": layers.linear_init(kv, cfg.d_model, cfg.kv_heads * hd, cfg.jdtype),
        "wo": layers.linear_init(ko, cfg.num_heads * hd, cfg.d_model, cfg.jdtype),
    }


def cross_kv(p, cfg: ModelConfig, memory):
    B, S, _ = memory.shape
    hd = cfg.dims_per_head
    k = layers.linear(p["wk"], memory).reshape(B, S, cfg.kv_heads, hd)
    v = layers.linear(p["wv"], memory).reshape(B, S, cfg.kv_heads, hd)
    return k, v


def cross_attend(p, cfg: ModelConfig, x, k, v):
    """x (B, T, D) queries over precomputed memory K/V (B, S, Kv, hd)."""
    B, T, _ = x.shape
    hd = cfg.dims_per_head
    Kv, g = cfg.kv_heads, cfg.num_heads // cfg.kv_heads
    q = layers.linear(p["wq"], x).reshape(B, T, Kv, g, hd)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("btkgh,bskh->bkgts", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    prob = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bskh->btkgh", prob, v.astype(jnp.float32))
    o = o.reshape(B, T, cfg.num_heads * hd).astype(x.dtype)
    return layers.linear(p["wo"], o)


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------

def _enc_layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": layers.norm_init(cfg.norm, cfg.d_model),
        "attn": attention.attn_init(k1, cfg),
        "ln2": layers.norm_init(cfg.norm, cfg.d_model),
        "ffn": mlp.mlp_init(k2, cfg),
    }


def _dec_layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": layers.norm_init(cfg.norm, cfg.d_model),
        "attn": attention.attn_init(k1, cfg),
        "ln_x": layers.norm_init(cfg.norm, cfg.d_model),
        "cross": cross_attn_init(k2, cfg),
        "ln2": layers.norm_init(cfg.norm, cfg.d_model),
        "ffn": mlp.mlp_init(k3, cfg),
    }


def _enc_layer(p, cfg: ModelConfig, x, positions):
    xn = layers.apply_norm(cfg.norm, p["ln1"], x, cfg.norm_eps)
    # bidirectional: full attention without causal mask
    B, T, _ = x.shape
    q, k, v = attention._project_qkv(p["attn"], cfg, xn, positions)
    pos = jnp.arange(T)
    h = attention._full_attention(q, k, v, pos, pos, None, None, causal=False)
    x = x + layers.linear(p["attn"]["wo"], h.reshape(B, T, -1))
    xn = layers.apply_norm(cfg.norm, p["ln2"], x, cfg.norm_eps)
    return x + mlp.mlp(p["ffn"], cfg, xn)


def _dec_layer(p, cfg: ModelConfig, x, positions, mem_k, mem_v):
    xn = layers.apply_norm(cfg.norm, p["ln1"], x, cfg.norm_eps)
    x = x + attention.attention_full(p["attn"], cfg, xn, positions)
    xn = layers.apply_norm(cfg.norm, p["ln_x"], x, cfg.norm_eps)
    x = x + cross_attend(p["cross"], cfg, xn, mem_k, mem_v)
    xn = layers.apply_norm(cfg.norm, p["ln2"], x, cfg.norm_eps)
    return x + mlp.mlp(p["ffn"], cfg, xn)


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def build_encdec(cfg: ModelConfig) -> Model:
    assert cfg.encoder_layers > 0

    def init(key):
        ke, kd, kt, kn = jax.random.split(key, 4)
        enc_keys = jax.random.split(ke, cfg.encoder_layers)
        dec_keys = jax.random.split(kd, cfg.num_layers)
        return {
            "embed": layers.embed_init(kt, cfg.vocab_padded, cfg.d_model, cfg.jdtype),
            "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
            "enc_norm": layers.norm_init(cfg.norm, cfg.d_model),
            "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
            "final_norm": layers.norm_init(cfg.norm, cfg.d_model),
        }

    def encode(params, frames):
        """frames (B, S_enc, D) — stub frontend embeddings."""
        B, S, _ = frames.shape
        positions = attention.default_positions(B, S, cfg)
        x = frames.astype(cfg.jdtype)

        enc_fn = lambda lp, x: _enc_layer(lp, cfg, x, positions)
        if cfg.remat:
            enc_fn = jax.checkpoint(enc_fn, policy=jax.checkpoint_policies.nothing_saveable)

        def body(x, lp):
            return enc_fn(lp, x), None

        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return layers.apply_norm(cfg.norm, params["enc_norm"], x, cfg.norm_eps)

    def _logits(params, x):
        x = layers.apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
        return layers.mask_padded_vocab(layers.unembed(params["embed"], x), cfg.vocab_size)

    def apply(params, tokens, frontend: Optional[jax.Array] = None,
              last_only: bool = False):
        """frontend = encoder frame embeddings (required)."""
        memory = encode(params, frontend)
        B, T = tokens.shape
        positions = attention.default_positions(B, T, cfg)
        x = layers.embed(params["embed"], tokens)

        def dec_fn(lp, x):
            k, v = cross_kv(lp["cross"], cfg, memory)
            return _dec_layer(lp, cfg, x, positions, k, v)
        if cfg.remat:
            dec_fn = jax.checkpoint(dec_fn, policy=jax.checkpoint_policies.nothing_saveable)

        def body(x, lp):
            return dec_fn(lp, x), None

        x, _ = jax.lax.scan(body, x, params["dec_layers"])
        if last_only:
            x = x[:, -1:]
        return _logits(params, x), jnp.zeros((), jnp.float32)

    def loss_fn(params, batch):
        logits, aux = apply(params, batch["tokens"], batch["frontend"])
        labels = batch["labels"]
        mask = labels >= 0
        safe = jnp.maximum(labels, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1)
        return ce + aux, {"ce": ce, "aux": aux}

    def init_cache(batch: int, cache_len: int):
        one = attention.init_attn_cache(cfg, batch, cache_len, cfg.jdtype)
        self_cache = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l[None], (cfg.num_layers,) + l.shape).copy(), one)
        # cross K/V filled by prime_cache from the encoder memory
        hd = cfg.dims_per_head
        S = max(cache_len // 4, 8)  # encoder frames (see configs/seamless)
        zeros = jnp.zeros((cfg.num_layers, batch, S, cfg.kv_heads, hd), cfg.jdtype)
        return {"self": self_cache, "cross_k": zeros, "cross_v": zeros}

    def prime_cache(params, cache, frames):
        """Run the encoder once and fill the cross-attention K/V."""
        memory = encode(params, frames)

        def per_layer(lp):
            return cross_kv(lp["cross"], cfg, memory)

        ks, vs = jax.vmap(per_layer)(params["dec_layers"])
        return {**cache, "cross_k": ks, "cross_v": vs}

    def decode_step(params, cache, tokens, pos):
        x = layers.embed(params["embed"], tokens)

        def body(x, lpc):
            lp, self_c, ck, cv = lpc
            xn = layers.apply_norm(cfg.norm, lp["ln1"], x, cfg.norm_eps)
            h, self_c = attention.attention_decode(lp["attn"], cfg, xn, pos, self_c)
            x = x + h
            xn = layers.apply_norm(cfg.norm, lp["ln_x"], x, cfg.norm_eps)
            x = x + cross_attend(lp["cross"], cfg, xn, ck, cv)
            xn = layers.apply_norm(cfg.norm, lp["ln2"], x, cfg.norm_eps)
            x = x + mlp.mlp(lp["ffn"], cfg, xn)
            return x, self_c

        x, new_self = jax.lax.scan(
            body, x, (params["dec_layers"], cache["self"], cache["cross_k"], cache["cross_v"]))
        return _logits(params, x), {**cache, "self": new_self}

    return Model(cfg=cfg, init=init, apply=apply, loss_fn=loss_fn,
                 init_cache=init_cache, decode_step=decode_step,
                 prime_cache=prime_cache)
