"""Attention: GQA/MHA, RoPE + M-RoPE, qk-norm, QKV bias, sliding window,
blockwise (memory-efficient) prefill, and ring-buffer KV-cache decode.

Design notes
------------
* GQA is computed grouped — queries reshaped to (B, kv_heads, group, T, hd)
  and contracted against un-repeated K/V, so no (B, H, S, hd) repeat is ever
  materialized.
* Sequences longer than ``BLOCKWISE_THRESHOLD`` use a two-level blockwise
  softmax (lax.scan over query chunks, inner scan over key chunks, online
  max/denominator) — O(qc*kc) temporaries instead of O(T^2). This is the
  pure-JAX reference; the Pallas flash kernel of the perf phase swaps in
  underneath `attention_full`.
* The decode cache is a ring buffer of ``cache_len`` slots with an explicit
  per-slot absolute-position array: full causal, sliding-window and the
  window_500k long-context variant all fall out of one mask rule
  (slot_pos >= 0) & (slot_pos <= pos) & (slot_pos > pos - window).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig

BLOCKWISE_THRESHOLD = 2048
Q_CHUNK = 512
K_CHUNK = 1024
NEG_INF = -1e30

# §Perf hillclimb: sequence-parallel attention. When set (an axis name, e.g.
# "model"), blockwise attention constrains q/k/v to shard their TIME dim over
# that axis instead of letting GSPMD shard the contracting head_dim — which
# (for head counts not divisible by the axis, e.g. llama4's 40 heads on 16
# chips) otherwise emits one partial-product all-reduce of the SCORE tensor
# per (layer x q-chunk x k-chunk): 98k all-reduces / 4.5 TB per device on
# llama4 prefill_32k. Enabled per-step via `sequence_parallel(axis)`.
import contextlib

_SEQ_PARALLEL_AXIS: list = [None]


@contextlib.contextmanager
def sequence_parallel(axis: str | None):
    _SEQ_PARALLEL_AXIS.append(axis)
    try:
        yield
    finally:
        _SEQ_PARALLEL_AXIS.pop()


# §Perf H2 iter 2: head padding. When a GQA head count doesn't divide the
# model axis (qwen2-7b: 28 heads on 16 chips), GSPMD factorizes the head dim
# with the CONTRACTING head_dim (e.g. 4x4) and emits a partial-product
# all-reduce of the score tensor per chunk. Padding each kv group with zero
# query heads up to g' = ceil-to-divisible is mathematically exact (padded
# outputs are sliced away before wo) and makes the head dim divide cleanly —
# no score collectives, +g'/g attention flops.
_HEAD_PAD_MULTIPLE: list = [None]
_HEAD_PAD_AXIS: list = [None]


@contextlib.contextmanager
def head_padding(multiple: int | None, axis: str | None = None):
    """axis: additionally constrain q head-sharded on `axis` and k/v
    REPLICATED over it — kv tensors are small and replicating them is what
    prevents GSPMD from sharding the contracting head_dim (iter 3)."""
    _HEAD_PAD_MULTIPLE.append(multiple)
    _HEAD_PAD_AXIS.append(axis)
    try:
        yield
    finally:
        _HEAD_PAD_MULTIPLE.pop()
        _HEAD_PAD_AXIS.pop()


def _padded_group(cfg, H: int, Kv: int) -> int:
    mult = _HEAD_PAD_MULTIPLE[-1]
    if mult is None or H % mult == 0:
        return H // Kv
    g = H // Kv
    # smallest g' >= g with Kv*g' % mult == 0
    g2 = g
    while (Kv * g2) % mult:
        g2 += 1
    return g2


def _maybe_pad_heads(q, k, v, cfg):
    """Pad heads so the sharded head dim divides the mesh axis.

    GQA (g > 1): pad each kv group with zero QUERY heads (k/v untouched).
    MHA/per-head (g == 1): pad BOTH q and k/v with dummy heads — each real
    head still attends only its own kv, dummy outputs are sliced away.
    Returns (q, k, v, H_orig, kv_padded: bool).
    """
    B, T, H, hd = q.shape
    Kv = cfg.kv_heads
    g = H // Kv
    mult = _HEAD_PAD_MULTIPLE[-1]
    if mult is None or H % mult == 0:
        return q, k, v, H, False
    if g > 1:
        g2 = _padded_group(cfg, H, Kv)
        qg = q.reshape(B, T, Kv, g, hd)
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, g2 - g), (0, 0)))
        return qg.reshape(B, T, Kv * g2, hd), k, v, H, False
    # MHA: pad q AND kv heads to the next multiple
    H2 = -(-H // mult) * mult
    pad = ((0, 0), (0, 0), (0, H2 - H), (0, 0))
    return jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad), H, True


def _maybe_unpad_heads(o, cfg, H_orig, kv_padded):
    B, T, H2, hd = o.shape
    if H2 == H_orig:
        return o
    if kv_padded:  # MHA padding: plain head slice
        return o[:, :, :H_orig]
    Kv = cfg.kv_heads
    g = H_orig // Kv
    og = o.reshape(B, T, Kv, H2 // Kv, hd)[:, :, :, :g]
    return og.reshape(B, T, H_orig, hd)


# §Perf H2 iter 1: batch-parallel attention for training. Per-node batch (16) ==
# model-axis size, so sharding the BATCH dim of q/k/v over "model" gives
# each chip whole sequences — zero attention collectives (vs partial-product
# all-reduces of score tensors when GSPMD shards the contracting head_dim
# for kv_heads < axis size). Train-only (prefill per-chip batch is too small).
_BATCH_PARALLEL_AXIS: list = [None]


@contextlib.contextmanager
def batch_parallel(axis: str | None):
    _BATCH_PARALLEL_AXIS.append(axis)
    try:
        yield
    finally:
        _BATCH_PARALLEL_AXIS.pop()


def _maybe_batchpar(q, k, v):
    axis = _BATCH_PARALLEL_AXIS[-1]
    if axis is None:
        return q, k, v
    from jax.sharding import PartitionSpec as P
    wsc = jax.lax.with_sharding_constraint
    spec = P(axis, None, None, None)
    return wsc(q, spec), wsc(k, spec), wsc(v, spec)


def _maybe_seqpar(q, k, v):
    axis = _SEQ_PARALLEL_AXIS[-1]
    if axis is None:
        return q, k, v
    from jax.sharding import PartitionSpec as P
    wsc = jax.lax.with_sharding_constraint
    spec = P(None, axis, None, None)
    return wsc(q, spec), wsc(k, spec), wsc(v, spec)


def _maybe_seqpar_out(o):
    """Keep the attention output time-sharded too (same region, no thrash)."""
    axis = _SEQ_PARALLEL_AXIS[-1]
    if axis is None:
        return o
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(o, P(None, axis, None, None))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_inv_freq(cfg: ModelConfig) -> jax.Array:
    hd = cfg.dims_per_head
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def default_positions(batch: int, seq: int, cfg: ModelConfig, offset=0) -> jax.Array:
    """Text positions. For M-RoPE, the 3 channels (t, h, w) coincide for text."""
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.rope_style == "mrope":
        return jnp.broadcast_to(pos[..., None], (batch, seq, 3))
    return pos


def apply_rope(x: jax.Array, positions: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x (B, T, H, hd); positions (B, T) or (B, T, 3) for mrope."""
    if cfg.rope_style == "none":
        return x
    inv_freq = rope_inv_freq(cfg)  # (hd/2,)
    if cfg.rope_style == "mrope":
        # Each frequency belongs to a section; section s reads positions[..., s].
        sections = cfg.mrope_sections
        assert sum(sections) == inv_freq.shape[0], (sections, inv_freq.shape)
        sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                            total_repeat_length=inv_freq.shape[0])  # (hd/2,)
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),
            jnp.broadcast_to(sec_id[None, None, :], positions.shape[:2] + sec_id.shape),
            axis=-1,
        )  # (B, T, hd/2): per-frequency position
        angles = pos * inv_freq[None, None, :]
    else:
        angles = positions.astype(jnp.float32)[..., None] * inv_freq[None, None, :]
    cos = jnp.cos(angles)[:, :, None, :]  # (B, T, 1, hd/2)
    sin = jnp.sin(angles)[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig) -> dict:
    hd = cfg.dims_per_head
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": layers.linear_init(kq, cfg.d_model, cfg.num_heads * hd, cfg.jdtype, cfg.use_qkv_bias),
        "wk": layers.linear_init(kk, cfg.d_model, cfg.kv_heads * hd, cfg.jdtype, cfg.use_qkv_bias),
        "wv": layers.linear_init(kv, cfg.d_model, cfg.kv_heads * hd, cfg.jdtype, cfg.use_qkv_bias),
        "wo": layers.linear_init(ko, cfg.num_heads * hd, cfg.d_model, cfg.jdtype, False),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    B, T, _ = x.shape
    hd = cfg.dims_per_head
    q = layers.linear(p["wq"], x).reshape(B, T, cfg.num_heads, hd)
    k = layers.linear(p["wk"], x).reshape(B, T, cfg.kv_heads, hd)
    v = layers.linear(p["wv"], x).reshape(B, T, cfg.kv_heads, hd)
    if cfg.use_qk_norm:
        q = layers.rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        k = layers.rms_head_norm(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)
    return q, k, v


def _softcap(s: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return s
    return cap * jnp.tanh(s / cap)


# ---------------------------------------------------------------------------
# full (quadratic) attention — short sequences
# ---------------------------------------------------------------------------

def _full_attention(q, k, v, pos_q, pos_k, window, softcap, causal=True):
    """q (B,T,H,hd), k/v (B,S,Kv,hd). Grouped GQA. Returns (B,T,H,hd)."""
    B, T, H, hd = q.shape
    S, Kv = k.shape[1], k.shape[2]
    g = H // Kv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, T, Kv, g, hd)
    s = jnp.einsum("btkgh,bskh->bkgts", qg.astype(jnp.float32), k.astype(jnp.float32)) * scale
    s = _softcap(s, softcap)
    mask = jnp.ones((T, S), bool) if not causal else (pos_k[None, :] <= pos_q[:, None])
    if window is not None:
        mask &= pos_k[None, :] > (pos_q[:, None] - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bskh->btkgh", p, v.astype(jnp.float32))
    return o.reshape(B, T, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# blockwise attention — long sequences (online softmax over KV chunks)
# ---------------------------------------------------------------------------

def _blockwise_attention(q, k, v, window, softcap, q_chunk=Q_CHUNK, k_chunk=K_CHUNK):
    """Causal blockwise attention; positions are arange (self-attention)."""
    q, k, v = _maybe_seqpar(q, k, v)
    B, T, H, hd = q.shape
    S, Kv = k.shape[1], k.shape[2]
    g = H // Kv
    scale = 1.0 / math.sqrt(hd)

    pad_q = (-T) % q_chunk
    pad_k = (-S) % k_chunk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Tq, Sk = T + pad_q, S + pad_k
    nq, nk = Tq // q_chunk, Sk // k_chunk

    # keep q/k/v in their input dtype (bf16 for full configs) — the score dot
    # accumulates in f32 via preferred_element_type, probabilities are cast
    # back for the p@v dot (flash numerics). Halves score-path HBM traffic
    # for bf16 models; exact no-op for f32 models (§Perf H2 iter 4).
    io_dtype = q.dtype
    qp = qp.reshape(B, nq, q_chunk, Kv, g, hd)
    kp = kp.reshape(B, nk, k_chunk, Kv, hd)
    vp = vp.reshape(B, nk, k_chunk, Kv, hd)

    def q_step(_, qi_blk):
        qi, q_blk = qi_blk  # q_blk (B, qc, Kv, g, hd)
        pos_q = qi * q_chunk + jnp.arange(q_chunk)

        def k_step(carry, kj_blk):
            m, l, acc = carry
            kj, k_blk, v_blk = kj_blk
            pos_k = kj * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("bqkgh,bskh->bkgqs", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, softcap)
            mask = (pos_k[None, :] <= pos_q[:, None]) & (pos_k[None, :] < S)
            if window is not None:
                mask &= pos_k[None, :] > (pos_q[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(io_dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Kv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Kv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Kv, g, q_chunk, hd), jnp.float32)
        ks = (jnp.arange(nk), jnp.moveaxis(kp, 1, 0), jnp.moveaxis(vp, 1, 0))
        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, a0), ks)
        o = acc / jnp.maximum(l[..., None], 1e-30)  # (B, Kv, g, qc, hd)
        return None, jnp.moveaxis(o, 3, 1)          # (B, qc, Kv, g, hd)

    qs = (jnp.arange(nq), jnp.moveaxis(qp, 1, 0))
    _, outs = jax.lax.scan(q_step, None, qs)        # (nq, B, qc, Kv, g, hd)
    o = jnp.moveaxis(outs, 0, 1).reshape(B, Tq, Kv * g, hd)[:, :T]
    # NOTE: constraining o here was tried and REFUTED (30x flop blowup via
    # involuntary remat — see EXPERIMENTS §Perf H1 iter 3); output layout is
    # left to GSPMD.
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# flash-style custom VJP: forward saves only (o, m, l); the backward
# RECOMPUTES score tiles chunk-by-chunk (flash attention backward). Without
# this, jax.lax.scan's autodiff stacks every (qc, kc) probability tile for
# the backward — measured at ~45% of the whole train-step HBM traffic on
# minicpm-2b train_4k (§Perf H3 iter 2). This pure-JAX formulation keeps
# the flash memory behaviour in the lowered HLO and runs everywhere; a
# Pallas forward kernel would be a drop-in TPU fast path on top of it.
# ---------------------------------------------------------------------------

def _blockwise_fwd_stats(q, k, v, window, softcap, q_chunk, k_chunk):
    """Like _blockwise_attention but also returns per-row (m, l) stats."""
    B, T, H, hd = q.shape
    S, Kv = k.shape[1], k.shape[2]
    g = H // Kv
    scale = 1.0 / math.sqrt(hd)
    pad_q = (-T) % q_chunk
    pad_k = (-S) % k_chunk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Tq, Sk = T + pad_q, S + pad_k
    nq, nk = Tq // q_chunk, Sk // k_chunk
    io_dtype = q.dtype
    qp = qp.reshape(B, nq, q_chunk, Kv, g, hd)
    kp = kp.reshape(B, nk, k_chunk, Kv, hd)
    vp = vp.reshape(B, nk, k_chunk, Kv, hd)

    def q_step(_, qi_blk):
        qi, q_blk = qi_blk
        pos_q = qi * q_chunk + jnp.arange(q_chunk)

        def k_step(carry, kj_blk):
            m, l, acc = carry
            kj, k_blk, v_blk = kj_blk
            pos_k = kj * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("bqkgh,bskh->bkgqs", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, softcap)
            mask = (pos_k[None, :] <= pos_q[:, None]) & (pos_k[None, :] < S)
            if window is not None:
                mask &= pos_k[None, :] > (pos_q[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(io_dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Kv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Kv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Kv, g, q_chunk, hd), jnp.float32)
        ks = (jnp.arange(nk), jnp.moveaxis(kp, 1, 0), jnp.moveaxis(vp, 1, 0))
        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, a0), ks)
        o = acc / jnp.maximum(l[..., None], 1e-30)
        return None, (jnp.moveaxis(o, 3, 1), m, l)  # o (B,qc,Kv,g,hd)

    qs = (jnp.arange(nq), jnp.moveaxis(qp, 1, 0))
    _, (outs, ms, ls) = jax.lax.scan(q_step, None, qs)
    o = jnp.moveaxis(outs, 0, 1).reshape(B, Tq, Kv * g, hd)[:, :T]
    return o.astype(q.dtype), ms, ls  # ms/ls (nq, B, Kv, g, qc)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_attention_jax(q, k, v, window, softcap, q_chunk, k_chunk):
    o, _, _ = _blockwise_fwd_stats(q, k, v, window, softcap, q_chunk, k_chunk)
    return o


def _flash_fwd(q, k, v, window, softcap, q_chunk, k_chunk):
    o, m, l = _blockwise_fwd_stats(q, k, v, window, softcap, q_chunk, k_chunk)
    return o, (q, k, v, o, m, l)


def _flash_bwd(window, softcap, q_chunk, k_chunk, res, do):
    q, k, v, o, ms, ls = res
    B, T, H, hd = q.shape
    S, Kv = k.shape[1], k.shape[2]
    g = H // Kv
    scale = 1.0 / math.sqrt(hd)
    io_dtype = q.dtype
    pad_q = (-T) % q_chunk
    pad_k = (-S) % k_chunk
    Tq, Sk = T + pad_q, S + pad_k
    nq, nk = Tq // q_chunk, Sk // k_chunk

    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))).reshape(
        B, nq, q_chunk, Kv, g, hd)
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))).reshape(
        B, nk, k_chunk, Kv, hd)
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))).reshape(
        B, nk, k_chunk, Kv, hd)
    dop = jnp.pad(do, ((0, 0), (0, pad_q), (0, 0), (0, 0))).reshape(
        B, nq, q_chunk, Kv, g, hd).astype(jnp.float32)
    op = jnp.pad(o, ((0, 0), (0, pad_q), (0, 0), (0, 0))).reshape(
        B, nq, q_chunk, Kv, g, hd).astype(jnp.float32)

    # D_i = rowsum(do * o) per query row — (nq, B, Kv, g, qc)
    D = jnp.einsum("bnqkgh,bnqkgh->nbkgq", dop, op)

    def q_step(carry_none, inp):
        qi, q_blk, do_blk, m_blk, l_blk, D_blk = inp
        pos_q = qi * q_chunk + jnp.arange(q_chunk)

        def k_step(dq_acc, kj_blk):
            kj, k_blk, v_blk = kj_blk
            pos_k = kj * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("bqkgh,bskh->bkgqs", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, softcap)
            mask = (pos_k[None, :] <= pos_q[:, None]) & (pos_k[None, :] < S)
            if window is not None:
                mask &= pos_k[None, :] > (pos_q[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - m_blk[..., None]) / jnp.maximum(
                l_blk[..., None], 1e-30)                      # (B,Kv,g,qc,kc)
            dp = jnp.einsum("bqkgh,bskh->bkgqs", do_blk, v_blk,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - D_blk[..., None])                   # (B,Kv,g,qc,kc)
            dv_j = jnp.einsum("bkgqs,bqkgh->bskh", p.astype(io_dtype),
                              do_blk.astype(io_dtype),
                              preferred_element_type=jnp.float32)
            dk_j = jnp.einsum("bkgqs,bqkgh->bskh", ds.astype(io_dtype),
                              q_blk,
                              preferred_element_type=jnp.float32) * scale
            dq_acc = dq_acc + jnp.einsum(
                "bkgqs,bskh->bqkgh", ds.astype(io_dtype), k_blk,
                preferred_element_type=jnp.float32) * scale
            return dq_acc, (dk_j, dv_j)

        dq0 = jnp.zeros((B, q_chunk, Kv, g, hd), jnp.float32)
        ks = (jnp.arange(nk), jnp.moveaxis(kp, 1, 0), jnp.moveaxis(vp, 1, 0))
        dq_blk, (dk_blks, dv_blks) = jax.lax.scan(k_step, dq0, ks)
        return carry_none, (dq_blk, dk_blks, dv_blks)

    do_q = jnp.moveaxis(dop, 1, 0).astype(io_dtype)
    q_q = jnp.moveaxis(qp, 1, 0)
    qs = (jnp.arange(nq), q_q, do_q, ms, ls, D)
    _, (dq_blks, dk_parts, dv_parts) = jax.lax.scan(q_step, None, qs)
    # dq: (nq, B, qc, Kv, g, hd) -> (B, T, H, hd)
    dq = jnp.moveaxis(dq_blks, 0, 1).reshape(B, Tq, H, hd)[:, :T]
    # dk/dv: (nq, nk, B, kc, Kv, hd) — sum over q chunks
    dk = jnp.moveaxis(dk_parts.sum(0), 0, 1).reshape(B, Sk, Kv, hd)[:, :S]
    dv = jnp.moveaxis(dv_parts.sum(0), 0, 1).reshape(B, Sk, Kv, hd)[:, :S]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_attention_jax.defvjp(_flash_fwd, _flash_bwd)


# §Perf H3: flip to enable the flash custom-VJP path in blockwise attention.
_FLASH_VJP: list = [False]


@contextlib.contextmanager
def flash_vjp(enabled: bool = True):
    _FLASH_VJP.append(enabled)
    try:
        yield
    finally:
        _FLASH_VJP.pop()


# ---------------------------------------------------------------------------
# public: training / prefill
# ---------------------------------------------------------------------------

def attention_full(p: dict, cfg: ModelConfig, x: jax.Array,
                   positions: Optional[jax.Array] = None,
                   window: Optional[int] = "cfg") -> jax.Array:
    """Causal self-attention over a whole sequence (training & prefill)."""
    B, T, _ = x.shape
    if positions is None:
        positions = default_positions(B, T, cfg)
    if window == "cfg":
        window = cfg.sliding_window
    q, k, v = _project_qkv(p, cfg, x, positions)
    q, k, v = _maybe_batchpar(q, k, v)
    q, k, v, H_orig, kv_padded = _maybe_pad_heads(q, k, v, cfg)
    if _HEAD_PAD_AXIS[-1] is not None and q.shape[2] % 16 == 0:
        from jax.sharding import PartitionSpec as P
        wsc = jax.lax.with_sharding_constraint
        ax = _HEAD_PAD_AXIS[-1]
        q = wsc(q, P(None, None, ax, None))
        if kv_padded:
            # MHA: kv heads padded too -> shard them the same way
            k = wsc(k, P(None, None, ax, None))
            v = wsc(v, P(None, None, ax, None))
        else:
            # GQA with few kv heads: replicate the (small) kv tensors so the
            # contracting head_dim is never sharded
            k = wsc(k, P(None, None, None, None))
            v = wsc(v, P(None, None, None, None))
    if T <= BLOCKWISE_THRESHOLD:
        pos = jnp.arange(T)
        o = _full_attention(q, k, v, pos, pos, window, cfg.attn_logit_softcap)
    elif _FLASH_VJP[-1]:
        o = _flash_attention_jax(q, k, v, window, cfg.attn_logit_softcap,
                                 Q_CHUNK, K_CHUNK)
    else:
        o = _blockwise_attention(q, k, v, window, cfg.attn_logit_softcap)
    o = _maybe_unpad_heads(o, cfg, H_orig, kv_padded)
    return layers.linear(p["wo"], o.reshape(B, T, -1))


# ---------------------------------------------------------------------------
# decode with ring-buffer KV cache
# ---------------------------------------------------------------------------

def init_attn_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> dict:
    hd = cfg.dims_per_head
    return {
        "k": jnp.zeros((batch, cache_len, cfg.kv_heads, hd), dtype),
        "v": jnp.zeros((batch, cache_len, cfg.kv_heads, hd), dtype),
        "slot_pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def attention_decode(p: dict, cfg: ModelConfig, x: jax.Array, pos: jax.Array,
                     cache: dict, window: Optional[int] = "cfg") -> tuple[jax.Array, dict]:
    """One-token decode. x (B, 1, D); pos (B,) absolute positions.

    The cache is a ring buffer: slot = pos % cache_len. Works for full causal
    (cache_len >= max_len) and windowed decode (cache_len >= window).
    """
    B, one, _ = x.shape
    assert one == 1
    if window == "cfg":
        window = cfg.sliding_window
    C = cache["k"].shape[1]
    hd = cfg.dims_per_head
    if cfg.rope_style == "mrope":
        positions = jnp.broadcast_to(pos[:, None, None], (B, 1, 3))
    else:
        positions = pos[:, None]
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)

    slot = (pos % C).astype(jnp.int32)  # (B,)
    upd = lambda buf, new: jax.vmap(
        lambda b, n, s: jax.lax.dynamic_update_slice(b, n, (s, 0, 0))
    )(buf, new, slot)
    k_cache = upd(cache["k"], k_new.astype(cache["k"].dtype))
    v_cache = upd(cache["v"], v_new.astype(cache["v"].dtype))
    slot_pos = jax.vmap(lambda sp, s, pv: sp.at[s].set(pv))(cache["slot_pos"], slot, pos)

    Kv, g = cfg.kv_heads, cfg.num_heads // cfg.kv_heads
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Kv, g, hd)
    s = jnp.einsum("bkgh,bckh->bkgc", qg.astype(jnp.float32), k_cache.astype(jnp.float32)) * scale
    s = _softcap(s, cfg.attn_logit_softcap)
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    if window is not None:
        valid &= slot_pos > (pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgc,bckh->bkgh", prob, v_cache.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.num_heads * hd).astype(x.dtype)
    y = layers.linear(p["wo"], o)
    return y, {"k": k_cache, "v": v_cache, "slot_pos": slot_pos}
