"""Functional NN primitives (no flax): params are plain nested dicts.

Initializers return param dicts; apply functions are pure. All matmul params
are created in cfg.dtype (bf16 for full configs), norms in f32 for stability.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _normal(key, shape, dtype, stddev):
    return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)


def linear_init(key, d_in: int, d_out: int, dtype, bias: bool = False) -> dict:
    p = {"w": _normal(key, (d_in, d_out), dtype, 1.0 / math.sqrt(d_in))}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embed_init(key, vocab: int, d_model: int, dtype) -> dict:
    # 1/sqrt(d) keeps tied-unembedding logits O(1) at init
    return {"table": _normal(key, (vocab, d_model), dtype, 1.0 / math.sqrt(d_model))}


def embed(p: dict, ids: jax.Array) -> jax.Array:
    return jnp.take(p["table"], ids, axis=0)


def unembed(p: dict, x: jax.Array) -> jax.Array:
    """Tied unembedding (logits in f32 for a stable softmax/CE)."""
    return x.astype(jnp.float32) @ p["table"].astype(jnp.float32).T


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(kind: str, dim: int) -> dict:
    p = {"scale": jnp.ones((dim,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), jnp.float32)
    return p


def apply_norm(kind: str, p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
        return (xf * p["scale"]).astype(x.dtype)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (xf * p["scale"] + p["bias"]).astype(x.dtype)
    raise ValueError(kind)


def rms_head_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMS norm over the last (head_dim) axis — qwen3 qk_norm."""
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
    return (xf * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# activations / losses
# ---------------------------------------------------------------------------

def activation(kind: str, x: jax.Array) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(kind)


def mask_padded_vocab(logits: jax.Array, vocab_real: int) -> jax.Array:
    """-inf the padded vocab columns (see ModelConfig.vocab_padded)."""
    V = logits.shape[-1]
    if V == vocab_real:
        return logits
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(col < vocab_real, logits, -1e30)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE. logits (..., V) f32, labels (...) int32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
