"""RWKV6 "Finch" block (arXiv:2404.05892) — attention-free, data-dependent decay.

Time mixing: token-shift interpolation with data-dependent (LoRA) mix
coefficients, multi-head WKV recurrence with per-channel *input-dependent*
decay w_t = exp(-exp(w0 + lora(x))) — the paper's headline feature — and a
bonus term u for the current token. Channel mixing: squared-ReLU FFN with
token shift.

The WKV recurrence is a lax.scan over time (the pure-JAX reference; the
chunked Pallas kernel is a perf-phase swap-in). Decode carries
(wkv_state (B,H,K,V), shift states) — O(1) per token, which is why rwkv6
legitimately runs the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig

LORA_RANK = 32


def _lora_init(key, d_in, d_out, rank, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "a": (jax.random.normal(k1, (d_in, rank), jnp.float32) * 0.01).astype(dtype),
        "b": jnp.zeros((rank, d_out), dtype),
    }


def _lora(p, x):
    return jnp.tanh(x @ p["a"]) @ p["b"]


def time_mix_init(key, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    K = cfg.rwkv_head_dim
    H = D // K
    ks = jax.random.split(key, 12)
    dt = cfg.jdtype
    p = {
        "mu": jnp.full((5, D), 0.5, jnp.float32),          # base mix for w,k,v,r,g
        "lora_mix": _lora_init(ks[0], D, 5 * D, LORA_RANK, jnp.float32),
        "w0": jnp.zeros((D,), jnp.float32) - 0.5,           # base decay
        "lora_w": _lora_init(ks[1], D, D, 2 * LORA_RANK, jnp.float32),
        "u": jnp.zeros((H, K), jnp.float32) + 0.1,          # bonus
        "wr": layers.linear_init(ks[2], D, D, dt),
        "wk": layers.linear_init(ks[3], D, D, dt),
        "wv": layers.linear_init(ks[4], D, D, dt),
        "wg": layers.linear_init(ks[5], D, D, dt),
        "wo": layers.linear_init(ks[6], D, D, dt),
        "ln_x": jnp.ones((D,), jnp.float32),                # per-head group norm scale
    }
    return p


def _wkv_scan(r, k, v, w, u, state0):
    """Multi-head WKV. r,k,w (B,T,H,K); v (B,T,H,K); u (H,K); state0 (B,H,K,K_v).

    y_t = r_t^T (S + u ⊙ k_t v_t^T);  S <- diag(w_t) S + k_t v_t^T
    (all in f32; head value dim == key dim K).
    """
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,K)
        kv = k_t[..., :, None] * v_t[..., None, :]            # (B,H,K,K)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1), state                      # (B,T,H,K), (B,H,K,K)


def _shift(x, x_prev):
    """Token shift: concat last-step feature, drop final. x (B,T,D)."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def time_mix(p, cfg: ModelConfig, x, x_prev, wkv_state):
    """x (B,T,D); x_prev (B,D) shift carry; wkv_state (B,H,K,K)."""
    B, T, D = x.shape
    K = cfg.rwkv_head_dim
    H = D // K
    xf = x.astype(jnp.float32)
    xx = _shift(xf, x_prev) - xf                               # (B,T,D)

    base = xf + xx * p["mu"][0]
    mixes = _lora(p["lora_mix"], base).reshape(B, T, 5, D)
    def mixed(i):
        return (xf + xx * (p["mu"][i] + mixes[:, :, i])).astype(cfg.jdtype)
    x_w, x_k, x_v, x_r, x_g = (mixed(i) for i in range(5))

    r = layers.linear(p["wr"], x_r).reshape(B, T, H, K).astype(jnp.float32)
    k = layers.linear(p["wk"], x_k).reshape(B, T, H, K).astype(jnp.float32)
    v = layers.linear(p["wv"], x_v).reshape(B, T, H, K).astype(jnp.float32)
    g = jax.nn.silu(layers.linear(p["wg"], x_g).astype(jnp.float32))

    # data-dependent decay (the Finch contribution)
    w_log = p["w0"] + _lora(p["lora_w"], x_w.astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w_log)).reshape(B, T, H, K)

    y, new_state = _wkv_scan(r, k, v, w, p["u"], wkv_state)
    y = y.reshape(B, T, D)
    # per-head group norm
    y = y.reshape(B, T, H, K)
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y), axis=-1, keepdims=True) + 64e-5)
    y = (y.reshape(B, T, D) * p["ln_x"]) * g
    out = layers.linear(p["wo"], y.astype(cfg.jdtype))
    return out, xf[:, -1], new_state


def channel_mix_init(key, cfg: ModelConfig) -> dict:
    kk, kr, kv = jax.random.split(key, 3)
    dt = cfg.jdtype
    return {
        "mu_k": jnp.full((cfg.d_model,), 0.5, jnp.float32),
        "mu_r": jnp.full((cfg.d_model,), 0.5, jnp.float32),
        "wk": layers.linear_init(kk, cfg.d_model, cfg.d_ff, dt),
        "wr": layers.linear_init(kr, cfg.d_model, cfg.d_model, dt),
        "wv": layers.linear_init(kv, cfg.d_ff, cfg.d_model, dt),
    }


def channel_mix(p, cfg: ModelConfig, x, x_prev):
    xf = x.astype(jnp.float32)
    xx = _shift(xf, x_prev) - xf
    xk = (xf + xx * p["mu_k"]).astype(cfg.jdtype)
    xr = (xf + xx * p["mu_r"]).astype(cfg.jdtype)
    k = jnp.square(jax.nn.relu(layers.linear(p["wk"], xk)))
    kv = layers.linear(p["wv"], k)
    out = jax.nn.sigmoid(layers.linear(p["wr"], xr).astype(jnp.float32)).astype(cfg.jdtype) * kv
    return out, xf[:, -1]


# ---------------------------------------------------------------------------
# block init / apply (train + decode share code paths: decode is T == 1)
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln1": layers.norm_init("layernorm", cfg.d_model),
        "tm": time_mix_init(k1, cfg),
        "ln2": layers.norm_init("layernorm", cfg.d_model),
        "cm": channel_mix_init(k2, cfg),
    }


def block_apply(p, cfg: ModelConfig, x, state):
    """state = {'tm_shift' (B,D), 'cm_shift' (B,D), 'wkv' (B,H,K,K)}."""
    h, tm_shift, wkv = time_mix(
        p["tm"], cfg, layers.apply_norm("layernorm", p["ln1"], x, cfg.norm_eps),
        state["tm_shift"], state["wkv"],
    )
    x = x + h
    h, cm_shift = channel_mix(
        p["cm"], cfg, layers.apply_norm("layernorm", p["ln2"], x, cfg.norm_eps),
        state["cm_shift"],
    )
    x = x + h
    return x, {"tm_shift": tm_shift, "cm_shift": cm_shift, "wkv": wkv}


def init_block_state(cfg: ModelConfig, batch: int) -> dict:
    D = cfg.d_model
    K = cfg.rwkv_head_dim
    H = D // K
    return {
        "tm_shift": jnp.zeros((batch, D), jnp.float32),
        "cm_shift": jnp.zeros((batch, D), jnp.float32),
        "wkv": jnp.zeros((batch, H, K, K), jnp.float32),
    }
