"""Gated MLP (SwiGLU family) — the dense FFN used by every assigned arch."""
from __future__ import annotations

import jax

from repro.models import layers
from repro.models.config import ModelConfig


def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "gate": layers.linear_init(kg, cfg.d_model, d_ff, cfg.jdtype),
        "up": layers.linear_init(ku, cfg.d_model, d_ff, cfg.jdtype),
        "down": layers.linear_init(kd, d_ff, cfg.d_model, cfg.jdtype),
    }


def mlp(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = layers.activation(cfg.act, layers.linear(p["gate"], x)) * layers.linear(p["up"], x)
    return layers.linear(p["down"], h)
