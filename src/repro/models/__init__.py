"""Model substrate: transformer / MoE / RWKV6 / RG-LRU / enc-dec families."""
from repro.models.config import ModelConfig, ShapeConfig, INPUT_SHAPES
from repro.models.transformer import Model, build_model as _build_decoder_only
from repro.models.encdec import build_encdec


def build_model(cfg: ModelConfig) -> Model:
    """Single entry point: dispatch on family."""
    if cfg.family == "encdec":
        return build_encdec(cfg)
    return _build_decoder_only(cfg)


__all__ = ["ModelConfig", "ShapeConfig", "INPUT_SHAPES", "Model", "build_model"]
