"""Decoder-only model assembly for every non-enc-dec family.

Families:
  dense        — attn + SwiGLU          (qwen2-7b, minicpm, internlm2, qwen3, qwen2-vl)
  moe          — attn + MoE             (mixtral, llama4-scout)
  rwkv6        — RWKV6 blocks           (rwkv6-3b)
  rglru_hybrid — (rec, rec, attn) + MLP (recurrentgemma)

Homogeneous families scan over stacked layer params (compact HLO at 64
layers); the hybrid pattern loops python-side. Multimodal archs (vlm/audio
decoder-only) consume stub frontend embeddings via early fusion: the first
``frontend_tokens`` positions of the sequence are replaced by the provided
embeddings and masked out of the loss.

The public surface is ``build_model(cfg) -> Model`` with pure functions:
  init(key) -> params
  apply(params, tokens, frontend=None) -> (logits, aux_loss)
  loss_fn(params, batch) -> (loss, metrics)
  init_cache(batch, cache_len) -> cache
  decode_step(params, cache, tokens, pos) -> (logits, cache)
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention, layers, mlp, moe, rglru, rwkv6
from repro.models.config import ModelConfig


class Model(NamedTuple):
    cfg: ModelConfig
    init: Any
    apply: Any
    loss_fn: Any
    init_cache: Any
    decode_step: Any
    prime_cache: Any = None  # enc-dec only: fill cross-attn K/V from encoder


# ---------------------------------------------------------------------------
# per-layer init/apply by family
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, kind: str) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind == "rwkv":
        return rwkv6.block_init(key, cfg)
    p = {"ln1": layers.norm_init(cfg.norm, cfg.d_model),
         "ln2": layers.norm_init(cfg.norm, cfg.d_model)}
    if kind == "attn":
        p["attn"] = attention.attn_init(k1, cfg)
        p["ffn"] = mlp.mlp_init(k2, cfg)
    elif kind == "moe":
        p["attn"] = attention.attn_init(k1, cfg)
        p["moe"] = moe.moe_init(k2, cfg)
    elif kind == "rec":
        p["rec"] = rglru.recurrent_block_init(k1, cfg)
        p["ffn"] = mlp.mlp_init(k2, cfg)
    elif kind == "local_attn":
        p["attn"] = attention.attn_init(k1, cfg)
        p["ffn"] = mlp.mlp_init(k2, cfg)
    else:
        raise ValueError(kind)
    return p


# §Perf: sequence-parallel residual stream (Megatron-SP). Constraining the
# between-layer activations to be TIME-sharded over the model axis turns the
# 2-per-layer full all-reduces of (B, T, D) partial sums into
# reduce-scatter + all-gather pairs (half the bytes, and the norm/elementwise
# region runs on 1/16th of the tokens per chip).
import contextlib

_SP_RESIDUAL_AXIS: list = [None]


@contextlib.contextmanager
def sp_residual(axis: str | None):
    _SP_RESIDUAL_AXIS.append(axis)
    try:
        yield
    finally:
        _SP_RESIDUAL_AXIS.pop()


def _maybe_sp(x):
    axis = _SP_RESIDUAL_AXIS[-1]
    if axis is None:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(None, axis, None))


def _layer_apply(p, cfg: ModelConfig, kind: str, x, positions):
    """Full-sequence (train/prefill) layer. Returns (x, aux)."""
    x = _maybe_sp(x)
    aux = jnp.zeros((), jnp.float32)
    if kind == "rwkv":
        state = rwkv6.init_block_state(cfg, x.shape[0])
        x, _ = rwkv6.block_apply(p, cfg, x, state)
        return x, aux
    xn = layers.apply_norm(cfg.norm, p["ln1"], x, cfg.norm_eps)
    if kind in ("attn", "moe"):
        h = attention.attention_full(p["attn"], cfg, xn, positions)
    elif kind == "local_attn":
        h = attention.attention_full(p["attn"], cfg, xn, positions,
                                     window=cfg.local_attn_window)
    elif kind == "rec":
        st = rglru.init_recurrent_state(cfg, x.shape[0])
        h, _ = rglru.recurrent_block_apply(p["rec"], cfg, xn, st)
    x = x + h
    xn = layers.apply_norm(cfg.norm, p["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        h, aux = moe.moe_apply(p["moe"], cfg, xn,
                               dispatch_groups=moe.current_dispatch_groups())
    else:
        h = mlp.mlp(p["ffn"], cfg, xn)
    return x + h, aux


def _layer_decode(p, cfg: ModelConfig, kind: str, x, pos, cache):
    """One-token decode layer. Returns (x, new_cache)."""
    if kind == "rwkv":
        return rwkv6.block_apply(p, cfg, x, cache)  # T == 1 works natively
    xn = layers.apply_norm(cfg.norm, p["ln1"], x, cfg.norm_eps)
    if kind in ("attn", "moe"):
        h, cache_attn = attention.attention_decode(p["attn"], cfg, xn, pos, cache["attn"])
        cache = {**cache, "attn": cache_attn}
    elif kind == "local_attn":
        h, cache_attn = attention.attention_decode(
            p["attn"], cfg, xn, pos, cache["attn"], window=cfg.local_attn_window)
        cache = {**cache, "attn": cache_attn}
    elif kind == "rec":
        h, rec_state = rglru.recurrent_block_step(p["rec"], cfg, xn, cache["rec"])
        cache = {**cache, "rec": rec_state}
    x = x + h
    xn = layers.apply_norm(cfg.norm, p["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        h, _ = moe.moe_apply(p["moe"], cfg, xn)
    else:
        h = mlp.mlp(p["ffn"], cfg, xn)
    return x + h, cache


def _layer_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int):
    if kind == "rwkv":
        return rwkv6.init_block_state(cfg, batch)
    c = {}
    if kind in ("attn", "moe"):
        c["attn"] = attention.init_attn_cache(cfg, batch, cache_len, cfg.jdtype)
    elif kind == "local_attn":
        c["attn"] = attention.init_attn_cache(
            cfg, batch, min(cache_len, cfg.local_attn_window), cfg.jdtype)
    elif kind == "rec":
        c["rec"] = rglru.init_recurrent_state(cfg, batch)
    return c


def layer_kinds(cfg: ModelConfig) -> list[str]:
    if cfg.family == "dense":
        return ["attn"] * cfg.num_layers
    if cfg.family == "moe":
        return ["moe"] * cfg.num_layers
    if cfg.family == "rwkv6":
        return ["rwkv"] * cfg.num_layers
    if cfg.family == "rglru_hybrid":
        pat = cfg.hybrid_pattern
        kinds = [("rec" if pat[i % len(pat)] == "rec" else "local_attn")
                 for i in range(cfg.num_layers)]
        return kinds
    raise ValueError(cfg.family)


def _is_homogeneous(cfg: ModelConfig) -> bool:
    kinds = layer_kinds(cfg)
    return cfg.scan_layers and all(k == kinds[0] for k in kinds)


# ---------------------------------------------------------------------------
# model builder
# ---------------------------------------------------------------------------

def build_model(cfg: ModelConfig) -> Model:
    kinds = layer_kinds(cfg)
    homogeneous = _is_homogeneous(cfg)

    # ---- init ----
    def init(key) -> dict:
        k_embed, k_layers, k_out = jax.random.split(key, 3)
        params = {
            "embed": layers.embed_init(k_embed, cfg.vocab_padded, cfg.d_model, cfg.jdtype),
            "final_norm": layers.norm_init(cfg.norm, cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = layers.linear_init(k_out, cfg.d_model, cfg.vocab_padded, cfg.jdtype)
        if homogeneous:
            keys = jax.random.split(k_layers, cfg.num_layers)
            params["layers"] = jax.vmap(lambda k: _layer_init(k, cfg, kinds[0]))(keys)
        else:
            keys = jax.random.split(k_layers, cfg.num_layers)
            params["layers"] = [
                _layer_init(keys[i], cfg, kinds[i]) for i in range(cfg.num_layers)
            ]
        return params

    def _logits(params, x):
        x = layers.apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = layers.unembed(params["embed"], x)
        else:
            logits = layers.linear(params["unembed"], x).astype(jnp.float32)
        return layers.mask_padded_vocab(logits, cfg.vocab_size)

    def _embed_inputs(params, tokens, frontend):
        x = layers.embed(params["embed"], tokens)
        if cfg.frontend is not None and frontend is not None:
            ft = frontend.shape[1]
            x = jnp.concatenate([frontend.astype(x.dtype), x[:, ft:]], axis=1)
        return x

    # ---- full-sequence apply ----
    def apply(params, tokens, frontend: Optional[jax.Array] = None,
              last_only: bool = False):
        """last_only: return logits for the final position only — prefill
        never needs the (B, T, V) logits tensor (§Perf hillclimb 1)."""
        B, T = tokens.shape
        x = _embed_inputs(params, tokens, frontend)
        positions = attention.default_positions(B, T, cfg)
        layer_fn = lambda lp, k, x: _layer_apply(lp, cfg, k, x, positions)
        if cfg.remat:
            layer_fn = jax.checkpoint(layer_fn, static_argnums=(1,),
                                      policy=jax.checkpoint_policies.nothing_saveable)
        if homogeneous:
            def body(x, layer_p):
                x, aux = layer_fn(layer_p, kinds[0], x)
                return x, aux
            x, auxes = jax.lax.scan(body, x, params["layers"])
            aux = jnp.sum(auxes)
        else:
            aux = jnp.zeros((), jnp.float32)
            for i, lp in enumerate(params["layers"]):
                x, a = layer_fn(lp, kinds[i], x)
                aux = aux + a
        if last_only:
            x = x[:, -1:]
        return _logits(params, x), aux

    # ---- loss ----
    def loss_fn(params, batch):
        tokens = batch["tokens"]
        labels = batch["labels"]
        frontend = batch.get("frontend")
        logits, aux = apply(params, tokens, frontend)
        mask = (labels >= 0)
        labels_safe = jnp.maximum(labels, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0]
        ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1)
        return ce + aux, {"ce": ce, "aux": aux}

    # ---- decode ----
    def init_cache(batch: int, cache_len: int):
        if homogeneous:
            one = _layer_cache(cfg, kinds[0], batch, cache_len)
            return jax.tree_util.tree_map(
                lambda l: jnp.broadcast_to(l[None], (cfg.num_layers,) + l.shape).copy(), one)
        return [_layer_cache(cfg, kinds[i], batch, cache_len) for i in range(cfg.num_layers)]

    def decode_step(params, cache, tokens, pos):
        """tokens (B, 1) int32; pos (B,) absolute positions."""
        x = layers.embed(params["embed"], tokens)
        if homogeneous:
            def body(x, layer_pc):
                layer_p, layer_c = layer_pc
                x, new_c = _layer_decode(layer_p, cfg, kinds[0], x, pos, layer_c)
                return x, new_c
            x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
        else:
            new_cache = []
            for i, lp in enumerate(params["layers"]):
                x, c = _layer_decode(lp, cfg, kinds[i], x, pos, cache[i])
                new_cache.append(c)
        return _logits(params, x), new_cache

    return Model(cfg=cfg, init=init, apply=apply, loss_fn=loss_fn,
                 init_cache=init_cache, decode_step=decode_step)
