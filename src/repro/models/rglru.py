"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Temporal block = gated branch * (conv1d -> RG-LRU recurrence), where
    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)            (input gate)
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The linear recurrence is evaluated with jax.lax.associative_scan (parallel
prefix — O(log T) depth instead of O(T), the natural TPU mapping of the
paper's sequential GPU loop). Decode is the O(1) single-step recurrence;
together with the 1:2 local-attention pattern this is why recurrentgemma
runs long_500k with a bounded cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig

RG_LRU_C = 8.0


def rglru_init(key, cfg: ModelConfig) -> dict:
    R = cfg.rnn_width
    ka, kx = jax.random.split(key)
    return {
        "wa": layers.linear_init(ka, R, R, jnp.float32, bias=True),
        "wx": layers.linear_init(kx, R, R, jnp.float32, bias=True),
        "lam": jnp.full((R,), 2.0, jnp.float32),  # softplus(2) ~ 2.1 -> a ~ exp(-17r)
    }


def _gates(p, x):
    r = jax.nn.sigmoid(layers.linear(p["wa"], x))
    i = jax.nn.sigmoid(layers.linear(p["wx"], x))
    log_a = -RG_LRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * (i * x)


def rglru_apply(p: dict, x: jax.Array, h0: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (B, T, R) f32, h0 (B, R). Returns (h (B,T,R), h_last)."""
    a, b = _gates(p, x)
    # fold h0 into the first step: h_1 = a_1 h_0 + b_1
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def rglru_step(p: dict, x1: jax.Array, h: jax.Array) -> jax.Array:
    """Single decode step. x1 (B, R), h (B, R) -> new h."""
    a, b = _gates(p, x1[:, None, :])
    return a[:, 0] * h + b[:, 0]


# ---------------------------------------------------------------------------
# causal depthwise conv1d (width cfg.rglru_conv_width)
# ---------------------------------------------------------------------------

def conv1d_init(key, cfg: ModelConfig) -> dict:
    R, W = cfg.rnn_width, cfg.rglru_conv_width
    return {
        "w": (jax.random.normal(key, (W, R), jnp.float32) / jnp.sqrt(W)).astype(jnp.float32),
        "b": jnp.zeros((R,), jnp.float32),
    }


def conv1d_apply(p, x, state=None):
    """x (B, T, R); state (B, W-1, R) trailing inputs from the previous chunk.

    Returns (y (B,T,R), new_state (B, W-1, R)).
    """
    B, T, R = x.shape
    W = p["w"].shape[0]
    if state is None:
        state = jnp.zeros((B, W - 1, R), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, T+W-1, R)
    y = sum(xp[:, i : i + T] * p["w"][i] for i in range(W)) + p["b"]
    return y, xp[:, -(W - 1):]


# ---------------------------------------------------------------------------
# full temporal block (recurrent flavor)
# ---------------------------------------------------------------------------

def recurrent_block_init(key, cfg: ModelConfig) -> dict:
    R = cfg.rnn_width
    kg, ki, ko, kc, kl = jax.random.split(key, 5)
    dt = cfg.jdtype
    return {
        "gate": layers.linear_init(kg, cfg.d_model, R, dt),
        "inp": layers.linear_init(ki, cfg.d_model, R, dt),
        "conv": conv1d_init(kc, cfg),
        "lru": rglru_init(kl, cfg),
        "out": layers.linear_init(ko, R, cfg.d_model, dt),
    }


def recurrent_block_apply(p, cfg: ModelConfig, x, state):
    """x (B,T,D); state {'conv' (B,W-1,R), 'h' (B,R)} -> (y, new_state)."""
    u = jax.nn.gelu(layers.linear(p["gate"], x).astype(jnp.float32))
    z = layers.linear(p["inp"], x).astype(jnp.float32)
    z, conv_state = conv1d_apply(p["conv"], z, state["conv"])
    h, h_last = rglru_apply(p["lru"], z, state["h"])
    y = layers.linear(p["out"], (u * h).astype(cfg.jdtype))
    return y, {"conv": conv_state, "h": h_last}


def recurrent_block_step(p, cfg: ModelConfig, x1, state):
    """Decode: x1 (B, 1, D)."""
    u = jax.nn.gelu(layers.linear(p["gate"], x1).astype(jnp.float32))[:, 0]
    z = layers.linear(p["inp"], x1).astype(jnp.float32)
    z, conv_state = conv1d_apply(p["conv"], z, state["conv"])
    h = rglru_step(p["lru"], z[:, 0], state["h"])
    y = layers.linear(p["out"], (u * h).astype(cfg.jdtype)[:, None])
    return y, {"conv": conv_state, "h": h}


def init_recurrent_state(cfg: ModelConfig, batch: int) -> dict:
    R, W = cfg.rnn_width, cfg.rglru_conv_width
    return {
        "conv": jnp.zeros((batch, W - 1, R), jnp.float32),
        "h": jnp.zeros((batch, R), jnp.float32),
    }
