"""Persistent sweep results store — one JSON-lines record per (point, seed).

Every record carries the RESOLVED spec, the axis coordinates, the seed, the
full `RunResult` record (trajectories, eps ledger, final_w — exact JSON
round-trip via `RunResult.to_record`/`from_record`), the wall-clock and the
git SHA, so figures regenerate from the store without re-running and a
record is auditable long after the code moved on.

Files live under ``experiments/store/<name>.jsonl``. Writes are upserts:
a new record REPLACES any stored record with the same identity
(coords, seed, engine, resolved spec), so re-running a sweep never
duplicates rows and a changed base spec never silently reuses stale data.

>>> import tempfile
>>> from repro.api import RunSpec
>>> from repro.sweep.store import SweepStore, spec_record
>>> store = SweepStore(tempfile.mkdtemp())
>>> spec = RunSpec(nodes=2, dim=8, horizon=4, eps=1.0)
>>> rec = {"sweep": "demo", "coords": {"eps": 1.0}, "seed": 0,
...        "engine": "sim", "spec": spec_record(spec),
...        "result": {"accuracy": 0.75}}
>>> store.upsert("demo", [rec])
>>> len(store.load("demo"))
1
>>> store.upsert("demo", [dict(rec, result={"accuracy": 0.5})])  # same key
>>> [r["result"]["accuracy"] for r in store.load("demo")]
[0.5]
>>> store.query("demo", eps=1.0)[0]["seed"]
0
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import subprocess
import time
from typing import Any, Callable, Iterable

import numpy as np

from repro.api.runner import RunResult
from repro.api.spec import RunSpec

__all__ = ["SweepStore", "spec_record", "spec_from_record", "git_sha",
           "record_key", "result_from_record", "aggregate_records",
           "DEFAULT_STORE"]

DEFAULT_STORE = "experiments/store"


def _jsonable(v: Any) -> bool:
    if v is None or isinstance(v, (bool, int, float, str)):
        return True
    if isinstance(v, (list, tuple)):
        return all(_jsonable(x) for x in v)
    if isinstance(v, dict):
        return all(isinstance(k, str) and _jsonable(x) for k, x in v.items())
    return False


def spec_record(spec: RunSpec) -> dict:
    """JSON-able dict of a RunSpec, field by field.

    Declarative fields (registry names, numbers, option dicts) serialize
    as-is; constructed protocol instances / callables can't round-trip and
    are recorded as ``{"__instance__": <type name>}`` markers — such records
    are kept for audit but never matched by the store-reuse path.
    """
    rec = {}
    for f in dataclasses.fields(spec):
        v = getattr(spec, f.name)
        rec[f.name] = v if _jsonable(v) else {"__instance__": type(v).__name__}
    return rec


def spec_from_record(rec: dict) -> RunSpec:
    """Rebuild a RunSpec from a declarative spec record."""
    kw = {}
    for k, v in rec.items():
        if isinstance(v, dict) and "__instance__" in v:
            raise ValueError(
                f"spec field {k!r} was a constructed {v['__instance__']} "
                "instance; the record is audit-only and cannot rebuild it")
        kw[k] = v
    return RunSpec(**kw)


def _normalize(obj: Any) -> Any:
    """Canonical JSON form (tuples -> lists, key order fixed) for matching."""
    return json.loads(json.dumps(obj, sort_keys=True))


def _canon(obj: Any) -> Any:
    """Numeric canonicalization for identity keys: ints become floats so
    eps=1 (CLI int parse) and eps=1.0 (Python API) produce the SAME key —
    string-level json comparison would otherwise defeat the upsert dedup."""
    if isinstance(obj, bool):
        return obj
    if isinstance(obj, int):
        return float(obj)
    if isinstance(obj, list):
        return [_canon(x) for x in obj]
    if isinstance(obj, dict):
        return {k: _canon(v) for k, v in obj.items()}
    return obj


def record_key(rec: dict) -> str:
    """Identity of a record: coords + seed + engine + resolved spec."""
    return json.dumps(_canon({
        "coords": _normalize(rec.get("coords") or {}),
        "seed": rec.get("seed"),
        "engine": rec.get("engine"),
        "spec": _normalize(rec.get("spec") or {}),
    }), sort_keys=True)


@functools.lru_cache(maxsize=8)
def git_sha(root: str | None = None) -> str | None:
    """HEAD SHA for record provenance; cached — a P-point x S-seed sweep
    stamps P*S records with the same constant, not P*S subprocess forks."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root or os.getcwd(),
            capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or None if out.returncode == 0 else None
    except Exception:
        return None


def result_from_record(rec: dict) -> RunResult:
    """The stored RunResult (exact round-trip) of one store record."""
    return RunResult.from_record(rec["result"])


def record_metric(rec: dict, name: str) -> Any:
    """Scalar metric from a record: result top-level, then metrics dict."""
    result = rec.get("result") or {}
    if name in result and isinstance(result[name], (int, float, type(None))):
        return result[name]
    return (result.get("metrics") or {}).get(name)


def aggregate_records(records: Iterable[dict], by: tuple[str, ...],
                      value: str | Callable[[dict], Any]) -> list[dict]:
    """Group records by coord fields and reduce ``value`` to mean/std/n.

    ``value`` is a metric name (see `record_metric`) or a callable taking
    the whole record. std is the population std over seeds (ddof=0).
    """
    get = value if callable(value) else (lambda r: record_metric(r, value))
    groups: dict[str, tuple[dict, list]] = {}
    for rec in records:
        coords = rec.get("coords") or {}
        key = json.dumps({k: coords.get(k) for k in by}, sort_keys=True,
                         default=str)
        groups.setdefault(key, ({k: coords.get(k) for k in by}, []))
        groups[key][1].append(get(rec))
    rows = []
    for coords, values in groups.values():
        clean = [v for v in values if v is not None]
        rows.append({
            **coords,
            "mean": float(np.mean(clean)) if clean else None,
            "std": float(np.std(clean)) if clean else None,
            "n": len(values),
            "values": values,
        })
    return rows


class SweepStore:
    """JSONL store under one root directory; one file per sweep name."""

    def __init__(self, root: str = DEFAULT_STORE):
        self.root = root

    def path(self, name: str) -> str:
        safe = name.replace(os.sep, "_")
        return os.path.join(self.root, f"{safe}.jsonl")

    def names(self) -> list[str]:
        if not os.path.isdir(self.root):
            return []
        return sorted(f[:-6] for f in os.listdir(self.root)
                      if f.endswith(".jsonl"))

    def load(self, name: str) -> list[dict]:
        """All records, deduped by identity — the LAST write wins.

        The file is an append-first log: the sweep engine appends refreshed
        records immediately (durability) and compacts with `upsert` at the
        end of the sweep. Deduping on read means a crash between those two
        steps never surfaces duplicate (or stale) identities to readers.
        """
        path = self.path(name)
        if not os.path.exists(path):
            return []
        with open(path) as f:
            lines = [line for line in f if line.strip()]
        rows = []
        for i, line in enumerate(lines):
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                if i == len(lines) - 1:
                    break   # torn trailing line from a crashed append — the
                    #         record is lost but the store stays readable
                raise       # a torn MIDDLE line is real corruption: surface it
        by_key = {record_key(r): r for r in rows}   # later rows replace earlier
        return list(by_key.values())

    def keys(self, name: str) -> set:
        """Identity keys of every stored record (see `record_key`)."""
        return {record_key(r) for r in self.load(name)}

    def append(self, name: str, records: Iterable[dict]) -> None:
        """O(1) append. Safe even for colliding identities — `load` keeps
        the last write per identity — but the file grows until a compacting
        `upsert`; the sweep engine appends every record immediately and
        compacts once per sweep."""
        os.makedirs(self.root, exist_ok=True)
        path = self.path(name)
        self._heal_torn_tail(path)
        with open(path, "a") as f:
            for rec in records:
                f.write(json.dumps(rec) + "\n")

    @staticmethod
    def _heal_torn_tail(path: str) -> None:
        """Drop a partial trailing line left by a crashed append.

        Without this, the next append would fuse onto the torn fragment and
        turn it into an invalid MID-file line — which `load` rightly treats
        as corruption. A line write can only tear into a prefix, so 'last
        byte is newline' iff the last line is whole; the O(file) repair
        rewrite runs only in the rare post-crash case.
        """
        try:
            if os.path.getsize(path) == 0:
                return
        except OSError:
            return
        with open(path, "rb") as f:
            f.seek(-1, os.SEEK_END)
            if f.read(1) == b"\n":
                return
            f.seek(0)
            data = f.read()
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data[:data.rfind(b"\n") + 1])
        os.replace(tmp, path)

    def compact(self, name: str) -> None:
        """Rewrite the log without superseded duplicate identities."""
        self.upsert(name, [])

    def upsert(self, name: str, records: Iterable[dict]) -> None:
        """Append records, REPLACING stored rows with the same identity."""
        records = list(records)
        fresh = {record_key(r) for r in records}
        kept = [r for r in self.load(name) if record_key(r) not in fresh]
        os.makedirs(self.root, exist_ok=True)
        path = self.path(name)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            for rec in kept + records:
                f.write(json.dumps(rec) + "\n")
        os.replace(tmp, path)

    def lookup(self, name: str, *, coords: dict, seed: int, engine: str,
               spec: dict | None = None,
               records: list[dict] | None = None) -> dict | None:
        """The stored record for one (point, seed), or None.

        When ``spec`` is given the record's resolved spec must match too —
        a changed base spec never silently reuses stale results. Records
        whose spec carries instance markers are never matched.

        Matching canonicalizes ints to floats exactly like `record_key`,
        so a record written from CLI-parsed values (eps=1) serves a reuse
        lookup with Python-API values (eps=1.0) — one identity for writes
        AND reads.
        """
        want_coords = _canon(_normalize(coords))
        want_spec = None if spec is None else _canon(_normalize(spec))
        for rec in (self.load(name) if records is None else records):
            if rec.get("seed") != seed or rec.get("engine") != engine:
                continue
            if _canon(_normalize(rec.get("coords") or {})) != want_coords:
                continue
            rspec = _normalize(rec.get("spec") or {})
            if any(isinstance(v, dict) and "__instance__" in v
                   for v in rspec.values()):
                continue
            if want_spec is not None and _canon(rspec) != want_spec:
                continue
            return rec
        return None

    def query(self, name: str, **filters: Any) -> list[dict]:
        """Records whose coords (or seed/engine) match every filter
        (int/float canonicalized like `lookup`)."""
        out = []
        for rec in self.load(name):
            coords = rec.get("coords") or {}
            view = {**coords, "seed": rec.get("seed"),
                    "engine": rec.get("engine")}
            if all(_canon(_normalize(view.get(k))) == _canon(_normalize(v))
                   for k, v in filters.items()):
                out.append(rec)
        return out

    def make_record(self, name: str, *, coords: dict, seed: int, engine: str,
                    spec: RunSpec, result: RunResult,
                    include_state: bool = False) -> dict:
        return {
            "sweep": name,
            "coords": dict(coords),
            "seed": seed,
            "engine": engine,
            "spec": spec_record(spec),
            "result": result.to_record(include_state=include_state),
            "wall_clock": result.wall_clock,
            "git_sha": git_sha(),
            "written_at": time.time(),
        }
