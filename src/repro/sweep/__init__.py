"""repro.sweep — vectorized experiment orchestration.

The paper's §V evidence is sweep-shaped (accuracy/regret vs eps, sparsity,
node count, topology); this subsystem makes a sweep a declarative object
instead of a hand-rolled loop:

  `SweepSpec`   — named axes over any `RunSpec` field (grid, or comma-zipped
                  fields) plus a vectorized ``seeds`` axis.
  `sweep()`     — runs every point; the seed axis goes through
                  `repro.api.run_batch` (`jax.vmap` over seeds inside the
                  runner's jitted per-chunk `lax.scan` — one compile and
                  ~one memory-bound pass per point) with a sequential
                  fallback when a stage resolves seed-dependently.
  `SweepStore`  — persistent JSONL records under experiments/store/
                  (resolved spec, seed, trajectories, eps ledger,
                  wall-clock, git SHA) with load/query/aggregate helpers,
                  so figures regenerate without re-running (``reuse=True``).

>>> from repro.sweep import SweepSpec, SweepResult, sweep, SweepStore
>>> from repro.api import RunSpec
>>> spec = SweepSpec(base=RunSpec(nodes=2, dim=8, horizon=4, eps=1.0),
...                  axes={"eps": (0.1, 1.0)}, seeds=(0, 1, 2))
>>> len(spec.points()), spec.store_name
(2, 'sweep_eps')
"""
from repro.sweep.spec import SweepPoint, SweepSpec
from repro.sweep.store import (DEFAULT_STORE, SweepStore, aggregate_records,
                               git_sha, record_key, result_from_record,
                               spec_from_record, spec_record)
from repro.sweep.engine import SweepResult, SweepStoreMiss, sweep

__all__ = [
    "SweepSpec", "SweepPoint", "SweepResult", "SweepStoreMiss", "sweep",
    "SweepStore", "DEFAULT_STORE", "aggregate_records", "git_sha",
    "record_key", "result_from_record", "spec_record", "spec_from_record",
]
