"""SweepSpec — a declarative grid of RunSpecs plus a vectorized seed axis.

A sweep names axes over `repro.api.RunSpec` fields; the engine
(`repro.sweep.engine.sweep`) resolves the cartesian product into concrete
points via `RunSpec.replace`, runs every point under all seeds (the seed
axis vectorizes through `repro.api.run_batch` — one compile per point, one
memory-bound pass for all seeds), and persists one JSONL record per
(point, seed) into the results store.

Axis keys are RunSpec field names. A comma-joined key zips several fields
into ONE axis (its values are tuples), for quantities that must co-vary —
e.g. Fig. 5's node count with its same-total-samples horizon:

>>> from repro.api import RunSpec
>>> from repro.sweep import SweepSpec
>>> base = RunSpec(nodes=4, dim=16, horizon=32, eps=1.0, lam=0.01)
>>> sw = SweepSpec(base=base, axes={"eps": (0.1, 1.0)}, seeds=(0, 1, 2))
>>> [p.coords for p in sw.points()]
[{'eps': 0.1}, {'eps': 1.0}]
>>> sw.points()[0].spec.eps, len(sw.seeds)
(0.1, 3)
>>> zipped = SweepSpec(base=base,
...                    axes={"nodes,horizon": ((4, 32), (8, 16)),
...                          "eps": (0.1, 1.0)})
>>> [p.coords for p in zipped.points()]   # zipped pair x grid over eps
[{'nodes': 4, 'horizon': 32, 'eps': 0.1},
 {'nodes': 4, 'horizon': 32, 'eps': 1.0},
 {'nodes': 8, 'horizon': 16, 'eps': 0.1},
 {'nodes': 8, 'horizon': 16, 'eps': 1.0}]
>>> zipped.points()[2].spec.nodes
8
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Mapping, Sequence

from repro.api.spec import RunSpec

__all__ = ["SweepSpec", "SweepPoint"]

_RUNSPEC_FIELDS = {f.name for f in dataclasses.fields(RunSpec)}


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One resolved grid point: its axis coordinates and the concrete spec
    (base spec with the coordinates applied; the seed axis is NOT applied —
    the engine fans the point out over ``SweepSpec.seeds``)."""

    coords: dict[str, Any]
    spec: RunSpec

    def label(self) -> str:
        return ",".join(f"{k}={v}" for k, v in self.coords.items()) or "base"


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Declarative experiment grid over RunSpec fields.

    base:    the RunSpec every point starts from (`RunSpec.replace`).
    axes:    ordered mapping axis-key -> sequence of values. A key that is
             a RunSpec field name sweeps that field; a comma-joined key
             ("nodes,horizon") zips several fields as one axis, each value a
             tuple with one entry per field. The grid is the cartesian
             product of the axes, last axis fastest.
    seeds:   the innermost, VECTORIZED axis — every point runs under all
             seeds in one vmapped batch when the point's resolved stages
             allow it (see `repro.api.runner.seed_vectorizable`).
    engine:  'sim' | 'dist' — which engine drives every point.
    name:    store group (the JSONL file stem under experiments/store/).
    chunk_rounds / compute_regret: forwarded to the runner per point.
    vectorize_seeds: True forces the vmapped path (error when impossible),
             False forces sequential per-seed run() calls, None (default)
             picks automatically per point.
    devices: shard the vmapped seed axis over this many local devices
             (`repro.api.run_batch(devices=)` — shard_map over a ("seed",)
             mesh, S padded to a multiple of the device count). "auto" uses
             every local device; None (default) / 1 stays on the
             single-device vmap. Ignored by the sequential fallback.
    """

    base: RunSpec
    axes: Mapping[str, Sequence] = dataclasses.field(default_factory=dict)
    seeds: Sequence[int] = (0,)
    engine: str = "sim"
    name: str | None = None
    chunk_rounds: int = 512
    compute_regret: bool = True
    vectorize_seeds: bool | None = None
    devices: int | str | None = None

    def __post_init__(self):
        if not self.seeds:
            raise ValueError("SweepSpec needs at least one seed")
        if self.devices is not None and self.devices != "auto":
            if not isinstance(self.devices, int) or self.devices < 1:
                raise ValueError(
                    f"devices must be None, 'auto' or a positive int, got "
                    f"{self.devices!r}")
        if len(set(self.seeds)) != len(tuple(self.seeds)):
            raise ValueError(f"duplicate seeds: {tuple(self.seeds)}")
        if self.engine not in ("sim", "dist"):
            raise ValueError(f"unknown engine {self.engine!r}")
        for key, values in self.axes.items():
            fields = self._axis_fields(key)
            unknown = [f for f in fields if f not in _RUNSPEC_FIELDS]
            if unknown:
                raise ValueError(
                    f"axis {key!r} names unknown RunSpec field(s) {unknown}; "
                    f"valid fields: {sorted(_RUNSPEC_FIELDS)}")
            if "seed" in fields:
                raise ValueError(
                    "'seed' is not a sweepable axis — use SweepSpec.seeds "
                    "(the vectorized innermost axis)")
            if len(values) == 0:
                raise ValueError(f"axis {key!r} has no values")
            if len(fields) > 1:
                bad = [v for v in values
                       if not isinstance(v, (tuple, list))
                       or len(v) != len(fields)]
                if bad:
                    raise ValueError(
                        f"zipped axis {key!r} needs {len(fields)}-tuples, "
                        f"got {bad[0]!r}")

    @staticmethod
    def _axis_fields(key: str) -> list[str]:
        return [f.strip() for f in key.split(",")]

    @property
    def store_name(self) -> str:
        if self.name:
            return self.name
        stem = "-".join(k.replace(",", "+") for k in self.axes) or "point"
        return f"sweep_{stem}"

    def points(self) -> list[SweepPoint]:
        """The resolved grid, in cartesian-product order (last axis fastest).

        Each point's coords flatten zipped keys into their individual
        fields, so store records are queryable per plain field name.
        """
        keys = list(self.axes.keys())
        pts = []
        for combo in itertools.product(*(self.axes[k] for k in keys)):
            coords: dict[str, Any] = {}
            for key, value in zip(keys, combo):
                fields = self._axis_fields(key)
                if len(fields) == 1:
                    coords[fields[0]] = value
                else:
                    coords.update(dict(zip(fields, value)))
            pts.append(SweepPoint(coords=coords,
                                  spec=self.base.replace(**coords)))
        return pts

    def replace(self, **kw: Any) -> "SweepSpec":
        return dataclasses.replace(self, **kw)
