"""The sweep engine: SweepSpec -> (vmapped) runs -> store -> SweepResult.

For every grid point the engine runs all seeds at once through the runner's
vmapped seed axis (`repro.api.run_batch` — one compilation and ~one
memory-bound pass per point) and falls back to sequential per-seed `run()`
calls when the point's resolved stages depend on the seed (seeded 'random'
/ 'time_varying' topologies, per-edge `delay_dist` draws) — outer axes that
change shapes (nodes / dim / mixer) are separate compiles by construction,
which is exactly why only the innermost seed axis is vectorized.

Results persist through `repro.sweep.store.SweepStore` (one JSONL record
per point x seed); ``reuse=True`` loads any already-stored record with a
matching resolved spec instead of re-running, so figure scripts regenerate
their JSONs from the store for free.

>>> import tempfile
>>> from repro.api import RunSpec
>>> from repro.sweep import SweepSpec, sweep
>>> base = RunSpec(nodes=2, dim=8, horizon=6, eps=1.0, alpha0=0.5, lam=0.01,
...                stream="drift", stream_options={"period": 3})
>>> sw = SweepSpec(base=base, axes={"eps": (0.5, 1.0)}, seeds=(0, 1),
...                name="doc_demo", chunk_rounds=6, compute_regret=False)
>>> out = sweep(sw, store=tempfile.mkdtemp(), warmup=False)
>>> len(out.points), [len(rs) for rs in out.results], out.ran_points
(2, [2, 2], 2)
>>> rows = out.aggregate("accuracy")
>>> [r["eps"] for r in rows], rows[0]["n"]
([0.5, 1.0], 2)
>>> again = sweep(sw, store=out.store.root, reuse=True, warmup=False)
>>> again.ran_points, again.loaded_points     # regenerated, nothing re-run
(0, 2)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from repro import obs as obslib
from repro.api.exec_config import ExecConfig
from repro.api.runner import RunResult, run, run_batch, seed_vectorizable
from repro.sweep.spec import SweepPoint, SweepSpec
from repro.sweep.store import (DEFAULT_STORE, SweepStore, aggregate_records,
                               record_key, spec_record)

__all__ = ["sweep", "SweepResult", "SweepStoreMiss"]


class SweepStoreMiss(RuntimeError):
    """Raised by ``sweep(reuse=True, require_store=True)`` when the store has
    no matching record for one or more (point, seed) identities — instead of
    silently re-running (or worse, emitting a figure from nothing)."""


def _metric(res: RunResult, value: str | Callable) -> Any:
    if callable(value):
        return value(res)
    if value == "regret_final":
        return None if res.regret is None else float(res.regret[-1])
    v = getattr(res, value, None)
    if isinstance(v, (int, float)):
        return float(v)
    return res.metrics.get(value)


@dataclasses.dataclass
class SweepResult:
    """Everything a finished sweep knows: the grid, the per-point per-seed
    RunResults, the records as persisted, and where they went."""

    spec: SweepSpec
    points: list[SweepPoint]
    results: list[list[RunResult]]       # [point][seed]
    records: list[dict]                  # flat, as written/loaded
    store: SweepStore | None
    wall_clock: float
    ran_points: int                      # points actually executed
    loaded_points: int                   # points served from the store

    def aggregate(self, value: str | Callable[[RunResult], Any] = "accuracy",
                  ) -> list[dict]:
        """Per-point mean/std over seeds of one scalar metric.

        ``value`` is a RunResult attribute / metrics key (e.g. 'accuracy',
        'regret_final', 'wall_clock') or a callable RunResult -> float.
        Rows are ``{**coords, mean, std, n, values}`` in grid order.
        """
        import numpy as np
        rows = []
        for point, results in zip(self.points, self.results):
            values = [_metric(r, value) for r in results]
            clean = [v for v in values if v is not None]
            rows.append({
                **point.coords,
                "mean": float(np.mean(clean)) if clean else None,
                "std": float(np.std(clean)) if clean else None,
                "n": len(values),
                "values": values,
            })
        return rows

    def point_records(self, index: int) -> list[dict]:
        coords = self.points[index].coords
        return [r for r in self.records if r.get("coords") == coords]

    def summary(self) -> dict:
        return {
            "name": self.spec.store_name,
            "engine": self.spec.engine,
            "points": len(self.points),
            "seeds": list(self.spec.seeds),
            "ran_points": self.ran_points,
            "loaded_points": self.loaded_points,
            "wall_clock_s": round(self.wall_clock, 3),
            "store": None if self.store is None else self.store.path(
                self.spec.store_name),
        }


def _run_point(point: SweepPoint, spec: SweepSpec, *,
               warmup: bool) -> list[RunResult]:
    seeds = list(spec.seeds)
    vec = spec.vectorize_seeds
    if vec is None:
        vec = len(seeds) > 1 and seed_vectorizable(point.spec, seeds)
    if vec:
        # spec.vectorize_seeds=None means WE just verified vectorizability;
        # an explicit True still lets run_batch's own check raise.
        # seed_vectorizable gates the sharded path exactly like the vmapped
        # one — a seed-dependent stage falls back to sequential runs below
        # whatever spec.devices asks for.
        return run_batch(point.spec, seeds, engine=spec.engine,
                         exec=ExecConfig(
                             chunk_rounds=spec.chunk_rounds,
                             compute_regret=spec.compute_regret, warmup=warmup,
                             check_vectorizable=spec.vectorize_seeds
                             is not None,
                             devices=spec.devices))
    return [run(point.spec.replace(seed=s), engine=spec.engine,
                exec=ExecConfig(chunk_rounds=spec.chunk_rounds,
                                compute_regret=spec.compute_regret,
                                warmup=warmup))
            for s in seeds]


def sweep(spec: SweepSpec, *, store: str | SweepStore | None = DEFAULT_STORE,
          reuse: bool = False, warmup: bool = True,
          include_state: bool = False, verbose: bool = False,
          require_store: bool = False) -> SweepResult:
    """Run (or reload) every grid point x seed; persist; return SweepResult.

    store:   store root (or SweepStore, or None to skip persistence).
    reuse:   serve a point from the store when ALL its seeds have records
             whose resolved spec matches exactly — the regenerate-figures-
             without-re-running path.
    warmup:  compile each point's chunk outside its timed region.
    include_state: persist the raw engine state inside each record.
    require_store: with ``reuse``, raise `SweepStoreMiss` (naming the
             missing points) instead of re-running anything when the store
             cannot serve every point — the contract behind --from-store.
    """
    if require_store and not reuse:
        raise ValueError(
            "require_store=True is only meaningful with reuse=True — "
            "without reuse every point re-runs, the exact thing "
            "require_store promises to prevent")
    store_obj = (store if isinstance(store, SweepStore)
                 else SweepStore(store) if store is not None else None)
    name = spec.store_name
    existing = store_obj.load(name) if store_obj else []
    # every finished point APPENDS immediately (O(1), durable under a
    # mid-sweep crash); identity collisions are resolved on read (load keeps
    # the last write) and compacted away once at the end of the sweep —
    # a P-point sweep stays O(P) I/O, not O(P^2)
    existing_keys = {record_key(r) for r in existing}

    def _cached(point: SweepPoint) -> list[dict] | None:
        """The point's stored records (one per seed), or None on any miss."""
        if store_obj is None:
            return None
        found = [store_obj.lookup(
                     name, coords=point.coords, seed=s, engine=spec.engine,
                     spec=spec_record(point.spec.replace(seed=s)),
                     records=existing)
                 for s in spec.seeds]
        # a record stored by a compute_regret=False sweep has no regret
        # trajectory — it cannot serve a sweep that asks for one
        if spec.compute_regret:
            found = [r if r is not None
                     and r["result"].get("regret") is not None else None
                     for r in found]
        return found if all(r is not None for r in found) else None

    points = spec.points()
    cached_points = [_cached(p) if reuse else None for p in points]
    if reuse and require_store:
        missing = [p.label() for p, c in zip(points, cached_points)
                   if c is None]
        if missing:
            where = (store_obj.path(name) if store_obj is not None
                     else "no store configured")
            shown = ", ".join(missing[:5]) + ("..." if len(missing) > 5
                                              else "")
            raise SweepStoreMiss(
                f"sweep {name!r}: the store ({where}) has no record "
                f"matching the resolved spec for {len(missing)}/"
                f"{len(points)} point(s) [{shown}] x seeds "
                f"{tuple(spec.seeds)}; run once without --from-store to "
                f"populate it (records also go stale when the base spec "
                f"changes)")

    # ambient telemetry (repro.obs): a no-op unless the caller enabled it —
    # each point gets a sweep.point span and a sweep_point event, and the
    # runs inside _run_point pick up the same ambient Telemetry themselves
    tel = obslib.active()

    results: list[list[RunResult]] = []
    records: list[dict] = []
    needs_compaction = False
    ran = loaded = 0
    t0 = time.time()
    for point, cached in zip(points, cached_points):
        if cached is not None:
            loaded += 1
            with tel.span("sweep.point", sweep=name, label=point.label(),
                          source="loaded"):
                point_results = [RunResult.from_record(r["result"])
                                 for r in cached]
            point_records = cached
        else:
            ran += 1
            with tel.span("sweep.point", sweep=name, label=point.label(),
                          source="ran"):
                point_results = _run_point(point, spec, warmup=warmup)
            point_records = [
                store_obj.make_record(
                    name, coords=point.coords, seed=s, engine=spec.engine,
                    spec=point.spec.replace(seed=s), result=res,
                    include_state=include_state)
                if store_obj is not None else
                {"sweep": name, "coords": dict(point.coords), "seed": s,
                 "engine": spec.engine,
                 "result": res.to_record(include_state=include_state)}
                for s, res in zip(spec.seeds, point_results)]
            if store_obj is not None:
                store_obj.append(name, point_records)
                fresh_keys = [record_key(r) for r in point_records]
                if any(k in existing_keys for k in fresh_keys):
                    needs_compaction = True
                existing_keys.update(fresh_keys)
        if tel.enabled:
            source = "loaded" if cached is not None else "ran"
            tel.metrics.counter(f"sweep.points_{source}").inc()
            tel.emit("sweep_point", sweep=name, label=point.label(),
                     seeds=list(spec.seeds), source=source)
        if verbose:
            accs = [r.accuracy for r in point_results]
            print(f"[sweep {name}] {point.label()}: "
                  f"{'loaded' if cached is not None else 'ran'} "
                  f"{len(point_results)} seeds, acc={accs}")
        results.append(point_results)
        records.extend(point_records)
    if store_obj is not None and needs_compaction:
        store_obj.compact(name)
    return SweepResult(spec=spec, points=points, results=results,
                       records=records, store=store_obj,
                       wall_clock=time.time() - t0,
                       ran_points=ran, loaded_points=loaded)
