"""Store-backed figure regeneration CLI.

    PYTHONPATH=src python -m repro.sweep.plot                # every sweep
    PYTHONPATH=src python -m repro.sweep.plot fig3_topology fig5_nodes
    PYTHONPATH=src python -m repro.sweep.plot --list

Every sweep run persists its (point, seed) records under
``experiments/store/<name>.jsonl`` — this CLI turns those records back into
figure data WITHOUT a single engine call: per sweep it aggregates each
metric over seeds at every coordinate (`aggregate_records`, the same
reduction the figure scripts use) and writes
``experiments/figures/<name>_plot.json``. When matplotlib is importable
(it is NOT in CI — the PNG path is best-effort by design) and the sweep
has exactly one varying axis, it also renders ``<name>_plot.png`` with
mean±std error bars.

>>> import tempfile
>>> from repro.sweep.plot import figure_rows
>>> recs = [{"coords": {"eps": e}, "seed": s, "engine": "sim",
...          "result": {"accuracy": 0.5 + 0.1 * s}}
...         for e in (0.1, 1.0) for s in (0, 1)]
>>> rows = figure_rows(recs, metric="accuracy")
>>> [(r["eps"], r["mean"], r["n"]) for r in rows]
[(0.1, 0.55, 2), (1.0, 0.55, 2)]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from repro.sweep.store import (DEFAULT_STORE, SweepStore, aggregate_records,
                               record_metric, result_from_record)

__all__ = ["figure_rows", "plot_sweep", "main"]

# scalar metrics every record carries; regret_final needs the decoded array
METRICS = ("accuracy", "regret_final", "rounds_per_sec")


def _metric_value(rec: dict, metric: str):
    if metric == "regret_final":
        try:
            res = result_from_record(rec)
        except Exception:
            return None
        if res.regret is None:
            return None
        return float(np.asarray(res.regret)[-1])
    return record_metric(rec, metric)


def coord_axes(records: list[dict]) -> tuple[str, ...]:
    """Every coordinate field any record carries, sorted."""
    return tuple(sorted({k for r in records
                         for k in (r.get("coords") or {})}))


def figure_rows(records: list[dict], *, metric: str = "accuracy",
                by: tuple[str, ...] | None = None) -> list[dict]:
    """Seed-aggregated (mean/std/n) rows of ``metric`` at every coordinate —
    the same reduction the figure scripts apply to live sweep results."""
    axes = coord_axes(records) if by is None else by
    rows = aggregate_records(records, axes, lambda r: _metric_value(r, metric))
    return sorted(rows, key=lambda r: json.dumps(
        {k: r.get(k) for k in axes}, sort_keys=True, default=str))


def _maybe_png(name: str, rows_by_metric: dict, axes: tuple[str, ...],
               out_dir: str) -> str | None:
    """Best-effort 1-axis PNG; None when matplotlib is unavailable (CI),
    the axis is not one-dimensional, or the axis is not numeric."""
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception:
        return None
    varying = [a for a in axes
               if len({json.dumps(r.get(a), default=str)
                       for rows in rows_by_metric.values()
                       for r in rows}) > 1] or list(axes)
    if len(varying) != 1:
        return None
    axis = varying[0]
    panels = [(m, rows) for m, rows in rows_by_metric.items()
              if any(r["mean"] is not None for r in rows)]
    if not panels:
        return None
    fig, axs = plt.subplots(1, len(panels),
                            figsize=(4.5 * len(panels), 3.5), squeeze=False)
    for ax, (metric, rows) in zip(axs[0], panels):
        pts = [(r[axis], r["mean"], r["std"]) for r in rows
               if r["mean"] is not None
               and isinstance(r.get(axis), (int, float))]
        if not pts:
            continue
        pts.sort(key=lambda p: p[0])
        xs, means, stds = map(np.asarray, zip(*pts))
        ax.errorbar(xs, means, yerr=stds, marker="o", capsize=3)
        ax.set_xlabel(axis)
        ax.set_ylabel(metric)
        ax.grid(alpha=0.3)
    fig.suptitle(name)
    fig.tight_layout()
    path = os.path.join(out_dir, f"{name}_plot.png")
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return path


def plot_sweep(name: str, *, store: SweepStore,
               out_dir: str = "experiments/figures",
               metrics: tuple[str, ...] = METRICS) -> dict | None:
    """Regenerate one sweep's figure data (and best-effort PNG) from the
    store. Returns the written summary, or None when no records exist."""
    records = store.load(name)
    if not records:
        return None
    axes = coord_axes(records)
    rows_by_metric = {}
    for metric in metrics:
        rows = figure_rows(records, metric=metric, by=axes)
        # drop the raw per-seed value lists from the JSON: seeds live in
        # the store; the figure file carries the aggregates
        rows_by_metric[metric] = [
            {k: v for k, v in r.items() if k != "values"} for r in rows]
    os.makedirs(out_dir, exist_ok=True)
    summary = {
        "sweep": name,
        "records": len(records),
        "axes": list(axes),
        "engines": sorted({r.get("engine") for r in records}),
        "metrics": rows_by_metric,
    }
    json_path = os.path.join(out_dir, f"{name}_plot.json")
    with open(json_path, "w") as f:
        json.dump(summary, f, indent=1)
    png = _maybe_png(name, rows_by_metric, axes, out_dir)
    summary["json_path"] = json_path
    summary["png_path"] = png
    return summary


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sweep.plot",
        description="Regenerate figure JSON (and PNG when matplotlib is "
                    "available) from stored sweep records — no engine calls")
    ap.add_argument("names", nargs="*",
                    help="sweep names (default: every sweep in the store)")
    ap.add_argument("--store", default=DEFAULT_STORE)
    ap.add_argument("--out-dir", default="experiments/figures")
    ap.add_argument("--list", action="store_true",
                    help="list stored sweep names and exit")
    args = ap.parse_args(argv)

    store = SweepStore(args.store)
    available = store.names()
    if args.list:
        for name in available:
            print(f"{name}: {len(store.load(name))} records")
        return 0
    names = args.names or available
    if not names:
        print(f"plot: no sweeps in {args.store} — run a sweep or a "
              f"benchmarks/ figure first", file=sys.stderr)
        return 1
    missing = [n for n in names if n not in available]
    if missing:
        print(f"plot: no stored records for {', '.join(missing)} "
              f"(have: {', '.join(available) or 'none'})", file=sys.stderr)
        return 1
    for name in names:
        summary = plot_sweep(name, store=store, out_dir=args.out_dir)
        made = summary["json_path"] + (
            f" + {summary['png_path']}" if summary["png_path"] else "")
        print(f"{name}: {summary['records']} records "
              f"over axes {summary['axes']} -> {made}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
