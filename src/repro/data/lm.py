"""Synthetic LM token stream for end-to-end transformer training.

A first-order Markov chain over the vocabulary with Zipf marginals: there
IS learnable structure (bigram statistics), so a ~100M model trained for a
few hundred steps shows a real loss decrease — without shipping a corpus.
Deterministic per (seed, step): replayable, and per-node streams are
disjoint (fold_in node id), matching the paper's parallel-composition
requirement.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.2
    markov_states: int = 64  # low-rank transition structure

    def _marginal(self) -> jax.Array:
        ranks = jnp.arange(1, self.vocab_size + 1, dtype=jnp.float32)
        p = ranks ** (-self.zipf_a)
        return p / p.sum()

    def sample(self, step: int, node: int, batch: int, seq: int) -> jax.Array:
        """Tokens (batch, seq) — a Markov walk keyed by (seed, step, node)."""
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), node)
        p = self._marginal()
        k0, kwalk = jax.random.split(key)
        # low-rank bigram: next ~ mixture of marginal and a state-dependent shift
        first = jax.random.categorical(k0, jnp.log(p)[None, :], shape=(batch, 1))

        def step_fn(prev, k):
            shift = (prev * 31 + 7) % self.vocab_size  # deterministic "structure"
            mix = jax.random.uniform(k, (batch,)) < 0.5
            nxt = jnp.where(
                mix, shift[:, 0],
                jax.random.categorical(k, jnp.log(p)[None, :], shape=(batch,)),
            )
            return nxt[:, None], nxt

        keys = jax.random.split(kwalk, seq - 1)
        _, rest = jax.lax.scan(step_fn, first, keys)
        toks = jnp.concatenate([first, rest.T], axis=1)
        return toks.astype(jnp.int32)


def lm_batches(vocab_size: int, batch: int, seq: int, nodes: int = 1,
               seed: int = 0) -> Iterator[dict]:
    """Yields {'tokens' (nodes, batch, seq) or (batch, seq), 'labels' ...}.

    Labels are next-token shifted; final position is masked (-1).
    """
    stream = TokenStream(vocab_size=vocab_size, seed=seed)
    step = 0
    while True:
        if nodes > 1:
            toks = jnp.stack([stream.sample(step, i, batch, seq) for i in range(nodes)])
        else:
            toks = stream.sample(step, 0, batch, seq)
        labels = jnp.concatenate(
            [toks[..., 1:], jnp.full(toks.shape[:-1] + (1,), -1, jnp.int32)], axis=-1)
        yield {"tokens": toks, "labels": labels}
        step += 1
