"""Data pipeline: synthetic social-data streams + LM token streams."""
from repro.data.social import SocialStream, make_social_stream
from repro.data.lm import TokenStream, lm_batches

__all__ = ["SocialStream", "make_social_stream", "TokenStream", "lm_batches"]
