"""Synthetic social-data stream matching the paper's simulation scale.

The paper uses 100,000 real social data points of dimensionality 10,000
(unreleased). We generate a stream with the same scale and task shape:
a sparse ground-truth w* (only `sparsity_true` fraction of features carry
signal — "a person's height cannot contribute to predicting his taste"),
features x normalized per the paper's pretreatment, labels y = sign(<w*,x>)
with optional flip noise. Each node's per-round sample is disjoint from all
others (fresh randomness per (t, i)) — the condition for Theorem 1's
parallel composition.

Streams are generated in jit-able chunks so a 100k x 10k simulation never
materializes 4 GB at once. Sampling is keyed per ABSOLUTE round (one
fold_in per t, vmapped), so ``chunk(a, b)`` returns the same rounds no
matter how the horizon is partitioned — the property `repro.api.run`
relies on for checkpoint resume and for sim-vs-dist bit-identity under
different chunk sizes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterator

import jax
import jax.numpy as jnp


def labels_from_logits(logits: jax.Array) -> jax.Array:
    """y = +1 iff <w*, x> >= 0 — an exact-zero logit maps to +1, never to
    the invalid label 0 (jnp.sign(0) == 0 would silently break the hinge
    workload: a 0 label zeroes the gradient AND can never be predicted)."""
    return jnp.where(logits >= 0, 1.0, -1.0).astype(jnp.float32)


def round_keys(base: jax.Array, t0: int, t1: int) -> jax.Array:
    """One PRNG key per absolute round in [t0, t1) — chunk-boundary
    invariant: the key for round t never depends on where chunks split."""
    return jax.vmap(lambda t: jax.random.fold_in(base, t))(jnp.arange(t0, t1))


@functools.lru_cache(maxsize=128)
def _w_true(n: int, sparsity_true: float, seed: int) -> jax.Array:
    kw, km = jax.random.split(jax.random.PRNGKey(seed))
    mask = jax.random.uniform(km, (n,)) < sparsity_true
    w = jax.random.normal(kw, (n,)) * mask
    return (w / jnp.maximum(jnp.linalg.norm(w), 1e-9)).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class SocialStream:
    n: int
    nodes: int
    rounds: int
    sparsity_true: float = 0.05
    label_noise: float = 0.0
    seed: int = 0

    # every round touches only samples that arrive at that round — the
    # Theorem-1 parallel-composition condition the PrivacyAccountant reads
    disjoint: bool = True

    def w_true(self) -> jax.Array:
        # cached across chunk() calls — the ground truth is a pure function
        # of (n, sparsity_true, seed) and used to be recomputed per chunk
        return _w_true(self.n, self.sparsity_true, self.seed)

    def chunk(self, t0: int, t1: int) -> tuple[jax.Array, jax.Array]:
        """Rounds [t0, t1): returns xs (t1-t0, m, n), ys (t1-t0, m)."""
        w = self.w_true()
        keys = round_keys(jax.random.PRNGKey(self.seed + 1), t0, t1)
        kx, kn = jax.vmap(lambda k: tuple(jax.random.split(k)))(keys)
        x = jax.vmap(
            lambda k: jax.random.normal(k, (self.nodes, self.n))
        )(kx) / jnp.sqrt(self.n)
        logits = jnp.einsum("n,tmn->tm", w, x)
        y = labels_from_logits(logits)
        if self.label_noise > 0:
            flip = jax.vmap(
                lambda k: jax.random.uniform(k, (self.nodes,))
            )(kn) < self.label_noise
            y = jnp.where(flip, -y, y)
        return x.astype(jnp.float32), y.astype(jnp.float32)

    def chunks(self, chunk_rounds: int = 512) -> Iterator[tuple[jax.Array, jax.Array]]:
        t = 0
        while t < self.rounds:
            t1 = min(t + chunk_rounds, self.rounds)
            yield self.chunk(t, t1)
            t = t1


def make_social_stream(cfg) -> SocialStream:
    """From a configs.social_linear.SocialLinearConfig."""
    return SocialStream(
        n=cfg.n, nodes=cfg.nodes, rounds=cfg.rounds,
        sparsity_true=cfg.sparsity_true, seed=cfg.seed,
    )
