"""Synthetic social-data stream matching the paper's simulation scale.

The paper uses 100,000 real social data points of dimensionality 10,000
(unreleased). We generate a stream with the same scale and task shape:
a sparse ground-truth w* (only `sparsity_true` fraction of features carry
signal — "a person's height cannot contribute to predicting his taste"),
features x normalized per the paper's pretreatment, labels y = sign(<w*,x>)
with optional flip noise. Each node's per-round sample is disjoint from all
others (fresh randomness per (t, i)) — the condition for Theorem 1's
parallel composition.

Streams are generated in jit-able chunks so a 100k x 10k simulation never
materializes 4 GB at once.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SocialStream:
    n: int
    nodes: int
    rounds: int
    sparsity_true: float = 0.05
    label_noise: float = 0.0
    seed: int = 0

    def w_true(self) -> jax.Array:
        kw, km = jax.random.split(jax.random.PRNGKey(self.seed))
        mask = jax.random.uniform(km, (self.n,)) < self.sparsity_true
        w = jax.random.normal(kw, (self.n,)) * mask
        return (w / jnp.maximum(jnp.linalg.norm(w), 1e-9)).astype(jnp.float32)

    def chunk(self, t0: int, t1: int) -> tuple[jax.Array, jax.Array]:
        """Rounds [t0, t1): returns xs (t1-t0, m, n), ys (t1-t0, m)."""
        w = self.w_true()
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), t0)
        kx, kn = jax.random.split(key)
        T = t1 - t0
        x = jax.random.normal(kx, (T, self.nodes, self.n)) / jnp.sqrt(self.n)
        logits = jnp.einsum("n,tmn->tm", w, x)
        y = jnp.sign(logits + 1e-12)
        if self.label_noise > 0:
            flip = jax.random.uniform(kn, y.shape) < self.label_noise
            y = jnp.where(flip, -y, y)
        return x.astype(jnp.float32), y.astype(jnp.float32)

    def chunks(self, chunk_rounds: int = 512) -> Iterator[tuple[jax.Array, jax.Array]]:
        t = 0
        while t < self.rounds:
            t1 = min(t + chunk_rounds, self.rounds)
            yield self.chunk(t, t1)
            t = t1


def make_social_stream(cfg) -> SocialStream:
    """From a configs.social_linear.SocialLinearConfig."""
    return SocialStream(
        n=cfg.n, nodes=cfg.nodes, rounds=cfg.rounds,
        sparsity_true=cfg.sparsity_true, seed=cfg.seed,
    )
