"""LM serving demo: batched prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve_lm --arch qwen2-7b --batch 4 \
        --prompt-len 32 --gen 16 [--smoke]

Greedy decode with the ring-buffer KV cache (or recurrent state for
SSM/hybrid archs). On CPU use --smoke. The social-prediction serving
front end lives in `repro.launch.serve`.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch import steps
from repro.models import build_model


def serve(arch: str, *, batch: int = 4, prompt_len: int = 32, gen: int = 16,
          cache_len: int = 128, smoke: bool = True, seed: int = 0) -> dict:
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    # independent randomness for params, prompts and priming frames —
    # reusing one key would correlate the weights with the inputs
    init_key, prompt_key, prime_key = jax.random.split(
        jax.random.PRNGKey(seed), 3)
    params = model.init(init_key)
    serve_step = jax.jit(steps.make_serve_step(model), donate_argnums=(1,))

    prompts = jax.random.randint(prompt_key, (batch, prompt_len), 0,
                                 cfg.vocab_size)
    cache = model.init_cache(batch, cache_len)
    if model.prime_cache is not None:
        frames = jax.random.normal(
            prime_key, (batch, max(cache_len // 4, 8), cfg.d_model))
        cache = model.prime_cache(params, cache, frames.astype(cfg.jdtype))

    # prefill token-by-token through the decode path (fills cache + state);
    # block-prefill via apply() is benchmarked separately in benchmarks/.
    t0 = time.time()
    tok = prompts[:, :1]
    out_tokens = [tok]
    for i in range(prompt_len - 1):
        pos = jnp.full((batch,), i, jnp.int32)
        nxt, cache = serve_step(params, cache, tok, pos)
        tok = prompts[:, i + 1: i + 2]
    # generate
    for i in range(gen):
        pos = jnp.full((batch,), prompt_len - 1 + i, jnp.int32)
        nxt, cache = serve_step(params, cache, tok, pos)
        tok = nxt[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)          # honest wall clock: wait for compute
    dt = time.time() - t0
    toks = np.asarray(jnp.concatenate(out_tokens, axis=1))
    print(f"{arch}: generated {gen} tokens x batch {batch} in {dt:.2f}s "
          f"({(prompt_len + gen - 1) / dt:.1f} steps/s)")
    print("sample token ids:", toks[0, -min(gen, 10):].tolist())
    return {"tokens": toks, "seconds": dt}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    args = ap.parse_args()
    serve(args.arch, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen,
          cache_len=args.cache_len, smoke=args.smoke)


if __name__ == "__main__":
    main()
