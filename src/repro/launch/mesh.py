"""Production meshes. Functions, not module constants — importing this file
never touches jax device state."""
from __future__ import annotations

import jax

try:  # AxisType landed after jax 0.4.x; older jax only has Auto semantics
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def make_mesh(shape, axes):
    """`jax.make_mesh` with Auto axis types when the installed jax has them."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16, 16) = ("data", "model").
    Multi-pod: 512 chips (2, 16, 16) = ("pod", "data", "model");
    each pod is one gossip data center (see DESIGN.md §4)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(data: int = 4, model: int = 2):
    """Small mesh for subprocess tests with --xla_force_host_platform_device_count."""
    return make_mesh((data, model), ("data", "model"))


def seed_mesh(devices: int | str | None = "auto"):
    """1-D ``("seed",)`` mesh for device-sharding independent per-seed runs.

    The seed axis of `repro.api.run_batch` is embarrassingly parallel — each
    seed is its own private run — so the only mesh it needs is a flat row of
    devices. ``devices="auto"`` uses every local device; an int asks for
    exactly that many (error with an XLA_FLAGS hint when the host has fewer);
    ``None``, 0 or 1 returns None — the caller's cue to stay on the
    single-device vmap path.
    """
    avail = jax.local_device_count()
    if devices == "auto":
        devices = avail
    devices = int(devices or 0)
    if devices <= 1:
        return None
    if devices > avail:
        raise ValueError(
            f"seed_mesh: asked for {devices} devices but only {avail} are "
            f"visible; on a CPU host, export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={devices} "
            f"before importing jax to fake a multi-device topology")
    return make_mesh((devices,), ("seed",))


def node_mesh(devices: int | str | None = "auto"):
    """1-D ``("node",)`` mesh for sharding the gossip node axis.

    Same semantics as `seed_mesh`: ``"auto"`` takes every local device, an
    int asks for exactly that many (error with the XLA_FLAGS hint when the
    host has fewer), and ``None``/0/1 returns None — the caller's cue to
    stay on the unsharded path. Unlike seeds, node shards are NOT
    independent: the sharded chunk program exchanges boundary theta~ between
    neighbors with `lax.ppermute` (see `repro.api.shard_node`).
    """
    avail = jax.local_device_count()
    if devices == "auto":
        devices = avail
    devices = int(devices or 0)
    if devices <= 1:
        return None
    if devices > avail:
        raise ValueError(
            f"node_mesh: asked for {devices} devices but only {avail} are "
            f"visible; on a CPU host, export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={devices} "
            f"before importing jax to fake a multi-device topology")
    return make_mesh((devices,), ("node",))


def seed_node_mesh(seed_devices: int | None = 1,
                   node_devices: int | str | None = "auto"):
    """2-D ``("seed", "node")`` grid: independent seed rows x node columns.

    `repro.api.run_batch` shards the vmapped seed axis over the rows and
    each seed's node axis over the columns. ``node_devices="auto"`` spreads
    whatever devices remain after the seed rows (avail // seed_devices);
    node_devices <= 1 returns None — fall back to `seed_mesh` / vmap.
    """
    avail = jax.local_device_count()
    s = int(seed_devices or 1) or 1
    if node_devices == "auto":
        node_devices = avail // s
    nd = int(node_devices or 0)
    if nd <= 1:
        return None
    if s * nd > avail:
        raise ValueError(
            f"seed_node_mesh: asked for {s} x {nd} = {s * nd} devices but "
            f"only {avail} are visible; on a CPU host, export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={s * nd} "
            f"before importing jax to fake a multi-device topology")
    return make_mesh((s, nd), ("seed", "node"))


def gossip_axes(mesh) -> tuple[str, ...]:
    """Which mesh axes carry the gossip node dimension."""
    return ("pod",) if "pod" in mesh.axis_names else ("data",)


def gossip_nodes(mesh) -> int:
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in gossip_axes(mesh)]))


def data_axes_for_batch(mesh) -> tuple[str, ...]:
    """Axes the *within-node* batch dim shards over."""
    return ("data",) if "pod" in mesh.axis_names else ()
