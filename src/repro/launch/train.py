"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --steps 50 \
        --strategy gossip --eps 1.0 --nodes 4 [--smoke]

On this CPU container use --smoke (reduced config, tiny batch); on a real
TPU pod the same driver runs the full config with the production mesh.
The paper's GossipDP strategy is the default; --strategy allreduce gives the
classic data-parallel baseline.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.data.lm import lm_batches
from repro.launch import steps
from repro.metrics import CSVLogger, MetricTracker
from repro.models import build_model


def train(arch: str, *, strategy: str = "gossip", nodes: int = 4, steps_n: int = 50,
          batch_per_node: int = 2, seq_len: int = 128, eps: float = 1.0,
          lam: float = 1e-4, smoke: bool = True, log_path: str | None = None,
          seed: int = 0, microbatches: int = 1, topology: str = "ring",
          local_rule: str = "omd", mechanism: str = "laplace",
          clip_style: str = "coordinate", delay: int = 0,
          delay_dist: str | None = None) -> dict:
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    recipe = steps.TrainRecipe(strategy=strategy, eps=eps, lam=lam,
                               microbatches=microbatches, topology=topology,
                               local_rule=local_rule, mechanism=mechanism,
                               clip_style=clip_style, delay=delay,
                               delay_dist=delay_dist)

    if strategy == "gossip":
        gdp = steps.make_gossip_dp(nodes, recipe)
        step_fn = jax.jit(steps.make_gossip_train_step(model, gdp, microbatches),
                          donate_argnums=(0,))
        state = steps.make_gossip_init(model, gdp, nodes)(seed)
        batch_nodes = nodes
    else:
        train_step, init = steps.make_allreduce_train_step(model, recipe)
        step_fn = jax.jit(train_step, donate_argnums=(0,))
        state = init(seed)
        batch_nodes = 1

    def add_frontend(batch):
        B_l = batch["tokens"].shape[:-1]
        if cfg.frontend is not None:
            batch["frontend"] = jnp.zeros(B_l + (max(cfg.frontend_tokens, 1), cfg.d_model),
                                          cfg.jdtype)
            batch["labels"] = batch["labels"].at[..., :cfg.frontend_tokens].set(-1)
        elif cfg.family == "encdec":
            batch["frontend"] = jnp.zeros(B_l + (max(seq_len // 4, 8), cfg.d_model),
                                          cfg.jdtype)
        return batch

    data = lm_batches(cfg.vocab_size, batch_per_node, seq_len,
                      nodes=batch_nodes, seed=seed)
    logger = CSVLogger(log_path) if log_path else None
    tracker = MetricTracker()
    t0 = time.time()
    history = []
    for i in range(steps_n):
        batch = add_frontend(next(data))
        if strategy == "gossip" and batch_nodes == 1:
            batch = jax.tree_util.tree_map(lambda x: x[None], batch)
        state, metrics = step_fn(state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        tracker.update(metrics)
        history.append(metrics)
        if logger:
            logger.log(i, metrics)
        if i % 10 == 0 or i == steps_n - 1:
            m = tracker.means()
            print(f"step {i:4d} loss={m.get('loss', 0):.4f} "
                  f"ce={m.get('ce', 0):.4f} "
                  f"sparsity={m.get('theta_sparsity', 0):.3f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    if logger:
        logger.close()
    return {"history": history, "final": tracker.means(), "state": state}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--strategy", default="gossip", choices=["gossip", "allreduce"])
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch-per-node", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--eps", type=float, default=1.0)
    ap.add_argument("--lam", type=float, default=1e-4)
    ap.add_argument("--topology", default="ring",
                    help="repro.api MIXERS registry name (ring, complete, "
                         "ring_alternating, disconnected, torus, ...)")
    ap.add_argument("--local-rule", default="omd",
                    help="repro.api LOCAL_RULES registry name (omd, tg, rda)")
    ap.add_argument("--mechanism", default="laplace",
                    help="repro.api MECHANISMS registry name (laplace, gaussian, none)")
    ap.add_argument("--clip-style", default="coordinate",
                    choices=["coordinate", "global"],
                    help="Laplace calibration (see TrainRecipe.clip_style)")
    ap.add_argument("--delay", type=int, default=0,
                    help="WAN gossip staleness in rounds; > 0 gives "
                         "GossipState a (delay+1)-deep history ring")
    ap.add_argument("--delay-dist", default=None,
                    choices=["constant", "uniform", "geometric"],
                    help="per-edge delay distribution (heterogeneous WAN "
                         "links), capped at --delay; default: uniform lag")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log", default=None)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    train(args.arch, strategy=args.strategy, nodes=args.nodes, steps_n=args.steps,
          batch_per_node=args.batch_per_node, seq_len=args.seq_len, eps=args.eps,
          lam=args.lam, smoke=args.smoke, log_path=args.log, seed=args.seed,
          microbatches=args.microbatches, topology=args.topology,
          local_rule=args.local_rule, mechanism=args.mechanism,
          clip_style=args.clip_style, delay=args.delay,
          delay_dist=args.delay_dist)


if __name__ == "__main__":
    main()
