"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --steps 50 \
        --strategy gossip --eps 1.0 --nodes 4 [--smoke]
    PYTHONPATH=src python -m repro.launch.train --stream drift --nodes 8 \
        --dim 256 --steps 500 --engine sim

Two workloads, one driving loop (`repro.api.run`):

  * ``--arch`` trains an LM architecture with the GossipDP strategy
    ('gossip', the paper) or the classic data-parallel baseline
    ('allreduce'); run() drives the per-step loop, metrics, eps accounting
    and checkpoints.
  * ``--stream`` runs the paper's linear workload on any STREAMS scenario
    (social_sparse, drift, heterogeneous, bursty) under either engine —
    the same call the benchmarks make, so the CLI and the benchmarks
    cannot diverge.

On this CPU container use --smoke (reduced config, tiny batch); on a real
TPU pod the same driver runs the full config with the production mesh.
"""
from __future__ import annotations

import argparse
import ast

import jax
import jax.numpy as jnp

from repro.api.exec_config import ExecConfig
from repro.api.runner import run as api_run
from repro.configs import ARCH_IDS, get_config
from repro.data.lm import lm_batches
from repro.launch import steps as steps_lib
from repro.models import build_model


def parse_stream_options(pairs: list[str] | None) -> dict:
    """['period=16', 'mode=rotate'] -> {'period': 16, 'mode': 'rotate'}."""
    opts = {}
    for pair in pairs or []:
        if "=" not in pair:
            raise ValueError(f"--stream-opt expects key=value, got {pair!r}")
        k, v = pair.split("=", 1)
        try:
            opts[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            opts[k] = v
    return opts


def train(arch: str | None = None, *, strategy: str = "gossip", nodes: int = 4,
          steps: int = 50, batch_per_node: int = 2, seq_len: int = 128,
          eps: float = 1.0, lam: float = 1e-4, smoke: bool = True,
          log_path: str | None = None, seed: int = 0, microbatches: int = 1,
          topology: str = "ring", local_rule: str = "omd",
          mechanism: str = "laplace", clip_style: str = "coordinate",
          delay: int = 0, delay_dist: str | None = None,
          stream: str | None = None, stream_options: dict | None = None,
          dim: int = 256, engine: str = "dist",
          checkpoint_every: int | None = None,
          checkpoint_dir: str | None = None) -> dict:
    recipe = steps_lib.TrainRecipe(strategy=strategy, eps=eps, lam=lam,
                                   microbatches=microbatches, topology=topology,
                                   local_rule=local_rule, mechanism=mechanism,
                                   clip_style=clip_style, delay=delay,
                                   delay_dist=delay_dist)

    if stream is not None:
        # the paper's linear workload on a STREAMS scenario, via run()
        spec = recipe.to_runspec(nodes).replace(
            dim=dim, horizon=steps, seed=seed,
            stream=stream, stream_options=stream_options or {})
        result = api_run(spec, engine=engine,
                         exec=ExecConfig(log_path=log_path,
                                         checkpoint_every=checkpoint_every,
                                         checkpoint_dir=checkpoint_dir))
        print(f"stream={stream} engine={engine} nodes={nodes} dim={dim} "
              f"rounds={result.rounds}: acc={result.accuracy:.3f} "
              f"regret={float(result.regret[-1]) if result.regret is not None else float('nan'):.1f} "
              f"eps_total={result.privacy['eps_total']} "
              f"({result.rounds_per_sec:.1f} rounds/s)")
        return {"result": result, "final": result.summary(),
                "history": None, "state": result.final_state}

    if arch is None:
        raise ValueError("train() needs arch= (an LM config) or stream= "
                         "(a STREAMS scenario)")
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)

    if strategy == "gossip":
        gdp = steps_lib.make_gossip_dp(nodes, recipe)
        step_fn = jax.jit(
            steps_lib.make_gossip_train_step(model, gdp, microbatches),
            donate_argnums=(0,))
        state = steps_lib.make_gossip_init(model, gdp, nodes)(seed)
        batch_nodes = nodes
        spec = recipe.to_runspec(nodes)
    else:
        train_step, init = steps_lib.make_allreduce_train_step(model, recipe)
        step_fn = jax.jit(train_step, donate_argnums=(0,))
        state = init(seed)
        batch_nodes = 1
        spec = None

    def add_frontend(batch):
        B_l = batch["tokens"].shape[:-1]
        if cfg.frontend is not None:
            batch["frontend"] = jnp.zeros(B_l + (max(cfg.frontend_tokens, 1), cfg.d_model),
                                          cfg.jdtype)
            batch["labels"] = batch["labels"].at[..., :cfg.frontend_tokens].set(-1)
        elif cfg.family == "encdec":
            batch["frontend"] = jnp.zeros(B_l + (max(seq_len // 4, 8), cfg.d_model),
                                          cfg.jdtype)
        return batch

    data = lm_batches(cfg.vocab_size, batch_per_node, seq_len,
                      nodes=batch_nodes, seed=seed)

    def batches():
        for raw in data:
            batch = add_frontend(raw)
            if strategy == "gossip" and batch_nodes == 1:
                batch = jax.tree_util.tree_map(lambda x: x[None], batch)
            yield batch

    result = api_run(spec, engine=strategy, step_fn=step_fn, state=state,
                     batches=batches(), horizon=steps,
                     exec=ExecConfig(log_path=log_path, print_every=10,
                                     checkpoint_every=checkpoint_every,
                                     checkpoint_dir=checkpoint_dir))
    return {"history": result.history, "final": result.metrics,
            "state": result.final_state, "result": result}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS,
                    help="LM architecture (omit when using --stream)")
    ap.add_argument("--strategy", default="gossip", choices=["gossip", "allreduce"])
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch-per-node", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--eps", type=float, default=1.0)
    ap.add_argument("--lam", type=float, default=1e-4)
    ap.add_argument("--topology", default="ring",
                    help="repro.api MIXERS registry name (ring, complete, "
                         "ring_alternating, disconnected, torus, ...)")
    ap.add_argument("--local-rule", default="omd",
                    help="repro.api LOCAL_RULES registry name (omd, tg, rda)")
    ap.add_argument("--mechanism", default="laplace",
                    help="repro.api MECHANISMS registry name (laplace, gaussian, none)")
    ap.add_argument("--clip-style", default="coordinate",
                    choices=["coordinate", "global"],
                    help="Laplace calibration (see TrainRecipe.clip_style)")
    ap.add_argument("--delay", type=int, default=0,
                    help="WAN gossip staleness in rounds; > 0 gives "
                         "GossipState a (delay+1)-deep history ring")
    ap.add_argument("--delay-dist", default=None,
                    choices=["constant", "uniform", "geometric"],
                    help="per-edge delay distribution (heterogeneous WAN "
                         "links), capped at --delay; default: uniform lag")
    ap.add_argument("--stream", default=None,
                    help="repro.api STREAMS registry name (social_sparse, "
                         "drift, heterogeneous, bursty): run the paper's "
                         "linear workload on this scenario via repro.api.run")
    ap.add_argument("--stream-opt", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="stream factory option, repeatable "
                         "(e.g. --stream-opt period=32)")
    ap.add_argument("--dim", type=int, default=256,
                    help="feature dimension for --stream runs")
    ap.add_argument("--engine", default="dist", choices=["sim", "dist"],
                    help="engine for --stream runs")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=None)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if not args.arch and not args.stream:
        ap.error("one of --arch or --stream is required")
    train(args.arch, strategy=args.strategy, nodes=args.nodes, steps=args.steps,
          batch_per_node=args.batch_per_node, seq_len=args.seq_len, eps=args.eps,
          lam=args.lam, smoke=args.smoke, log_path=args.log, seed=args.seed,
          microbatches=args.microbatches, topology=args.topology,
          local_rule=args.local_rule, mechanism=args.mechanism,
          clip_style=args.clip_style, delay=args.delay,
          delay_dist=args.delay_dist, stream=args.stream,
          stream_options=parse_stream_options(args.stream_opt),
          dim=args.dim, engine=args.engine,
          checkpoint_every=args.checkpoint_every,
          checkpoint_dir=args.checkpoint_dir)


if __name__ == "__main__":
    main()
