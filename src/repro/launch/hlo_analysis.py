"""Parse compiled HLO text: collective-op operand bytes + roofline terms.

cost_analysis() gives FLOPs and HBM bytes but NOT collective traffic; we
recover it by walking the HLO: build a name->shape table from instruction
definitions, then sum operand sizes for every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.

Roofline constants (TPU v5e target):
  peak 197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable

PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link (per chip, one direction)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w\.\-]+)\s*=\s*(.+)$")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all `dtype[shape]` occurrences in a type string."""
    total = 0
    for dt, dims in _TYPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def summary(self) -> dict:
        return {
            "total_bytes": self.total_bytes,
            "bytes_by_kind": dict(self.bytes_by_kind),
            "count_by_kind": dict(self.count_by_kind),
        }


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective op in an HLO module text.

    Strategy: each instruction line defines `%name = <type> <op>(...)`;
    we record each defined name's type-bytes, then for collective lines sum
    the recorded sizes of their `%operand` references. Fallback to the
    *result* size when an operand is undefined in our table (e.g. fusion
    parameters) — result size equals operand size for permute/all-reduce
    and over-counts all-gather only by the gather factor of that op.
    """
    shapes: dict[str, int] = {}
    bytes_by_kind: dict[str, int] = {}
    count_by_kind: dict[str, int] = {}

    def _result_type_bytes(rhs: str) -> int:
        """Bytes of the result type — the leading `dtype[...]` or
        `(tuple, of, types)` before the opcode."""
        rhs = rhs.strip()
        if rhs.startswith("("):
            depth = 0
            for i, ch in enumerate(rhs):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        return _shape_bytes(rhs[: i + 1])
            return _shape_bytes(rhs)
        return _shape_bytes(rhs.split(" ", 1)[0])

    lines = hlo_text.splitlines()
    # pass 1: record defined shapes
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, rhs = m.groups()
        shapes[name.lstrip("%")] = _result_type_bytes(rhs)

    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, rhs = m.groups()
        kind = next((c for c in _COLLECTIVES if re.search(rf"\b{c}", rhs)), None)
        if kind is None:
            continue
        # skip the -done halves of async pairs (count once at -start)
        if re.search(rf"\b{kind}-done", rhs):
            continue
        # operand list: text inside the parens right after the opcode
        op_pos = rhs.find(kind)
        paren = rhs.find("(", op_pos)
        operands: list[str] = []
        if paren != -1:
            depth = 0
            for i, ch in enumerate(rhs[paren:], start=paren):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        inner = rhs[paren + 1: i]
                        for part in inner.split(","):
                            mm = re.match(r"\s*%?([\w\.\-]+)", part)
                            if mm:
                                operands.append(mm.group(1))
                        break
        size = sum(shapes.get(o, 0) for o in operands)
        if size == 0:
            # fallback: result type size (== operand size for permute/AR)
            size = _result_type_bytes(rhs)
        bytes_by_kind[kind] = bytes_by_kind.get(kind, 0) + size
        count_by_kind[kind] = count_by_kind.get(kind, 0) + 1
    return CollectiveStats(bytes_by_kind, count_by_kind)


def roofline_terms(flops: float, hbm_bytes: float, collective_bytes: float,
                   chips: int = 1) -> dict:
    """The three §Roofline terms, in seconds.

    Inputs are PER-DEVICE quantities (the compiled HLO is the SPMD
    per-device program), so each term divides by a single chip's rate;
    ``chips`` is kept for callers that pass global totals."""
    t_compute = flops / (chips * PEAK_FLOPS)
    t_memory = hbm_bytes / (chips * HBM_BW)
    t_collective = collective_bytes / (chips * ICI_BW)
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_collective),
        key=lambda kv: kv[1],
    )[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
    }


def model_flops(n_params_active: float, tokens: float) -> float:
    """6 * N * D rule (N = active params, D = tokens this step)."""
    return 6.0 * n_params_active * tokens
