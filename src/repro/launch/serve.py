"""Serving launcher: online predictions while gossip training advances.

    PYTHONPATH=src python -m repro.launch.serve --smoke
    PYTHONPATH=src python -m repro.launch.serve --nodes 8 --dim 64 \
        --horizon 2048 --chunk-rounds 64 --ticks 512 --json serve.json

Stands up a `repro.serve.ServeService` (background gossip trainer +
admission/batching front end), replays the `bursty` stream's heavy-tailed
arrival process against it, then:

  * verifies a served response is BIT-IDENTICAL to a fresh reference
    `repro.api.run` at the recorded snapshot round (the atomic-publication
    contract),
  * demonstrates eps-exhaustion refusal under sequential composition with a
    finite budget,
  * prints (and optionally writes) the latency / QPS / staleness summary.

The LM decode demo that used to live here moved to `repro.launch.serve_lm`.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.api.spec import RunSpec
from repro.serve import BurstyReplay, ServeConfig, ServeService

__all__ = ["serve_social", "demo_refusal", "main"]


def demo_refusal(*, nodes: int = 2, dim: int = 8, horizon: int = 32,
                 eps: float = 1.0, eps_budget: float = 10.0,
                 chunk_rounds: int = 4, timeout_s: float = 120.0) -> dict:
    """Train under sequential composition until the eps budget is spent,
    then show the service refuses a request."""
    spec = RunSpec(nodes=nodes, dim=dim, horizon=horizon, eps=eps,
                   alpha0=0.5, lam=0.01, stream="bursty")
    svc = ServeService(ServeConfig(
        spec=spec, chunk_rounds=chunk_rounds, composition="sequential",
        eps_budget=eps_budget, max_batch=4, max_wait_ms=0.5,
        warmup=False)).start()
    deadline = time.perf_counter() + timeout_s
    while not svc.exhausted() and time.perf_counter() < deadline:
        time.sleep(0.01)
    refused = svc.submit([1.0] * dim, node=0).wait(timeout_s)
    svc.stop(timeout_s)
    out = {
        "eps_budget": eps_budget,
        "eps_spent": svc.eps_spent(),
        "exhausted": svc.exhausted(),
        "refused_status": refused.status,
        "last_round": svc.state.current.round,
    }
    if not out["exhausted"] or out["refused_status"] != "refused":
        raise RuntimeError(f"eps-exhaustion refusal failed: {out}")
    return out


def serve_social(*, nodes: int = 8, dim: int = 32, horizon: int = 512,
                 eps: float = 10.0, engine: str = "sim", mode: str = "node",
                 chunk_rounds: int = 32, max_batch: int = 32,
                 max_wait_ms: float = 1.0, queue_capacity: int = 1024,
                 ticks: int = 256, rate_ticks_per_s: float | None = None,
                 checkpoint_dir: str | None = None, verify: bool = True,
                 warmup: bool = True, timeout_s: float = 300.0) -> dict:
    """Replay a bursty workload against a live training service; return the
    end-to-end summary (and verify one response against a reference run)."""
    spec = RunSpec(nodes=nodes, dim=dim, horizon=horizon, eps=eps,
                   alpha0=0.5, lam=0.01, stream="bursty")
    cfg = ServeConfig(spec=spec, engine=engine, mode=mode,
                      chunk_rounds=chunk_rounds, max_batch=max_batch,
                      max_wait_ms=max_wait_ms, queue_capacity=queue_capacity,
                      checkpoint_dir=checkpoint_dir, warmup=warmup,
                      # keep every publication so verify() can always find
                      # the sampled response's snapshot in the history ring
                      keep_snapshots=max(horizon // chunk_rounds + 2, 8))
    svc = ServeService(cfg).start()
    replay = BurstyReplay(spec.resolve_stream())
    drive = replay.drive(svc, 0, min(ticks, horizon),
                         rate_ticks_per_s=rate_ticks_per_s,
                         timeout_s=timeout_s)
    svc.stop(timeout_s)

    verified = None
    if verify:
        # last-served request: its snapshot is the most recent, so it is
        # still inside the keep_snapshots history ring
        served = [r for r in drive["requests"] if r.status == "ok"]
        sample = max(served, key=lambda r: (r.snapshot_version or 0))
        verified = svc.verify(sample)
        if not verified:
            raise RuntimeError(
                "served prediction did not match the reference model at "
                f"snapshot round {sample.snapshot_round}")

    stats = svc.stats()
    drive.pop("requests")
    return {
        "spec": {"nodes": nodes, "dim": dim, "horizon": horizon, "eps": eps,
                 "engine": engine, "mode": mode,
                 "chunk_rounds": chunk_rounds},
        "replay": drive,
        "admission": stats["admission"],
        "serving": stats["serving"],
        "snapshot_identical": verified,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--horizon", type=int, default=512)
    ap.add_argument("--eps", type=float, default=10.0)
    ap.add_argument("--engine", choices=("sim", "dist"), default="sim")
    ap.add_argument("--mode", choices=("node", "average"), default="node")
    ap.add_argument("--chunk-rounds", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-wait-ms", type=float, default=1.0)
    ap.add_argument("--queue-capacity", type=int, default=1024)
    ap.add_argument("--ticks", type=int, default=256)
    ap.add_argument("--rate", type=float, default=None,
                    help="replay pacing in ticks/s (default: open throttle)")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--json", dest="json_path", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="small spec + refusal demo; exercises every "
                         "acceptance path on CPU in seconds")
    args = ap.parse_args(argv)

    if args.smoke:
        summary = serve_social(
            nodes=4, dim=16, horizon=96, eps=10.0, engine=args.engine,
            mode=args.mode, chunk_rounds=8, max_batch=8, max_wait_ms=0.5,
            queue_capacity=256, ticks=64, warmup=False)
        summary["refusal"] = demo_refusal()
    else:
        summary = serve_social(
            nodes=args.nodes, dim=args.dim, horizon=args.horizon,
            eps=args.eps, engine=args.engine, mode=args.mode,
            chunk_rounds=args.chunk_rounds, max_batch=args.max_batch,
            max_wait_ms=args.max_wait_ms,
            queue_capacity=args.queue_capacity, ticks=args.ticks,
            rate_ticks_per_s=args.rate, checkpoint_dir=args.checkpoint_dir)

    adm, rep = summary["admission"], summary["replay"]
    print(f"replayed {rep['submitted']} requests over {rep['ticks']} ticks: "
          f"{rep['served']} served / {rep['shed']} shed / "
          f"{rep['refused']} refused at {rep['qps']:.0f} qps")
    print(f"latency p50={adm['p50_latency_ms']}ms p99={adm['p99_latency_ms']}ms"
          f"  staleness mean={adm['staleness_mean_rounds']} "
          f"max={adm['staleness_max_rounds']} rounds")
    print(f"snapshot bit-identical to reference run: "
          f"{summary['snapshot_identical']}")
    if "refusal" in summary:
        r = summary["refusal"]
        print(f"eps budget {r['eps_budget']} spent at round {r['last_round']}"
              f" -> request {r['refused_status']}")
    if args.json_path:
        with open(args.json_path, "w") as fh:
            json.dump(summary, fh, indent=2, sort_keys=True)
        print(f"wrote {args.json_path}")
    return summary


if __name__ == "__main__":
    main()
