"""`python -m repro.launch.obs report` — render run-event streams.

Reads the JSONL event stream `repro.obs` writes next to the sweep store
(``experiments/store/events.jsonl`` by default, ``--events`` for another)
and renders one summary block per run: phase counts, rounds/sec, the eps
ledger endpoint, checkpoint/publish activity, the predicted-vs-measured
chunk cost, sweep progress and — when a serving run left its exit record —
the full serving summary including shed reasons. ``--json`` emits the same
structure machine-readably; ``--run`` narrows to one run id.

    PYTHONPATH=src python -m repro.launch.obs report
    PYTHONPATH=src python -m repro.launch.obs report --events e.jsonl --json
    PYTHONPATH=src python -m repro.launch.obs report --run 8d76664f

>>> import json, os, tempfile
>>> path = os.path.join(tempfile.mkdtemp(), "events.jsonl")
>>> from repro.obs import EventLog
>>> log = EventLog(path)
>>> _ = log.emit("run_start", run_id="ab12", kind="run", engine="sim",
...              stream="drift", horizon=8)
>>> _ = log.emit("chunk", run_id="ab12", round_start=0, round_end=8,
...              seconds=0.5, eps=1.0)
>>> _ = log.emit("run_end", run_id="ab12", rounds=8, wall_clock_s=0.5,
...              rounds_per_sec=16.0, accuracy=0.75, eps_total=1.0)
>>> log.close()
>>> main(["report", "--events", path])
run ab12  (run, engine=sim, stream=drift)
  rounds: 8  wall: 0.500s  rounds/sec: 16
  chunks: 1  checkpoints: 0  publishes: 0
  accuracy: 0.75  eps_total: 1
0
>>> out = summarize_events(path)
>>> out["runs"]["ab12"]["rounds"], out["runs"]["ab12"]["chunks"]
(8, 1)
>>> main(["report", "--events", path, "--run", "nope"])
no events for run id 'nope'
1
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs import DEFAULT_EVENTS_PATH, group_runs, read_events

__all__ = ["main", "summarize_events"]


def _summarize_run(events: list[dict]) -> dict:
    """One run's event list -> flat JSON-able summary."""
    out: dict = {"events": len(events)}
    counts = {"chunk": 0, "checkpoint": 0, "publish": 0, "sweep_point": 0}
    for e in events:
        kind = e.get("event")
        if kind in counts:
            counts[kind] += 1
        if kind == "run_start":
            for k in ("kind", "engine", "stream", "horizon", "seeds",
                      "devices"):
                if k in e:
                    out[k] = e[k]
        elif kind == "chunk":
            out["rounds"] = e.get("round_end", out.get("rounds"))
            if e.get("eps") is not None:
                out["eps_total"] = e["eps"]
        elif kind == "chunk_cost":
            out["cost"] = {k: e.get(k) for k in
                           ("predicted_s", "measured_mean_s", "error_ratio",
                            "flops", "hbm_bytes")}
        elif kind == "run_end":
            for k in ("rounds", "wall_clock_s", "rounds_per_sec", "accuracy",
                      "eps_total"):
                if e.get(k) is not None:
                    out[k] = e[k]
        elif kind == "serve_summary":
            out["serve"] = {k: v for k, v in e.items()
                            if k not in ("ts", "event", "run_id")}
    out["chunks"] = counts["chunk"]
    out["checkpoints"] = counts["checkpoint"]
    out["publishes"] = counts["publish"]
    if counts["sweep_point"]:
        out["sweep_points"] = counts["sweep_point"]
    return out


def summarize_events(path: str = DEFAULT_EVENTS_PATH,
                     run_id: str | None = None) -> dict:
    """{'events': N, 'runs': {run_id: summary}} for the whole stream (or one
    run). Events without a run_id — the serving layer's publish /
    serve_summary records — group under the id ``"-"``. Unknown ``run_id``
    yields an empty ``runs`` dict."""
    events = read_events(path)
    runs = {(rid or "-"): evs for rid, evs in group_runs(events).items()}
    if run_id is not None:
        runs = {run_id: runs[run_id]} if run_id in runs else {}
    return {"events": len(events), "path": path,
            "runs": {rid: _summarize_run(evs) for rid, evs in runs.items()}}


def _fmt(v, digits: int = 3):
    if isinstance(v, float):
        return f"{v:.{digits}f}".rstrip("0").rstrip(".") or "0"
    return v


def _render_text(summary: dict) -> list[str]:
    lines = []
    for rid, run in summary["runs"].items():
        head = ", ".join(f"{k}={run[k]}" for k in ("engine", "stream")
                         if k in run)
        lines.append(f"run {rid}  ({run.get('kind', 'run')}"
                     + (f", {head}" if head else "") + ")")
        row = [f"rounds: {run['rounds']}"] if "rounds" in run else []
        if "wall_clock_s" in run:
            row.append(f"wall: {run['wall_clock_s']:.3f}s")
        if "rounds_per_sec" in run:
            row.append(f"rounds/sec: {_fmt(run['rounds_per_sec'], 1)}")
        if row:
            lines.append("  " + "  ".join(row))
        lines.append(f"  chunks: {run['chunks']}  "
                     f"checkpoints: {run['checkpoints']}  "
                     f"publishes: {run['publishes']}")
        tail = [f"{k}: {_fmt(run[k])}" for k in ("accuracy", "eps_total")
                if run.get(k) is not None]
        if tail:
            lines.append("  " + "  ".join(tail))
        if "sweep_points" in run:
            lines.append(f"  sweep points: {run['sweep_points']}")
        cost = run.get("cost")
        if cost:
            lines.append(
                f"  cost: predicted {_fmt(cost['predicted_s'], 6)}s vs "
                f"measured {_fmt(cost['measured_mean_s'], 6)}s "
                f"(error ratio {_fmt(cost['error_ratio'])})")
        serve = run.get("serve")
        if serve:
            adm = serve.get("admission", {})
            lines.append(
                f"  serve: served={adm.get('served')} shed={adm.get('shed')} "
                f"refused={adm.get('refused')} "
                f"shed_reasons={adm.get('shed_reasons')}")
    return lines


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(prog="python -m repro.launch.obs")
    sub = parser.add_subparsers(dest="cmd", required=True)
    rep = sub.add_parser("report", help="summarize a run-event stream")
    rep.add_argument("--events", default=DEFAULT_EVENTS_PATH,
                     help=f"events JSONL (default {DEFAULT_EVENTS_PATH})")
    rep.add_argument("--run", default=None, help="narrow to one run id")
    rep.add_argument("--json", action="store_true",
                     help="machine-readable output")
    args = parser.parse_args(argv)

    summary = summarize_events(args.events, run_id=args.run)
    try:
        if not summary["runs"]:
            what = (f"run id {args.run!r}" if args.run
                    else f"stream {args.events!r}")
            print(f"no events for {what}")
            return 1
        if args.json:
            print(json.dumps(summary, indent=2))
        else:
            print("\n".join(_render_text(summary)))
    except BrokenPipeError:               # e.g. `report | head`
        sys.stderr.close()
    return 0


if __name__ == "__main__":             # pragma: no cover - CLI entry
    raise SystemExit(main())
