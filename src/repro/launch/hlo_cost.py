"""HLO roll-up cost model: FLOPs / HBM bytes / collective bytes from the
compiled per-device program, with **loop trip-count multipliers**.

Why this exists: XLA's ``compiled.cost_analysis()`` on the CPU backend counts
each ``while`` body ONCE, so anything under ``lax.scan`` (layer stacks,
blockwise attention, WKV/LRU time scans) is undercounted by its trip count.
The dry-run's roofline would be garbage without correcting this. We parse the
optimized HLO text, build the computation call graph, and roll up:

  flops        traverse fusions + while bodies (x known_trip_count) + calls;
               dots: 2 * result_elems * contracted_elems; elementwise: 1/elem;
               reduce: input elems.
  hbm bytes    top-level op operand+result bytes per computation (fusion
               internals excluded — they never touch HBM), rolled through
               while/call with multipliers.
  collectives  per-kind operand bytes, rolled through while/call with
               multipliers (a collective inside a scanned layer really does
               run L times).

Everything is per-device (the HLO is the SPMD per-device program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops counted at 1 flop per output element (transcendentals weighted higher)
_ELEMWISE_1 = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "negate",
    "abs", "sign", "and", "or", "xor", "not", "select", "clamp", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
}
_ELEMWISE_TRANS = {"exponential": 4, "log": 4, "log-plus-one": 4, "tanh": 6,
                   "rsqrt": 2, "sqrt": 2, "power": 8, "logistic": 6,
                   "exponential-minus-one": 4, "sine": 6, "cosine": 6, "atan2": 8,
                   "erf": 6, "cbrt": 4}

def cost_analysis_get(cost, key: str) -> float:
    """Read one metric out of ``compiled.cost_analysis()`` across jax
    versions (older jax wraps the dict in a one-element list); prefix-sums
    keyed entries like 'bytes accessed{operand 0}'."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    if not cost:
        return 0.0
    if key in cost:
        return float(cost[key])
    return float(sum(v for k, v in cost.items() if k.startswith(key)))


_TYPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->\s*(.+)\s*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_TRIP_RE = re.compile(r'known_trip_count[\\"\s:{]*n[\\"\s:]*[\\"]*(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    """(elements, bytes) of all dtype[shape] occurrences in a type string."""
    elems = 0
    nbytes = 0
    for dt, dims in _TYPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


def _result_type(rhs: str) -> str:
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rhs[: i + 1]
        return rhs
    return rhs.split(" ", 1)[0]


def _opcode(rhs: str) -> str:
    """Opcode = first bare word after the result type."""
    rest = rhs[len(_result_type(rhs)):].strip()
    m = re.match(r"([\w\-]+)", rest)
    return m.group(1) if m else ""


def _split_top_level(s: str) -> list[str]:
    """Split on commas not nested in () / [] / {} — operand shapes like
    f32[512,512]{1,0} carry commas of their own."""
    parts, cur, depth = [], [], 0
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def _operands(rhs: str, opcode: str) -> list[str]:
    pos = rhs.find(opcode)
    paren = rhs.find("(", pos)
    if paren == -1:
        return []
    depth = 0
    for i, ch in enumerate(rhs[paren:], start=paren):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                inner = rhs[paren + 1: i]
                out = []
                for part in _split_top_level(inner):
                    # newer HLO prints typed operands ("f32[512,512]{1,0}
                    # %Arg_0.1") — the %name is the LAST token; older dumps
                    # print the bare %name first
                    m_name = re.search(r"%([\w\.\-]+)\s*$", part.strip())
                    if m_name:
                        out.append(m_name.group(1))
                        continue
                    mm = re.match(r"\s*%?([\w\.\-]+)", part)
                    if mm:
                        out.append(mm.group(1))
                return out
    return []


@dataclasses.dataclass
class _Instr:
    name: str
    rhs: str
    opcode: str
    result_type: str
    operands: list
    is_root: bool = False


@dataclasses.dataclass
class _Computation:
    name: str
    is_entry: bool
    param_types: dict      # name -> type str
    instrs: list           # list[_Instr]
    fusion_called: bool = False


def _parse_computations(hlo: str) -> dict:
    comps: dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for ln in hlo.splitlines():
        h = _HEADER_RE.match(ln.strip())
        if h and (ln.rstrip().endswith("{")):
            is_entry = bool(h.group(1))
            name = h.group(2)
            params = {}
            for pm in re.finditer(r"([\w\.\-]+)\s*:\s*((?:\([^)]*\))|(?:[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))", h.group(3)):
                params[pm.group(1)] = pm.group(2)
            cur = _Computation(name=name, is_entry=is_entry, param_types=params, instrs=[])
            comps[name] = cur
            continue
        if ln.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        name, rhs = m.groups()
        opc = _opcode(rhs)
        cur.instrs.append(_Instr(name=name, rhs=rhs, opcode=opc,
                                 result_type=_result_type(rhs),
                                 operands=_operands(rhs, opc),
                                 is_root=ln.lstrip().startswith("ROOT")))
    return comps


def _dot_flops(instr: _Instr, shape_of) -> float:
    res_elems, _ = _shape_elems_bytes(instr.result_type)
    lhs_type = shape_of(instr.operands[0]) if instr.operands else ""
    mdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rhs)
    if not lhs_type or not mdims:
        return 2.0 * res_elems  # degenerate fallback
    dims_m = _TYPE_RE.search(lhs_type)
    if not dims_m:
        return 2.0 * res_elems
    lhs_shape = [int(d) for d in dims_m.group(2).split(",") if d]
    contracted = 1
    for idx in mdims.group(1).split(","):
        if idx != "" and int(idx) < len(lhs_shape):
            contracted *= lhs_shape[int(idx)]
    return 2.0 * res_elems * contracted


# opcodes assumed to fuse for free on the TPU target (VPU elementwise chains
# never round-trip HBM); the CPU backend wraps each in its own mini-fusion,
# which would wildly overstate HBM traffic if counted at face value.
_TRIVIAL_FUSABLE = (
    _ELEMWISE_1 | set(_ELEMWISE_TRANS) |
    {"broadcast", "convert", "compare", "select", "reshape", "bitcast",
     "iota", "constant", "parameter", "tuple", "get-tuple-element", "pad",
     "slice", "concatenate", "reverse", "rng-bit-generator", "exponential",
     "reduce-precision", "copy-done", "copy-start",
     # reductions fuse with their producer chain on TPU (softmax max/sum
     # never round-trip HBM); boundary traffic is carried by the dots
     "reduce", "reduce-window",
     # loop-carry copies / layout transposes: aliased or folded into MXU
     # loads on the TPU target (CPU layout-assignment artifacts otherwise)
     "copy", "transpose"}
)


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float          # fused-estimate (TPU-target): trivial chains free
    hbm_bytes_unfused: float  # CPU-fusion-granularity upper bound
    collective_bytes: float
    bytes_by_kind: dict
    count_by_kind: dict
    while_trip_counts: list

    def summary(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "hbm_bytes_unfused": self.hbm_bytes_unfused,
            "collective_bytes": self.collective_bytes,
            "collective_bytes_by_kind": dict(self.bytes_by_kind),
            "collective_count_by_kind": dict(self.count_by_kind),
            "while_trip_counts": list(self.while_trip_counts),
        }


def analyze(hlo: str) -> HloCost:
    comps = _parse_computations(hlo)

    # mark fusion-called computations (their ops never touch HBM directly)
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.opcode == "fusion":
                m = _CALLS_RE.search(ins.rhs)
                if m and m.group(1) in comps:
                    comps[m.group(1)].fusion_called = True

    trip_counts: list[int] = []
    memo: dict[str, tuple] = {}
    all_trivial_memo: dict[str, bool] = {}

    def _all_trivial(comp_name: str) -> bool:
        """True if a fused computation contains only free-fusable ops."""
        if comp_name in all_trivial_memo:
            return all_trivial_memo[comp_name]
        comp = comps.get(comp_name)
        ok = comp is not None and all(
            i.opcode in _TRIVIAL_FUSABLE for i in comp.instrs)
        all_trivial_memo[comp_name] = ok
        return ok

    def shape_of_factory(comp: _Computation):
        local = dict(comp.param_types)
        for ins in comp.instrs:
            local[ins.name] = ins.result_type
        def shape_of(name: str) -> str:
            return local.get(name, "")
        return shape_of

    def visit(name: str, inside_fusion: bool) -> tuple:
        """returns (flops, hbm_fused, hbm_unfused, coll_bytes, bytes_by_kind, count_by_kind)"""
        key = (name, inside_fusion)
        if key in memo:
            return memo[key]
        comp = comps[name]
        shape_of = shape_of_factory(comp)
        flops = 0.0
        hbm = 0.0
        hbm_unfused = 0.0
        coll = 0.0
        bk: dict[str, float] = {}
        ck: dict[str, int] = {}

        def _op_hbm(ins: _Instr) -> float:
            """HBM traffic of one top-level op; aliasing-aware special cases
            so scan-carry dynamic-update-slices don't charge the full stacked
            buffer every iteration."""
            opc = ins.opcode
            _, res_bytes = _shape_elems_bytes(ins.result_type)
            if opc in ("parameter", "constant", "tuple", "get-tuple-element",
                       "bitcast", "while", "call", "conditional", "iota",
                       "after-all", "partition-id", "replica-id"):
                return 0.0
            if opc == "dynamic-slice":
                return 2.0 * res_bytes
            if opc == "dynamic-update-slice":
                upd = _shape_elems_bytes(shape_of(ins.operands[1]))[1] if len(ins.operands) > 1 else res_bytes
                return 2.0 * upd
            if opc == "gather":
                idx = _shape_elems_bytes(shape_of(ins.operands[1]))[1] if len(ins.operands) > 1 else 0
                return 2.0 * res_bytes + idx
            if opc == "scatter":
                upd = _shape_elems_bytes(shape_of(ins.operands[2]))[1] if len(ins.operands) > 2 else res_bytes
                idx = _shape_elems_bytes(shape_of(ins.operands[1]))[1] if len(ins.operands) > 1 else 0
                return 2.0 * upd + idx
            if opc == "fusion":
                m = _CALLS_RE.search(ins.rhs)
                called = comps.get(m.group(1)) if m else None
                if called is None:
                    op_bytes = sum(_shape_elems_bytes(shape_of(o))[1] for o in ins.operands)
                    return res_bytes + op_bytes
                # Look INSIDE the fused computation and charge only real
                # traffic: sliced reads at slice size, stack writes at update
                # size, matmul/convolution operand+result; layout copies /
                # transposes and elementwise are VMEM-resident on the TPU
                # target. Whole stacked scan buffers passed as operands are
                # NOT charged (only their touched slices are).
                c_shape = shape_of_factory(called)
                total = 0.0
                root = next((i for i in called.instrs if i.is_root), None)
                for ci in called.instrs:
                    cb = _shape_elems_bytes(ci.result_type)[1]
                    if ci.opcode == "dynamic-slice":
                        total += 2.0 * cb
                    elif ci.opcode == "dynamic-update-slice":
                        upd = _shape_elems_bytes(c_shape(ci.operands[1]))[1] \
                            if len(ci.operands) > 1 else cb
                        total += 2.0 * upd
                    elif ci.opcode == "gather":
                        total += 2.0 * cb
                    elif ci.opcode == "scatter":
                        upd = _shape_elems_bytes(c_shape(ci.operands[2]))[1] \
                            if len(ci.operands) > 2 else cb
                        total += 2.0 * upd
                    elif ci.opcode in ("dot", "dot-general", "convolution"):
                        ob = sum(_shape_elems_bytes(c_shape(o))[1] for o in ci.operands)
                        total += cb + ob
                if root is not None and root.opcode not in (
                        "dynamic-update-slice", "dynamic-slice", "tuple"):
                    total += res_bytes  # the fusion's own output write
                return total
            op_bytes = sum(_shape_elems_bytes(shape_of(o))[1] for o in ins.operands)
            return res_bytes + op_bytes

        def _op_is_trivial(ins: _Instr) -> bool:
            if ins.opcode in _TRIVIAL_FUSABLE:
                return True
            if ins.opcode == "fusion":
                m = _CALLS_RE.search(ins.rhs)
                return bool(m) and _all_trivial(m.group(1))
            return False

        for ins in comp.instrs:
            opc = ins.opcode
            res_elems, res_bytes = _shape_elems_bytes(ins.result_type)

            # ---- HBM bytes: only at non-fusion level ----
            if not inside_fusion and not comp.fusion_called:
                b = _op_hbm(ins)
                hbm_unfused += b
                if not _op_is_trivial(ins):
                    hbm += b

            # ---- collectives ----
            kind = next((c for c in _COLLECTIVES if opc.startswith(c)), None)
            if kind is not None and not opc.endswith("-done"):
                size = sum(_shape_elems_bytes(shape_of(o))[1] for o in ins.operands)
                if size == 0:
                    size = res_bytes
                coll += size
                bk[kind] = bk.get(kind, 0.0) + size
                ck[kind] = ck.get(kind, 0) + 1

            # ---- flops ----
            if opc in ("dot", "dot-general"):
                flops += _dot_flops(ins, shape_of)
            elif opc == "convolution":
                flops += 2.0 * res_elems * 64  # crude (we emit no convs)
            elif opc in _ELEMWISE_1:
                flops += res_elems
            elif opc in _ELEMWISE_TRANS:
                flops += res_elems * _ELEMWISE_TRANS[opc]
            elif opc in ("reduce", "reduce-window"):
                in_elems = sum(_shape_elems_bytes(shape_of(o))[0]
                               for o in ins.operands[: max(1, len(ins.operands) // 2)])
                flops += in_elems

            # ---- recurse ----
            if opc == "fusion":
                m = _CALLS_RE.search(ins.rhs)
                if m and m.group(1) in comps:
                    sub = visit(m.group(1), True)
                    flops += sub[0]
                    coll += sub[3]
                    for k, v in sub[4].items():
                        bk[k] = bk.get(k, 0.0) + v
                    for k, v in sub[5].items():
                        ck[k] = ck.get(k, 0) + v
            elif opc == "while":
                m = _WHILE_RE.search(ins.rhs)
                trips = 1
                tm = _TRIP_RE.search(ins.rhs)
                if tm:
                    trips = int(tm.group(1))
                    trip_counts.append(trips)
                if m:
                    body = m.group(2)
                    if body in comps:
                        sub = visit(body, inside_fusion)
                        flops += trips * sub[0]
                        hbm += trips * sub[1]
                        hbm_unfused += trips * sub[2]
                        coll += trips * sub[3]
                        for k, v in sub[4].items():
                            bk[k] = bk.get(k, 0.0) + trips * v
                        for k, v in sub[5].items():
                            ck[k] = ck.get(k, 0) + trips * v
            elif opc in ("call", "async-start", "custom-call"):
                m = _TOAPPLY_RE.search(ins.rhs) or _CALLS_RE.search(ins.rhs)
                if m and m.group(1) in comps:
                    sub = visit(m.group(1), inside_fusion)
                    flops += sub[0]
                    hbm += sub[1]
                    hbm_unfused += sub[2]
                    coll += sub[3]
                    for k, v in sub[4].items():
                        bk[k] = bk.get(k, 0.0) + v
                    for k, v in sub[5].items():
                        ck[k] = ck.get(k, 0) + v
            elif opc == "conditional":
                m = _BRANCH_RE.search(ins.rhs)
                if m:
                    subs = [visit(b.strip().lstrip("%"), inside_fusion)
                            for b in m.group(1).split(",") if b.strip().lstrip("%") in comps]
                    if subs:
                        # cost of the most expensive branch
                        best = max(subs, key=lambda s: s[0] + s[1])
                        flops += best[0]; hbm += best[1]
                        hbm_unfused += best[2]; coll += best[3]
                        for k, v in best[4].items():
                            bk[k] = bk.get(k, 0.0) + v
                        for k, v in best[5].items():
                            ck[k] = ck.get(k, 0) + v

        out = (flops, hbm, hbm_unfused, coll, bk, ck)
        memo[key] = out
        return out

    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry is None:
        return HloCost(0.0, 0.0, 0.0, 0.0, {}, {}, [])
    flops, hbm, hbm_unfused, coll, bk, ck = visit(entry, False)
    return HloCost(flops=flops, hbm_bytes=hbm, hbm_bytes_unfused=hbm_unfused,
                   collective_bytes=coll, bytes_by_kind=bk, count_by_kind=ck,
                   while_trip_counts=trip_counts)
