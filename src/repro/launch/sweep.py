"""Sweep CLI: declarative axes over RunSpec fields from the command line.

    PYTHONPATH=src python -m repro.launch.sweep \
        --nodes 16 --dim 512 --horizon 500 --stream social_sparse \
        --axis eps=0.1,1,10,inf --seeds 0,1,2 --name fig2_cli

Zipped axes co-vary several fields as one axis (values are ':'-joined):

    python -m repro.launch.sweep --axis nodes,horizon=4:800,8:400 ...

Every (point, seed) lands as one JSONL record in the store
(--store, default experiments/store/); --from-store reuses matching
records instead of re-running, so the same command regenerates its
summary for free. The seed axis is vectorized (vmapped) per point unless
--no-vmap or a seed-dependent stage forces the sequential fallback.
"""
from __future__ import annotations

import argparse
import json
from typing import Any

from repro.api import RunSpec
from repro.sweep import DEFAULT_STORE, SweepSpec, SweepStoreMiss, sweep


def _value(text: str) -> Any:
    """int -> float (inf included) -> bare string, in that order."""
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            continue
    return text


def parse_axis(arg: str) -> tuple[str, tuple]:
    """'eps=0.1,1,inf' -> ('eps', (0.1, 1.0, inf));
    'nodes,horizon=4:800,8:400' -> ('nodes,horizon', ((4, 800), (8, 400)))."""
    if "=" not in arg:
        raise argparse.ArgumentTypeError(
            f"--axis needs NAME=V1,V2,... (got {arg!r})")
    key, _, raw = arg.partition("=")
    key = key.strip()
    zipped = "," in key
    values = []
    for item in raw.split(","):
        if zipped:
            values.append(tuple(_value(v) for v in item.split(":")))
        else:
            values.append(_value(item))
    return key, tuple(values)


def parse_opts(items: list[str]) -> dict:
    out = {}
    for item in items or []:
        k, _, v = item.partition("=")
        out[k] = _value(v)
    return out


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.sweep",
        description="Declarative RunSpec sweep -> vmapped multi-seed runs "
                    "-> persistent JSONL store")
    # base RunSpec
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--horizon", type=int, default=500)
    ap.add_argument("--eps", type=float, default=1.0)
    ap.add_argument("--lam", type=float, default=0.01)
    ap.add_argument("--alpha0", type=float, default=1.0)
    ap.add_argument("--mixer", default="ring")
    ap.add_argument("--mechanism", default="laplace")
    ap.add_argument("--local-rule", default="omd")
    ap.add_argument("--calibration", default="coordinate",
                    choices=["global", "coordinate"])
    ap.add_argument("--clip-norm", type=float, default=1.0)
    ap.add_argument("--delay", type=int, default=0)
    ap.add_argument("--delay-dist", default=None)
    ap.add_argument("--stream", default="social_sparse")
    ap.add_argument("--stream-opt", action="append", default=[],
                    metavar="K=V")
    # sweep shape
    ap.add_argument("--axis", action="append", default=[], metavar="NAME=V,V",
                    help="sweep axis over RunSpec field(s); repeatable; "
                         "comma-joined names zip fields (values ':'-joined)")
    ap.add_argument("--seeds", default="0,1,2",
                    help="comma-separated seed list (vectorized axis)")
    ap.add_argument("--engine", default="sim", choices=["sim", "dist"])
    ap.add_argument("--name", default=None, help="store group name")
    ap.add_argument("--chunk-rounds", type=int, default=512)
    ap.add_argument("--no-regret", action="store_true")
    ap.add_argument("--no-vmap", action="store_true",
                    help="force the sequential per-seed fallback")
    ap.add_argument("--force-vmap", action="store_true",
                    help="error instead of falling back on seed-dependent "
                         "stages")
    ap.add_argument("--devices", default=None, metavar="N|auto",
                    help="shard the vmapped seed axis over N local devices "
                         "(shard_map over a ('seed',) mesh; 'auto' = "
                         "jax.local_device_count(), falling back to plain "
                         "vmap on a 1-device host)")
    # store
    ap.add_argument("--store", default=DEFAULT_STORE)
    ap.add_argument("--no-store", action="store_true")
    ap.add_argument("--from-store", action="store_true",
                    help="reuse matching stored records instead of running")
    ap.add_argument("--metric", default="accuracy",
                    help="metric to aggregate in the printed table")
    return ap


def main(argv: list[str] | None = None) -> dict:
    args = build_parser().parse_args(argv)
    axes = dict(parse_axis(a) for a in args.axis)
    base = RunSpec(
        nodes=args.nodes, dim=args.dim, horizon=args.horizon, eps=args.eps,
        lam=args.lam, alpha0=args.alpha0, mixer=args.mixer,
        mechanism=args.mechanism, local_rule=args.local_rule,
        calibration=args.calibration, clip_norm=args.clip_norm,
        delay=args.delay, delay_dist=args.delay_dist, stream=args.stream,
        stream_options=parse_opts(args.stream_opt))
    vectorize = (False if args.no_vmap
                 else True if args.force_vmap else None)
    devices = (None if args.devices is None
               else "auto" if args.devices == "auto" else int(args.devices))
    spec = SweepSpec(
        base=base, axes=axes,
        seeds=tuple(int(s) for s in args.seeds.split(",")),
        engine=args.engine, name=args.name,
        chunk_rounds=args.chunk_rounds,
        compute_regret=not args.no_regret, vectorize_seeds=vectorize,
        devices=devices)
    try:
        out = sweep(spec, store=None if args.no_store else args.store,
                    reuse=args.from_store, verbose=True,
                    require_store=args.from_store)
    except SweepStoreMiss as e:
        # --from-store promises regeneration WITHOUT re-running; dying with
        # the miss explained beats silently emitting an empty/recomputed table
        raise SystemExit(f"error: {e}")

    rows = out.aggregate(args.metric)
    print(json.dumps(out.summary(), indent=1))
    header = list(out.points[0].coords.keys()) if out.points else []
    print("  ".join(header + [f"{args.metric}(mean±std over "
                              f"{len(spec.seeds)} seeds)"]))
    for row in rows:
        coords = "  ".join(str(row[k]) for k in header)
        if row["mean"] is None:
            print(f"{coords}  n/a")
        else:
            print(f"{coords}  {row['mean']:.4f} ± {row['std']:.4f}")
    return {"summary": out.summary(), "rows": rows}


if __name__ == "__main__":
    main()
