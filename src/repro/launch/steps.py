"""Step functions + input/shard-spec builders for training and serving.

Everything here is mesh-agnostic pure-function plumbing shared by
launch/train.py (real execution), launch/serve.py and launch/dryrun.py
(lower/compile only). The GossipDP strategy is the paper's technique as a
first-class citizen; 'allreduce' is the classic data-parallel baseline the
paper compares against (its "centralized" comparator).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.api import RunSpec
from repro.core import GossipDP
from repro.launch import mesh as mesh_lib
from repro.models import build_model, Model
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim import adamw, apply_updates, warmup_cosine
from repro.sharding import rules as shard_rules


# ---------------------------------------------------------------------------
# strategy configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainRecipe:
    """Training-launch knobs; the gossip path materialises as a
    `repro.api.RunSpec` (see :meth:`to_runspec`), so every registry-backed
    mixer / mechanism / local rule is reachable from the CLI."""

    strategy: str = "gossip"        # 'gossip' (the paper) | 'allreduce' (baseline)
    eps: float = 1.0                # DP budget per round (gossip only)
    L: float = 1.0                  # clip norm
    lam: float = 1e-4               # Lasso strength
    alpha0: float = 0.01
    topology: str = "ring"          # repro.api MIXERS registry name
    lr: float = 3e-4                # allreduce baseline LR
    noise_self: bool = True
    microbatches: int = 1           # grad-accumulation chunks per round
    # Laplace calibration: 'coordinate' (2*alpha*L/eps per coordinate) is the
    # deployable default at transformer scale; the paper's exact Lemma-1
    # 'global' scale carries a sqrt(n) factor that destroys learning for
    # n ~ 10^9 parameters (DESIGN.md deviation #3) — selectable for the
    # paper-faithful linear workload.
    clip_style: str = "coordinate"
    mechanism: str = "laplace"      # repro.api MECHANISMS registry name
    local_rule: str = "omd"         # repro.api LOCAL_RULES registry name
    clipper: str = "l2"             # repro.api CLIPPERS registry name
    # WAN staleness (rounds): delay > 0 gives GossipState a (delay+1)-deep
    # history ring; delay_dist ('constant'|'uniform'|'geometric') draws
    # per-edge delays <= delay from a seeded distribution instead of one
    # uniform lag (see docs/delayed_gossip.md for the memory trade-off).
    delay: int = 0
    delay_dist: str | None = None

    def to_runspec(self, nodes: int) -> RunSpec:
        return RunSpec(
            nodes=nodes,
            mixer=self.topology,
            mechanism=self.mechanism,
            local_rule=self.local_rule,
            clipper=self.clipper,
            eps=self.eps,
            clip_norm=self.L,
            noise_self=self.noise_self,
            calibration=self.clip_style,
            alpha0=self.alpha0,
            schedule="sqrt_t",
            lam=self.lam,
            delay=self.delay,
            delay_dist=self.delay_dist,
        )


def effective_config(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Shape-dependent tweaks: the long_500k sliding-window variant."""
    if shape.name == "long_500k" and cfg.window_500k and cfg.sliding_window is None:
        return dataclasses.replace(cfg, sliding_window=cfg.window_500k)
    return cfg


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return ("full-attention arch without a windowed variant; 500k decode "
                "needs a sub-quadratic mechanism (DESIGN.md §Arch-applicability)")
    return None


def decode_cache_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    if cfg.sliding_window is not None:
        return min(shape.seq_len, cfg.sliding_window)
    return shape.seq_len


# ---------------------------------------------------------------------------
# train step builders
# ---------------------------------------------------------------------------

class GossipTrainState(NamedTuple):
    gossip: Any   # core.gossip.GossipState (theta = node-stacked params)


def make_gossip_dp(cfg_nodes: int, recipe: TrainRecipe) -> GossipDP:
    return recipe.to_runspec(cfg_nodes).build_distributed()


def make_gossip_train_step(model: Model, gdp: GossipDP, microbatches: int = 1,
                           node_axis: str | None = None,
                           batchpar_attn: bool = False,
                           head_pad: int | None = None,
                           flash: bool = False):
    """Batch leaves carry a leading node axis; params/theta are node-stacked.

    ``microbatches`` > 1 grad-accumulates over chunks of the per-node batch
    (peak activation memory / microbatches; identical update in expectation).
    ``node_axis`` names the mesh axis of the node dim (enables
    spmd_axis_name so sharding constraints inside the vmapped loss work).
    ``batchpar_attn`` is §Perf H2: shard attention over the per-node batch.
    """
    from repro.models import attention as attn_mod

    def train_step(state: GossipTrainState, batch):
        w = gdp.primal(state.gossip)  # node-stacked primal params (steps 6-7)
        w_model = jax.tree_util.tree_map(
            lambda a: a.astype(model.cfg.jdtype) if a.dtype == jnp.float32 else a, w)

        def node_loss(params, node_batch):
            with attn_mod.batch_parallel("model" if batchpar_attn else None), \
                 attn_mod.head_padding(head_pad, "model" if head_pad else None), \
                 attn_mod.flash_vjp("flash" if flash else False):
                loss, metrics = model.loss_fn(params, node_batch)
            return loss, metrics

        grad_fn = jax.vmap(jax.value_and_grad(node_loss, has_aux=True),
                           spmd_axis_name=node_axis)
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(w_model, batch)
        else:
            def to_mb(leaf):
                n, b = leaf.shape[:2]
                mb = b // microbatches
                return jnp.moveaxis(
                    leaf.reshape((n, microbatches, mb) + leaf.shape[2:]), 1, 0)

            mb_batch = jax.tree_util.tree_map(to_mb, batch)

            def mb_body(acc, mb):
                (l, met), g = grad_fn(w_model, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(jnp.float32), acc, g)
                return acc, (l, met)

            acc0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), w_model)
            grads, (losses, mets) = jax.lax.scan(mb_body, acc0, mb_batch)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss = jnp.mean(losses, axis=0)
            metrics = jax.tree_util.tree_map(lambda m: jnp.mean(m, axis=0), mets)
        new_gossip, gossip_metrics = gdp.update(state.gossip, grads)
        out = {
            "loss": jnp.mean(loss),
            "ce": jnp.mean(metrics["ce"]),
            "aux": jnp.mean(metrics["aux"]),
            **gossip_metrics,
        }
        return GossipTrainState(gossip=new_gossip), out

    return train_step


def make_gossip_init(model: Model, gdp: GossipDP, nodes: int):
    def init(seed: int = 0):
        k0, k1 = jax.random.split(jax.random.PRNGKey(seed))
        params = model.init(k0)
        node_params = shard_rules.with_node_axis(params, nodes)
        return GossipTrainState(gossip=gdp.init(node_params, k1))
    return init


def gossip_state_pspecs(state_struct: GossipTrainState,
                        theta_specs: Any) -> GossipTrainState:
    """PartitionSpecs for a GossipTrainState, given the theta leaf specs.

    The history ring (present when the mixer carries a delay) shards like
    theta with an extra unsharded leading ring axis, so the stale copies
    live on the same chips as the live ones and delayed mixing lowers to
    the same collectives as the synchronous path.
    """
    gossip = state_struct.gossip
    hist_specs = None
    if gossip.history is not None:
        hist_specs = jax.tree_util.tree_map(
            lambda s: P(*((None,) + tuple(s))), theta_specs,
            is_leaf=lambda x: isinstance(x, P))
    return GossipTrainState(gossip=type(gossip)(
        theta=theta_specs, t=P(), key=P(), history=hist_specs))


class AllreduceTrainState(NamedTuple):
    params: Any
    opt: Any


def make_allreduce_train_step(model: Model, recipe: TrainRecipe, total_steps: int = 10_000):
    optimizer = adamw(warmup_cosine(recipe.lr, 200, total_steps))
    M = recipe.microbatches

    def train_step(state: AllreduceTrainState, batch):
        vg = jax.value_and_grad(model.loss_fn, has_aux=True)
        if M == 1:
            (loss, metrics), grads = vg(state.params, batch)
        else:
            def to_mb(leaf):
                b = leaf.shape[0]
                return leaf.reshape((M, b // M) + leaf.shape[1:])

            mb_batch = jax.tree_util.tree_map(to_mb, batch)

            def mb_body(acc, mb):
                (l, met), g = vg(state.params, mb)
                return jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(jnp.float32), acc, g), (l, met)

            acc0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            grads, (losses, mets) = jax.lax.scan(mb_body, acc0, mb_batch)
            grads = jax.tree_util.tree_map(lambda g: g / M, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree_util.tree_map(jnp.mean, mets)
        updates, opt = optimizer.update(grads, state.opt, state.params)
        params = apply_updates(state.params, updates)
        return AllreduceTrainState(params, opt), {"loss": loss, **metrics}

    def init(seed: int = 0):
        params = model.init(jax.random.PRNGKey(seed))
        return AllreduceTrainState(params, optimizer.init(params))

    return train_step, init


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------

def make_prefill_step(model: Model, last_only: bool = False,
                      seqpar_axis: str | None = None,
                      moe_groups: int = 1, moe_group_axis: str | None = None,
                      head_pad: int | None = None, sp_axis: str | None = None):
    """§Perf hillclimb variants:
      last_only    — skip the (B, T, V) logits (prefill only needs the last
                     position). Refuted as a win: XLA already pushes the
                     slice through the unembed matmul (see EXPERIMENTS §Perf).
      seqpar_axis  — sequence-parallel blockwise attention (shard time over
                     the model axis instead of the contracting head_dim).
      moe_groups   — grouped (shard-local) MoE dispatch: argsort/scatter per
                     data shard instead of replicated global scatters."""
    from repro.models import attention as attn_mod
    from repro.models import moe as moe_mod
    from repro.models import transformer as tfm_mod

    def prefill_step(params, batch):
        with attn_mod.sequence_parallel(seqpar_axis), \
             moe_mod.grouped_dispatch(moe_groups, moe_group_axis), \
             attn_mod.head_padding(head_pad, "model" if head_pad else None), \
             tfm_mod.sp_residual(sp_axis):
            logits, _ = model.apply(params, batch["tokens"], batch.get("frontend"),
                                    last_only=last_only)
        return jnp.argmax(logits[:, -1], axis=-1)
    return prefill_step


def make_serve_step(model: Model):
    def serve_step(params, cache, tokens, pos):
        logits, cache = model.decode_step(params, cache, tokens, pos)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache
    return serve_step


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation) + shardings
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      strategy: str) -> tuple[Any, Any]:
    """Returns (batch_structs, batch_pspecs)."""
    B, T = shape.global_batch, shape.seq_len
    if strategy == "gossip":
        nodes = mesh_lib.gossip_nodes(mesh)
        pnb = B // nodes
        lead_axes = mesh_lib.gossip_axes(mesh)
        inner = mesh_lib.data_axes_for_batch(mesh)
        lead = lead_axes[0] if len(lead_axes) == 1 else lead_axes
        bspec = P(lead, inner[0] if inner else None, None)
        shape3 = (nodes, pnb, T)
        fe_spec = P(lead, inner[0] if inner else None, None, None)
        fe_dims = (nodes, pnb)
    else:
        axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        lead = axes if len(axes) > 1 else axes[0]
        bspec = P(lead, None)
        shape3 = (B, T)
        fe_spec = P(lead, None, None)
        fe_dims = (B,)

    batch = {
        "tokens": _sds(shape3, jnp.int32),
        "labels": _sds(shape3, jnp.int32),
    }
    specs = {"tokens": bspec, "labels": bspec}
    if cfg.frontend == "vision":
        batch["frontend"] = _sds(fe_dims + (cfg.frontend_tokens, cfg.d_model), cfg.jdtype)
        specs["frontend"] = fe_spec
    elif cfg.family == "encdec":
        batch["frontend"] = _sds(fe_dims + (max(T // 4, 8), cfg.d_model), cfg.jdtype)
        specs["frontend"] = fe_spec
    return batch, specs


def serve_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Decode inputs: tokens (B, 1), pos (B,). Batch over all data axes;
    batch==1 (long_500k) replicates."""
    B = shape.global_batch
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    total = int(np.prod([mesh.shape[a] for a in axes]))
    lead = (tuple(axes) if len(axes) > 1 else axes[0]) if B >= total else None
    tokens = _sds((B, 1), jnp.int32)
    pos = _sds((B,), jnp.int32)
    return (tokens, pos), (P(lead, None), P(lead))


def batch_axes_for_serve(mesh, B: int):
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    total = int(np.prod([mesh.shape[a] for a in axes]))
    if B >= total:
        return tuple(axes)
    return ()


def named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
