import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes, WITHOUT allocating real tensors (ShapeDtypeStruct only).

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--strategy gossip]

Per run it prints/records:
  * compiled.memory_analysis()  — bytes per device (proves it fits)
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline
  * collective-op operand bytes parsed from the HLO (§Roofline third term)
Results land in experiments/dryrun/<arch>__<shape>__<mesh>__<strategy>.json.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch import hlo_analysis, hlo_cost, steps
from repro.launch.mesh import make_production_mesh, gossip_nodes, gossip_axes
from repro.models import build_model
from repro.models.config import INPUT_SHAPES
from repro.sharding import rules as shard_rules

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


_cost_get = hlo_cost.cost_analysis_get


def count_params(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))


def active_param_count(cfg, params_struct) -> float:
    """N_active for MODEL_FLOPS = 6 N D: MoE counts only routed-active experts."""
    total = count_params(params_struct)
    if cfg.num_experts:
        # expert stacks: gate/up/down (E, ..) — count k/E of them (+ shared fully)
        expert = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(params_struct)[0]:
            pstr = "/".join(str(getattr(p, "key", p)) for p in path)
            if "moe/" in pstr and ("gate" in pstr or "up" in pstr or "down" in pstr):
                expert += int(np.prod(leaf.shape))
        active = total - expert + expert * cfg.num_experts_per_tok / cfg.num_experts
        return active
    return total


def pick_microbatches(cfg, shape, mesh) -> int:
    """Grad-accumulation factor so the per-chip remat carry stack (layers x
    per-node-microbatch x seq x d_model x 2B) stays under ~2 GB."""
    from repro.launch.mesh import gossip_nodes
    nodes = gossip_nodes(mesh)
    pnb = max(shape.global_batch // nodes, 1)
    if "pod" in mesh.axis_names:
        pnb = max(pnb // mesh.shape["data"], 1)
    layers_total = cfg.num_layers + cfg.encoder_layers
    carry = layers_total * pnb * shape.seq_len * cfg.d_model * 2
    m = 1
    while carry / m > 2e9 and m < pnb:
        m *= 2
    return m


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               strategy: str = "gossip", recipe: steps.TrainRecipe | None = None,
               save: bool = True, verbose: bool = True, opt: str = "",
               delay: int = 0, delay_dist: str | None = None) -> dict:
    """opt: comma-separated perf-variant flags ('last_only', ...) — results
    are saved under strategy+opt so baselines stay untouched. delay /
    delay_dist install a history ring for WAN-stale gossip (ignored when an
    explicit recipe is passed)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    opt_flags = set(f for f in opt.split(",") if f)
    strategy_tag = strategy + ("+" + opt if opt else "")
    shape = INPUT_SHAPES[shape_name]
    base_cfg = get_config(arch)
    reason = steps.skip_reason(base_cfg, shape)
    if reason:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "strategy": strategy, "status": "skipped", "reason": reason}
        if save:
            _save(rec)
        if verbose:
            print(f"[skip] {arch} x {shape_name}: {reason}")
        return rec

    if "bigq" in opt_flags:
        from repro.models import attention as _attn
        _attn.Q_CHUNK = 1024  # §Perf H3 iter 3: halve k/v reload count
    cfg = steps.effective_config(base_cfg, shape)
    model = build_model(cfg)
    if recipe is None:
        recipe = steps.TrainRecipe(
            strategy=strategy,
            microbatches=pick_microbatches(cfg, shape, mesh) if shape.kind == "train" else 1,
            delay=delay, delay_dist=delay_dist,
        )
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            if strategy == "gossip":
                nodes = gossip_nodes(mesh)
                gdp = steps.make_gossip_dp(nodes, recipe)
                step = steps.make_gossip_train_step(
                    model, gdp, recipe.microbatches,
                    node_axis=gossip_axes(mesh)[0] if "batchpar" in opt_flags else None,
                    batchpar_attn="batchpar" in opt_flags,
                    head_pad=16 if "padheads" in opt_flags else None,
                    flash="flash" in opt_flags)
                init = steps.make_gossip_init(model, gdp, nodes)
                state_struct = jax.eval_shape(init)
                node_axes = gossip_axes(mesh)
                theta_specs = shard_rules.param_pspecs(
                    state_struct.gossip.theta, node_axes=node_axes, mesh=mesh)
                if "zerotheta" in opt_flags and multi_pod:
                    # Beyond-paper: ZeRO-shard theta over the intra-pod
                    # "data" axis (each pod = one gossip node owns its theta,
                    # but stores it sharded across its 256 chips). Gossip
                    # ppermutes over "pod" work on sharded leaves unchanged.
                    from jax.sharding import PartitionSpec as P
                    def _zero(path, spec_leaf):
                        leaf = None
                        # find matching struct leaf for divisibility check
                        import jax.tree_util as jtu
                        return spec_leaf
                    def _add_data(spec, leaf):
                        dims = list(spec) + [None] * (leaf.ndim - len(spec))
                        if "data" in dims:
                            return spec
                        for i in range(1, leaf.ndim):
                            if dims[i] is None and leaf.shape[i] % mesh.shape["data"] == 0                                     and leaf.shape[i] >= mesh.shape["data"]:
                                dims[i] = "data"
                                return P(*dims)
                        return spec
                    theta_specs = jax.tree_util.tree_map(
                        _add_data, theta_specs, state_struct.gossip.theta,
                        is_leaf=lambda x: isinstance(x, P))
                state_specs = steps.gossip_state_pspecs(state_struct,
                                                        theta_specs)
            else:
                step, init = steps.make_allreduce_train_step(model, recipe)
                state_struct = jax.eval_shape(init)
                from jax.sharding import PartitionSpec as P
                pspecs = shard_rules.param_pspecs(state_struct.params, mesh=mesh)
                opt_specs = {
                    "step": P(),
                    "m": shard_rules.param_pspecs(state_struct.opt["m"], mesh=mesh),
                    "v": shard_rules.param_pspecs(state_struct.opt["v"], mesh=mesh),
                }
                state_specs = steps.AllreduceTrainState(params=pspecs, opt=opt_specs)
            if "ep" in opt_flags:
                # Beyond-paper: EXPERT-PARALLEL MoE — shard the expert axis
                # over "model" (llama4: 16 experts / 16 chips). Expert
                # buffers shrink 16x; dispatch becomes a token all-to-all.
                import re as _re
                from jax.sharding import PartitionSpec as P
                def _ep(path, spec_leaf):
                    ps = "/".join(str(getattr(q, "key", q)) for q in path)
                    if _re.search(r"moe/(gate|up|down)$", ps):
                        nd = 4 if strategy == "gossip" else 3  # node axis?
                        lead = list(spec_leaf)[:1] if strategy == "gossip" else []
                        return P(*(lead + ["model", None, None]))
                    return spec_leaf
                if strategy == "gossip":
                    theta_specs = jax.tree_util.tree_map_with_path(
                        _ep, theta_specs, is_leaf=lambda x: isinstance(x, P))
                    state_specs = steps.gossip_state_pspecs(state_struct,
                                                            theta_specs)
            batch_struct, batch_specs = steps.train_batch_specs(cfg, shape, mesh, strategy)
            in_shardings = (steps.named(mesh, state_specs), steps.named(mesh, batch_specs))
            fn = jax.jit(step, in_shardings=in_shardings, donate_argnums=(0,))
            lowered = fn.lower(state_struct, batch_struct)
        elif shape.kind == "prefill":
            params_struct = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            pspecs = shard_rules.param_pspecs(params_struct, mesh=mesh)
            if "repattn" in opt_flags:
                # H1 iter 4: replicate attention weights over the model axis
                # so the T-sharded attention region has one consistent layout
                import re as _re
                from jax.sharding import PartitionSpec as P
                def _rep(path, spec):
                    ps = "/".join(str(getattr(q, "key", q)) for q in path)
                    if _re.search(r"(attn|cross)/w[qkvo]", ps):
                        return P()
                    return spec
                pspecs = jax.tree_util.tree_map_with_path(_rep, pspecs,
                    is_leaf=lambda x: isinstance(x, P))
            batch_struct, batch_specs = steps.train_batch_specs(
                cfg, shape, mesh, "allreduce")
            batch_struct.pop("labels"); batch_specs.pop("labels")
            fn = jax.jit(steps.make_prefill_step(model, last_only="last_only" in opt_flags,
                                                 seqpar_axis="model" if "seqpar" in opt_flags else None,
                                                 moe_groups=16 if "moegroup" in opt_flags else 1,
                                                 moe_group_axis="data" if "moegroup" in opt_flags else None,
                                                 head_pad=16 if "padheads" in opt_flags else None,
                                                 sp_axis="model" if "spres" in opt_flags else None),
                         in_shardings=(steps.named(mesh, pspecs),
                                       steps.named(mesh, batch_specs)))
            lowered = fn.lower(params_struct, batch_struct)
        else:  # decode
            params_struct = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            pspecs = shard_rules.param_pspecs(params_struct, mesh=mesh)
            cache_len = steps.decode_cache_len(cfg, shape)
            cache_struct = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, cache_len))
            baxes = steps.batch_axes_for_serve(mesh, shape.global_batch)
            cache_specs = shard_rules.cache_pspecs(cache_struct, baxes or ("data",), mesh=mesh)
            if not baxes:  # batch too small to shard: replicate batch dims
                from jax.sharding import PartitionSpec as P
                cache_specs = jax.tree_util.tree_map(
                    lambda s: P(*[None if d in ("data", "pod") or
                                  (isinstance(d, tuple)) else d for d in s]),
                    cache_specs, is_leaf=lambda x: isinstance(x, P))
            (tok_struct, pos_struct), (tok_spec, pos_spec) = steps.serve_batch_specs(
                cfg, shape, mesh)
            fn = jax.jit(steps.make_serve_step(model),
                         in_shardings=(steps.named(mesh, pspecs),
                                       steps.named(mesh, cache_specs),
                                       steps.named(mesh, tok_spec),
                                       steps.named(mesh, pos_spec)),
                         donate_argnums=(1,))
            lowered = fn.lower(params_struct, cache_struct, tok_struct, pos_struct)

        compiled = lowered.compile()

    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()

    # loop-aware roll-up cost model (per-device; see hlo_cost.py for why
    # raw cost_analysis undercounts scanned layers on the CPU backend)
    rollup = hlo_cost.analyze(hlo)
    flops = rollup.flops
    hbm_bytes = rollup.hbm_bytes
    coll_bytes = rollup.collective_bytes
    terms = hlo_analysis.roofline_terms(flops, hbm_bytes, coll_bytes, chips=1)

    # MODEL_FLOPS = 6 N D (training: fwd+bwd is already in the 6ND rule;
    # decode: D = global_batch tokens)
    params_struct = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    n_active = active_param_count(cfg, params_struct)
    n_total = count_params(params_struct)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mf = hlo_analysis.model_flops(n_active, tokens)
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = hlo_analysis.model_flops(n_active, tokens) / 3.0  # fwd only: 2ND
    else:
        tokens = shape.global_batch
        mf = hlo_analysis.model_flops(n_active, tokens) / 3.0

    bytes_per_device = None
    if mem is not None:
        try:
            bytes_per_device = {
                "temp": int(getattr(mem, "temp_size_in_bytes", 0)),
                "arguments": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output": int(getattr(mem, "output_size_in_bytes", 0)),
                "alias": int(getattr(mem, "alias_size_in_bytes", 0)),
            }
        except Exception:
            bytes_per_device = {"repr": str(mem)}

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "strategy": strategy_tag,
        "status": "ok", "chips": chips, "compile_s": round(compile_s, 1),
        "hlo_flops": flops, "hlo_bytes": hbm_bytes,
        "collectives": rollup.summary(),
        "xla_cost_analysis_raw": {"flops": _cost_get(cost, "flops"),
                                  "bytes_accessed": _cost_get(cost, "bytes accessed")},
        "roofline": terms,
        "model_flops_6nd": mf,
        "useful_flops_ratio": (mf / (flops * chips)) if flops else None,
        "n_params": n_total, "n_params_active": n_active,
        "memory_per_device": bytes_per_device,
    }
    if save:
        _save(rec)
    if verbose:
        print(f"[ok] {arch} x {shape_name} @ {mesh_name}/{strategy}: "
              f"compile {compile_s:.0f}s flops={flops:.3g} bytes={hbm_bytes:.3g} "
              f"coll={coll_bytes:.3g}B dominant={terms['dominant']} "
              f"useful={rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'], 3)}")
    return rec


def dryrun_stream(stream: str, *, engine: str = "sim", nodes: int = 8,
                  dim: int = 256, chunk_rounds: int = 64,
                  stream_options: dict | None = None, save: bool = True,
                  verbose: bool = True) -> dict:
    """Lower + compile the exact chunk program `repro.api.run` scans for a
    STREAMS scenario (no real horizon executed) and record its HLO cost —
    proves a declared scenario compiles on either engine before you pay for
    the run."""
    from repro.api import RunSpec
    from repro.api.runner import make_chunk_fn

    spec = RunSpec(nodes=nodes, dim=dim, horizon=chunk_rounds, eps=1.0,
                   alpha0=0.5, lam=0.01, stream=stream,
                   stream_options=stream_options or {})
    fn, state = make_chunk_fn(spec, engine)
    xs = jax.ShapeDtypeStruct((chunk_rounds, nodes, dim), np.float32)
    ys = jax.ShapeDtypeStruct((chunk_rounds, nodes), np.float32)
    t0 = time.time()
    compiled = jax.jit(fn).lower(state, xs, ys).compile()
    compile_s = time.time() - t0
    rollup = hlo_cost.analyze(compiled.as_text())
    rec = {
        "arch": f"stream-{stream}", "shape": f"chunk{chunk_rounds}",
        "mesh": "host", "strategy": engine, "status": "ok",
        "stream": stream, "engine": engine, "nodes": nodes, "dim": dim,
        "chunk_rounds": chunk_rounds, "compile_s": round(compile_s, 1),
        "hlo_flops": rollup.flops, "hlo_bytes": rollup.hbm_bytes,
        "collectives": rollup.summary(),
    }
    if save:
        _save(rec)
    if verbose:
        print(f"[ok] stream={stream} engine={engine} m={nodes} n={dim} "
              f"chunk={chunk_rounds}: compile {compile_s:.1f}s "
              f"flops={rollup.flops:.3g} bytes={rollup.hbm_bytes:.3g}")
    return rec


def _save(rec: dict) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    fn = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}__{rec['strategy']}.json"
    with open(os.path.join(OUT_DIR, fn), "w") as f:
        json.dump(rec, f, indent=2)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default="gossip", choices=["gossip", "allreduce"])
    ap.add_argument("--opt", default="", help="perf-variant flags, comma separated")
    ap.add_argument("--delay", type=int, default=0,
                    help="WAN gossip staleness (rounds); adds the history "
                         "ring to the lowered GossipState")
    ap.add_argument("--delay-dist", default=None,
                    choices=["constant", "uniform", "geometric"])
    ap.add_argument("--stream", default=None,
                    help="repro.api STREAMS name: lower/compile the "
                         "repro.api.run chunk program instead of an arch")
    ap.add_argument("--stream-opt", action="append", default=[],
                    metavar="KEY=VALUE")
    ap.add_argument("--engine", default="sim", choices=["sim", "dist"])
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--chunk-rounds", type=int, default=64)
    args = ap.parse_args()

    if args.stream:
        from repro.launch.train import parse_stream_options
        dryrun_stream(args.stream, engine=args.engine, nodes=args.nodes,
                      dim=args.dim, chunk_rounds=args.chunk_rounds,
                      stream_options=parse_stream_options(args.stream_opt))
        return 0

    runs = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in INPUT_SHAPES:
                runs.append((arch, shape))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        runs.append((args.arch, args.shape))

    failures = 0
    for arch, shape in runs:
        try:
            dryrun_one(arch, shape, multi_pod=args.multi_pod, strategy=args.strategy,
                       opt=args.opt, delay=args.delay, delay_dist=args.delay_dist)
        except Exception:
            failures += 1
            print(f"[FAIL] {arch} x {shape}:\n{traceback.format_exc()}")
            _save({"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "strategy": args.strategy, "status": "failed",
                   "error": traceback.format_exc()[-2000:]})
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
