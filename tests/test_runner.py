"""`repro.api.run` — RunSpec -> RunResult on either engine."""
import dataclasses
import math
import os

import numpy as np
import pytest

from repro.api import RunSpec, SocialStream, run


def _spec(**kw):
    base = dict(nodes=4, dim=64, horizon=256, eps=1.0, alpha0=0.5, lam=0.01,
                stream="drift")
    base.update(kw)
    return RunSpec(**base)


@pytest.mark.parametrize("stream", ["social_sparse", "drift"])
def test_sim_and_dist_bit_identical(stream):
    """The acceptance contract: seeded sim-vs-dist iterates are
    bit-identical — including the Laplace noise stream (eps=1)."""
    spec = _spec(stream=stream)
    sim = run(spec, engine="sim", chunk_rounds=128, warmup=False)
    dist = run(spec, engine="dist", chunk_rounds=128, warmup=False)
    for r in (sim, dist):
        assert r.rounds == 256
        assert r.regret is not None and len(r.regret) == 256
        assert r.eps_ledger is not None and len(r.eps_ledger) == 256
        assert r.wall_clock > 0 and r.rounds_per_sec > 0
    np.testing.assert_array_equal(sim.final_w, dist.final_w)
    np.testing.assert_array_equal(sim.correct, dist.correct)
    np.testing.assert_array_equal(sim.w_bar_loss, dist.w_bar_loss)
    np.testing.assert_array_equal(sim.regret, dist.regret)


def test_run_chunking_does_not_change_results():
    spec = _spec(stream="social_sparse", horizon=96)
    a = run(spec, engine="sim", chunk_rounds=96, warmup=False,
            compute_regret=False)
    b = run(spec, engine="sim", chunk_rounds=17, warmup=False,
            compute_regret=False)
    np.testing.assert_array_equal(a.final_w, b.final_w)
    np.testing.assert_array_equal(a.correct, b.correct)


def test_eps_ledger_parallel_composition():
    res = run(_spec(horizon=64), engine="sim", warmup=False,
              compute_regret=False)
    np.testing.assert_array_equal(res.eps_ledger, np.full(64, 1.0))
    assert res.privacy["eps_total"] == 1.0
    assert res.privacy["composition"] == "parallel (disjoint)"


def test_eps_ledger_sequential_fallback():
    stream = dataclasses.replace(
        SocialStream(n=64, nodes=4, rounds=32), disjoint=False)
    res = run(_spec(stream=stream, horizon=32), engine="sim", warmup=False,
              compute_regret=False)
    np.testing.assert_allclose(res.eps_ledger, np.arange(1, 33) * 1.0)
    assert res.privacy["composition"] == "sequential"


def test_non_private_run_has_infinite_ledger():
    res = run(_spec(eps=math.inf, horizon=16), engine="sim", warmup=False,
              compute_regret=False)
    assert np.isinf(res.eps_ledger).all()


def test_run_learns_on_social_sparse():
    spec = _spec(stream="social_sparse", eps=math.inf, horizon=400,
                 alpha0=1.0, calibration="coordinate")
    res = run(spec, engine="sim", warmup=False, compute_regret=False)
    assert res.accuracy > 0.7
    # regret off but trajectories on
    assert res.sparsity is not None and res.loss.shape == (400, 4)


def test_run_csv_log(tmp_path):
    path = str(tmp_path / "run.csv")
    run(_spec(horizon=8), engine="sim", warmup=False, compute_regret=False,
        log_path=path)
    lines = open(path).read().strip().splitlines()
    assert len(lines) == 9  # header + one row per round
    assert "eps" in lines[0] and "accuracy" in lines[0]


def test_run_unknown_engine_raises():
    with pytest.raises(ValueError):
        run(_spec(horizon=8), engine="tpu-cluster", warmup=False)


def test_run_custom_step_fn_loop(tmp_path):
    """The loop launch.train drives LM training through."""
    calls = []

    def step_fn(state, batch):
        calls.append(batch)
        return state + batch, {"loss": float(state)}

    def batches():
        i = 0
        while True:
            yield i
            i += 1

    res = run(None, engine="custom", step_fn=step_fn, state=0,
              batches=batches(), horizon=5, print_every=None,
              log_path=str(tmp_path / "steps.csv"))
    assert res.final_state == 0 + 1 + 2 + 3 + 4
    assert len(res.history) == 5 and calls == [0, 1, 2, 3, 4]
    assert res.history[-1] == {"loss": 6.0}
    assert os.path.exists(tmp_path / "steps.csv")


def test_run_custom_mode_requires_horizon():
    with pytest.raises(ValueError):
        run(None, step_fn=lambda s, b: (s, {}), batches=iter([]), state=0)


def test_run_without_spec_or_step_fn_raises():
    with pytest.raises(ValueError):
        run(None)
