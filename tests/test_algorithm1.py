"""Behavioural tests of the paper's Algorithm 1 (simulator)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import RunSpec
from repro.core.regret import best_fixed_hinge, cumulative_regret, theorem2_bound
from repro.data.social import SocialStream


def _stream(m=8, n=64, T=300, seed=0):
    s = SocialStream(n=n, nodes=m, rounds=T, sparsity_true=0.2, seed=seed)
    return s.chunk(0, T)


def _spec(eps, m=8, n=64, lam=1e-3, topology="ring"):
    return RunSpec(nodes=m, dim=n, mixer=topology, mechanism="laplace",
                   eps=eps, clip_norm=1.0, calibration="global",
                   alpha0=1.0, schedule="sqrt_t", lam=lam)


def _run(eps, m=8, n=64, T=300, lam=1e-3, topology="ring", seed=1):
    xs, ys = _stream(m, n, T)
    alg = _spec(eps, m, n, lam, topology).build_simulator()
    outs = alg.run(jax.random.PRNGKey(seed), xs, ys)
    return xs, ys, outs


def test_nonprivate_learns():
    _, _, outs = _run(math.inf)
    acc = float(outs.correct[-100:].mean())
    assert acc > 0.8, acc


def test_regret_sublinear_nonprivate():
    xs, ys, outs = _run(math.inf, T=400)
    reg = cumulative_regret(outs.w_bar_loss, xs, ys, 8)
    # average regret decreasing over time = sublinear
    assert reg[-1] / 400 < reg[100] / 100 + 1e-6


def test_privacy_hurts_monotonically():
    accs = {}
    for eps in (0.5, 5.0, math.inf):
        _, _, outs = _run(eps)
        accs[eps] = float(outs.correct[-100:].mean())
    assert accs[math.inf] >= accs[5.0] - 0.05
    assert accs[5.0] >= accs[0.5] - 0.05
    assert accs[math.inf] > accs[0.5]  # strictly: heavy noise must hurt


def test_topology_invariance_paper_fig3():
    """Fig. 3: topology makes no *significant* difference."""
    finals = []
    for topo in ("ring", "complete", "hypercube"):
        _, _, outs = _run(math.inf, topology=topo)
        finals.append(float(outs.correct[-100:].mean()))
    assert max(finals) - min(finals) < 0.1, finals


def test_lasso_induces_sparsity():
    _, _, outs_dense = _run(math.inf, lam=0.0)
    _, _, outs_sparse = _run(math.inf, lam=0.3)
    assert float(outs_sparse.sparsity[-1]) > float(outs_dense.sparsity[-1])
    assert float(outs_sparse.sparsity[-1]) > 0.05


def test_consensus_under_mixing():
    """Ring-mixed nodes end closer together than disconnected ones."""
    xs, ys = _stream()
    def spread(topology):
        alg = _spec(math.inf, topology=topology).build_simulator()
        w, _ = alg.final_params(jax.random.PRNGKey(0), xs, ys)
        return float(jnp.linalg.norm(w - w.mean(0, keepdims=True)))
    assert spread("ring") < spread("disconnected")


def test_time_varying_topology_runs():
    xs, ys = _stream()
    alg = _spec(1.0, topology="time_varying").build_simulator()
    outs = alg.run(jax.random.PRNGKey(0), xs, ys)
    assert np.isfinite(np.asarray(outs.loss)).all()


def test_theorem2_bound_shape():
    b_lo = theorem2_bound(1000, 64, 10_000, 1.0, 0.01, 2.0, eps=0.1)
    b_hi = theorem2_bound(1000, 64, 10_000, 1.0, 0.01, 2.0, eps=10.0)
    b_np = theorem2_bound(1000, 64, 10_000, 1.0, 0.01, 2.0, eps=math.inf)
    assert b_lo > b_hi > b_np > 0  # higher privacy (smaller eps) = worse bound


def test_best_fixed_comparator_quality():
    xs, ys = _stream(T=200)
    w = best_fixed_hinge(xs, ys, steps=300)
    margins = ys * jnp.einsum("n,tmn->tm", w, xs)
    acc = float((margins > 0).mean())
    assert acc > 0.9
