"""Per-architecture smoke tests (reduced configs, forward + train step +
decode==apply consistency) — deliverable (f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model


def _batch(cfg, B=2, T=32, seed=7):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    tokens = jax.random.randint(ks[0], (B, T), 0, cfg.vocab_size)
    labels = jax.random.randint(ks[1], (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.frontend == "vision":
        batch["frontend"] = jax.random.normal(ks[2], (B, cfg.frontend_tokens, cfg.d_model))
        batch["labels"] = labels.at[:, :cfg.frontend_tokens].set(-1)
    elif cfg.family == "encdec":
        batch["frontend"] = jax.random.normal(ks[2], (B, max(T // 4, 8), cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = model.apply(params, batch["tokens"], batch.get("frontend"))
    assert logits.shape == (2, 32, cfg.vocab_padded)
    assert not bool(jnp.any(jnp.isnan(logits)))

    (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(params, batch)
    assert np.isfinite(float(loss))
    assert 0 < float(loss) < 50
    for leaf in jax.tree_util.tree_leaves(grads):
        assert not bool(jnp.any(jnp.isnan(leaf)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_consistency(arch):
    """Token-by-token decode logits == full-sequence apply logits."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, T = 2, 16
    batch = _batch(cfg, B, T)
    tokens = batch["tokens"]

    # reference: text-only apply (decode embeds tokens only; vlm frontend
    # injection happens at prefill in production, orthogonal to cache logic)
    frontend = batch.get("frontend") if cfg.family == "encdec" else None
    logits_full, _ = model.apply(params, tokens, frontend)

    cache = model.init_cache(B, cache_len=T)
    if model.prime_cache is not None:
        cache = model.prime_cache(params, cache, batch["frontend"])
    outs = []
    for i in range(T):
        step_logits, cache = model.decode_step(
            params, cache, tokens[:, i:i+1], jnp.full((B,), i, jnp.int32))
        outs.append(step_logits)
    logits_dec = jnp.concatenate(outs, axis=1)

    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_full),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", ["minicpm-2b", "rwkv6-3b", "mixtral-8x7b"])
def test_train_loss_decreases(arch):
    """A few SGD steps on a fixed batch must reduce the loss."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    batch = _batch(cfg)
    vg = jax.jit(jax.value_and_grad(lambda p: model.loss_fn(p, batch)[0]))
    l0, _ = vg(params)
    for _ in range(5):
        loss, g = vg(params)
        params = jax.tree_util.tree_map(lambda p, gg: p - 0.5 * gg.astype(p.dtype),
                                        params, g)
    l1, _ = vg(params)
    assert float(l1) < float(l0), (float(l0), float(l1))


def test_full_configs_match_assignment():
    """The exact numbers from the assignment table."""
    specs = {
        "rwkv6-3b": dict(num_layers=32, d_model=2560, d_ff=8960, vocab_size=65536),
        "recurrentgemma-2b": dict(num_layers=26, d_model=2560, num_heads=10,
                                  num_kv_heads=1, d_ff=7680, vocab_size=256000),
        "mixtral-8x7b": dict(num_layers=32, d_model=4096, num_heads=32,
                             num_kv_heads=8, d_ff=14336, vocab_size=32000,
                             num_experts=8, num_experts_per_tok=2),
        "qwen2-vl-2b": dict(num_layers=28, d_model=1536, num_heads=12,
                            num_kv_heads=2, d_ff=8960, vocab_size=151936),
        "llama4-scout-17b-a16e": dict(num_layers=48, d_model=5120, num_heads=40,
                                      num_kv_heads=8, d_ff=8192, vocab_size=202048,
                                      num_experts=16, num_experts_per_tok=1),
        "qwen2-7b": dict(num_layers=28, d_model=3584, num_heads=28,
                         num_kv_heads=4, d_ff=18944, vocab_size=152064),
        "minicpm-2b": dict(num_layers=40, d_model=2304, num_heads=36,
                           num_kv_heads=36, d_ff=5760, vocab_size=122753),
        "seamless-m4t-medium": dict(num_layers=12, d_model=1024, num_heads=16,
                                    num_kv_heads=16, d_ff=4096, vocab_size=256206),
        "internlm2-20b": dict(num_layers=48, d_model=6144, num_heads=48,
                              num_kv_heads=8, d_ff=16384, vocab_size=92544),
        "qwen3-32b": dict(num_layers=64, d_model=5120, num_heads=64,
                          num_kv_heads=8, d_ff=25600, vocab_size=151936),
    }
    for arch, want in specs.items():
        cfg = get_config(arch)
        for k, v in want.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    # feature flags
    assert get_config("qwen3-32b").use_qk_norm
    assert get_config("qwen2-7b").use_qkv_bias
    assert get_config("qwen2-vl-2b").rope_style == "mrope"
    assert get_config("mixtral-8x7b").sliding_window == 4096
    assert get_config("llama4-scout-17b-a16e").shared_expert
    assert get_config("seamless-m4t-medium").encoder_layers == 12
    assert get_config("recurrentgemma-2b").hybrid_pattern == ("rec", "rec", "attn")
