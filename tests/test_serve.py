"""repro.serve — snapshots, admission, background training, replay."""
import os
import time

import numpy as np
import pytest

from repro.api import RunSpec, run
from repro.checkpoint import AsyncCheckpointer, restore_checkpoint
from repro.serve import (BackgroundTrainer, BurstyReplay, ServeConfig,
                         ServeService, ServeState, verify_snapshot)


def _spec(**kw):
    base = dict(nodes=4, dim=16, horizon=32, eps=1.0, alpha0=0.5, lam=0.01,
                stream="bursty")
    base.update(kw)
    return RunSpec(**base)


# -- runner on_chunk hook -----------------------------------------------------

def test_on_chunk_fires_at_every_boundary_with_live_state():
    spec = _spec()
    seen = []
    run(spec, chunk_rounds=8, warmup=False, compute_regret=False,
        on_chunk=lambda b, st, acc: seen.append((b, int(st.t))) and False)
    assert [b for b, _ in seen] == [8, 16, 24, 32]
    assert all(b == t for b, t in seen)     # state is synchronized to b


def test_on_chunk_truthy_stops_early_and_result_reflects_it():
    spec = _spec()
    res = run(spec, chunk_rounds=8, warmup=False, compute_regret=False,
              on_chunk=lambda b, st, acc: b >= 16)
    assert res.rounds == 16
    # the early-stopped state equals a fresh run to the same horizon
    ref = run(_spec(horizon=16), chunk_rounds=8, warmup=False,
              compute_regret=False)
    np.testing.assert_array_equal(np.asarray(res.final_w),
                                  np.asarray(ref.final_w))


# -- snapshots ----------------------------------------------------------------

@pytest.mark.parametrize("engine", ["sim", "dist"])
def test_published_snapshot_bit_identical_to_reference_run(engine):
    spec = _spec()
    state = ServeState(spec, engine=engine)
    state.publish_initial()
    tr = BackgroundTrainer(spec, state, engine=engine, chunk_rounds=8,
                           warmup=False)
    tr.run_blocking()
    snap = state.current
    # 1 initial (round 0) + 4 chunk-boundary publications
    assert snap.round == 32 and state.published == 5
    assert verify_snapshot(spec, engine, snap, chunk_rounds=8)
    # a corrupted snapshot must NOT verify
    bad = snap.__class__(version=snap.version, round=snap.round,
                         theta=snap.theta, w=np.asarray(snap.w) + 1e-3,
                         w_bar=snap.w_bar, eps_spent=snap.eps_spent)
    assert not verify_snapshot(spec, engine, bad, chunk_rounds=8)


def test_history_ring_prunes_to_keep():
    spec = _spec()
    state = ServeState(spec, keep=2)
    state.publish_initial()
    BackgroundTrainer(spec, state, chunk_rounds=8,
                      warmup=False).run_blocking()
    assert state.snapshot(4) is not None and state.snapshot(3) is not None
    assert state.snapshot(1) is None        # pruned


@pytest.mark.parametrize("engine", ["sim", "dist"])
def test_sparse_topology_trainer_snapshot_verifies_and_serves(engine):
    """repro.serve over a sparse-graph trainer (the node-shardable mixer):
    published snapshots verify bit-for-bit against the sparse reference run,
    and the batched predict path serves the trained per-node rows."""
    spec = _spec(nodes=10, mixer="sparse",
                 mixer_options={"topology": "ring"})
    state = ServeState(spec, engine=engine)
    state.publish_initial()
    BackgroundTrainer(spec, state, engine=engine, chunk_rounds=8,
                      warmup=False).run_blocking()
    snap = state.current
    assert snap.round == 32
    assert verify_snapshot(spec, engine, snap, chunk_rounds=8)
    # batched predict against the sparse-trained model: node rows, not w_bar
    feats = np.linspace(-1, 1, spec.dim * 6).reshape(6, spec.dim)
    nodes = np.asarray([0, 3, 9, 9, 1, 5])
    margins, labels, used = state.predict(feats, nodes)
    assert used.version == snap.version
    ref = (np.asarray(snap.w)[nodes] * feats).sum(axis=1)
    np.testing.assert_allclose(np.asarray(margins), ref, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(labels),
                                  np.where(np.asarray(margins) >= 0, 1, -1))


def test_verify_snapshot_atol_bounds_cross_layout_drift():
    """The new atol= mode: exact comparison still rejects perturbed models,
    while a reduction-order-sized bound accepts them (the contract a
    node-sharded snapshot relies on when replayed under another layout)."""
    spec = _spec(nodes=10, mixer="sparse",
                 mixer_options={"topology": "ring"})
    res = run(spec, chunk_rounds=8, warmup=False, compute_regret=False)
    from repro.serve.state import snapshot_from_state
    snap = snapshot_from_state(spec, "sim", res.final_state, version=1,
                               eps_spent=1.0)
    assert verify_snapshot(spec, "sim", snap, chunk_rounds=8)
    nudged = snap.__class__(version=1, round=snap.round, theta=snap.theta,
                            w=np.asarray(snap.w) + 1e-7,
                            w_bar=np.asarray(snap.w_bar) + 1e-7,
                            eps_spent=snap.eps_spent)
    assert not verify_snapshot(spec, "sim", nudged, chunk_rounds=8)
    assert verify_snapshot(spec, "sim", nudged, chunk_rounds=8, atol=2e-6)
    # a genuinely wrong model fails even the bounded check
    bad = snap.__class__(version=1, round=snap.round, theta=snap.theta,
                         w=np.asarray(snap.w) + 1e-3, w_bar=snap.w_bar,
                         eps_spent=snap.eps_spent)
    assert not verify_snapshot(spec, "sim", bad, chunk_rounds=8, atol=2e-6)


# -- admission / batching -----------------------------------------------------

def test_service_predict_matches_direct_predict_despite_padding():
    spec = _spec()
    svc = ServeService(ServeConfig(spec=spec, train=False, warmup=False,
                                   max_batch=8, max_wait_ms=0.2)).start()
    try:
        feats = np.linspace(-1, 1, spec.dim).astype(np.float32)
        req = svc.predict(feats, node=2, timeout=30.0)
        assert req.status == "ok" and req.snapshot_round == 0
        snap = svc.state.current
        direct_feats = np.zeros((8, spec.dim), np.float32)
        direct_feats[0] = feats
        nodes = np.zeros((8,), np.int32)
        nodes[0] = 2
        margins, labels = svc.state.predict_fn(snap.w, snap.w_bar,
                                               direct_feats, nodes)
        assert float(np.asarray(margins)[0]) == req.margin
        assert float(np.asarray(labels)[0]) == req.label
        assert svc.verify(req)
    finally:
        svc.stop()


def test_full_queue_sheds_instead_of_blocking():
    spec = _spec()
    svc = ServeService(ServeConfig(spec=spec, train=False, warmup=False,
                                   queue_capacity=4, max_batch=2,
                                   max_wait_ms=0.1))
    # batcher NOT started: the queue can only fill
    svc.state.publish_initial()
    feats = [1.0] * spec.dim
    reqs = [svc.submit(feats, node=0) for _ in range(10)]
    shed = [r for r in reqs if r.status == "shed"]
    assert len(shed) == 6 and all(r.done() for r in shed)
    assert svc.stats()["admission"]["shed"] == 6


def test_sequential_budget_exhausts_and_refuses():
    spec = _spec(horizon=32)
    svc = ServeService(ServeConfig(spec=spec, chunk_rounds=4,
                                   composition="sequential", eps_budget=10.0,
                                   max_batch=2, max_wait_ms=0.2,
                                   warmup=False)).start()
    try:
        deadline = time.time() + 120
        while not svc.exhausted() and time.time() < deadline:
            time.sleep(0.01)
        assert svc.exhausted()
        # budget 10.0 at eps=1.0/round: rounds 4 and 8 publish, 12 would
        # overspend — training stops at 8 and the snapshot stays there
        assert svc.state.current.round == 8
        assert svc.eps_spent() <= 10.0
        req = svc.submit([1.0] * spec.dim, node=0).wait(30.0)
        assert req.status == "refused"
        assert svc.stats()["admission"]["refused"] >= 1
    finally:
        svc.stop()


def test_parallel_composition_never_exhausts_on_disjoint_stream():
    spec = _spec(horizon=32)
    svc = ServeService(ServeConfig(spec=spec, chunk_rounds=8,
                                   composition="parallel", eps_budget=10.0,
                                   warmup=False)).start()
    try:
        deadline = time.time() + 120
        while svc.state.current.round < 32 and time.time() < deadline:
            time.sleep(0.01)
        assert svc.state.current.round == 32
        assert not svc.exhausted()
        assert svc.eps_spent() == pytest.approx(spec.eps)   # Theorem 1: flat
    finally:
        svc.stop()


# -- end to end ---------------------------------------------------------------

def test_replay_end_to_end_serves_while_training(tmp_path):
    spec = _spec(horizon=48)
    svc = ServeService(ServeConfig(spec=spec, chunk_rounds=8, max_batch=8,
                                   max_wait_ms=0.5, queue_capacity=64,
                                   checkpoint_dir=str(tmp_path),
                                   keep_snapshots=16, warmup=False)).start()
    replay = BurstyReplay(spec.resolve_stream())
    out = replay.drive(svc, 0, 32, timeout_s=120.0)
    svc.stop()
    assert out["submitted"] == replay.total_requests(0, 32)
    assert out["served"] > 0 and out["qps"] > 0
    assert out["served"] + out["shed"] + out["refused"] == out["submitted"]
    # served responses carry a published snapshot and verify bitwise
    served = [r for r in out["requests"] if r.status == "ok"]
    sample = max(served, key=lambda r: r.snapshot_version)
    assert sample.staleness_rounds is not None
    assert sample.staleness_rounds >= 0
    assert svc.verify(sample)
    # async checkpoints of published snapshots landed on disk
    rounds = sorted(int(f.split("_")[-1].split(".")[0])
                    for f in os.listdir(tmp_path) if f.endswith(".npz"))
    assert rounds and set(rounds) <= {8, 16, 24, 32, 40, 48}
    snap = svc.state.snapshot(sample.snapshot_version)
    restored = restore_checkpoint(str(tmp_path),
                                  {"theta": np.zeros_like(snap.w)},
                                  step=snap.round)
    np.testing.assert_array_equal(np.asarray(restored["theta"]),
                                  np.asarray(snap.theta))


def test_replay_derives_load_from_stream_counts():
    spec = _spec(horizon=16)
    stream = spec.resolve_stream()
    replay = BurstyReplay(stream)
    counts = np.asarray(stream.counts(0, 16))
    ticks = list(replay.ticks(0, 16))
    assert [len(t) for t in ticks] == counts.sum(axis=1).tolist()
    with pytest.raises(ValueError):
        BurstyReplay(object())


# -- async checkpointing ------------------------------------------------------

def test_async_checkpointer_roundtrip_and_error_surfacing(tmp_path):
    import jax.numpy as jnp
    good = tmp_path / "good"
    with AsyncCheckpointer(str(good)) as ck:
        for step in (1, 2, 3):
            ck.save(step, {"w": jnp.full((3,), float(step))})
        ck.wait()
    out = restore_checkpoint(str(good), {"w": jnp.zeros((3,))}, step=2)
    np.testing.assert_array_equal(np.asarray(out["w"]), [2.0, 2.0, 2.0])
    # a failing write surfaces on the NEXT call, not silently
    bad_parent = tmp_path / "not_a_dir"
    bad_parent.write_text("file, not dir")
    ck = AsyncCheckpointer(str(bad_parent / "sub"))
    ck.save(1, {"w": jnp.zeros((2,))})
    with pytest.raises(RuntimeError, match="async checkpoint"):
        ck.wait()
    with pytest.raises(RuntimeError, match="closed"):
        ck.close() or ck.save(2, {"w": jnp.zeros((2,))})
