"""repro.obs: spans, metrics registry, event streams, cost loop, and the
telemetry-off bit-identity contract across the runner / sweep / serve stack.
"""
import json
import os
import threading

import numpy as np
import pytest

import repro.obs as obs
from repro.api import RunSpec, run, run_batch
from repro.launch.obs import main as obs_main
from repro.launch.obs import summarize_events
from repro.obs import (EventLog, MetricsRegistry, Telemetry, Tracer,
                       group_runs, read_events)
from repro.obs.cost import CostModel, analyze_chunk

FIELDS = ("final_w", "loss", "correct", "w_bar_loss", "sparsity",
          "eps_ledger")


def _spec(horizon=8, **kw):
    base = dict(nodes=2, dim=8, horizon=horizon, eps=1.0, alpha0=0.5,
                lam=0.01, stream="drift", stream_options={"period": 3})
    base.update(kw)
    return RunSpec(**base)


@pytest.fixture(autouse=True)
def _ambient_off():
    """Every test starts and ends with the ambient default (disabled)."""
    obs.disable()
    yield
    obs.disable()


# -- tracer ------------------------------------------------------------------

def test_span_nesting_records_parent_and_depth():
    tr = Tracer()
    with tr.span("outer"):
        with tr.span("inner", k=1):
            pass
    inner, outer = tr.spans
    assert (inner.name, inner.parent, inner.depth) == ("inner", "outer", 1)
    assert (outer.name, outer.parent, outer.depth) == ("outer", None, 0)
    assert inner.args == {"k": 1}
    assert outer.duration_s >= inner.duration_s >= 0.0


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("never") as sp:
        pass
    assert tr.spans == [] and sp.duration_s == 0.0


def test_tracer_thread_stacks_are_independent():
    tr = Tracer()
    # barrier keeps all workers alive at once — thread idents are reused
    # after exit, and the distinct-thread assertion needs real overlap
    gate = threading.Barrier(4)

    def worker(name):
        with tr.span(name):
            gate.wait(timeout=10)

    threads = [threading.Thread(target=worker, args=(f"t{i}",))
               for i in range(4)]
    with tr.span("main"):
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    by_name = {s.name: s for s in tr.spans}
    # worker spans ran inside the main span's wall-time but on other
    # threads, so they must NOT pick up "main" as a parent
    assert all(by_name[f"t{i}"].parent is None for i in range(4))
    assert len({s.thread for s in tr.spans}) == 5


def test_tracer_max_spans_drops_not_grows():
    tr = Tracer(max_spans=2)
    for i in range(5):
        with tr.span(f"s{i}"):
            pass
    assert len(tr.spans) == 2 and tr.dropped == 3


def test_chrome_export_shape(tmp_path):
    tr = Tracer()
    with tr.span("phase", engine="sim"):
        pass
    path = tr.export_chrome(str(tmp_path / "trace.json"))
    payload = json.load(open(path))
    events = payload["traceEvents"]
    metas = [e for e in events if e["ph"] == "M"]
    xs = [e for e in events if e["ph"] == "X"]
    assert len(metas) == 1 and len(xs) == 1
    assert xs[0]["name"] == "phase" and xs[0]["args"]["engine"] == "sim"
    assert xs[0]["dur"] >= 0


# -- metrics registry --------------------------------------------------------

def test_registry_get_or_create_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("a").inc(2)
    reg.counter("a").inc(3)
    reg.gauge("g").set(1.5)
    for v in (0.1, 0.2):
        reg.histogram("h").observe(v)
    snap = reg.snapshot()
    assert snap["a"] == 5 and snap["g"] == 1.5
    assert snap["h"]["count"] == 2 and abs(snap["h"]["mean"] - 0.15) < 1e-12
    assert reg.names() == ["a", "g", "h"]


def test_registry_type_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError, match="already a Counter"):
        reg.gauge("x")


def test_counter_rejects_negative():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.counter("c").inc(-1)


def test_counter_concurrent_increments_lose_nothing():
    reg = MetricsRegistry()
    c = reg.counter("n")

    def worker():
        for _ in range(1000):
            c.inc()

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 8000


def test_histogram_reservoir_caps_samples():
    reg = MetricsRegistry()
    h = reg.histogram("h", max_samples=10)
    for i in range(100):
        h.observe(float(i))
    assert h.count == 100           # exact count survives the cap
    assert len(h._samples) == 10
    assert h.summary()["max"] == 99.0


# -- event streams -----------------------------------------------------------

def test_event_log_roundtrip_and_grouping(tmp_path):
    path = str(tmp_path / "events.jsonl")
    log = EventLog(path)
    log.emit("run_start", run_id="r1", engine="sim")
    log.emit("chunk", run_id="r1", round_end=4)
    log.emit("publish", round=4)            # no run_id
    log.close()
    events = read_events(path)
    assert [e["event"] for e in events] == ["run_start", "chunk", "publish"]
    runs = group_runs(events)
    assert len(runs["r1"]) == 2 and len(runs[""]) == 1


def test_read_events_tolerates_torn_tail(tmp_path):
    path = str(tmp_path / "events.jsonl")
    with open(path, "w") as f:
        f.write('{"ts": 1, "event": "a"}\n{"ts": 2, "ev')
    assert [e["event"] for e in read_events(path)] == ["a"]


def test_read_events_raises_on_mid_file_corruption(tmp_path):
    path = str(tmp_path / "events.jsonl")
    with open(path, "w") as f:
        f.write('{"ts": 1, "ev\n{"ts": 2, "event": "b"}\n')
    with pytest.raises(json.JSONDecodeError):
        read_events(path)


# -- Telemetry / ambient -----------------------------------------------------

def test_ambient_default_disabled_and_enable_disable():
    assert obs.active().enabled is False
    tel = obs.enable()
    assert obs.active() is tel and tel.enabled
    obs.disable()
    assert obs.active().enabled is False


def test_disabled_telemetry_is_inert(tmp_path):
    tel = Telemetry(enabled=False, events=str(tmp_path / "e.jsonl"),
                    cost=True)
    with tel.span("x"):
        tel.emit("never")
    assert tel.events is None and tel.cost_enabled is False
    assert tel.tracer.spans == []
    assert not os.path.exists(tmp_path / "e.jsonl")


# -- cost loop ---------------------------------------------------------------

def test_analyze_chunk_predicts_from_hlo():
    import jax
    import jax.numpy as jnp
    fn = jax.jit(lambda x: x @ x)
    x = jnp.ones((32, 32), jnp.float32)
    model = CostModel(peak_flops=1e12, peak_bandwidth=1e11)
    cc = analyze_chunk(fn, x, model=model)
    assert cc.cost.flops >= 2 * 32 ** 3
    assert cc.predicted_s == model.predict_seconds(cc.cost) > 0
    cc.record(cc.predicted_s)               # measured == predicted
    assert abs(cc.summary()["error_ratio"] - 1.0) < 1e-9
    assert cc.summary()["measured_chunks"] == 1


# -- runner integration ------------------------------------------------------

def _assert_identical(a, b):
    for f in FIELDS:
        assert np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))), f


@pytest.mark.parametrize("engine", ["sim", "dist"])
def test_run_bit_identical_with_telemetry(engine, tmp_path):
    spec = _spec()
    off = run(spec, engine=engine, chunk_rounds=4, warmup=False)
    tel = Telemetry(events=str(tmp_path / "e.jsonl"), cost=True)
    on = run(spec, engine=engine, chunk_rounds=4, warmup=False, obs=tel)
    tel.close()
    _assert_identical(off, on)
    info = on.metrics["obs"]
    assert len(info["run_id"]) == 8
    cost = info["cost"]
    assert cost["measured_chunks"] == 2 and cost["predicted_s"] > 0
    assert cost["error_ratio"] is not None
    kinds = [e["event"] for e in read_events(str(tmp_path / "e.jsonl"))]
    assert kinds == ["run_start", "chunk", "chunk", "chunk_cost", "run_end"]
    assert tel.tracer.summary()["run.chunk"]["count"] == 2
    assert tel.metrics.snapshot()["run.rounds"] == 8
    assert "obs" not in off.metrics         # telemetry off leaves no trace


def test_run_batch_bit_identical_with_telemetry(tmp_path):
    spec = _spec()
    off = run_batch(spec, [0, 1], chunk_rounds=4, warmup=False)
    tel = Telemetry(events=str(tmp_path / "e.jsonl"), cost=True)
    on = run_batch(spec, [0, 1], chunk_rounds=4, warmup=False, obs=tel)
    tel.close()
    for o, n in zip(off, on):
        _assert_identical(o, n)
    # one run_id shared by the whole batch
    ids = {r.metrics["obs"]["run_id"] for r in on}
    assert len(ids) == 1
    events = read_events(str(tmp_path / "e.jsonl"))
    starts = [e for e in events if e["event"] == "run_start"]
    assert starts[0]["kind"] == "run_batch" and starts[0]["seeds"] == [0, 1]
    assert tel.metrics.snapshot()["run_batch.rounds"] == 8


def test_run_checkpoint_events_and_span(tmp_path):
    spec = _spec()
    tel = Telemetry(events=str(tmp_path / "e.jsonl"))
    run(spec, chunk_rounds=4, warmup=False, checkpoint_every=4,
        checkpoint_dir=str(tmp_path / "ckpt"), obs=tel)
    tel.close()
    kinds = [e["event"] for e in read_events(str(tmp_path / "e.jsonl"))]
    assert kinds.count("checkpoint") == 2
    assert tel.tracer.summary()["run.checkpoint"]["count"] == 2


def test_ambient_telemetry_reaches_run():
    tel = obs.enable()
    res = run(_spec(), chunk_rounds=4, warmup=False)
    assert res.metrics["obs"]["run_id"]
    assert tel.metrics.snapshot()["run.rounds"] == 8


# -- sweep integration -------------------------------------------------------

def test_sweep_emits_point_spans_and_events(tmp_path):
    from repro.sweep import SweepSpec, sweep
    tel = obs.enable(events=str(tmp_path / "e.jsonl"))
    sw = SweepSpec(base=_spec(horizon=6), axes={"eps": (0.5, 1.0)},
                   seeds=(0,), name="obs_demo", chunk_rounds=6,
                   compute_regret=False)
    sweep(sw, store=str(tmp_path / "store"), warmup=False)
    assert tel.tracer.summary()["sweep.point"]["count"] == 2
    assert tel.metrics.snapshot()["sweep.points_ran"] == 2
    points = [e for e in read_events(str(tmp_path / "e.jsonl"))
              if e["event"] == "sweep_point"]
    assert len(points) == 2 and all(p["source"] == "ran" for p in points)


# -- serve integration -------------------------------------------------------

def test_serve_counters_spans_and_summary_event(tmp_path):
    from repro.serve import ServeConfig, ServeService
    tel = obs.enable(events=str(tmp_path / "e.jsonl"))
    spec = RunSpec(nodes=2, dim=8, horizon=8, eps=1.0, alpha0=0.5, lam=0.01,
                   stream="bursty")
    svc = ServeService(ServeConfig(spec=spec, chunk_rounds=4, max_batch=4,
                                   max_wait_ms=0.5, warmup=False)).start()
    r = svc.predict([1.0] * 8, node=0, timeout=30.0)
    assert r.status == "ok"
    svc.stop()
    snap = tel.metrics.snapshot()
    assert snap["serve.served"] >= 1 and snap["serve.batches"] >= 1
    assert snap["serve.latency_s"]["count"] >= 1
    assert snap["serve.published"] >= 1
    assert tel.tracer.summary()["serve.batch"]["count"] >= 1
    assert tel.tracer.summary()["serve.publish"]["count"] >= 1
    events = read_events(str(tmp_path / "e.jsonl"))
    summaries = [e for e in events if e["event"] == "serve_summary"]
    assert len(summaries) == 1
    # the exit record carries the FULL admission summary, shed_reasons
    # included — the obs report CLI renders it after the service is gone
    adm = summaries[0]["admission"]
    assert adm["served"] >= 1 and "shed_reasons" in adm
    assert any(e["event"] == "publish" for e in events)


def test_serve_stats_summary_pins_shed_reasons():
    from repro.serve.admission import ServeStats
    stats = ServeStats()
    stats.record_shed(reason="full")
    stats.record_shed(2, reason="timeout")
    out = stats.summary()
    assert out["shed_reasons"] == {"full": 1, "timeout": 2}
    assert out["shed"] == 3


def test_shed_reasons_mirror_into_registry():
    from repro.serve.admission import ServeStats
    tel = obs.enable()
    stats = ServeStats()
    stats.record_shed(reason="timeout")
    stats.record_refused(2)
    snap = tel.metrics.snapshot()
    assert snap["serve.shed.timeout"] == 1 and snap["serve.refused"] == 2


# -- report CLI --------------------------------------------------------------

def test_report_cli_text_and_json(tmp_path, capsys):
    path = str(tmp_path / "e.jsonl")
    tel = Telemetry(events=path, cost=True)
    run(_spec(), chunk_rounds=4, warmup=False, obs=tel)
    tel.close()
    rid = next(iter(summarize_events(path)["runs"]))

    assert obs_main(["report", "--events", path]) == 0
    text = capsys.readouterr().out
    assert f"run {rid}" in text and "cost: predicted" in text

    assert obs_main(["report", "--events", path, "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["runs"][rid]["chunks"] == 2
    assert payload["runs"][rid]["cost"]["error_ratio"] is not None

    assert obs_main(["report", "--events", path, "--run", rid]) == 0
    capsys.readouterr()
    assert obs_main(["report", "--events", path, "--run", "nope"]) == 1


def test_report_cli_missing_stream(tmp_path, capsys):
    assert obs_main(["report", "--events",
                     str(tmp_path / "absent.jsonl")]) == 1
    assert "no events" in capsys.readouterr().out
