"""The paper's OMD+Lasso vs its cited baselines (truncated gradient, RDA)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import RunSpec
from repro.data.social import SocialStream


def _run(method, lam, T=300, m=8, n=128, gamma=1.0):
    s = SocialStream(n=n, nodes=m, rounds=T, sparsity_true=0.1, seed=2)
    xs, ys = s.chunk(0, T)
    alg = RunSpec(
        nodes=m, dim=n, mixer="ring", mechanism="laplace", eps=math.inf,
        clip_norm=1.0, calibration="global", alpha0=1.0, schedule="sqrt_t",
        lam=lam, local_rule=method,
        local_rule_options={"gamma": gamma} if method == "rda" else {},
    ).build_simulator()
    return alg.run(jax.random.PRNGKey(0), xs, ys)


def test_all_methods_learn():
    for method, lam in (("omd", 0.3), ("tg", 0.003), ("rda", 0.002)):
        outs = _run(method, lam)
        acc = float(outs.correct[-80:].mean())
        assert acc > 0.7, (method, acc)


def test_rda_produces_sparsity():
    outs = _run("rda", 0.005)
    assert float(outs.sparsity[-1]) > 0.2


def test_tg_truncation_sparsifies_vs_no_reg():
    dense = _run("tg", 0.0)
    sparse = _run("tg", 0.01)
    assert float(sparse.sparsity[-1]) > float(dense.sparsity[-1])


def test_unknown_method_rejected():
    with pytest.raises(ValueError):
        RunSpec(nodes=4, dim=8, local_rule="nope").build_simulator()
