"""Node-axis sharding — shard_map over a ("node",) / ("seed","node") mesh.

Multi-device equivalence runs in subprocesses with 8 fake CPU devices
(XLA_FLAGS, same harness as tests/test_shard_seed.py). The contract:

  * node-sharded runs match dense `run()` within an ASSERTED float32
    reduction-order bound — Laplace noise on, delay in {0, 2}, both
    engines, m=10 on 4 devices (so the pad-to-12 rule is always live);
  * the sharded program is engine-agnostic: sim and dist sharded runs are
    BIT-identical to each other, and a sharded run re-executed under the
    same device count is bit-identical (determinism / resume anchor);
  * checkpoints cross device counts: 4 -> 1 and 1 -> 4;
  * the ("seed","node") grid matches per-seed sequential runs;
  * a node-sharded snapshot serves: verify_snapshot + batched predict.

In-process tests cover the 1-device fallback, the error surfaces, the
mixer-to-sparse-graph lowering and the edge partitioner.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import RunSpec, run
from repro.api.mixers import (MIXERS, DelayedMixer, RingRollMixer,
                              SparseMixer)
from repro.api.shard_node import (partition_graph, resolve_node_mesh,
                                  sparse_graph_and_delay)
from repro.core.graph import SparseGraph, ring_edges
from repro.launch.mesh import make_mesh, node_mesh, seed_node_mesh

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_PRELUDE = r"""
import numpy as np
from repro.api import RunSpec, run
from repro.api.runner import run_batch

ATOL = 5e-6      # float32 reduction-order bound, asserted on every field
FIELDS = ("final_w", "loss", "correct", "w_bar_loss", "sparsity")


def spec(**kw):
    base = dict(nodes=10, dim=8, horizon=14, eps=1.0, alpha0=0.5, lam=0.01,
                stream="drift", stream_options={"period": 7},
                mixer="sparse", mixer_options={"topology": "ring"})
    base.update(kw)
    return RunSpec(**base)


def assert_close(a, b, what, atol=ATOL):
    for f in FIELDS:
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        d = np.abs(x - y).max()
        assert d <= atol, f"{what}: field {f} off by {d} (> {atol})"


def assert_identical(a, b, what):
    for f in FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{what}: field {f} diverged")
"""


def _run(code: str, timeout=520) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run([sys.executable, "-c", _PRELUDE + code],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# -- multi-device equivalence (subprocesses, 8 fake devices) -----------------

@pytest.mark.slow
def test_node_sharded_matches_dense_and_engines_agree():
    """node_devices=4, m=10 (pads to 12): within the asserted bound of the
    dense run for both engines x delay {0, 2}, noise on — and the sharded
    sim/dist runs are BIT-identical to each other (shared round body)."""
    out = _run(r"""
import jax
assert jax.local_device_count() == 8
for delay in (0, 2):
    sharded = {}
    for engine in ("sim", "dist"):
        dense = run(spec(mixer="ring", mixer_options={}, delay=delay),
                    engine=engine, chunk_rounds=7, warmup=False,
                    compute_regret=False)
        sh = run(spec(delay=delay), engine=engine, chunk_rounds=7,
                 warmup=False, compute_regret=False, node_devices=4)
        assert_close(sh, dense, f"{engine}/delay={delay} sharded vs dense")
        np.testing.assert_array_equal(dense.eps_ledger, sh.eps_ledger)
        sharded[engine] = sh
        print(engine, delay, "OK")
    assert_identical(sharded["sim"], sharded["dist"],
                     f"delay={delay} sharded sim vs dist")
""")
    assert out.count("OK") == 4


@pytest.mark.slow
def test_node_sharded_deterministic_and_padding_exact():
    """Re-running under the same node count is bit-identical; m=8 on 8
    devices (block=1, no padding) and m=10 on 8 (pad 10->16) both hold the
    dense bound."""
    out = _run(r"""
a = run(spec(), chunk_rounds=7, warmup=False, compute_regret=False,
        node_devices=4)
b = run(spec(), chunk_rounds=7, warmup=False, compute_regret=False,
        node_devices=4)
assert_identical(a, b, "same-layout determinism")
for m in (8, 10):
    dense = run(spec(nodes=m, mixer="ring", mixer_options={}),
                chunk_rounds=7, warmup=False, compute_regret=False)
    sh = run(spec(nodes=m), chunk_rounds=7, warmup=False,
             compute_regret=False, node_devices=8)
    assert sh.final_w.shape == (m, 8)
    assert_close(sh, dense, f"m={m} on 8 devices")
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_checkpoint_crosses_node_device_counts():
    """Save under node_devices=4, resume under 1 (and 1 -> 4): state crossing
    the chunk boundary is global and unpadded, so resume continues exactly;
    same-layout save/resume is bit-identical to the uninterrupted run."""
    out = _run(r"""
import tempfile
sp = spec(delay=1, horizon=12)
full_sharded = run(sp, chunk_rounds=6, warmup=False, compute_regret=False,
                   node_devices=4)
full_dense = run(sp.replace(mixer="ring", mixer_options={}), chunk_rounds=6,
                 warmup=False, compute_regret=False)
# 4 devices -> 4 devices: bit-identical to the uninterrupted sharded run
ck = tempfile.mkdtemp()
run(sp, chunk_rounds=6, warmup=False, compute_regret=False, horizon=6,
    checkpoint_every=6, checkpoint_dir=ck, node_devices=4)
same = run(sp, chunk_rounds=6, warmup=False, compute_regret=False,
           checkpoint_dir=ck, resume=True, node_devices=4)
assert same.start_round == 6
np.testing.assert_array_equal(full_sharded.final_w, same.final_w)
# 4 devices -> 1 device (unsharded sparse): stays within the dense bound
down = run(sp, chunk_rounds=6, warmup=False, compute_regret=False,
           checkpoint_dir=ck, resume=True)
assert down.start_round == 6
assert np.abs(down.final_w - full_dense.final_w).max() <= ATOL
# 1 device -> 4 devices
ck2 = tempfile.mkdtemp()
run(sp, chunk_rounds=6, warmup=False, compute_regret=False, horizon=6,
    checkpoint_every=6, checkpoint_dir=ck2)
up = run(sp, chunk_rounds=6, warmup=False, compute_regret=False,
         checkpoint_dir=ck2, resume=True, node_devices=4)
assert up.start_round == 6
assert np.abs(up.final_w - full_dense.final_w).max() <= ATOL
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_seed_node_grid_matches_sequential():
    """run_batch over the ("seed","node") grid (2 x 4 devices): every seed
    within the bound of its sequential run(); the grid result is
    bit-identical across seed-device counts (1x4 vs 2x4) because node
    reduction order is fixed by the node count alone."""
    out = _run(r"""
seeds = [0, 1, 2]
grid = run_batch(spec(), seeds, chunk_rounds=7, warmup=False,
                 compute_regret=False, devices=2, node_devices=4)
assert grid[0].metrics["batch"]["devices"] == 2
narrow = run_batch(spec(), seeds, chunk_rounds=7, warmup=False,
                   compute_regret=False, node_devices=4)
for s, g, nv in zip(seeds, grid, narrow):
    seq = run(spec().replace(seed=s), chunk_rounds=7, warmup=False,
              compute_regret=False)
    assert_close(g, seq, f"grid seed={s} vs sequential")
    assert_identical(g, nv, f"seed={s}: 2x4 vs 1x4 grid")
# delay + dist engine over the grid
for r, s in zip(run_batch(spec(delay=2), seeds, engine="dist",
                          chunk_rounds=7, warmup=False,
                          compute_regret=False, devices=2, node_devices=4),
                seeds):
    seq = run(spec(delay=2).replace(seed=s), engine="dist", chunk_rounds=7,
              warmup=False, compute_regret=False)
    assert_close(r, seq, f"dist/delay=2 grid seed={s}")
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_node_sharded_snapshot_serves():
    """repro.serve on a node-sharded trainer: verify_snapshot replays the
    sharded layout bit-identically (and bounds the dense cross-check), and
    the batched predict path serves the sharded model's rows."""
    out = _run(r"""
import jax.numpy as jnp
from repro.serve.state import (make_predict_fn, snapshot_from_state,
                               verify_snapshot)
sp = spec()
res = run(sp, chunk_rounds=7, warmup=False, compute_regret=False,
          node_devices=4)
snap = snapshot_from_state(sp, "sim", res.final_state, version=1,
                           eps_spent=1.0)
assert snap.round == 14
np.testing.assert_array_equal(snap.w, res.final_w)
assert verify_snapshot(sp, "sim", snap, node_devices=4)          # bit replay
assert verify_snapshot(sp, "sim", snap, atol=ATOL)               # dense bound
assert not verify_snapshot(sp, "sim", snap)                      # dense bits differ
predict = make_predict_fn("node")
feats = jnp.ones((5, sp.dim), jnp.float32)
nodes = jnp.array([0, 3, 9, 9, 1])
margins, labels = predict(snap.w, snap.w_bar, feats, nodes)
ref = np.asarray(res.final_w).sum(axis=1)[np.asarray(nodes)]
np.testing.assert_allclose(np.asarray(margins), ref, atol=1e-6)
assert set(np.asarray(labels)) <= {-1.0, 1.0}
print("OK")
""")
    assert "OK" in out


# -- 1-device behavior (in-process) ------------------------------------------

def _spec(**kw):
    base = dict(nodes=10, dim=8, horizon=10, eps=1.0, alpha0=0.5, lam=0.01,
                stream="drift", stream_options={"period": 7},
                mixer="sparse", mixer_options={"topology": "ring"})
    base.update(kw)
    return RunSpec(**base)


def test_node_mesh_single_device_fallback():
    import jax
    if jax.local_device_count() != 1:
        pytest.skip("needs the default 1-device test process")
    assert node_mesh(None) is None
    assert node_mesh(0) is None
    assert node_mesh(1) is None
    assert node_mesh("auto") is None
    assert seed_node_mesh(1, "auto") is None
    assert seed_node_mesh(1, 1) is None


def test_node_mesh_too_many_devices_errors():
    import jax
    want = jax.local_device_count() + 1
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        node_mesh(want)
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        seed_node_mesh(jax.local_device_count(), 2)


def test_run_node_devices_one_is_the_plain_path():
    plain = run(_spec(), chunk_rounds=5, warmup=False, compute_regret=False)
    fallback = run(_spec(), chunk_rounds=5, warmup=False,
                   compute_regret=False, node_devices=1)
    np.testing.assert_array_equal(plain.final_w, fallback.final_w)
    np.testing.assert_array_equal(np.asarray(plain.loss),
                                  np.asarray(fallback.loss))


def test_one_device_node_mesh_runs_the_sharded_program():
    """An explicit 1-device ("node",) mesh exercises shard_map + halo code
    in-process and stays within the bound of the unsharded sparse run."""
    sharded = run(_spec(), chunk_rounds=5, warmup=False,
                  compute_regret=False, node_mesh=make_mesh((1,), ("node",)))
    plain = run(_spec(), chunk_rounds=5, warmup=False, compute_regret=False)
    assert np.abs(sharded.final_w - plain.final_w).max() <= 5e-6
    assert np.abs(np.asarray(sharded.w_bar_loss)
                  - np.asarray(plain.w_bar_loss)).max() <= 5e-6


def test_resolve_node_mesh_error_surfaces():
    with pytest.raises(ValueError, match="'node' axis"):
        resolve_node_mesh(None, make_mesh((1,), ("seed",)))
    assert resolve_node_mesh(None, None) is None
    assert resolve_node_mesh(1, None) is None


def test_run_batch_rejects_node_mesh_without_seed_axis():
    from repro.api.runner import run_batch
    with pytest.raises(ValueError, match="seed"):
        run_batch(_spec(), (0, 1), mesh=make_mesh((1,), ("node",)),
                  chunk_rounds=5, warmup=False)


# -- mixer lowering / partitioner units --------------------------------------

def test_sparse_graph_and_delay_unwraps_mixers():
    g, d = sparse_graph_and_delay(SparseMixer(graph=ring_edges(6)))
    assert d == 0 and g.m == 6
    g, d = sparse_graph_and_delay(
        DelayedMixer(inner=SparseMixer(graph=ring_edges(6)), delay=3))
    assert d == 3 and g.m == 6
    # RingRollMixer lowers to its exact edge-list form
    g, d = sparse_graph_and_delay(RingRollMixer(m=8, self_weight=0.3))
    from repro.core.graph import ring_matrix
    np.testing.assert_array_equal(g.to_dense(), ring_matrix(8, 0.3))
    # fixed dense single-matrix stacks convert; schedules refuse
    g, _ = sparse_graph_and_delay(MIXERS.build("hypercube", m=8))
    assert g.edges > 0
    with pytest.raises(ValueError, match="time-varying"):
        sparse_graph_and_delay(MIXERS.build("time_varying", m=8))
    with pytest.raises(ValueError, match="node-sharded"):
        sparse_graph_and_delay(MIXERS.build("het_delayed", m=8, delay=2))
    with pytest.raises(ValueError, match="node-sharded"):
        sparse_graph_and_delay(MIXERS.build("disconnected", m=8))


@pytest.mark.parametrize("devices", [1, 2, 3, 4])
def test_partition_reassembles_to_the_dense_matrix(devices):
    g = SparseGraph.make("ring", 10)
    part = partition_graph(g, devices)
    assert part.block * devices == part.m_pad >= 10
    A = np.zeros((part.m_pad, part.m_pad), np.float32)
    for o, dl, sl, ww in part.offsets:
        for d in range(devices):
            s = (d + o) % devices
            np.add.at(A, (dl[d] + d * part.block, sl[d] + s * part.block),
                      ww[d])
    np.testing.assert_array_equal(A[:10, :10], g.to_dense())
    assert np.all(A[10:] == 0) and np.all(A[:, 10:] == 0)
    np.testing.assert_array_equal(part.diag_blocks.ravel()[:10], g.diag())


def test_partition_rejects_zero_devices():
    with pytest.raises(ValueError, match="devices"):
        partition_graph(ring_edges(4), 0)
