import numpy as np
import pytest

from repro.core.graph import (
    GossipGraph, assert_doubly_stochastic, complete_matrix, disconnected_matrix,
    hypercube_matrix, metropolis_hastings, random_regular_matrix, ring_matrix,
    ring_neighbor_weights, spectral_gap, time_varying_schedule, torus_matrix,
)


@pytest.mark.parametrize("m", [1, 2, 3, 8, 64])
def test_ring_doubly_stochastic(m):
    assert_doubly_stochastic(ring_matrix(m))


@pytest.mark.parametrize("m", [2, 4, 16, 64])
def test_hypercube_doubly_stochastic(m):
    assert_doubly_stochastic(hypercube_matrix(m))


def test_hypercube_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        hypercube_matrix(6)


@pytest.mark.parametrize("rows,cols", [(2, 2), (4, 4), (2, 8), (8, 8)])
def test_torus_doubly_stochastic(rows, cols):
    assert_doubly_stochastic(torus_matrix(rows, cols))


@pytest.mark.parametrize("m", [4, 8, 64])
def test_random_regular_doubly_stochastic(m):
    assert_doubly_stochastic(random_regular_matrix(m, seed=1))


def test_complete_and_disconnected():
    assert_doubly_stochastic(complete_matrix(7))
    assert_doubly_stochastic(disconnected_matrix(7))
    assert spectral_gap(complete_matrix(7)) > 0.99
    assert spectral_gap(disconnected_matrix(7)) < 1e-9


def test_time_varying_all_doubly_stochastic():
    for A in time_varying_schedule(8):
        assert_doubly_stochastic(A)
    for A in time_varying_schedule(8, kind="random_matching", seed=3):
        assert_doubly_stochastic(A)


@pytest.mark.parametrize("m,sw", [
    (2, 0.1), (2, 0.9), (3, 0.5), (5, 0.25), (8, 0.33), (13, 0.8),
    (17, 0.1), (24, 0.66), (32, 0.5), (32, 0.9),
])
def test_ring_property(m, sw):
    A = ring_matrix(m, self_weight=sw)
    assert_doubly_stochastic(A)
    # mixing preserves the mean of any vector
    x = np.random.default_rng(0).normal(size=(m,))
    assert np.isclose((A @ x).mean(), x.mean(), atol=1e-6)


@pytest.mark.parametrize("m", [2, 3, 4, 6, 9, 12, 16, 19, 22, 24])
def test_metropolis_from_random_adjacency(m):
    rng = np.random.default_rng(m)
    adj = rng.uniform(size=(m, m)) < 0.4
    adj = np.triu(adj, 1)
    adj = adj | adj.T
    for i in range(m):  # ensure no isolated nodes
        adj[i, (i + 1) % m] = adj[(i + 1) % m, i] = True
    np.fill_diagonal(adj, False)
    assert_doubly_stochastic(metropolis_hastings(adj))


def test_gossip_graph_factory_and_spectral_ordering():
    ring = GossipGraph.make("ring", 16)
    comp = GossipGraph.make("complete", 16)
    assert ring.m == comp.m == 16
    # complete mixes faster than ring
    assert spectral_gap(comp.at(0)) > spectral_gap(ring.at(0))


def test_ring_neighbor_weights_match_matrix():
    w = ring_neighbor_weights(0.5)
    A = ring_matrix(8, 0.5)
    assert np.isclose(A[0, 0], w[0])
    assert np.isclose(A[0, 1], w[1])
    assert np.isclose(A[0, 7], w[-1])


# -- symmetry where the paper/model claims it ---------------------------------
# Every fixed undirected topology must produce A == A.T (gossip weights are
# assigned per undirected edge); the sparse edge-list form must agree.

@pytest.mark.parametrize("make,args", [
    (ring_matrix, (9,)), (ring_matrix, (2, 0.3)),
    (torus_matrix, (3, 4)), (hypercube_matrix, (16,)),
    (complete_matrix, (7,)), (disconnected_matrix, (5,)),
    (random_regular_matrix, (12, 3, 1)),
])
def test_fixed_generators_are_symmetric(make, args):
    A = make(*args)
    np.testing.assert_allclose(A, A.T, atol=1e-12)


def test_metropolis_is_symmetric():
    rng = np.random.default_rng(7)
    adj = rng.uniform(size=(10, 10)) < 0.4
    adj = np.triu(adj, 1)
    adj = adj | adj.T
    for i in range(10):
        adj[i, (i + 1) % 10] = adj[(i + 1) % 10, i] = True
    np.fill_diagonal(adj, False)
    A = metropolis_hastings(adj)
    np.testing.assert_allclose(A, A.T, atol=1e-12)


def test_time_varying_matchings_are_symmetric():
    for A in time_varying_schedule(8, kind="random_matching", seed=5):
        np.testing.assert_allclose(A, A.T, atol=1e-12)


@pytest.mark.parametrize("topology,m", [("ring", 11), ("torus", 16),
                                        ("hypercube", 8), ("random", 12),
                                        ("complete", 6)])
def test_sparse_form_symmetric_where_dense_is(topology, m):
    from repro.core.graph import SparseGraph
    g = SparseGraph.make(topology, m, seed=4)
    A = np.asarray(GossipGraph.make(topology, m, seed=4).at(0))
    assert g.is_symmetric(atol=1e-7) == bool(np.allclose(A, A.T, atol=1e-7))
    assert g.is_symmetric(atol=1e-7)
