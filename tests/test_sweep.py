"""repro.sweep — SweepSpec grids, the vmapped seed axis, the results store.

The load-bearing guarantees:
  * a vmapped seed batch is BIT-identical to per-seed sequential `run()`
    on both engines, noise on, including delay>0 (history ring) and
    checkpoint_every/resume;
  * RunResult survives the JSON record round-trip exactly;
  * the store regenerates sweep results without re-running (reuse), and
    never silently reuses records from a changed spec.
"""
import json
import math

import numpy as np
import pytest

from repro.api import RunSpec, run, run_batch, seed_vectorizable
from repro.api.runner import RunResult
from repro.sweep import (SweepSpec, SweepStore, aggregate_records,
                         record_key, spec_from_record, spec_record, sweep)

SEEDS = (0, 1, 2)


def _spec(**kw):
    base = dict(nodes=3, dim=16, horizon=30, eps=1.0, alpha0=0.5, lam=0.01,
                stream="drift", stream_options={"period": 7})
    base.update(kw)
    return RunSpec(**base)


def _assert_results_equal(a: RunResult, b: RunResult, regret: bool = True):
    fields = ["final_w", "loss", "w_bar_loss", "correct", "sparsity",
              "eps_ledger"] + (["regret"] if regret else [])
    for f in fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"field {f} diverged")
    assert a.accuracy == b.accuracy


# -- SweepSpec grid resolution ----------------------------------------------

def test_points_grid_order():
    sw = SweepSpec(base=_spec(), axes={"eps": (0.1, 1.0), "lam": (0.0, 0.5)})
    assert [p.coords for p in sw.points()] == [
        {"eps": 0.1, "lam": 0.0}, {"eps": 0.1, "lam": 0.5},
        {"eps": 1.0, "lam": 0.0}, {"eps": 1.0, "lam": 0.5}]
    assert sw.points()[1].spec.eps == 0.1 and sw.points()[1].spec.lam == 0.5
    assert sw.store_name == "sweep_eps-lam"


def test_points_zipped_axis_crosses_with_grid():
    sw = SweepSpec(base=_spec(),
                   axes={"nodes,horizon": ((2, 10), (4, 5)),
                         "eps": (0.5, 1.0)})
    coords = [p.coords for p in sw.points()]
    assert coords[0] == {"nodes": 2, "horizon": 10, "eps": 0.5}
    assert coords[3] == {"nodes": 4, "horizon": 5, "eps": 1.0}
    assert sw.points()[3].spec.nodes == 4 and sw.points()[3].spec.horizon == 5


def test_spec_validation():
    with pytest.raises(ValueError, match="unknown RunSpec field"):
        SweepSpec(base=_spec(), axes={"nope": (1,)})
    with pytest.raises(ValueError, match="SweepSpec.seeds"):
        SweepSpec(base=_spec(), axes={"seed": (0, 1)})
    with pytest.raises(ValueError, match="duplicate seeds"):
        SweepSpec(base=_spec(), seeds=(0, 0))
    with pytest.raises(ValueError, match="2-tuples"):
        SweepSpec(base=_spec(), axes={"nodes,horizon": (4,)})
    with pytest.raises(ValueError, match="no values"):
        SweepSpec(base=_spec(), axes={"eps": ()})


# -- RunResult JSON round-trip ----------------------------------------------

@pytest.mark.parametrize("engine,delay", [("sim", 0), ("sim", 2), ("dist", 2)])
def test_runresult_record_round_trip_exact(engine, delay):
    res = run(_spec(delay=delay), engine=engine, chunk_rounds=15,
              warmup=False)
    rec = json.loads(json.dumps(res.to_record(include_state=True)))
    back = RunResult.from_record(rec)
    _assert_results_equal(res, back)
    assert back.rounds == res.rounds and back.engine == engine
    assert back.privacy == res.privacy
    # the engine state (incl. the delay history ring) survives exactly
    orig, rest = res.final_state, back.final_state
    assert type(rest).__name__ == type(orig).__name__
    np.testing.assert_array_equal(np.asarray(orig.t), np.asarray(rest.t))
    np.testing.assert_array_equal(np.asarray(orig.key), np.asarray(rest.key))
    if engine == "sim":
        np.testing.assert_array_equal(np.asarray(orig.theta),
                                      np.asarray(rest.theta))
    else:
        np.testing.assert_array_equal(np.asarray(orig.theta["w"]),
                                      np.asarray(rest.theta["w"]))
    if delay:
        h_orig = (orig.history if engine == "sim" else orig.history["w"])
        h_back = (rest.history if engine == "sim" else rest.history["w"])
        np.testing.assert_array_equal(np.asarray(h_orig), np.asarray(h_back))


def test_record_handles_inf_eps():
    res = run(_spec(eps=math.inf), engine="sim", chunk_rounds=30,
              warmup=False, compute_regret=False)
    back = RunResult.from_record(json.loads(json.dumps(res.to_record())))
    assert math.isinf(back.privacy["eps_per_round"])
    np.testing.assert_array_equal(back.eps_ledger, res.eps_ledger)


# -- seed-vmap equivalence (the acceptance contract) -------------------------

@pytest.mark.parametrize("engine", ["sim", "dist"])
@pytest.mark.parametrize("delay", [0, 2])
def test_seed_vmap_bit_identical(engine, delay):
    """A vmapped seed batch matches per-seed sequential run() bit-for-bit
    on both engines, Laplace noise ON, including under delay>0 (ring)."""
    spec = _spec(delay=delay)
    batch = run_batch(spec, SEEDS, engine=engine, chunk_rounds=13,
                      warmup=False)
    for s, vec in zip(SEEDS, batch):
        seq = run(spec.replace(seed=s), engine=engine, chunk_rounds=13,
                  warmup=False)
        _assert_results_equal(seq, vec)


def test_seed_vmap_checkpoint_resume_bit_identical(tmp_path):
    """A batch that checkpoints and resumes mid-horizon continues exactly
    where the uninterrupted batch (and the sequential runs) would be."""
    spec = _spec(delay=1, horizon=24)
    full = run_batch(spec, SEEDS, chunk_rounds=6, warmup=False)
    ck = str(tmp_path / "ck")
    first = run_batch(spec, SEEDS, chunk_rounds=6, warmup=False,
                      checkpoint_every=12, checkpoint_dir=ck, horizon=12)
    resumed = run_batch(spec, SEEDS, chunk_rounds=6, warmup=False,
                        checkpoint_dir=ck, resume=True,
                        compute_regret=False)
    assert resumed[0].start_round == 12
    for f, r in zip(full, resumed):
        np.testing.assert_array_equal(f.final_w, r.final_w)
        np.testing.assert_array_equal(np.asarray(f.correct)[12:],
                                      np.asarray(r.correct))
    seq = run(spec.replace(seed=SEEDS[1]), chunk_rounds=24, warmup=False)
    np.testing.assert_array_equal(seq.final_w, resumed[1].final_w)
    assert first[0].rounds == 12


def test_batch_resume_when_already_complete(tmp_path):
    """Resuming a batch whose checkpoint is already at the horizon returns
    gracefully (empty trajectories, like run()) instead of crashing."""
    spec = _spec(horizon=12)
    ck = str(tmp_path / "ck")
    run_batch(spec, SEEDS, chunk_rounds=6, warmup=False,
              checkpoint_every=12, checkpoint_dir=ck,
              compute_regret=False)
    done = run_batch(spec, SEEDS, chunk_rounds=6, warmup=False,
                     checkpoint_dir=ck, resume=True, compute_regret=False)
    assert done[0].start_round == 12 and done[0].rounds == 12
    assert done[0].loss is None and done[0].accuracy is None
    assert len(done) == len(SEEDS)


def test_seed_dependent_mixer_fallback():
    """Seeded topologies resolve differently per seed: run_batch refuses,
    seed_vectorizable says no, and sweep() falls back to sequential runs
    that match per-seed run() exactly."""
    spec_dd = _spec(delay=2, delay_dist="uniform", horizon=16)
    if not seed_vectorizable(spec_dd, (0, 1)):
        with pytest.raises(ValueError, match="depends on RunSpec.seed"):
            run_batch(spec_dd, (0, 1), chunk_rounds=16)
    out = sweep(SweepSpec(base=spec_dd, seeds=(0, 1), chunk_rounds=16,
                          compute_regret=False),
                store=None, warmup=False)
    for s, res in zip((0, 1), out.results[0]):
        seq = run(spec_dd.replace(seed=s), chunk_rounds=16, warmup=False,
                  compute_regret=False)
        _assert_results_equal(seq, res, regret=False)


def test_vectorizable_predicate():
    assert seed_vectorizable(_spec(), SEEDS)
    assert seed_vectorizable(_spec(mixer="complete"), SEEDS)
    assert not seed_vectorizable(_spec(delay=2, delay_dist="uniform"), SEEDS)


# -- sweep engine + store ----------------------------------------------------

def test_sweep_end_to_end_with_store(tmp_path):
    sw = SweepSpec(base=_spec(horizon=12), axes={"eps": (0.5, 1.0)},
                   seeds=(0, 1), name="t_e2e", chunk_rounds=12,
                   compute_regret=False)
    out = sweep(sw, store=str(tmp_path), warmup=False)
    assert out.ran_points == 2 and out.loaded_points == 0
    assert len(out.records) == 4
    store = SweepStore(str(tmp_path))
    assert store.names() == ["t_e2e"]
    assert len(store.load("t_e2e")) == 4
    assert {r["seed"] for r in store.query("t_e2e", eps=0.5)} == {0, 1}

    rows = out.aggregate("accuracy")
    assert [r["eps"] for r in rows] == [0.5, 1.0]
    assert all(r["n"] == 2 and r["std"] is not None for r in rows)

    # reuse: everything served from the store, results identical
    again = sweep(sw, store=str(tmp_path), reuse=True, warmup=False)
    assert again.ran_points == 0 and again.loaded_points == 2
    for a, b in zip(out.results, again.results):
        for ra, rb in zip(a, b):
            _assert_results_equal(ra, rb, regret=False)

    # re-running WITHOUT reuse upserts — no duplicate records, and the
    # end-of-sweep compaction leaves the file itself duplicate-free
    sweep(sw, store=str(tmp_path), warmup=False)
    assert len(store.load("t_e2e")) == 4
    with open(store.path("t_e2e")) as f:
        assert sum(1 for line in f if line.strip()) == 4


def test_store_append_first_crash_durability(tmp_path):
    """Refreshed records persist the moment their point finishes (append),
    and a 'crash' before the end-of-sweep compaction still reads back
    deduped with the LAST write winning."""
    store = SweepStore(str(tmp_path))
    old = {"coords": {"eps": 1.0}, "seed": 0, "engine": "sim",
           "spec": {"lam": 0.0}, "result": {"accuracy": 0.1}}
    new = dict(old, result={"accuracy": 0.9})
    store.append("t_crash", [old])
    store.append("t_crash", [new])        # same identity, no compaction yet
    rows = store.load("t_crash")
    assert len(rows) == 1 and rows[0]["result"]["accuracy"] == 0.9
    store.compact("t_crash")
    with open(store.path("t_crash")) as f:
        assert sum(1 for line in f if line.strip()) == 1
    assert store.load("t_crash")[0]["result"]["accuracy"] == 0.9


def test_store_tolerates_torn_trailing_line(tmp_path):
    """A crash mid-append leaves a truncated final line; load() drops that
    one record and keeps the store readable. A torn MIDDLE line is real
    corruption and still raises."""
    store = SweepStore(str(tmp_path))
    rec = {"coords": {"eps": 1.0}, "seed": 0, "engine": "sim",
           "spec": {}, "result": {"accuracy": 0.5}}
    store.append("t_torn", [rec])
    with open(store.path("t_torn"), "a") as f:
        f.write('{"coords": {"eps": 2.0}, "seed": 1, "eng')   # torn write
    rows = store.load("t_torn")
    assert len(rows) == 1 and rows[0]["seed"] == 0
    with open(store.path("t_torn"), "a") as f:
        f.write("\n" + json.dumps(dict(rec, seed=2)) + "\n")
    with pytest.raises(json.JSONDecodeError):      # torn line now mid-file
        store.load("t_torn")


def test_store_append_heals_torn_tail(tmp_path):
    """Appending after a crash must not fuse the new record onto the torn
    fragment — append repairs the tail first, so the store stays readable
    and only the torn record is lost."""
    store = SweepStore(str(tmp_path))
    rec = {"coords": {"eps": 1.0}, "seed": 0, "engine": "sim",
           "spec": {}, "result": {"accuracy": 0.5}}
    store.append("t_heal", [rec])
    with open(store.path("t_heal"), "a") as f:
        f.write('{"coords": {"eps": 2.0}, "seed": 1')       # torn, no \n
    store.append("t_heal", [dict(rec, seed=2)])
    rows = store.load("t_heal")
    assert sorted(r["seed"] for r in rows) == [0, 2]
    store.compact("t_heal")
    with open(store.path("t_heal")) as f:
        assert sum(1 for line in f if line.strip()) == 2


def test_store_reuse_requires_regret_when_requested(tmp_path):
    """Records stored without a regret trajectory cannot serve a sweep
    that asks for one — it re-runs (and the refreshed record then can)."""
    sw = SweepSpec(base=_spec(horizon=12), axes={"eps": (0.5,)}, seeds=(0,),
                   name="t_regret", chunk_rounds=12, compute_regret=False)
    sweep(sw, store=str(tmp_path), warmup=False)
    again = sweep(sw.replace(compute_regret=True), store=str(tmp_path),
                  reuse=True, warmup=False)
    assert again.ran_points == 1 and again.results[0][0].regret is not None
    third = sweep(sw.replace(compute_regret=True), store=str(tmp_path),
                  reuse=True, warmup=False)
    assert third.loaded_points == 1
    assert third.results[0][0].regret is not None


def test_store_never_reuses_changed_spec(tmp_path):
    sw = SweepSpec(base=_spec(horizon=12), axes={"eps": (0.5,)}, seeds=(0,),
                   name="t_stale", chunk_rounds=12, compute_regret=False)
    sweep(sw, store=str(tmp_path), warmup=False)
    changed = sw.replace(base=_spec(horizon=12, lam=0.5))
    out = sweep(changed, store=str(tmp_path), reuse=True, warmup=False)
    assert out.ran_points == 1 and out.loaded_points == 0


def test_spec_record_round_trip():
    spec = _spec(eps=math.inf, delay=3)
    rec = json.loads(json.dumps(spec_record(spec)))
    back = spec_from_record(rec)
    assert back == spec
    assert record_key({"coords": {"a": 1}, "seed": 0, "engine": "sim",
                       "spec": rec}) == record_key(
        {"spec": rec, "engine": "sim", "seed": 0, "coords": {"a": 1}})


def test_record_key_int_float_coords_identical(tmp_path):
    """The CLI parses eps=1 as int, the Python API passes 1.0 — both must
    map to ONE record identity so upsert dedups instead of duplicating."""
    a = {"coords": {"eps": 1}, "seed": 0, "engine": "sim", "spec": {"lam": 0}}
    b = {"coords": {"eps": 1.0}, "seed": 0, "engine": "sim",
         "spec": {"lam": 0.0}}
    assert record_key(a) == record_key(b)
    store = SweepStore(str(tmp_path))
    store.upsert("t_kk", [dict(a, result={"accuracy": 0.1})])
    store.upsert("t_kk", [dict(b, result={"accuracy": 0.2})])
    rows = store.load("t_kk")
    assert len(rows) == 1 and rows[0]["result"]["accuracy"] == 0.2


def test_spec_record_marks_instances():
    from repro.api import SocialStream
    stream = SocialStream(n=16, nodes=3, rounds=8)
    rec = spec_record(_spec(stream=stream))
    assert rec["stream"] == {"__instance__": "SocialStream"}
    with pytest.raises(ValueError, match="audit-only"):
        spec_from_record(rec)


def test_aggregate_records():
    recs = [{"coords": {"eps": e}, "seed": s,
             "result": {"accuracy": 0.5 + 0.1 * s}}
            for e in (0.5, 1.0) for s in (0, 1)]
    rows = aggregate_records(recs, by=("eps",), value="accuracy")
    assert len(rows) == 2
    assert rows[0]["mean"] == pytest.approx(0.55)
    assert rows[0]["n"] == 2


# -- CLI ---------------------------------------------------------------------

def test_cli_axis_parsing():
    from repro.launch.sweep import parse_axis
    assert parse_axis("eps=0.1,1,inf") == ("eps", (0.1, 1, math.inf))
    assert parse_axis("nodes,horizon=4:8,8:4") == (
        "nodes,horizon", ((4, 8), (8, 4)))
    assert parse_axis("mixer=ring,complete") == ("mixer",
                                                 ("ring", "complete"))


def test_store_lookup_int_float_identity(tmp_path):
    """Records written with CLI-parsed int values (eps=1) must serve a
    reuse lookup with float values (eps=1.0) — lookup canonicalizes like
    record_key, so one identity governs writes AND reads."""
    from repro.launch.sweep import main
    argv = ["--nodes", "3", "--dim", "16", "--horizon", "12",
            "--seeds", "0", "--chunk-rounds", "12", "--no-regret",
            "--store", str(tmp_path), "--name", "t_if"]
    main(argv + ["--axis", "eps=1"])                 # int axis value
    out = main(argv + ["--axis", "eps=1.0", "--from-store"])  # float
    assert out["summary"]["loaded_points"] == 1
    assert out["summary"]["ran_points"] == 0
    store = SweepStore(str(tmp_path))
    assert len(store.query("t_if", eps=1)) == 1      # query canonicalizes too
    assert len(store.query("t_if", eps=1.0)) == 1


def test_require_store_raises_on_missing_records(tmp_path):
    """reuse + require_store refuses to run anything when the store cannot
    serve every (point, seed) — the contract behind --from-store."""
    from repro.sweep import SweepStoreMiss
    sw = SweepSpec(base=_spec(horizon=12), axes={"eps": (0.5, 1.0)},
                   seeds=(0, 1), name="t_req", chunk_rounds=12,
                   compute_regret=False)
    with pytest.raises(SweepStoreMiss, match="no record"):
        sweep(sw, store=str(tmp_path), reuse=True, require_store=True,
              warmup=False)
    assert not SweepStore(str(tmp_path)).load("t_req")   # nothing ran
    sweep(sw, store=str(tmp_path), warmup=False)          # populate
    out = sweep(sw, store=str(tmp_path), reuse=True, require_store=True,
                warmup=False)
    assert out.ran_points == 0 and out.loaded_points == 2
    # a changed base spec goes stale -> miss again, named in the error
    with pytest.raises(SweepStoreMiss, match="eps=0.5"):
        sweep(sw.replace(base=_spec(horizon=12, lam=0.5)),
              store=str(tmp_path), reuse=True, require_store=True,
              warmup=False)


def test_require_store_without_reuse_rejected(tmp_path):
    sw = SweepSpec(base=_spec(horizon=12), seeds=(0,), chunk_rounds=12)
    with pytest.raises(ValueError, match="reuse=True"):
        sweep(sw, store=str(tmp_path), require_store=True, warmup=False)


def test_cli_from_store_empty_store_errors(tmp_path):
    """--from-store on an empty/stale store dies with a clear message
    instead of silently re-running (or emitting an empty figure)."""
    from repro.launch.sweep import main
    argv = ["--nodes", "3", "--dim", "16", "--horizon", "12",
            "--axis", "eps=0.5", "--seeds", "0,1", "--chunk-rounds", "12",
            "--no-regret", "--store", str(tmp_path), "--name", "t_fs"]
    with pytest.raises(SystemExit, match="no record"):
        main(argv + ["--from-store"])
    main(argv)                                   # populate the store
    out = main(argv + ["--from-store"])          # now served entirely
    assert out["summary"]["loaded_points"] == 1
    assert out["summary"]["ran_points"] == 0


def test_cli_main_smoke(tmp_path):
    from repro.launch.sweep import main
    out = main(["--nodes", "3", "--dim", "16", "--horizon", "12",
                "--axis", "eps=0.5,1.0", "--seeds", "0,1",
                "--chunk-rounds", "12", "--no-regret",
                "--store", str(tmp_path), "--name", "t_cli"])
    assert out["summary"]["ran_points"] == 2
    assert len(out["rows"]) == 2 and out["rows"][0]["eps"] == 0.5
    assert SweepStore(str(tmp_path)).load("t_cli")
