"""History-ring buffer tests: GossipState staleness support, delay=0
degeneration, per-edge heterogeneous delays, and the seeded cross-engine
equivalence suite (simulator == distributed for every supported delay)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (MIXERS, DelayedMixer, HeterogeneousDelayMixer,
                       RingRollMixer, RunSpec, ring_read, ring_write,
                       sample_edge_delays)
from repro.core.algorithm1 import hinge_loss_and_grad


def _spec(delay=0, m=8, n=16, eps=math.inf, **kw):
    return RunSpec(nodes=m, dim=n, mixer="ring", mechanism="laplace",
                   eps=eps, clip_norm=1.0, calibration="global",
                   alpha0=0.5, schedule="sqrt_t", lam=0.01, delay=delay, **kw)


def _stream(m=8, n=16, T=12, seed=3):
    key = jax.random.PRNGKey(seed)
    xs = jax.random.normal(key, (T, m, n)) / np.sqrt(n)
    ys = jnp.sign(jax.random.normal(jax.random.fold_in(key, 1), (T, m)))
    return xs, ys


# ---------------------------------------------------------------------------
# ring primitives
# ---------------------------------------------------------------------------

def test_ring_write_read_roundtrip():
    depth, m, n = 4, 2, 3
    hist = jnp.zeros((depth, m, n))
    vals = [jnp.full((m, n), float(t + 1)) for t in range(7)]
    for t, v in enumerate(vals):
        hist = ring_write(hist, t, v)
        # d = 0 reads back the slot just written, bit-for-bit
        np.testing.assert_array_equal(
            np.asarray(ring_read(hist, t, 0, jnp.zeros((m, n)))),
            np.asarray(v))
    t = 6
    for d in range(depth):
        got = ring_read(hist, t, d, jnp.full((m, n), -1.0))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(vals[t - d]))


def test_ring_read_warmup_falls_back_to_current():
    hist = jnp.zeros((3, 2, 2))
    fallback = jnp.full((2, 2), 9.0)
    got = ring_read(hist, jnp.asarray(1, jnp.int32), 2, fallback)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(fallback))


# ---------------------------------------------------------------------------
# GossipState history buffer
# ---------------------------------------------------------------------------

def test_history_buffer_contents_after_k_rounds():
    """After k rounds the ring holds the theta broadcast of the last
    depth rounds, slot r % depth <- theta from round r (noise-free, so
    theta~ == theta exactly)."""
    m, n, delay, k = 4, 8, 3, 6
    gdp = _spec(delay=delay, m=m, n=n).build_distributed()
    state = gdp.init({"w": jax.random.normal(jax.random.PRNGKey(0), (m, n))},
                     jax.random.PRNGKey(1))
    depth = delay + 1
    assert state.history["w"].shape == (depth, m, n)
    thetas = []
    for t in range(k):
        thetas.append(np.asarray(state.theta["w"]))
        g = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(2), t),
                              (m, n))
        state, _ = gdp.update(state, {"w": g})
    for slot in range(depth):
        # last round r < k with r % depth == slot
        r = max(r for r in range(k) if r % depth == slot)
        np.testing.assert_array_equal(np.asarray(state.history["w"][slot]),
                                      thetas[r])


def test_delay_zero_bitwise_identical_to_sync_path():
    """delay=0 must not allocate history and must reproduce the synchronous
    engine bit-for-bit, including under a private (noised) mechanism."""
    m, n, T = 4, 8, 6
    base = _spec(m=m, n=n, eps=1.0).build_distributed()
    zero = _spec(delay=0, m=m, n=n, eps=1.0).build_distributed()
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (m, n))}
    sa = base.init(params, jax.random.PRNGKey(1))
    sb = zero.init(params, jax.random.PRNGKey(1))
    assert sa.history is None and sb.history is None
    for t in range(T):
        g = {"w": jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(2), t),
                                    (m, n))}
        sa, _ = base.update(sa, g)
        sb, _ = zero.update(sb, g)
    np.testing.assert_array_equal(np.asarray(sa.theta["w"]),
                                  np.asarray(sb.theta["w"]))


def test_delayed_mixer_in_gossip_dp_no_longer_raises():
    """Regression: PR-1 GossipDP rejected any mixer with delay > 0."""
    gdp = _spec(delay=2).build_distributed()   # must not raise
    assert isinstance(gdp.mixer, DelayedMixer) and gdp.delay == 2
    state = gdp.init({"w": jnp.zeros((8, 16))}, jax.random.PRNGKey(0))
    state, metrics = gdp.update(state, {"w": jnp.ones((8, 16))})
    assert int(state.t) == 1 and np.isfinite(float(metrics["alpha_t"]))


def test_gossip_dp_delayed_update_is_scan_and_jit_safe():
    gdp = _spec(delay=2).build_distributed()
    state = gdp.init({"w": jnp.zeros((8, 16))}, jax.random.PRNGKey(0))
    grads = jnp.ones((5, 8, 16))

    @jax.jit
    def run(state, grads):
        def body(st, g):
            st, m = gdp.update(st, {"w": g})
            return st, m["alpha_t"]
        return jax.lax.scan(body, state, grads)

    state, alphas = run(state, grads)
    assert int(state.t) == 5
    assert np.isfinite(np.asarray(alphas)).all()


# ---------------------------------------------------------------------------
# seeded cross-engine equivalence (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("delay", [0, 1, 3])
def test_cross_engine_equivalence_per_delay(delay):
    """For every supported delay the dense simulator and the distributed
    engine produce IDENTICAL iterates on the ring topology (noise-free)."""
    m, n, T = 8, 16, 12
    xs, ys = _stream(m, n, T)
    spec = _spec(delay=delay, m=m, n=n)

    alg = spec.build_simulator()
    state_s = alg.init(jax.random.PRNGKey(9))

    gdp = spec.build_distributed()
    state_d = gdp.init({"w": jnp.zeros((m, n))}, jax.random.PRNGKey(9))
    for t in range(T):
        state_s, _ = alg.round(state_s, (xs[t], ys[t]))
        w = gdp.primal(state_d)["w"]
        _, grad = hinge_loss_and_grad(w, xs[t], ys[t])
        state_d, _ = gdp.update(state_d, {"w": grad})
    np.testing.assert_array_equal(np.asarray(state_d.theta["w"]),
                                  np.asarray(state_s.theta))
    if delay:
        np.testing.assert_array_equal(np.asarray(state_d.history["w"]),
                                      np.asarray(state_s.history))


@pytest.mark.parametrize("delay_dist", ["constant", "uniform", "geometric"])
def test_cross_engine_equivalence_heterogeneous(delay_dist):
    """Per-edge delays agree across engines too (same seeded mixer)."""
    m, n, T = 8, 16, 10
    xs, ys = _stream(m, n, T)
    spec = _spec(delay=3, m=m, n=n, delay_dist=delay_dist)

    alg = spec.build_simulator()
    state_s = alg.init(jax.random.PRNGKey(9))
    gdp = spec.build_distributed()
    state_d = gdp.init({"w": jnp.zeros((m, n))}, jax.random.PRNGKey(9))
    for t in range(T):
        state_s, _ = alg.round(state_s, (xs[t], ys[t]))
        w = gdp.primal(state_d)["w"]
        _, grad = hinge_loss_and_grad(w, xs[t], ys[t])
        state_d, _ = gdp.update(state_d, {"w": grad})
    np.testing.assert_array_equal(np.asarray(state_d.theta["w"]),
                                  np.asarray(state_s.theta))


# ---------------------------------------------------------------------------
# heterogeneous delay mixer semantics
# ---------------------------------------------------------------------------

def test_sample_edge_delays_seeded_and_bounded():
    a = sample_edge_delays(8, 5, "uniform", seed=7)
    b = sample_edge_delays(8, 5, "uniform", seed=7)
    c = sample_edge_delays(8, 5, "uniform", seed=8)
    np.testing.assert_array_equal(a, b)          # same seed -> same draw
    assert not np.array_equal(a, c)              # different seed differs
    assert a.min() >= 0 and a.max() <= 5
    assert (np.diag(a) == 0).all()               # own state is never stale
    with pytest.raises(ValueError):
        sample_edge_delays(4, 2, "nope")


def test_het_constant_matches_uniform_delayed_mixer():
    """delay_dist='constant' is exactly DelayedMixer over the dense form."""
    m, n, d = 6, 12, 2
    het = HeterogeneousDelayMixer.from_topology("ring", m, delay=d,
                                                delay_dist="constant")
    assert het.delay == d and het.m == m
    uni = MIXERS.build("delayed", m=m, inner="dense", delay=d,
                       topology="ring")
    x = jax.random.normal(jax.random.PRNGKey(0), (m, n))
    hist = jnp.zeros((d + 1, m, n))
    for t in range(d + 1):
        hist = ring_write(hist, t, x * (t + 1))
    t = jnp.asarray(d, jnp.int32)
    tilde = x * (d + 1)
    got = het.mix_history(x, tilde, hist, True, t)
    want = uni.mix_history(x, tilde, hist, True, t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_het_all_zero_delays_degenerate_to_synchronous():
    m, n = 6, 12
    het = HeterogeneousDelayMixer.from_topology("ring", m, delay=1,
                                                delay_dist="uniform", seed=0)
    zero = HeterogeneousDelayMixer(inner=het.inner,
                                   delays=np.zeros((m, m), np.int32))
    assert zero.delay == 0
    x = jax.random.normal(jax.random.PRNGKey(1), (m, n))
    t = jnp.asarray(0, jnp.int32)
    got = zero.mix_history(x, x, None, True, t)
    want = zero.inner.mix(x, x, True, t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_het_mixer_requires_history_when_stale():
    het = HeterogeneousDelayMixer.from_topology("ring", 4, delay=2,
                                                delay_dist="constant")
    x = jnp.ones((4, 3))
    with pytest.raises(ValueError):
        het.mix_history(x, x, None, True, jnp.asarray(0, jnp.int32))


def test_uniform_delayed_mixer_requires_history_when_stale():
    """A missing ring must raise, not silently mix synchronously."""
    mixer = DelayedMixer(inner=RingRollMixer(m=4), delay=2)
    x = jnp.ones((4, 3))
    with pytest.raises(ValueError, match="history"):
        mixer.mix_history(x, x, None, True, jnp.asarray(0, jnp.int32))


def test_runspec_delay_dist_validation():
    with pytest.raises(ValueError):
        _spec(delay=0, delay_dist="uniform").resolve_mixer()
    with pytest.raises(ValueError):
        RunSpec(nodes=4, mixer=RingRollMixer(m=4), delay=2,
                delay_dist="uniform").resolve_mixer()
    # a valid MIXERS name that is not a dense GossipGraph topology must
    # name delay_dist in the error, not a bare 'unknown topology'
    with pytest.raises(ValueError, match="delay_dist"):
        RunSpec(nodes=4, mixer="ring_alternating", delay=2,
                delay_dist="uniform").resolve_mixer()


def test_engine_delay_kwarg_actually_delays():
    """Regression: Algorithm1(delay=d) with a plain (delay-less) mixer must
    wrap it in DelayedMixer — not silently run the synchronous exchange
    while allocating the ring."""
    from repro.api import LaplaceMechanism
    from repro.core import Algorithm1, OMDConfig

    m, n, T = 8, 16, 10
    xs, ys = _stream(m, n, T)

    def build(**kw):
        return Algorithm1(omd=OMDConfig(alpha0=0.5, schedule="sqrt_t",
                                        lam=0.01),
                          n=n, mixer=RingRollMixer(m=m),
                          mechanism=LaplaceMechanism(eps=math.inf), **kw)

    alg = build(delay=3)
    assert isinstance(alg.mixer, DelayedMixer) and alg.delay == 3
    stale = alg.run(jax.random.PRNGKey(0), xs, ys)
    sync = build().run(jax.random.PRNGKey(0), xs, ys)
    assert not np.array_equal(np.asarray(stale.loss), np.asarray(sync.loss))
    # and it matches the RunSpec(delay=...) spelling exactly
    spec = _spec(delay=3, m=m, n=n).build_simulator()
    np.testing.assert_array_equal(
        np.asarray(stale.loss),
        np.asarray(spec.run(jax.random.PRNGKey(0), xs, ys).loss))
