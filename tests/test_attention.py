"""Attention-substrate correctness: blockwise==full, GQA, windows, M-RoPE,
decode ring cache == full-sequence apply."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention
from repro.models.config import ModelConfig


def _cfg(**kw):
    base = dict(name="t", family="dense", num_layers=1, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def _qkv(cfg, B=2, T=64, seed=0):
    key = jax.random.PRNGKey(seed)
    p = attention.attn_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, T, cfg.d_model))
    return p, x


def test_blockwise_equals_full():
    cfg = _cfg()
    p, x = _qkv(cfg, T=256)
    pos = attention.default_positions(2, 256, cfg)
    q, k, v = attention._project_qkv(p, cfg, x, pos)
    o_full = attention._full_attention(q, k, v, jnp.arange(256), jnp.arange(256),
                                       None, None)
    o_block = attention._blockwise_attention(q, k, v, None, None,
                                             q_chunk=64, k_chunk=32)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_block),
                               rtol=2e-4, atol=2e-5)


def test_blockwise_equals_full_with_window():
    cfg = _cfg()
    p, x = _qkv(cfg, T=256)
    pos = attention.default_positions(2, 256, cfg)
    q, k, v = attention._project_qkv(p, cfg, x, pos)
    o_full = attention._full_attention(q, k, v, jnp.arange(256), jnp.arange(256),
                                       64, None)
    o_block = attention._blockwise_attention(q, k, v, 64, None,
                                             q_chunk=32, k_chunk=64)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_block),
                               rtol=2e-4, atol=2e-5)


def test_blockwise_nondivisible_lengths():
    cfg = _cfg()
    p, x = _qkv(cfg, T=100)
    pos = attention.default_positions(2, 100, cfg)
    q, k, v = attention._project_qkv(p, cfg, x, pos)
    o_full = attention._full_attention(q, k, v, jnp.arange(100), jnp.arange(100),
                                       None, None)
    o_block = attention._blockwise_attention(q, k, v, None, None,
                                             q_chunk=32, k_chunk=32)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_block),
                               rtol=2e-4, atol=2e-5)


def test_gqa_equals_repeated_kv_mha():
    """GQA grouped computation == MHA with explicitly repeated K/V heads."""
    cfg = _cfg(num_heads=4, num_kv_heads=2)
    p, x = _qkv(cfg)
    pos = attention.default_positions(2, 64, cfg)
    q, k, v = attention._project_qkv(p, cfg, x, pos)
    o = attention._full_attention(q, k, v, jnp.arange(64), jnp.arange(64), None, None)
    # repeat kv to full heads and compute with Kv == H
    k_rep = jnp.repeat(k, 2, axis=2)
    v_rep = jnp.repeat(v, 2, axis=2)
    o_rep = attention._full_attention(q, k_rep, v_rep, jnp.arange(64),
                                      jnp.arange(64), None, None)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_rep), rtol=2e-4, atol=1e-5)


def test_causality():
    """Changing future tokens must not change past outputs."""
    cfg = _cfg()
    p, x = _qkv(cfg, T=32)
    pos = attention.default_positions(2, 32, cfg)
    y1 = attention.attention_full(p, cfg, x, pos)
    x2 = x.at[:, 20:].set(99.0)
    y2 = attention.attention_full(p, cfg, x2, pos)
    np.testing.assert_allclose(np.asarray(y1[:, :20]), np.asarray(y2[:, :20]),
                               rtol=1e-4, atol=1e-5)


def test_sliding_window_limits_receptive_field():
    cfg = _cfg(sliding_window=8)
    p, x = _qkv(cfg, T=32)
    pos = attention.default_positions(2, 32, cfg)
    y1 = attention.attention_full(p, cfg, x, pos)
    # tokens > window behind position 31 must not affect it
    x2 = x.at[:, :16].set(-7.0)
    y2 = attention.attention_full(p, cfg, x2, pos)
    np.testing.assert_allclose(np.asarray(y1[:, -1]), np.asarray(y2[:, -1]),
                               rtol=1e-4, atol=1e-5)


def test_mrope_text_equals_standard_rope():
    """With equal (t, h, w) positions, M-RoPE == standard RoPE."""
    cfg_std = _cfg(rope_style="standard")
    cfg_mr = _cfg(rope_style="mrope", mrope_sections=(2, 3, 3))  # head_dim 16 -> half 8
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 16))
    pos_std = attention.default_positions(2, 16, cfg_std)
    pos_mr = attention.default_positions(2, 16, cfg_mr)
    np.testing.assert_allclose(
        np.asarray(attention.apply_rope(x, pos_std, cfg_std)),
        np.asarray(attention.apply_rope(x, pos_mr, cfg_mr)),
        rtol=1e-5, atol=1e-6)


def test_mrope_diverges_for_spatial_positions():
    cfg_mr = _cfg(rope_style="mrope", mrope_sections=(2, 3, 3))
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 4, 16))
    pos_text = attention.default_positions(1, 8, cfg_mr)
    pos_img = pos_text.at[..., 1].set(pos_text[..., 1] + 5)  # h channel differs
    a = attention.apply_rope(x, pos_text, cfg_mr)
    b = attention.apply_rope(x, pos_img, cfg_mr)
    assert not np.allclose(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("window", [None, 8])
def test_decode_matches_full_apply(window):
    """Ring-buffer decode, token by token, == full-sequence attention."""
    cfg = _cfg(sliding_window=window)
    T = 24
    p, x = _qkv(cfg, T=T)
    pos = attention.default_positions(2, T, cfg)
    y_full = attention.attention_full(p, cfg, x, pos)

    cache = attention.init_attn_cache(cfg, 2, cache_len=T if window is None else window,
                                      dtype=jnp.float32)
    outs = []
    for i in range(T):
        y1, cache = attention.attention_decode(
            p, cfg, x[:, i:i+1], jnp.full((2,), i, jnp.int32), cache)
        outs.append(y1)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_dec),
                               rtol=3e-4, atol=3e-5)
