"""Pallas kernel allclose sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("rows", [8, 16, 64, 512, 1024])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pdomd_update_sweep(rows, dtype):
    keys = jax.random.split(jax.random.PRNGKey(rows), 4)
    args = [jax.random.normal(k, (rows, 128), dtype) for k in keys]
    alpha, lam = jnp.float32(0.05), jnp.float32(0.02)
    w, th = ops.pdomd_update(*args, alpha, lam)
    w_r, th_r = ref.pdomd_update_ref(*args, alpha, lam,
                                     jnp.float32(0.5), jnp.float32(0.25))
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_r), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(th), np.asarray(th_r), rtol=tol, atol=tol)


@pytest.mark.parametrize("block_rows", [8, 64, 512])
def test_pdomd_update_block_shapes(block_rows):
    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    args = [jax.random.normal(k, (1024, 128)) for k in keys]
    w, th = ops.pdomd_update(*args, jnp.float32(0.1), jnp.float32(0.01),
                             block_rows=block_rows)
    w_r, th_r = ref.pdomd_update_ref(*args, jnp.float32(0.1), jnp.float32(0.01),
                                     jnp.float32(0.5), jnp.float32(0.25))
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_r), rtol=1e-5, atol=1e-6)


def test_pdomd_update_produces_sparsity():
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    args = [jax.random.normal(k, (64, 128)) for k in keys]
    w, _ = ops.pdomd_update(*args, jnp.float32(0.0), jnp.float32(0.8))
    assert float((w == 0).mean()) > 0.3


@pytest.mark.parametrize("B,n", [(8, 128), (32, 256), (128, 1024), (100, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hinge_grad_sweep(B, n, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(B + n), 3)
    x = (jax.random.normal(k1, (B, n)) / np.sqrt(n)).astype(dtype)
    y = jnp.sign(jax.random.normal(k2, (B,))).astype(dtype)
    w = jax.random.normal(k3, (n,)).astype(dtype)
    loss, g, margin = ops.hinge_grad(x, y, w)
    loss_r, g_r, margin_r = ref.hinge_grad_ref(x, y, w)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(loss), np.asarray(loss_r), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_r), rtol=tol, atol=tol)


def test_hinge_grad_matches_jax_autodiff():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    B, n = 64, 256
    x = jax.random.normal(k1, (B, n)) / np.sqrt(n)
    y = jnp.sign(jax.random.normal(k2, (B,)))
    w = jax.random.normal(k3, (n,))
    _, g, _ = ops.hinge_grad(x, y, w)
    g_auto = jax.grad(lambda w: jnp.mean(jnp.maximum(1 - y * (x @ w), 0.0)))(w)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_auto), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("rows8,lam", [
    (1, 0.0), (1, 2.0), (2, 0.5), (4, 0.1), (8, 1.0), (13, 0.01),
    (16, 1.5), (25, 0.8), (32, 0.3), (40, 2.0),
])
def test_pdomd_kernel_property_sparsity_monotone(rows8, lam):
    rows = rows8 * 8
    keys = jax.random.split(jax.random.PRNGKey(7), 4)
    args = [jax.random.normal(k, (rows, 128)) for k in keys]
    w1, _ = ops.pdomd_update(*args, jnp.float32(0.0), jnp.float32(lam))
    w2, _ = ops.pdomd_update(*args, jnp.float32(0.0), jnp.float32(lam + 0.5))
    assert float((w2 == 0).mean()) >= float((w1 == 0).mean())


def test_tree_tiles_roundtrip():
    tree = {"a": jnp.arange(300, dtype=jnp.bfloat16).reshape(20, 15),
            "b": {"c": jnp.ones((7,), jnp.float32)}}
    tiles = ops.tree_to_tiles(tree)
    assert tiles.shape[1] == 128 and tiles.shape[0] % 8 == 0
    back = ops.tiles_to_tree(tiles, tree)
    np.testing.assert_allclose(np.asarray(back["a"], np.float32),
                               np.asarray(tree["a"], np.float32))
    assert back["b"]["c"].dtype == jnp.float32
