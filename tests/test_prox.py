import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.prox import (
    elastic_net_prox, group_soft_threshold, l2_mirror_map, soft_threshold,
    soft_threshold_tree, sparsity, sparsity_tree,
)


def test_soft_threshold_closed_form():
    p = jnp.array([-3.0, -0.5, 0.0, 0.5, 3.0])
    out = soft_threshold(p, 1.0)
    np.testing.assert_allclose(np.asarray(out), [-2.0, 0.0, 0.0, 0.0, 2.0])


def test_soft_threshold_solves_lasso_prox():
    # w* = argmin 1/2||p - w||^2 + lam ||w||_1  — verify against grid search
    p = jnp.array([1.3])
    lam = 0.4
    w_star = float(soft_threshold(p, lam)[0])
    grid = np.linspace(-3, 3, 20001)
    obj = 0.5 * (1.3 - grid) ** 2 + lam * np.abs(grid)
    assert abs(grid[obj.argmin()] - w_star) < 1e-3


@pytest.mark.parametrize("seed,lam", [
    (0, 0.0), (1, 0.01), (2, 0.1), (3, 0.5), (4, 1.0), (5, 2.0),
    (6, 3.7), (7, 5.0), (8, 8.0), (9, 10.0),
])
def test_soft_threshold_properties(seed, lam):
    rng = np.random.default_rng(seed)
    p_np = rng.uniform(-50.0, 50.0, size=(37,)).astype(np.float32)
    if seed % 3 == 0:  # exercise exact zeros and +/-lam boundary values
        p_np[::5] = 0.0
        p_np[1::7] = lam
    p = jnp.asarray(p_np)
    w = soft_threshold(p, lam)
    w_np = np.asarray(w)
    # 1. shrinkage: |w| <= |p|
    assert np.all(np.abs(w_np) <= np.abs(p_np) + 1e-6)
    # 2. sign preservation
    assert np.all((w_np == 0) | (np.sign(w_np) == np.sign(p_np)))
    # 3. kill zone: |p| <= lam -> 0
    assert np.all(w_np[np.abs(p_np) <= lam] == 0)
    # 4. sparsity monotone in lambda
    w2 = np.asarray(soft_threshold(p, lam + 1.0))
    assert (w2 == 0).sum() >= (w_np == 0).sum()


def test_group_soft_threshold_zeros_whole_rows():
    p = jnp.array([[0.1, 0.1], [3.0, 4.0]])
    out = np.asarray(group_soft_threshold(p, 1.0))
    assert np.all(out[0] == 0.0)       # ||row0|| < 1 -> whole group killed
    np.testing.assert_allclose(np.linalg.norm(out[1]), 4.0, rtol=1e-5)  # 5-1


def test_elastic_net_prox():
    out = elastic_net_prox(jnp.array([2.0]), 1.0, 1.0)
    assert float(out[0]) == 0.5  # (2-1)/(1+1)


def test_mirror_map_identity():
    x = jnp.arange(5.0)
    np.testing.assert_array_equal(np.asarray(l2_mirror_map(x)), np.asarray(x))


def test_sparsity_measures():
    w = jnp.array([0.0, 1.0, 0.0, 2.0])
    assert float(sparsity(w)) == 0.5
    tree = {"a": w, "b": jnp.zeros((4,))}
    assert float(sparsity_tree(tree)) == 0.75
    out = soft_threshold_tree(tree, 10.0)
    assert float(sparsity_tree(out)) == 1.0
