"""repro.api strategy-layer tests: registry round-trips, explicit-instance vs
RunSpec seeded equivalence, and cross-engine (simulator vs distributed)
agreement."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (CLIPPERS, LOCAL_RULES, MECHANISMS, MIXERS,
                       AlternatingRingMixer, CompleteMixer, DelayedMixer,
                       DenseMatrixMixer, DisconnectedMixer, LaplaceMechanism,
                       NoNoise, PerNodeL2Clipper, RingRollMixer, RunSpec,
                       StepContext)
from repro.core import Algorithm1, GossipDP, GossipGraph, OMDConfig
from repro.core.algorithm1 import hinge_loss_and_grad
from repro.core.graph import ring_matrix


def _stream(m=8, n=32, T=40, seed=0):
    key = jax.random.PRNGKey(seed)
    xs = jax.random.normal(key, (T, m, n)) / np.sqrt(n)
    ys = jnp.sign(jax.random.normal(jax.random.fold_in(key, 1), (T, m)))
    return xs, ys


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------

def test_registry_names_cover_the_paper():
    for name in ("ring", "complete", "disconnected", "ring_alternating",
                 "dense", "torus", "hypercube", "random", "time_varying",
                 "delayed"):
        assert name in MIXERS.names()
    for name in ("laplace", "gaussian", "none"):
        assert name in MECHANISMS.names()
    for name in ("omd", "tg", "rda"):
        assert name in LOCAL_RULES.names()
    for name in ("l2", "value", "none"):
        assert name in CLIPPERS.names()


def test_registry_build_roundtrip():
    mixer = MIXERS.build("ring", m=8, self_weight=0.6)
    assert isinstance(mixer, RingRollMixer) and mixer.self_weight == 0.6
    assert isinstance(MIXERS.build("complete", m=4), CompleteMixer)
    assert isinstance(MIXERS.build("disconnected", m=4), DisconnectedMixer)
    assert isinstance(MIXERS.build("ring_alternating", m=4), AlternatingRingMixer)
    assert isinstance(MIXERS.build("torus", m=16), DenseMatrixMixer)
    # instances pass through untouched
    assert MIXERS.build(mixer) is mixer
    mech = MECHANISMS.build("laplace", eps=2.0, L=0.5, calibration="coordinate")
    assert isinstance(mech, LaplaceMechanism) and mech.eps == 2.0
    assert isinstance(MECHANISMS.build("none"), NoNoise)
    assert isinstance(CLIPPERS.build("l2", max_norm=3.0), PerNodeL2Clipper)


def test_registry_unknown_name_is_value_and_key_error():
    with pytest.raises(ValueError):
        MIXERS.build("nope", m=4)
    with pytest.raises(KeyError):
        LOCAL_RULES.get("nope")


def test_new_mixer_plugs_in_without_engine_changes():
    """A scenario plugin registers a topology and both engines accept it."""
    from repro.api.mixers import MixerBase
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class SelfLoopMixer(MixerBase):
        m: int
        delay: int = 0

        def apply(self, x, t):
            return x

        def diag(self, t):
            return jnp.ones((self.m,), jnp.float32)

    name = "selfloop_test"
    if name not in MIXERS.names():
        MIXERS.register(name)(lambda m, **kw: SelfLoopMixer(m=m))
    spec = RunSpec(nodes=4, dim=8, mixer=name, eps=math.inf, alpha0=1.0)
    xs, ys = _stream(m=4, n=8, T=5)
    outs = spec.build_simulator().run(jax.random.PRNGKey(0), xs, ys)
    assert np.isfinite(np.asarray(outs.loss)).all()
    gdp = spec.build_distributed()
    state = gdp.init({"w": jnp.zeros((4, 8))}, jax.random.PRNGKey(1))
    state, _ = gdp.update(state, {"w": jnp.ones((4, 8))})
    assert int(state.t) == 1


# ---------------------------------------------------------------------------
# mixer semantics
# ---------------------------------------------------------------------------

def test_ring_roll_matches_dense_ring_matrix():
    m, n = 8, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (m, n))
    t = jnp.zeros((), jnp.int32)
    roll = RingRollMixer(m=m, self_weight=0.5)
    dense = DenseMatrixMixer(stack=ring_matrix(m, 0.5))
    np.testing.assert_allclose(np.asarray(roll.apply(x, t)),
                               np.asarray(dense.apply(x, t)),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(roll.diag(t)),
                               np.asarray(dense.diag(t)), rtol=1e-6)


def test_dense_mixer_hoists_matrix_stack():
    g = GossipGraph.make("time_varying", 8)
    mixer = DenseMatrixMixer.from_graph(g)
    assert mixer.stack.shape == (len(g.matrices), 8, 8)
    # schedule indexing matches GossipGraph.at
    for t in range(4):
        np.testing.assert_allclose(
            np.asarray(mixer.stack[t % mixer.stack.shape[0]]),
            np.asarray(g.at(t)))


def test_noise_self_false_removes_own_noise_generic():
    """mix(clean, tilde, noise_self=False) == apply(tilde) - diag*(tilde-clean)
    and for the complete graph equals the legacy closed form."""
    m, n = 4, 16
    clean = jnp.ones((m, n))
    delta = jax.random.normal(jax.random.PRNGKey(0), (m, n))
    tilde = clean + delta
    t = jnp.zeros((), jnp.int32)
    mixer = CompleteMixer(m=m)
    got = mixer.mix(clean, tilde, False, t)
    legacy = jnp.broadcast_to(jnp.mean(tilde, 0, keepdims=True), tilde.shape) \
        + (clean - tilde) / m
    np.testing.assert_allclose(np.asarray(got), np.asarray(legacy),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# seeded equivalence: explicitly-constructed protocol instances vs RunSpec
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", ["omd", "tg", "rda"])
def test_simulator_runspec_matches_explicit_instances(rule):
    m, n, T = 8, 32, 30
    xs, ys = _stream(m, n, T)
    explicit = Algorithm1(
        omd=OMDConfig(alpha0=1.0, schedule="sqrt_t", lam=0.01),
        n=n,
        mixer=RingRollMixer(m=m, self_weight=0.5),
        mechanism=LaplaceMechanism(eps=1.0, L=1.0, calibration="global"),
        local_rule=LOCAL_RULES.build(rule),
        clipper=PerNodeL2Clipper(max_norm=1.0),
    )
    spec = RunSpec(nodes=m, dim=n, mixer="ring", mechanism="laplace",
                   local_rule=rule, clipper="l2", eps=1.0, clip_norm=1.0,
                   calibration="global", alpha0=1.0, schedule="sqrt_t",
                   lam=0.01)
    new = spec.build_simulator()
    w_l, outs_l = explicit.final_params(jax.random.PRNGKey(7), xs, ys)
    w_n, outs_n = new.final_params(jax.random.PRNGKey(7), xs, ys)
    np.testing.assert_allclose(np.asarray(w_n), np.asarray(w_l),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(outs_n.loss), np.asarray(outs_l.loss),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("topology", ["ring", "complete", "disconnected",
                                      "ring_alternating"])
def test_distributed_runspec_matches_explicit_instances(topology):
    m, n, T = 8, 16, 10
    explicit = GossipDP(
        omd=OMDConfig(alpha0=0.5, schedule="sqrt_t", lam=0.01),
        mixer=MIXERS.build(topology, m=m),
        mechanism=LaplaceMechanism(eps=1.0, L=1.0, calibration="global"),
    )
    spec = RunSpec(nodes=m, mixer=topology, mechanism="laplace",
                   local_rule="omd", clipper="l2", eps=1.0, clip_norm=1.0,
                   calibration="global", alpha0=0.5, schedule="sqrt_t",
                   lam=0.01)
    new = spec.build_distributed()

    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (m, n)),
              "b": jax.random.normal(jax.random.PRNGKey(1), (m, 4))}
    sl = explicit.init(params, jax.random.PRNGKey(2))
    sn = new.init(params, jax.random.PRNGKey(2))
    for t in range(T):
        g = {"w": jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(3), t),
                                    (m, n)),
             "b": jnp.ones((m, 4))}
        sl, ml = explicit.update(sl, g)
        sn, mn = new.update(sn, g)
    np.testing.assert_allclose(np.asarray(sl.theta["w"]),
                               np.asarray(sn.theta["w"]), rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(sl.theta["b"]),
                               np.asarray(sn.theta["b"]), rtol=1e-6, atol=1e-7)
    assert float(ml["noise_scale"]) == float(mn["noise_scale"])


# ---------------------------------------------------------------------------
# cross-engine: simulator vs distributed on a linear model
# ---------------------------------------------------------------------------

def test_cross_engine_ring_equivalence():
    """Algorithm1 with the ring Mixer == GossipDP rounds (noise-free)."""
    m, n, T = 8, 32, 25
    xs, ys = _stream(m, n, T, seed=3)
    spec = RunSpec(nodes=m, dim=n, mixer="ring", eps=math.inf, clip_norm=1.0,
                   local_rule="omd", lam=0.01, alpha0=0.5, schedule="sqrt_t")

    alg = spec.build_simulator()
    state_s = alg.init(jax.random.PRNGKey(9))
    w_sim, _ = alg.final_params(jax.random.PRNGKey(9), xs, ys)

    gdp = spec.build_distributed()
    state = gdp.init({"w": jnp.zeros((m, n))}, jax.random.PRNGKey(9))
    for t in range(T):
        state_s, _ = alg.round(state_s, (xs[t], ys[t]))
        w = gdp.primal(state)["w"]
        _, grad = hinge_loss_and_grad(w, xs[t], ys[t])
        state, _ = gdp.update(state, {"w": grad})
    # dual trajectories agree exactly; primal comparison is looser because
    # final_params evaluates the prox at t=T while primal uses t=T+1
    np.testing.assert_allclose(np.asarray(state.theta["w"]),
                               np.asarray(state_s.theta), rtol=1e-5, atol=1e-6)
    w_dist = gdp.primal(state)["w"]
    np.testing.assert_allclose(np.asarray(w_dist), np.asarray(w_sim),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("rule", ["omd", "tg", "rda"])
def test_cross_engine_rules_agree(rule):
    """Every local rule produces the same trajectory in both engines."""
    m, n, T = 4, 16, 12
    xs, ys = _stream(m, n, T, seed=5)
    spec = RunSpec(nodes=m, dim=n, mixer="ring", eps=math.inf,
                   local_rule=rule, lam=0.01, alpha0=0.5, schedule="sqrt_t")
    alg = spec.build_simulator()
    state_s = alg.init(jax.random.PRNGKey(4))

    gdp = spec.build_distributed()
    state_d = gdp.init({"w": jnp.zeros((m, n))}, jax.random.PRNGKey(4))
    for t in range(T):
        state_s, _ = alg.round(state_s, (xs[t], ys[t]))
        w = gdp.primal(state_d)["w"]
        _, grad = hinge_loss_and_grad(w, xs[t], ys[t])
        state_d, _ = gdp.update(state_d, {"w": grad})
    np.testing.assert_allclose(np.asarray(state_d.theta["w"]),
                               np.asarray(state_s.theta), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# RunSpec surface
# ---------------------------------------------------------------------------

def test_disconnected_dense_escape_hatch_matches_identity_graph():
    """mixer='disconnected' means clean local state in BOTH engines; the
    README documents mixer='dense' + topology='disconnected' as the
    noised-self-loop-through-identity-A variant. Check the escape hatch is
    exactly a dense identity mix (same graph-backed construction)."""
    m, n, T = 4, 16, 10
    xs, ys = _stream(m, n, T)
    explicit = Algorithm1(
        omd=OMDConfig(alpha0=1.0, schedule="sqrt_t", lam=0.01),
        n=n,
        mixer=DenseMatrixMixer.from_graph(GossipGraph.make("disconnected", m)),
        mechanism=LaplaceMechanism(eps=1.0, L=1.0, calibration="global"),
    )
    spec = RunSpec(nodes=m, dim=n, mixer="dense",
                   mixer_options={"topology": "disconnected"},
                   eps=1.0, clip_norm=1.0, calibration="global",
                   alpha0=1.0, schedule="sqrt_t", lam=0.01)
    w_l, _ = explicit.final_params(jax.random.PRNGKey(2), xs, ys)
    w_n, _ = spec.build_simulator().final_params(jax.random.PRNGKey(2), xs, ys)
    np.testing.assert_allclose(np.asarray(w_n), np.asarray(w_l),
                               rtol=1e-5, atol=1e-6)


def test_runspec_rejects_mixer_node_mismatch():
    with pytest.raises(ValueError):
        RunSpec(nodes=64, dim=16, mixer=RingRollMixer(m=8)).build_simulator()


def test_typoed_option_raises_instead_of_running_default():
    with pytest.raises(TypeError):
        RunSpec(nodes=8, dim=16, mixer="ring",
                mixer_options={"self_wieght": 0.9}).build_simulator()


def test_engine_rejects_conflicting_delay_kwarg():
    with pytest.raises(ValueError):
        Algorithm1(omd=OMDConfig(), n=16,
                   mixer=DelayedMixer(inner=RingRollMixer(m=4), delay=16),
                   mechanism=LaplaceMechanism(), delay=4)


def test_runspec_conflicting_delays_rejected():
    spec = RunSpec(nodes=8, dim=16, mixer="ring",
                   mixer_options={"delay": 2}, delay=16)
    with pytest.raises(ValueError):
        spec.build_simulator()


def test_rda_state_initialises_to_zero_gradient_sum():
    """RDA's dual state is the cumulative gradient sum G, not the weights —
    GossipDP.init must not seed it with the model init."""
    spec = RunSpec(nodes=4, local_rule="rda", eps=math.inf, alpha0=1.0)
    gdp = spec.build_distributed()
    params = {"w": jnp.full((4, 8), 3.0)}
    state = gdp.init(params, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(state.theta["w"]), 0.0)
    # omd keeps the model init
    gdp_omd = spec.replace(local_rule="omd").build_distributed()
    state_omd = gdp_omd.init(params, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(state_omd.theta["w"]), 3.0)


def test_default_clipper_follows_mechanism_bound():
    alg = Algorithm1(omd=OMDConfig(), n=8,
                     mixer=RingRollMixer(m=4),
                     mechanism=LaplaceMechanism(eps=1.0, L=0.5))
    assert alg.clipper.max_norm == 0.5


def test_runspec_delay_wraps_mixer_in_both_engines():
    spec = RunSpec(nodes=8, dim=16, mixer="ring", eps=math.inf, delay=3)
    alg = spec.build_simulator()
    assert alg.delay == 3
    xs, ys = _stream(m=8, n=16, T=8)
    outs = alg.run(jax.random.PRNGKey(0), xs, ys)
    assert np.isfinite(np.asarray(outs.loss)).all()
    # the distributed engine carries the same staleness via its history ring
    gdp = spec.build_distributed()
    assert gdp.delay == 3
    state = gdp.init({"w": jnp.zeros((8, 16))}, jax.random.PRNGKey(1))
    assert state.history["w"].shape == (4, 8, 16)
    state, _ = gdp.update(state, {"w": jnp.ones((8, 16))})
    assert int(state.t) == 1


def test_runspec_requires_dim_for_simulator():
    with pytest.raises(ValueError):
        RunSpec(nodes=4).build_simulator()


def test_engines_reject_partial_construction():
    with pytest.raises(ValueError):
        Algorithm1(omd=OMDConfig(), n=8)
    with pytest.raises(ValueError):
        GossipDP(omd=OMDConfig())


def test_legacy_constructors_removed():
    """graph=/privacy=/method= and gossip=/privacy= completed their
    one-release deprecation window and now fail fast."""
    with pytest.raises(TypeError):
        Algorithm1(graph=GossipGraph.make("ring", 4), omd=OMDConfig(),
                   privacy=object(), n=8)
    with pytest.raises(TypeError):
        Algorithm1(omd=OMDConfig(), n=8, mixer=RingRollMixer(m=4),
                   mechanism=LaplaceMechanism(), method="omd")
    with pytest.raises(TypeError):
        GossipDP(gossip=object(), omd=OMDConfig(), privacy=object())


def test_mechanism_options_override_shared_knobs():
    spec = RunSpec(nodes=4, dim=8, eps=1.0,
                   mechanism_options={"eps": 5.0})
    assert spec.resolve_mechanism().eps == 5.0


def test_gaussian_mechanism_via_spec():
    spec = RunSpec(nodes=4, dim=8, mixer="ring", mechanism="gaussian",
                   eps=1.0, alpha0=1.0)
    xs, ys = _stream(m=4, n=8, T=6)
    outs = spec.build_simulator().run(jax.random.PRNGKey(0), xs, ys)
    assert np.isfinite(np.asarray(outs.loss)).all()


def test_step_context_schedule_values():
    spec = RunSpec(nodes=4, dim=8, alpha0=1.0, schedule="sqrt_t", lam=0.2)
    alg = spec.build_simulator()
    ctx = alg._ctx(jnp.asarray(4, jnp.int32))
    assert isinstance(ctx, StepContext)
    assert float(ctx.alpha_t) == pytest.approx(0.5)      # 1/sqrt(4)
    assert float(ctx.lam_t) == pytest.approx(0.1)        # alpha_t * lam
