"""repro.metrics: CSVLogger resume/append semantics and MetricTracker
windows (plus the obs-registry mirror the logger grew in the telemetry PR).
"""
import pytest

import repro.obs as obs
from repro.metrics import CSVLogger, MetricTracker, MetricsRegistry


@pytest.fixture(autouse=True)
def _ambient_off():
    obs.disable()
    yield
    obs.disable()


def _lines(path):
    return open(path).read().strip().splitlines()


def test_csv_logger_writes_header_once(tmp_path):
    path = str(tmp_path / "m.csv")
    lg = CSVLogger(path)
    lg.log(0, {"loss": 1.0, "acc": 0.5})
    lg.log(1, {"loss": 0.9, "acc": 0.6})
    lg.close()
    assert _lines(path) == ["step,acc,loss", "0,0.5,1.0", "1,0.6,0.9"]


def test_csv_logger_resume_appends_instead_of_clobbering(tmp_path):
    path = str(tmp_path / "m.csv")
    first = CSVLogger(path)
    first.log(0, {"loss": 1.0})
    first.close()
    resumed = CSVLogger(path)           # the resume path used to open "w"
    resumed.log(1, {"loss": 0.5})
    resumed.close()
    assert _lines(path) == ["step,loss", "0,1.0", "1,0.5"]


def test_csv_logger_rejects_unknown_keys(tmp_path):
    path = str(tmp_path / "m.csv")
    lg = CSVLogger(path)
    lg.log(0, {"loss": 1.0})
    with pytest.raises(ValueError, match=r"row keys \['extra'\]"):
        lg.log(1, {"loss": 0.5, "extra": 2.0})   # used to drop it silently
    lg.close()


def test_csv_logger_rejects_mismatched_fieldnames_on_resume(tmp_path):
    path = str(tmp_path / "m.csv")
    first = CSVLogger(path)
    first.log(0, {"loss": 1.0})
    first.close()
    other = CSVLogger(path, fieldnames=["step", "other"])
    with pytest.raises(ValueError, match="do not match the existing header"):
        other.log(1, {"other": 2.0})


def test_csv_logger_missing_fields_stay_empty(tmp_path):
    path = str(tmp_path / "m.csv")
    lg = CSVLogger(path, fieldnames=["step", "loss", "acc"])
    lg.log(0, {"loss": 1.0})            # acc absent: empty cell, no error
    lg.close()
    assert _lines(path) == ["step,loss,acc", "0,1.0,"]


def test_csv_logger_mirrors_into_ambient_registry(tmp_path):
    tel = obs.enable()
    lg = CSVLogger(str(tmp_path / "m.csv"))
    lg.log(0, {"loss": 1.0})
    lg.log(1, {"loss": 0.25})
    lg.close()
    assert tel.metrics.snapshot()["log.loss"] == 0.25


def test_metrics_reexports_registry_types():
    assert MetricsRegistry is obs.MetricsRegistry


def test_metric_tracker_empty_window():
    tr = MetricTracker(window=3)
    assert tr.means() == {}
    tr.update({})
    assert tr.means() == {}


def test_metric_tracker_window_eviction():
    tr = MetricTracker(window=2)
    for v in (1.0, 2.0, 3.0, 4.0):
        tr.update({"loss": v})
    assert tr.means() == {"loss": 3.5}   # only the last two survive
    tr.update({"other": 7.0})
    assert tr.means()["other"] == 7.0    # keys window independently
