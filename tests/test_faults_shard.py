"""Fault injection x node-axis sharding (tests/test_shard_node.py harness).

Multi-device equivalence runs in subprocesses with 8 fake CPU devices
(XLA_FLAGS). The contract:

  * a zero-rate FaultSpec under node_devices=4 is BIT-identical to the
    clean sharded run — both engines, delay in {0, 2} (the node-sharded
    leg of the ``zero_fault_identical`` gate in benchmarks/bench_faults.py);
  * a FAULTY sharded run (link drops + crash + transient partition)
    matches the faulty unsharded run within the same asserted float32
    reduction-order bound as the clean path, and stays deterministic
    under re-execution;
  * crash participation accounting is layout-independent.

In-process tests cover the error surfaces: stragglers (per-class delay
rings do not shard) and dense faulty mixers are rejected up front.
"""
import os
import subprocess
import sys

import pytest

from repro.api import run
from repro.api.spec import RunSpec
from repro.faults import FaultSpec
from repro.launch.mesh import make_mesh

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_PRELUDE = r"""
import numpy as np
from repro.api import RunSpec, run
from repro.faults import FaultSpec

ATOL = 5e-6      # float32 reduction-order bound, asserted on every field
FIELDS = ("final_w", "loss", "correct", "w_bar_loss", "sparsity")


def spec(**kw):
    base = dict(nodes=10, dim=8, horizon=14, eps=1.0, alpha0=0.5, lam=0.01,
                stream="drift", stream_options={"period": 7},
                mixer="sparse", mixer_options={"topology": "ring"})
    base.update(kw)
    return RunSpec(**base)


def assert_close(a, b, what, atol=ATOL):
    for f in FIELDS:
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        d = np.abs(x - y).max()
        assert d <= atol, f"{what}: field {f} off by {d} (> {atol})"


def assert_identical(a, b, what):
    for f in FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{what}: field {f} diverged")
"""


def _run(code: str, timeout=520) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run([sys.executable, "-c", _PRELUDE + code],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# -- multi-device equivalence (subprocesses, 8 fake devices) -----------------

@pytest.mark.slow
def test_zero_fault_bit_identical_under_node_sharding():
    """node_devices=4, m=10 (pads to 12): a link_rate=0.0 FaultSpec must be
    bit-identical to the clean sharded run — the fault machinery (uniform
    draws, keep masks, healed-mass fold) runs but masks nothing."""
    out = _run(r"""
import jax
assert jax.local_device_count() == 8
kw = dict(chunk_rounds=7, warmup=False, compute_regret=False)
for engine in ("sim", "dist"):
    for delay in (0, 2):
        clean = run(spec(delay=delay), engine=engine, node_devices=4, **kw)
        zero = run(spec(delay=delay, faults="links",
                        faults_options={"link_rate": 0.0}),
                   engine=engine, node_devices=4, **kw)
        assert_identical(clean, zero, f"{engine}/delay={delay} zero-rate")
        print(engine, delay, "OK")
""")
    assert out.count("OK") == 4


@pytest.mark.slow
def test_faulty_sharded_matches_faulty_unsharded():
    """Link drops + a crash window + a transient partition, sharded over 4
    devices: within the float32 bound of the faulty unsharded run for both
    engines, deterministic under re-execution, and the crash's masked
    eps accounting is layout-independent."""
    out = _run(r"""
faults = FaultSpec(link_rate=0.15, crashes=((3, 4, 9),),
                   partitions=((5, 8, 5),), seed=7)
kw = dict(chunk_rounds=7, warmup=False, compute_regret=False)
for engine in ("sim", "dist"):
    flat = run(spec(faults=faults), engine=engine, **kw)
    sh = run(spec(faults=faults), engine=engine, node_devices=4, **kw)
    assert_close(sh, flat, f"{engine} faulty sharded vs unsharded")
    np.testing.assert_array_equal(flat.connectivity, sh.connectivity)
    assert (sh.privacy["participated_rounds"]
            == flat.privacy["participated_rounds"])
    assert sh.privacy["participated_rounds"][3] == 14 - 5
    again = run(spec(faults=faults), engine=engine, node_devices=4, **kw)
    assert_identical(sh, again, f"{engine} faulty sharded determinism")
    print(engine, "OK")
""")
    assert out.count("OK") == 2


# -- error surfaces (in-process, any device count) ---------------------------

def _spec(**kw):
    base = dict(nodes=8, dim=8, horizon=8, eps=1.0, alpha0=0.5, lam=0.01,
                stream="drift", stream_options={"period": 7},
                mixer="sparse", mixer_options={"topology": "ring"})
    base.update(kw)
    return RunSpec(**base)


def test_stragglers_rejected_under_node_sharding():
    s = _spec(faults=FaultSpec(stragglers=((0, 2),)))
    with pytest.raises(ValueError, match="straggler"):
        run(s, chunk_rounds=4, warmup=False, compute_regret=False,
            node_mesh=make_mesh((1,), ("node",)))


def test_dense_faulty_mixer_rejected_under_node_sharding():
    s = _spec(mixer="dense", faults="links",
              faults_options={"link_rate": 0.1})
    with pytest.raises(ValueError, match="sparse edge-list"):
        run(s, chunk_rounds=4, warmup=False, compute_regret=False,
            node_mesh=make_mesh((1,), ("node",)))


def test_one_device_node_mesh_runs_faults_in_process():
    """An explicit 1-device ("node",) mesh exercises the FaultySharded
    mixer's shard_map path without fake devices; zero-rate stays
    bit-identical to the unsharded clean run's sharded twin."""
    import numpy as np
    kw = dict(chunk_rounds=4, warmup=False, compute_regret=False)
    mesh = make_mesh((1,), ("node",))
    clean = run(_spec(), node_mesh=mesh, **kw)
    zero = run(_spec(faults="links", faults_options={"link_rate": 0.0}),
               node_mesh=make_mesh((1,), ("node",)), **kw)
    np.testing.assert_array_equal(clean.final_w, zero.final_w)
    faulty = run(_spec(faults="links", faults_options={"link_rate": 0.5}),
                 node_mesh=make_mesh((1,), ("node",)), **kw)
    flat = run(_spec(faults="links", faults_options={"link_rate": 0.5}), **kw)
    assert np.abs(faulty.final_w - flat.final_w).max() <= 5e-6
