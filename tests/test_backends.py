"""The BACKENDS registry and the fused Pallas round body vs the reference.

The contract (docs/kernels.md):

  * `RunSpec(backend="pallas")` matches the reference backend per-field on
    every STREAMS scenario, both engines, Laplace noise ON, delay rings in
    {0, 2}: `correct` / `sparsity` / `eps_ledger` bit-exact (the noise is
    sampled outside the kernel from the identical PRNG stream), float
    trajectories within the f32 reduction-order bound;
  * the kernels themselves hold on odd shapes — dims not multiples of the
    128-lane tile, node counts not multiples of the 8-row sublane — via
    explicit zero-padding (`tests` drive `round_stats` / `round_update` /
    `dual_step` directly against jnp oracles);
  * checkpoints are backend-portable: pallas resumes from a reference
    checkpoint (and vice versa) bit-identically, because init and state
    layout are backend-independent;
  * unsupported specs fail loudly, naming the reference fallback.

Multi-device (node-sharded) pallas equivalence runs in a subprocess with
8 fake CPU devices, same harness as tests/test_shard_node.py.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import BACKENDS, ExecConfig, PallasBackend, RunSpec, run
from repro.api.backends import pallas_supported
from repro.api.registry import UnknownEntryError
from repro.api.runner import run_batch
from repro.kernels.round_fused import (dual_step, round_stats, round_update,
                                       _pad_cols, _pad_rows)

ATOL = 5e-6      # float32 reduction-order bound for float trajectories
EXACT = ("correct", "sparsity", "eps_ledger")
CLOSE = ("final_w", "loss", "w_bar_loss")

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CFG = ExecConfig(chunk_rounds=3, warmup=False, compute_regret=False)


def _spec(**kw):
    base = dict(nodes=6, dim=40, horizon=6, eps=1.0, alpha0=0.5, lam=0.01,
                stream="drift", stream_options={"period": 3},
                mixer="sparse", mixer_options={"topology": "ring"})
    base.update(kw)
    return RunSpec(**base)


def assert_backends_agree(ref, pal, what):
    for f in EXACT:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, f)), np.asarray(getattr(pal, f)),
            err_msg=f"{what}: field {f} must be bit-exact")
    for f in CLOSE:
        d = np.abs(np.asarray(getattr(ref, f))
                   - np.asarray(getattr(pal, f))).max()
        assert d <= ATOL, f"{what}: field {f} off by {d} (> {ATOL})"


# -- registry ----------------------------------------------------------------

def test_backends_registry_names_and_describe():
    assert set(BACKENDS.names()) >= {"reference", "pallas"}
    desc = BACKENDS.describe()
    assert "pallas" in desc and desc["pallas"]


def test_unknown_backend_names_available():
    with pytest.raises(UnknownEntryError, match="pallas"):
        run(_spec(backend="nope"), exec=CFG)


def test_backend_options_typo_raises():
    with pytest.raises(TypeError, match="mode"):
        run(_spec(backend="pallas", backend_options={"moed": "auto"}),
            exec=CFG)


def test_backend_instance_passes_through():
    be = PallasBackend(mode="hybrid")
    res = run(_spec(backend=be), exec=CFG)
    ref = run(_spec(), exec=CFG)
    assert_backends_agree(ref, res, "instance backend")


# -- equivalence: streams x engines x delay, noise on ------------------------

@pytest.mark.parametrize("stream", ["social_sparse", "drift",
                                    "heterogeneous", "bursty"])
@pytest.mark.parametrize("engine", ["sim", "dist"])
def test_pallas_matches_reference_all_streams(stream, engine):
    spec = _spec(stream=stream,
                 stream_options={"period": 3} if stream == "drift" else {})
    ref = run(spec, engine=engine, exec=CFG)
    pal = run(spec.replace(backend="pallas"), engine=engine, exec=CFG)
    assert_backends_agree(ref, pal, f"{stream}/{engine}")


@pytest.mark.parametrize("delay", [0, 2])
@pytest.mark.parametrize("engine", ["sim", "dist"])
@pytest.mark.parametrize("mode", ["fused", "hybrid"])
def test_pallas_modes_match_reference_with_delay(delay, engine, mode):
    spec = _spec(delay=delay)
    ref = run(spec, engine=engine, exec=CFG)
    pal = run(spec.replace(backend="pallas",
                           backend_options={"mode": mode}),
              engine=engine, exec=CFG)
    assert_backends_agree(ref, pal, f"mode={mode}/{engine}/delay={delay}")


def test_pallas_matches_reference_under_faults():
    """Fault schedules force the hybrid path (time-varying mixing stays in
    XLA); crashes exercise the in-kernel alive-freeze mask."""
    spec = _spec(horizon=8, faults="links",
                 faults_options={"link_rate": 0.3, "seed": 1})
    ref = run(spec, exec=CFG)
    pal = run(spec.replace(backend="pallas"), exec=CFG)
    assert_backends_agree(ref, pal, "link faults")
    from repro.faults import FaultSpec
    crash = _spec(horizon=8, faults=FaultSpec(crashes=((2, 3, 6),)))
    ref = run(crash, exec=CFG)
    pal = run(crash.replace(backend="pallas"), exec=CFG)
    assert_backends_agree(ref, pal, "crash faults")
    np.testing.assert_array_equal(ref.connectivity, pal.connectivity)


def test_pallas_run_batch_matches_reference():
    seeds = [0, 1]
    ref = run_batch(_spec(), seeds, exec=CFG)
    pal = run_batch(_spec(backend="pallas"), seeds, exec=CFG)
    for s, (r, p) in enumerate(zip(ref, pal)):
        assert_backends_agree(r, p, f"run_batch seed {s}")


def test_fused_mode_refuses_what_it_cannot_fuse():
    with pytest.raises(ValueError, match="hybrid"):
        run(_spec(faults="links", faults_options={"link_rate": 0.1},
                  backend="pallas", backend_options={"mode": "fused"}),
            exec=CFG)


def test_pallas_rejects_unsupported_spec():
    spec = _spec(backend="pallas", local_rule="rda")
    if pallas_supported(spec):      # rda may one day lower; guard intent
        pytest.skip("rda became pallas-supported")
    with pytest.raises(ValueError, match="reference"):
        run(spec, exec=CFG)


# -- checkpoint portability --------------------------------------------------

@pytest.mark.parametrize("engine", ["sim", "dist"])
def test_pallas_checkpoint_resume_bit_stable(tmp_path, engine):
    """A pallas run checkpointed mid-horizon resumes bit-identically to its
    own uninterrupted run — and a REFERENCE run can resume from the pallas
    checkpoint (state layout is backend-independent)."""
    spec = _spec(horizon=12, backend="pallas")
    full = run(spec, engine=engine, exec=CFG.replace(chunk_rounds=4))
    d = str(tmp_path / "ckpt")
    run(spec, engine=engine, horizon=8,
        exec=CFG.replace(chunk_rounds=4, checkpoint_every=8,
                         checkpoint_dir=d))
    res = run(spec, engine=engine,
              exec=CFG.replace(chunk_rounds=4, checkpoint_dir=d,
                               resume=True))
    assert res.start_round == 8
    np.testing.assert_array_equal(res.final_w, full.final_w)
    cross = run(spec.replace(backend="reference"), engine=engine,
                exec=CFG.replace(chunk_rounds=4, checkpoint_dir=d,
                                 resume=True))
    d2 = np.abs(np.asarray(cross.final_w) - np.asarray(full.final_w)).max()
    assert d2 <= ATOL


# -- kernel property tests: odd shapes vs jnp oracles ------------------------

def _padded(a, m_pad, n_pad):
    m, n = a.shape
    return jnp.pad(a, ((0, m_pad - m), (0, n_pad - n)))


@pytest.mark.parametrize("m,n", [(3, 40), (8, 128), (10, 200), (6, 1025),
                                 (17, 64)])
def test_round_stats_odd_shapes(m, n):
    """Soft-threshold stats on zero-padded blocks match the row-wise jnp
    math on the unpadded arrays — padding rows/cols contribute nothing."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(m * n))
    theta = jax.random.normal(k1, (m, n))
    x = jax.random.normal(k2, (m, n)) / np.sqrt(n)
    lam_t = 0.37
    m_pad, n_pad = _pad_rows(m), _pad_cols(n)
    dot, xsq, nnz, wbdot, wsum = round_stats(
        _padded(theta, m_pad, n_pad), _padded(x, m_pad, n_pad),
        jnp.float32(lam_t), m, interpret=True)
    w = jnp.sign(theta) * jnp.maximum(jnp.abs(theta) - lam_t, 0.0)
    np.testing.assert_allclose(np.asarray(dot[:m]),
                               np.asarray(jnp.sum(w * x, axis=1)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(xsq[:m]),
                               np.asarray(jnp.sum(x * x, axis=1)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(nnz[:m]),
                                  np.asarray(jnp.sum(w != 0, axis=1),
                                             np.float32))
    w_bar = jnp.mean(w, axis=0)
    np.testing.assert_allclose(np.asarray(wbdot[:m]),
                               np.asarray(jnp.sum(w_bar[None] * x, axis=1)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(wsum[:n]),
                               np.asarray(jnp.sum(w, axis=0)),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(wsum[n:]).max(initial=0.0)) == 0.0


@pytest.mark.parametrize("m,n", [(4, 40), (10, 130)])
@pytest.mark.parametrize("use_recv", [0.0, 1.0])
def test_round_update_odd_shapes(m, n, use_recv):
    keys = jax.random.split(jax.random.PRNGKey(7 * m + n), 6)
    theta = jax.random.normal(keys[0], (m, n))
    delta = 0.1 * jax.random.normal(keys[1], (m, n))
    x = jax.random.normal(keys[2], (m, n)) / np.sqrt(n)
    recv = jax.random.normal(keys[3], (m, n))
    coeff = jax.random.normal(keys[4], (m,))
    A = jax.nn.softmax(jax.random.normal(keys[5], (m, m)), axis=1)
    diag = jnp.diagonal(A)
    alive = jnp.ones((m,), jnp.float32).at[1].set(0.0)
    m_pad, n_pad = _pad_rows(m), _pad_cols(n)
    pad1 = lambda v: jnp.pad(v, (0, m_pad - m))
    theta_next, tilde = round_update(
        _padded(A, m_pad, m_pad), _padded(theta, m_pad, n_pad),
        _padded(delta, m_pad, n_pad), _padded(x, m_pad, n_pad),
        _padded(recv, m_pad, n_pad), pad1(coeff), pad1(diag), pad1(alive),
        jnp.float32(0.25), jnp.float32(use_recv), noise_self=True,
        interpret=True)
    tilde_ref = theta + delta
    r = recv if use_recv else tilde_ref
    mixed = A @ r + diag[:, None] * (tilde_ref - r)
    want = mixed - 0.25 * coeff[:, None] * x
    want = jnp.where(alive[:, None] > 0, want, theta)
    np.testing.assert_allclose(np.asarray(theta_next[:m, :n]),
                               np.asarray(want), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(tilde[:m, :n]),
                               np.asarray(tilde_ref), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("m,n", [(5, 40), (8, 384)])
def test_dual_step_odd_shapes(m, n):
    keys = jax.random.split(jax.random.PRNGKey(m + n), 4)
    mixed = jax.random.normal(keys[0], (m, n))
    x = jax.random.normal(keys[1], (m, n))
    theta = jax.random.normal(keys[2], (m, n))
    coeff = jax.random.normal(keys[3], (m,))
    alive = jnp.ones((m,), jnp.float32).at[0].set(0.0)
    m_pad, n_pad = _pad_rows(m), _pad_cols(n)
    out = dual_step(_padded(mixed, m_pad, n_pad), _padded(x, m_pad, n_pad),
                    _padded(theta, m_pad, n_pad),
                    jnp.pad(coeff, (0, m_pad - m)),
                    jnp.pad(alive, (0, m_pad - m)),
                    jnp.float32(0.5), interpret=True)
    want = jnp.where(alive[:, None] > 0,
                     mixed - 0.5 * coeff[:, None] * x, theta)
    np.testing.assert_allclose(np.asarray(out[:m, :n]), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_round_stats_rejects_unpadded():
    with pytest.raises(ValueError, match="padded"):
        round_stats(jnp.zeros((3, 40)), jnp.zeros((3, 40)),
                    jnp.float32(0.1), 3, interpret=True)


def test_f32_scalar_schedule():
    """alpha_t / lam_t arrive as traced f32 scalars from the OMD schedule —
    the kernels must accept them without retracing per round."""
    spec = _spec(horizon=4, backend="pallas")
    res = run(spec, exec=CFG.replace(chunk_rounds=2))
    assert res.rounds == 4 and np.isfinite(np.asarray(res.loss)).all()


# -- node-sharded pallas (subprocess, 8 fake devices) ------------------------

@pytest.mark.slow
def test_node_sharded_pallas_matches_reference():
    """backend="pallas" under node_devices=4 (m=10 pads to 12): per-shard
    stats kernels + psum'd w_bar must match the unsharded reference within
    the same bound as the reference sharded path, and stay engine-agnostic."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    code = r"""
import numpy as np
from repro.api import ExecConfig, RunSpec, run

ATOL = 5e-6
cfg = ExecConfig(chunk_rounds=7, warmup=False, compute_regret=False)

def spec(**kw):
    base = dict(nodes=10, dim=8, horizon=14, eps=1.0, alpha0=0.5, lam=0.01,
                stream="drift", stream_options={"period": 7},
                mixer="sparse", mixer_options={"topology": "ring"})
    base.update(kw)
    return RunSpec(**base)

for engine in ("sim", "dist"):
    ref = run(spec(), engine=engine, exec=cfg)
    pal = run(spec(backend="pallas"), engine=engine,
              exec=cfg.replace(node_devices=4))
    for f in ("final_w", "loss", "correct", "w_bar_loss", "sparsity"):
        d = np.abs(np.asarray(getattr(ref, f))
                   - np.asarray(getattr(pal, f))).max()
        assert d <= ATOL, f"{engine}: {f} off by {d}"
    np.testing.assert_array_equal(ref.eps_ledger, pal.eps_ledger)
    print(engine, "OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=520, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    assert out.stdout.count("OK") == 2
