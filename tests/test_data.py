import jax
import jax.numpy as jnp
import numpy as np

from repro.data.lm import TokenStream, lm_batches
from repro.data.social import SocialStream, labels_from_logits


def test_social_stream_deterministic_and_chunked():
    s = SocialStream(n=64, nodes=4, rounds=100, seed=3)
    x1, y1 = s.chunk(0, 50)
    x2, y2 = s.chunk(0, 50)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    assert x1.shape == (50, 4, 64) and y1.shape == (50, 4)
    assert set(np.unique(np.asarray(y1))) <= {-1.0, 1.0}


def test_social_labels_match_ground_truth():
    s = SocialStream(n=64, nodes=4, rounds=10, seed=0)
    xs, ys = s.chunk(0, 10)
    w = s.w_true()
    np.testing.assert_array_equal(
        np.asarray(labels_from_logits(jnp.einsum("n,tmn->tm", w, xs))),
        np.asarray(ys))
    # ground truth is sparse
    frac = float((w != 0).mean())
    assert 0.01 < frac < 0.15


def test_social_streams_disjoint_across_nodes_and_rounds():
    s = SocialStream(n=32, nodes=4, rounds=8, seed=1)
    xs, _ = s.chunk(0, 8)
    flat = np.asarray(xs).reshape(-1, 32)
    # no two samples identical (fresh randomness per (t, i))
    assert len(np.unique(flat.round(6), axis=0)) == flat.shape[0]


def test_token_stream_shapes_and_determinism():
    ts = TokenStream(vocab_size=128, seed=0)
    a = ts.sample(step=3, node=1, batch=4, seq=32)
    b = ts.sample(step=3, node=1, batch=4, seq=32)
    c = ts.sample(step=3, node=2, batch=4, seq=32)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))  # per-node disjoint
    assert a.shape == (4, 32) and int(a.max()) < 128 and int(a.min()) >= 0


def test_token_stream_has_learnable_structure():
    """Bigram mutual structure: the deterministic-shift transition must show up."""
    ts = TokenStream(vocab_size=64, seed=0)
    toks = np.asarray(ts.sample(0, 0, 64, 128))
    pairs = toks[:, :-1] * 64 + toks[:, 1:]
    shift_pairs = toks[:, :-1] * 64 + (toks[:, :-1] * 31 + 7) % 64
    frac = (pairs == shift_pairs).mean()
    assert frac > 0.3  # ~half the transitions follow the learnable rule


def test_lm_batches_labels_are_shifted():
    it = lm_batches(vocab_size=100, batch=2, seq=16, nodes=3)
    b = next(it)
    assert b["tokens"].shape == (3, 2, 16)
    np.testing.assert_array_equal(np.asarray(b["labels"][..., :-1]),
                                  np.asarray(b["tokens"][..., 1:]))
    assert int(b["labels"][..., -1].max()) == -1
